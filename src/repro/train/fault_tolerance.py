"""Fault tolerance: resilient run loop, elastic re-meshing, straggler watchdog.

At thousand-node scale the failure model is: a pod/slice dies (hardware or
preemption), a host hangs (straggler), or the job restarts. The strategy here:

  * step-atomic checkpoints (train/checkpoint.py) + deterministic data cursor
    (data/synthetic.py) => restart is exact,
  * ``run_resilient`` retries the step loop through injected/real failures,
    restoring from the newest checkpoint,
  * ``elastic_remesh`` re-shards the restored state onto whatever mesh the
    surviving devices form (drop a pod: (2,16,16) -> (16,16)) — sharding
    rules are rank-polymorphic in axis *names*, so the same rule table
    produces the new layout,
  * ``StepWatchdog`` flags stragglers: steps slower than k x the trailing
    median trigger a (configurable) re-mesh/requeue callback instead of
    stalling the whole job.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager

log = logging.getLogger("repro.ft")


class StepWatchdog:
    """Trailing-median step timer; flags stragglers at ratio x median."""

    def __init__(self, ratio: float = 3.0, window: int = 20,
                 grace_steps: int = 3):
        self.ratio, self.window, self.grace = ratio, window, grace_steps
        self.times: List[float] = []

    def observe(self, dt: float) -> bool:
        """Returns True when dt flags a straggler."""
        self.times.append(dt)
        self.times = self.times[-self.window:]
        if len(self.times) <= self.grace:
            return False
        med = float(np.median(self.times[:-1]))
        return dt > self.ratio * max(med, 1e-9)


@dataclass
class ResilienceReport:
    steps_run: int = 0
    restarts: int = 0
    straggler_events: int = 0
    final_loss: float = float("nan")
    history: List[float] = field(default_factory=list)


def run_resilient(train_step: Callable, state: Any, next_batch: Callable,
                  *, steps: int, ckpt: CheckpointManager,
                  ckpt_every: int = 10,
                  fail_at: Optional[Dict[int, Exception]] = None,
                  max_restarts: int = 10,
                  watchdog: Optional[StepWatchdog] = None,
                  on_straggler: Optional[Callable] = None,
                  state_restore: Optional[Callable] = None
                  ) -> ResilienceReport:
    """Run ``steps`` train steps surviving failures.

    fail_at: {step: exception} — fault injection for tests (the exception is
    raised after the step's compute, as a crash would land). state_restore:
    maps the raw (numpy) checkpoint tree back into jax arrays/shardings.
    """
    report = ResilienceReport()
    fail_at = dict(fail_at or {})
    step = int(np.asarray(state["opt"]["step"]))
    restarts = 0
    while step < steps:
        try:
            while step < steps:
                t0 = time.perf_counter()
                batch = next_batch(step)
                state, metrics = train_step(state, batch)
                loss = float(np.asarray(metrics["loss"]))
                report.history.append(loss)
                step += 1
                report.steps_run += 1
                if step in fail_at:
                    raise fail_at.pop(step)
                if watchdog is not None:
                    if watchdog.observe(time.perf_counter() - t0):
                        report.straggler_events += 1
                        if on_straggler is not None:
                            state = on_straggler(state)
                if step % ckpt_every == 0 or step == steps:
                    ckpt.save(step, state, meta={"step": step})
            break
        except Exception as e:                        # noqa: BLE001
            restarts += 1
            report.restarts = restarts
            if restarts > max_restarts:
                raise
            log.warning("step %d failed (%s); restoring", step, e)
            restored = ckpt.restore_or_none()
            if restored is None:
                raise
            tree, ck_step, _ = restored
            state = state_restore(tree) if state_restore else tree
            step = ck_step
    ckpt.wait()
    report.final_loss = report.history[-1] if report.history else float("nan")
    return report


def elastic_remesh(state: Any, new_mesh, state_shape: Any) -> Any:
    """Re-shard a (host/numpy) state tree onto a new mesh using the same
    rank-polymorphic rules — the 'drop a pod and keep training' path."""
    from repro.distributed.sharding import param_specs
    from jax.sharding import NamedSharding

    specs = param_specs(new_mesh, state_shape)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        state, specs)
