"""Step-atomic checkpointing with integrity digests, retention and resume.

Layout:  <dir>/step_000123/
             manifest.json     (tree structure, shapes, dtypes, digests, meta)
             arrays.npz        (flat path -> ndarray)
         <dir>/LATEST          (atomically updated pointer)

Writes go to a temp dir + os.replace for atomicity (a crashed writer never
corrupts LATEST); every array carries a crc32 digest verified on restore.
``CheckpointManager`` adds retention, auto-resume and an async (background
thread) save mode for tail-tolerant checkpointing at scale.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.pruning import _flatten, _unflatten


def _digest(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def save_checkpoint(path: str, step: int, tree: Any,
                    meta: Optional[Dict] = None) -> str:
    """Atomic write of one checkpoint. Returns the final directory."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "meta": meta or {},
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "crc32": _digest(v)} for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(path, ".LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(path, ".LATEST.tmp"), os.path.join(path, "LATEST"))
    return final


def latest_step(path: str) -> Optional[int]:
    p = os.path.join(path, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(path, name)):
        return None
    return int(name.split("_")[-1])


def restore_checkpoint(path: str, step: Optional[int] = None,
                       verify: bool = True):
    """Returns (tree, step, meta). Raises on digest mismatch."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    if verify:
        for k, info in manifest["arrays"].items():
            if _digest(flat[k]) != info["crc32"]:
                raise IOError(f"checkpoint corruption: digest mismatch at {k}")
    return _unflatten(flat), manifest["step"], manifest.get("meta", {})


class CheckpointManager:
    """Retention + auto-resume + optional async save."""

    def __init__(self, path: str, keep: int = 3, async_save: bool = False):
        self.path = path
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None):
        tree = jax.tree_util.tree_map(np.asarray, tree)   # snapshot off-device
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, tree, meta), daemon=True)
            self._thread.start()
        else:
            self._save_sync(step, tree, meta)

    def _save_sync(self, step, tree, meta):
        save_checkpoint(self.path, step, tree, meta)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        if not os.path.isdir(self.path):
            return
        steps = sorted(int(n.split("_")[-1]) for n in os.listdir(self.path)
                       if n.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_or_none(self):
        try:
            return restore_checkpoint(self.path)
        except (FileNotFoundError, IOError):
            return None
