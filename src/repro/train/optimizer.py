"""Optimizers (self-contained: no optax in-container).

AdamW with decoupled weight decay, global-norm gradient clipping, cosine LR
schedule with warmup, and configurable optimizer-state dtype:
  * f32 (default)
  * bf16 (halves optimizer HBM — used by the biggest assigned configs)
  * int8 block-quantized moments (beyond-paper memory hillclimb; error is
    bounded by per-block absmax scaling like 8-bit Adam)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"          # float32 | bfloat16 | int8
    quant_block: int = 256


def lr_at(cfg: OptConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * jnp.clip(prog, 0, 1)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


# --------------------------------------------------------------------- #
# int8 block quantization for moments
# --------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
class Packed8:
    """int8 block-quantized moment: children (q, scale); static shape."""

    def __init__(self, q, s, shape):
        self.q, self.s, self.shape = q, s, tuple(shape)

    def tree_flatten(self):
        return (self.q, self.s), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(children[0], children[1], shape)


def _quant(x: jnp.ndarray, block: int) -> Packed8:
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    b = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return Packed8(q, scale.astype(jnp.float32), shape)


def _dequant(p: Packed8) -> jnp.ndarray:
    flat = (p.q.astype(jnp.float32) * p.s).reshape(-1)
    n = 1
    for d in p.shape:
        n *= d
    return flat[:n].reshape(p.shape)


def _to_state_dtype(x: jnp.ndarray, cfg: OptConfig):
    if cfg.state_dtype == "float32":
        return x.astype(jnp.float32)
    if cfg.state_dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    if cfg.state_dtype == "int8":
        return _quant(x, cfg.quant_block)
    raise ValueError(cfg.state_dtype)


def _from_state_dtype(x, cfg: OptConfig) -> jnp.ndarray:
    if isinstance(x, Packed8):
        return _dequant(x)
    return x.astype(jnp.float32)


def init_opt_state(params, cfg: OptConfig):
    def zeros():
        return jax.tree_util.tree_map(
            lambda p: _to_state_dtype(jnp.zeros_like(p, jnp.float32), cfg),
            params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt_state, cfg: OptConfig,
                 mask: Optional[Any] = None):
    """Returns (new_params, new_opt_state, metrics). mask: pytree of bool for
    weight decay (norms/biases excluded by default heuristic if None)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, decay):
        g = g.astype(jnp.float32) * scale
        m_f = _from_state_dtype(m, cfg)
        v_f = _from_state_dtype(v, cfg)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        u = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        p_f = p.astype(jnp.float32)
        p_new = p_f - lr * (u + cfg.weight_decay * p_f * decay)
        return p_new.astype(p.dtype), _to_state_dtype(m_f, cfg), \
            _to_state_dtype(v_f, cfg)

    if mask is None:
        mask = jax.tree_util.tree_map(lambda p: float(p.ndim >= 2), params)

    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(opt_state["m"])
    leaves_v = treedef.flatten_up_to(opt_state["v"])
    leaves_d = treedef.flatten_up_to(mask)
    out = [upd(p, g, m, v, d) for p, g, m, v, d in
           zip(leaves_p, leaves_g, leaves_m, leaves_v, leaves_d)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


def sgd_update(params, grads, opt_state, cfg: OptConfig):
    """Plain SGD w/ momentum in m (baseline for tests)."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)

    def upd(p, g, m):
        m_f = 0.9 * _from_state_dtype(m, cfg) + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m_f).astype(p.dtype), \
            _to_state_dtype(m_f, cfg)
    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(opt_state["m"])
    out = [upd(p, g, m) for p, g, m in zip(leaves_p, leaves_g, leaves_m)]
    return treedef.unflatten([o[0] for o in out]), \
        {"m": treedef.unflatten([o[1] for o in out]),
         "v": opt_state["v"], "step": step}, {"lr": lr}
