"""Train-step factory: grad accumulation, remat, mixed precision, sharding.

``make_train_step`` builds the jit-able (state, batch) -> (state, metrics)
function used identically by the CPU examples, the integration tests, and the
512-chip dry-run (only in/out shardings differ). Microbatched gradient
accumulation runs as a lax.scan so compute of microbatch i+1 overlaps the
reduce-scatter of microbatch i under XLA's latency-hiding scheduler.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.optimizer import OptConfig


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum: int = 1                     # gradient-accumulation microbatches
    remat: Optional[str] = "full"      # None | "full" | "dots"
    grad_dtype: str = "float32"        # accumulation dtype
    compress_grads: bool = False       # int8 error-feedback collective
    cast_params_bf16: bool = False     # cast f32 masters to bf16 *before* use
                                       # so FSDP all-gathers move bf16 (§Perf)


def make_train_step(loss_fn: Callable, tcfg: TrainConfig,
                    sparsity: Optional[Any] = None) -> Callable:
    """loss_fn(params, batch, *, sparsity, remat) -> (loss, metrics)."""

    gdt = jnp.dtype(tcfg.grad_dtype)

    def compute_grads(params, batch):
        def lfn(p, b):
            if tcfg.cast_params_bf16:
                p = jax.tree_util.tree_map(
                    lambda a: a.astype(jnp.bfloat16)
                    if a.dtype == jnp.float32 else a, p)
            loss, metrics = loss_fn(p, b, sparsity=sparsity, remat=tcfg.remat)
            return loss, metrics

        if tcfg.accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(
                params, batch)
            return grads, loss, metrics

        def mb(batch, i):
            return jax.tree_util.tree_map(
                lambda x: x.reshape((tcfg.accum, -1) + x.shape[1:])[i], batch)

        def body(carry, i):
            g_acc, l_acc = carry
            (loss, metrics), g = jax.value_and_grad(lfn, has_aux=True)(
                params, mb(batch, i))
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(gdt), g_acc, g)
            return (g_acc, l_acc + loss), metrics

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, gdt), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32)),
            jnp.arange(tcfg.accum))
        grads = jax.tree_util.tree_map(lambda g: g / tcfg.accum, grads)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return grads, loss_sum / tcfg.accum, metrics

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt"]
        grads, loss, metrics = compute_grads(params, batch)
        out = dict(state)
        if tcfg.compress_grads:
            # int8 error-feedback compression of the gradient payload (the
            # shard_map int8 collective lives in distributed.collectives;
            # here we apply the identical numerics inside the GSPMD step)
            from repro.distributed.collectives import ef_quantize
            pairs = jax.tree_util.tree_map(ef_quantize, grads, state["ef"])
            grads = jax.tree_util.tree_map(lambda p: p[0], pairs,
                                           is_leaf=lambda x: isinstance(x, tuple))
            out["ef"] = jax.tree_util.tree_map(
                lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_params, new_opt, opt_metrics = opt_lib.adamw_update(
            params, grads, opt_state, tcfg.opt)
        out["params"], out["opt"] = new_params, new_opt
        m = {"loss": loss, **opt_metrics}
        for k, v in metrics.items():
            m[k] = v
        return out, m

    return train_step


def init_train_state(init_fn: Callable, tcfg: TrainConfig, rng) -> Dict:
    params = init_fn(rng)
    state = {"params": params,
             "opt": opt_lib.init_opt_state(params, tcfg.opt)}
    if tcfg.compress_grads:
        state["ef"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def train_state_shape(init_fn: Callable, tcfg: TrainConfig):
    """eval_shape'd train state — no allocation (dry-run path)."""
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: init_train_state(init_fn, tcfg, rng))
