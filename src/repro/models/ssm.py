"""Mamba2 (SSD) blocks and the Zamba2 hybrid (Mamba2 + shared attention).

Mamba2 recurrence per head h (state in R^{hd x N}):
    a_t = exp(-dt_t * exp(A_log))            (scalar per head)
    H_t = a_t * H_{t-1} + (dt_t * x_t) ⊗ B_t
    y_t = H_t · C_t + D ⊙ x_t
with a depthwise causal conv (width 4) in front of x/B/C and a silu(z) gate.

Zamba2 applies one *shared* (weight-tied) full-attention transformer block
every ``hybrid_attn_every`` mamba layers; its input is proj(concat(h, h_emb0))
per the Zamba recipe (per-invocation LoRA omitted — noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard
from repro.models import transformer as tfm
from repro.models.common import (act_clip, dense_init, dtype_of, embed_init,
                                 maybe_scan, rmsnorm)

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    return d_in, H, s.head_dim, s.state_dim, s.conv_dim


def init_mamba_params(cfg: ModelConfig, rng, L: int) -> Params:
    d = cfg.d_model
    d_in, H, hd, N, K = _dims(cfg)
    conv_ch = d_in + 2 * N
    ks = jax.random.split(rng, 8)
    return {
        "ln": jnp.ones((L, d)),
        "in_proj": dense_init(ks[0], (L, d, 2 * d_in + 2 * N + H)),
        "conv_w": dense_init(ks[1], (L, K, conv_ch), in_axis=-2),
        "conv_b": jnp.zeros((L, conv_ch)),
        "A_log": jnp.zeros((L, H)),
        "D": jnp.ones((L, H)),
        "dt_bias": jnp.zeros((L, H)),
        "out_norm": jnp.ones((L, d_in)),
        "out_proj": dense_init(ks[2], (L, d_in, d)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x:(B,S,C), w:(K,C). state:(B,K-1,C) or None."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                   # (B,S+K-1,C)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return jax.nn.silu(out), xp[:, -(K - 1):]                # new conv state


def mamba_block(p, x, cfg: ModelConfig, state=None, act_tau=None):
    """x: (B,S,d). state: {'conv': (B,K-1,C), 'ssm': (B,H,hd,N)} or None."""
    B, S, d = x.shape
    d_in, H, hd, N, K = _dims(cfg)
    x = act_clip(x, act_tau)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    conv_state = state["conv"] if state else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, Bc, Cc = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, S, H, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))                          # (B,S,H)
    dx = (dt[..., None] * xs.astype(jnp.float32))                   # (B,S,H,hd)

    def step(Hst, inp):
        a_t, dx_t, B_t, C_t = inp           # (B,H) (B,H,hd) (B,N) (B,N)
        Hst = a_t[..., None, None] * Hst + \
            jnp.einsum("bhd,bn->bhdn", dx_t, B_t.astype(jnp.float32))
        y = jnp.einsum("bhdn,bn->bhd", Hst, C_t.astype(jnp.float32))
        return Hst, y

    H0 = state["ssm"] if state else jnp.zeros((B, H, hd, N), jnp.float32)
    xs_t = tuple(jnp.moveaxis(v, 1, 0) for v in (a, dx, Bc, Cc))
    H_new, ys = maybe_scan(step, H0, xs_t)
    y = jnp.moveaxis(ys, 0, 1)                                      # (B,S,H,hd)
    y = y + p["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    y = shard(y, "batch", None, "ff")
    out = y @ p["out_proj"]
    new_state = {"conv": new_conv, "ssm": H_new}
    return out, new_state


# --------------------------------------------------------------------- #
# Zamba2 hybrid model
# --------------------------------------------------------------------- #
def _n_shared(cfg: ModelConfig) -> int:
    return -(-cfg.num_layers // cfg.hybrid_attn_every)      # ceil


def init_params(cfg: ModelConfig, rng) -> Params:
    ks = jax.random.split(rng, 6)
    p: Params = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model)),
        "mamba": init_mamba_params(cfg, ks[1], cfg.num_layers),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if cfg.hybrid_attn_every:
        p["shared"] = tfm._block_params(ks[2], cfg, 1)      # one weight-tied block
        p["shared_proj"] = dense_init(ks[3], (2 * cfg.d_model, cfg.d_model))
    if not cfg.tied_embeddings:
        p["lm_head"] = dense_init(ks[4], (cfg.d_model, cfg.vocab_size))
    return p


def _shared_attn(cfg, params, h, h0, positions, cache=None, pos=None,
                 window=0, return_kv_eff=0):
    """Apply the weight-tied attention block. cache: per-invocation KV.
    return_kv_eff>0 (train path): also return the last ``eff`` K/V rows,
    right-padded — the prefill cache for this invocation site."""
    dt = h.dtype
    p = tfm._cast(jax.tree_util.tree_map(lambda a: a[0], params["shared"]), dt)
    z = jnp.concatenate([h, h0], axis=-1) @ params["shared_proj"].astype(dt)
    x = rmsnorm(z, p["ln1"], cfg.norm_eps)
    if cache is None:
        o = tfm.attention_block(p["attn"], x, cfg, positions, causal=True)
        new_cache = None
        if return_kv_eff:
            q, kk, vv = tfm._gqa_qkv(p["attn"], x, cfg, positions)

            def to_cache(a):
                eff = return_kv_eff
                if a.shape[1] >= eff:
                    return a[:, -eff:]
                pad = [(0, 0)] * a.ndim
                pad[1] = (0, eff - a.shape[1])
                return jnp.pad(a, pad)
            new_cache = {"k": to_cache(kk), "v": to_cache(vv)}
    else:
        o, new_cache = tfm._gqa_decode_attn(p["attn"], x, cfg, cache, pos,
                                            window)
    x2 = rmsnorm(z + o, p["ln2"], cfg.norm_eps)
    y, _ = tfm.ffn_block(p["ffn"], x2, cfg)
    return h + z + o + y, new_cache


def forward(cfg: ModelConfig, params, tokens, *, sparsity=None, remat=None,
            state=None, return_state=False, S_max: int = 0):
    dt = dtype_of(cfg.dtype)
    B, S = tokens.shape
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, "embed")
    h0 = h
    positions = jnp.arange(S)
    k = cfg.hybrid_attn_every
    L = cfg.num_layers
    d_in, Hh, hd, N, K = _dims(cfg)

    def mamba_step(h, xs):
        p, taus = xs
        p = tfm._cast(p, dt)
        f_tau = taus.get("ffn") if taus else None
        x = rmsnorm(h, p["ln"], cfg.norm_eps)
        if return_state:
            zero = {"conv": jnp.zeros((B, K - 1, d_in + 2 * N), dt),
                    "ssm": jnp.zeros((B, Hh, hd, N), jnp.float32)}
            y, st = mamba_block(p, x, cfg, state=zero, act_tau=f_tau)
            return h + y, st
        y, _ = mamba_block(p, x, cfg, act_tau=f_tau)
        return h + y, 0.0

    if remat:
        mamba_step = jax.checkpoint(mamba_step)

    groups = [(g * k, min((g + 1) * k, L)) for g in range(_n_shared(cfg))] \
        if k else [(0, L)]
    states, attn_kv = [], []
    eff = min(S_max or S, 4096)
    for (lo, hi) in groups:
        if k:
            h, kv = _shared_attn(cfg, params, h, h0, positions,
                                 return_kv_eff=eff if return_state else 0)
            if return_state:
                attn_kv.append(kv)
        sub = jax.tree_util.tree_map(lambda a: a[lo:hi], params["mamba"])
        taus = jax.tree_util.tree_map(lambda a: a[lo:hi], sparsity) \
            if sparsity else None
        if taus is None:
            h, ys = maybe_scan(lambda c, p: mamba_step(c, (p, None)), h, sub,
                               length=hi - lo)
        else:
            h, ys = maybe_scan(mamba_step, h, (sub, taus), length=hi - lo)
        if return_state:
            states.append(ys)

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tied_embeddings else params["lm_head"]
    logits = h @ w.astype(dt)
    logits = shard(logits, "batch", None, "vocab")
    if return_state:
        full = jax.tree_util.tree_map(
            lambda *a: jnp.concatenate(a, axis=0), *states) \
            if len(states) > 1 else states[0]
        st = {"conv": full["conv"], "ssm": full["ssm"],
              "pos": jnp.full((B,), S, jnp.int32)}
        if k:
            st["attn_k"] = jnp.stack([kv["k"] for kv in attn_kv])
            st["attn_v"] = jnp.stack([kv["v"] for kv in attn_kv])
        return logits, st
    return logits


def prefill(cfg: ModelConfig, params, tokens, S_max: int, **kw):
    """Parallel prefill: one forward over the prompt, states collected per
    layer (mamba conv/ssm finals + windowed shared-attn KV)."""
    B, S = tokens.shape
    eff = min(S_max, 4096) if cfg.hybrid_attn_every else S_max
    assert S <= eff or S % eff == 0, (S, eff)
    logits, state = forward(cfg, params, tokens, return_state=True,
                            S_max=S_max)
    return logits[:, -1:], state


def loss(cfg: ModelConfig, params, batch, *, sparsity=None, remat=None):
    from repro.models.transformer import softmax_xent
    tokens = batch["tokens"]
    logits = forward(cfg, params, tokens, sparsity=sparsity, remat=remat)
    l = softmax_xent(logits[:, :-1], tokens[:, 1:]).mean()
    return l, {"xent": l}


# --------------------------------------------------------------------- #
# Serving
# --------------------------------------------------------------------- #
def init_state(cfg: ModelConfig, B: int, S_max: int):
    d_in, H, hd, N, K = _dims(cfg)
    L = cfg.num_layers
    dt = dtype_of(cfg.dtype)
    st = {
        "conv": jnp.zeros((L, B, K - 1, d_in + 2 * N), dt),
        "ssm": jnp.zeros((L, B, H, hd, N), jnp.float32),
        "pos": jnp.zeros((B,), jnp.int32),
    }
    if cfg.hybrid_attn_every:
        n = _n_shared(cfg)
        KV, ahd = cfg.num_kv_heads, cfg.resolved_head_dim
        eff = min(S_max, 4096)          # shared-attn KV windowed for long ctx
        st["attn_k"] = jnp.zeros((n, B, eff, KV, ahd), dt)
        st["attn_v"] = jnp.zeros((n, B, eff, KV, ahd), dt)
    return st


def decode_step(cfg: ModelConfig, params, state, token):
    dt = dtype_of(cfg.dtype)
    B = token.shape[0]
    h = params["embed"].astype(dt)[token]
    pos = state["pos"]
    h0 = h                 # Zamba: shared block sees the current-token embedding
    k = cfg.hybrid_attn_every
    L = cfg.num_layers
    new_state = {"pos": pos + 1}

    def mamba_step(carry, xs):
        h = carry
        p, st = xs
        p = tfm._cast(p, dt)
        y, new_st = mamba_block(p, rmsnorm(h, p["ln"], cfg.norm_eps), cfg,
                                state=st)
        return h + y, new_st

    groups = [(g * k, min((g + 1) * k, L)) for g in range(_n_shared(cfg))] \
        if k else [(0, L)]
    new_conv, new_ssm, new_ak, new_av = [], [], [], []
    for gi, (lo, hi) in enumerate(groups):
        if k:
            cache = {"k": state["attn_k"][gi], "v": state["attn_v"][gi]}
            h, nc = _shared_attn(cfg, params, h, h0, None, cache=cache,
                                 pos=pos, window=4096)
            new_ak.append(nc["k"])
            new_av.append(nc["v"])
        sub_p = jax.tree_util.tree_map(lambda a: a[lo:hi], params["mamba"])
        sub_st = {"conv": state["conv"][lo:hi], "ssm": state["ssm"][lo:hi]}
        h, sts = maybe_scan(mamba_step, h, (sub_p, sub_st), length=hi - lo)
        new_conv.append(sts["conv"])
        new_ssm.append(sts["ssm"])

    new_state["conv"] = jnp.concatenate(new_conv, axis=0)
    new_state["ssm"] = jnp.concatenate(new_ssm, axis=0)
    if k:
        new_state["attn_k"] = jnp.stack(new_ak)
        new_state["attn_v"] = jnp.stack(new_av)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tied_embeddings else params["lm_head"]
    return h @ w.astype(dt), new_state


