"""RWKV6 "Finch": attention-free linear RNN with data-dependent decay.

Time-mix implements the Finch recurrence per head (state S in R^{hd x hd}):
    y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(w0 + lora_w(x_t)))
with ddlerp token-shift mixing. The baseline runs the recurrence as a
lax.scan over time (exact); a chunked matmul form is a §Perf candidate with
this scan as its oracle. O(1) decode state => long_500k runs.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard
from repro.models.common import (act_clip, dense_init, dtype_of, embed_init,
                                 maybe_scan, rmsnorm)

MIX_KEYS = ("w", "k", "v", "r", "g")


def init_params(cfg: ModelConfig, rng) -> Dict[str, Any]:
    d, L, f = cfg.d_model, cfg.num_layers, cfg.d_ff
    rw = cfg.rwkv
    H, hd = d // rw.head_dim, rw.head_dim
    ks = jax.random.split(rng, 24)
    blocks = {
        "ln1": jnp.ones((L, d)), "ln2": jnp.ones((L, d)),
        # ddlerp token-shift
        "mu_base": jnp.zeros((L, d)),
        "mix_w1": dense_init(ks[0], (L, d, 5 * rw.mix_lora)),
        "mix_w2": dense_init(ks[1], (L, 5, rw.mix_lora, d), in_axis=-2),
        "mu": jnp.zeros((L, 5, d)),
        # projections
        "wr": dense_init(ks[2], (L, d, d)),
        "wk": dense_init(ks[3], (L, d, d)),
        "wv": dense_init(ks[4], (L, d, d)),
        "wg": dense_init(ks[5], (L, d, d)),
        "wo": dense_init(ks[6], (L, d, d)),
        # data-dependent decay
        "w0": jnp.full((L, d), -4.0),
        "decay_a": dense_init(ks[7], (L, d, rw.decay_lora)),
        "decay_b": dense_init(ks[8], (L, rw.decay_lora, d)),
        "u": jnp.zeros((L, H, hd)),          # per-head bonus
        "ln_x": jnp.ones((L, d)),            # per-head group norm scale
        # channel-mix
        "cm_mu_k": jnp.zeros((L, d)),
        "cm_mu_r": jnp.zeros((L, d)),
        "cm_wk": dense_init(ks[9], (L, d, f)),
        "cm_wv": dense_init(ks[10], (L, f, d)),
        "cm_wr": dense_init(ks[11], (L, d, d)),
    }
    return {
        "embed": embed_init(ks[12], (cfg.vocab_size, d)),
        "blocks": blocks,
        "final_norm": jnp.ones((d,)),
        "lm_head": dense_init(ks[13], (d, cfg.vocab_size)),
    }


def _ddlerp(p, x, sx):
    """Finch data-dependent token-shift. x, sx: (B,S,d)."""
    dx = sx - x
    base = x + dx * p["mu_base"]
    low = jnp.tanh(base @ p["mix_w1"])                       # (B,S,5*ml)
    B_, S_, _ = low.shape
    low = low.reshape(B_, S_, 5, -1)
    offs = jnp.einsum("bsfm,fmd->bsfd", low, p["mix_w2"])    # (B,S,5,d)
    mixed = x[:, :, None] + dx[:, :, None] * (p["mu"][None, None] + offs)
    return {k: mixed[:, :, i] for i, k in enumerate(MIX_KEYS)}


def _decay(p, xw):
    return jnp.exp(-jnp.exp(
        (p["w0"] + jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]).astype(jnp.float32)))


def _time_mix(p, x, cfg, state):
    """x: (B,S,d). state: {'sx': (B,d), 'S': (B,H,hd,hd)} carried across calls."""
    B, S, d = x.shape
    rw = cfg.rwkv
    H, hd = d // rw.head_dim, rw.head_dim
    sx = jnp.concatenate([state["sx"][:, None], x[:, :-1]], axis=1)
    m = _ddlerp(p, x, sx)
    r = (m["r"] @ p["wr"]).reshape(B, S, H, hd)
    k = (m["k"] @ p["wk"]).reshape(B, S, H, hd)
    v = (m["v"] @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(m["g"] @ p["wg"])
    w = _decay(p, m["w"]).reshape(B, S, H, hd)               # f32 in (0,1)
    u = p["u"]

    def step(Sst, inp):
        r_t, k_t, v_t, w_t = inp                             # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        out = jnp.einsum("bhk,bhkv->bhv",
                         r_t.astype(jnp.float32),
                         Sst + u[None, :, :, None] * kv)
        Sst = w_t[..., None] * Sst + kv
        return Sst, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))  # (S,B,H,hd)
    S_new, outs = maybe_scan(step, state["S"], xs)
    y = jnp.moveaxis(outs, 0, 1).reshape(B, S, d).astype(x.dtype)
    # per-head group norm
    y = rmsnorm(y.reshape(B, S, H, hd),
                p["ln_x"].reshape(H, hd), cfg.norm_eps).reshape(B, S, d)
    y = (y * g) @ p["wo"]
    return y, {"sx": x[:, -1], "S": S_new}


def _channel_mix(p, x, state, act_tau=None):
    B, S, d = x.shape
    sx = jnp.concatenate([state["sx"][:, None], x[:, :-1]], axis=1)
    dx = sx - x
    xk = act_clip(x + dx * p["cm_mu_k"], act_tau)
    xr = x + dx * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    kk = shard(kk, "batch", None, "ff")
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (act_clip(kk, act_tau) @ p["cm_wv"])
    return out, {"sx": x[:, -1]}


def init_state(cfg: ModelConfig, B: int):
    d = cfg.d_model
    rw = cfg.rwkv
    H, hd = d // rw.head_dim, rw.head_dim
    L = cfg.num_layers
    return {
        "att_sx": jnp.zeros((L, B, d), dtype_of(cfg.dtype)),
        "ffn_sx": jnp.zeros((L, B, d), dtype_of(cfg.dtype)),
        "S": jnp.zeros((L, B, H, hd, hd), jnp.float32),
        "pos": jnp.zeros((B,), jnp.int32),
    }


def forward(cfg: ModelConfig, params, tokens, *, state=None, sparsity=None,
            remat=None):
    """Returns (logits, new_state). state=None -> zeros (training)."""
    dt = dtype_of(cfg.dtype)
    B, S = tokens.shape
    if state is None:
        state = init_state(cfg, B)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, "embed")

    def block(h, xs):
        p, st, taus = xs
        p = jax.tree_util.tree_map(
            lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, p)
        f_tau = taus.get("ffn") if taus else None
        a_tau = taus.get("attn") if taus else None
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        x = act_clip(x, a_tau)
        y, att_st = _time_mix(p, x, cfg, {"sx": st["att_sx"], "S": st["S"]})
        h = h + y
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        y, ffn_st = _channel_mix(p, x, {"sx": st["ffn_sx"]}, f_tau)
        h = h + y
        new_st = {"att_sx": att_st["sx"], "S": att_st["S"], "ffn_sx": ffn_st["sx"]}
        return h, new_st

    if remat:
        block = jax.checkpoint(block)

    st_in = {k: state[k] for k in ("att_sx", "ffn_sx", "S")}

    def body(c, xs):
        return block(c, xs)

    taus = sparsity if sparsity else None
    if taus is None:
        h, new_st = maybe_scan(lambda c, xs: body(c, (xs[0], xs[1], None)),
                                 h, (params["blocks"], st_in),
                                 length=cfg.num_layers)
    else:
        h, new_st = maybe_scan(body, h, (params["blocks"], st_in, taus),
                                 length=cfg.num_layers)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(dt)
    logits = shard(logits, "batch", None, "vocab")
    new_state = dict(new_st)
    new_state["pos"] = state["pos"] + S
    return logits, new_state


def loss(cfg: ModelConfig, params, batch, *, sparsity=None, remat=None):
    from repro.models.transformer import softmax_xent
    tokens = batch["tokens"]
    logits, _ = forward(cfg, params, tokens, sparsity=sparsity, remat=remat)
    l = softmax_xent(logits[:, :-1], tokens[:, 1:]).mean()
    return l, {"xent": l}


def prefill(cfg: ModelConfig, params, tokens, S_max: int, **kw):
    logits, state = forward(cfg, params, tokens)
    return logits[:, -1:], state


def decode_step(cfg: ModelConfig, params, state, token):
    logits, state = forward(cfg, params, token, state=state)
    return logits, state
