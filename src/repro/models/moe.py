"""Mixture-of-Experts FFN with capacity-based sort dispatch.

Sort-based dispatch (argsort token-slots by expert, scatter into a fixed
(E, C, d) buffer) keeps memory at E*C*d instead of the T*E*C one-hot blowup,
and the (E, C) buffer shards cleanly over the 'model' mesh axis (expert
parallelism); GSPMD inserts the token all-to-all at the data->expert sharding
boundary. Tokens beyond capacity are dropped (standard capacity semantics);
the router aux loss keeps the load balanced so drops stay rare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import activation


def capacity(T: int, moe: MoEConfig) -> int:
    c = int(moe.capacity_factor * T * moe.top_k / moe.num_experts)
    return max(8, -(-c // 8) * 8)                       # round up to 8


def route(x, router_w, moe: MoEConfig):
    """x: (T, d) -> gates (T, k), expert ids (T, k), aux loss."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, moe.top_k)        # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss.
    me = probs.mean(axis=0)                                         # (E,)
    ce = jnp.zeros((moe.num_experts,)).at[idx.reshape(-1)].add(1.0) \
        / (idx.size)
    aux = moe.num_experts * jnp.sum(me * ce) * moe.aux_loss_coef
    return gates, idx, aux


def dispatch_combine(x, gates, idx, moe: MoEConfig, expert_fn,
                     n_buckets: int = 0, cap: int = 0):
    """Run expert_fn over a capacity-bounded (E, C, d) buffer.

    x: (T, d); gates/idx: (T, k); expert_fn: (E, C, d) -> (E, C, d_out).
    n_buckets/cap override the bucket count and per-bucket capacity (used by
    the shard_map dispatch where the last bucket is a drop bucket).
    """
    T, d = x.shape
    k, E = moe.top_k, n_buckets or moe.num_experts
    C = cap or capacity(T, moe)

    slot_expert = idx.reshape(T * k)                    # (T*k,)
    slot_token = jnp.repeat(jnp.arange(T), k)
    slot_gate = gates.reshape(T * k)

    order = jnp.argsort(slot_expert, stable=True)       # group by expert
    se, st, sg = slot_expert[order], slot_token[order], slot_gate[order]
    # position within expert group = rank - first_rank_of_expert
    ranks = jnp.arange(T * k, dtype=jnp.int32)
    group_start = jnp.full((E,), T * k, jnp.int32).at[se].min(ranks)
    pos = ranks - group_start[se]
    keep = pos < C

    buf = jnp.zeros((E, C, d), dtype=x.dtype)
    buf = buf.at[jnp.where(keep, se, E - 1),
                 jnp.where(keep, pos, C - 1)].add(
        jnp.where(keep[:, None], x[st], 0).astype(x.dtype))

    import os as _os
    if _os.environ.get("REPRO_MOE_SHARD_CAP", "0") == "1":
        # shard the capacity dim over the data axes too: the (E, C, d) buffer
        # otherwise replicates over 'data' and blows temp memory (§Perf)
        from repro.distributed.ctx import shard
        buf = shard(buf, "experts", "batch", None)

    out_buf = expert_fn(buf)                            # (E, C, d_out)

    gathered = out_buf[se, jnp.minimum(pos, C - 1)]     # (T*k, d_out)
    gathered = jnp.where(keep[:, None], gathered, 0)
    out = jnp.zeros((T, out_buf.shape[-1]), dtype=jnp.float32)
    out = out.at[st].add(gathered.astype(jnp.float32) * sg[:, None])
    return out.astype(x.dtype)


def moe_ffn(x, p, moe: MoEConfig, act_name: str = "silu", act_tau=None):
    """x: (T, d). p: {'router': (d,E), 'w_gate','w_up': (E,d,f), 'w_down': (E,f,d),
    optional 'shared_*' dense expert}."""
    from repro.models.common import act_clip
    act = activation(act_name)
    gates, idx, aux = route(x, p["router"], moe)

    import os as _os
    if _os.environ.get("REPRO_MOE_SHARDMAP", "0") == "1":
        y = _shard_map_dispatch(act_clip(x, act_tau), gates, idx, p, moe,
                                act, act_tau)
        if y is not None:
            if "shared_w_gate" in p:
                h = act(x @ p["shared_w_gate"]) * (x @ p["shared_w_up"])
                y = y + act_clip(h, act_tau) @ p["shared_w_down"]
            return y, aux

    def experts(buf):                                   # (E, C, d)
        h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        h = act_clip(h, act_tau)
        return jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    y = dispatch_combine(act_clip(x, act_tau), gates, idx, moe, experts)
    if "shared_w_gate" in p:
        h = act(x @ p["shared_w_gate"]) * (x @ p["shared_w_up"])
        y = y + act_clip(h, act_tau) @ p["shared_w_down"]
    return y, aux


def _shard_map_dispatch(x, gates, idx, p, moe: MoEConfig, act, act_tau):
    """Expert-parallel dispatch without the GSPMD scatter blow-up (§Perf).

    Activations are replicated over the 'model' axis (batch shards over
    'data'), so each model column can *locally* select the tokens routed to
    its own E/n experts — no token all-to-all exists in this layout at all.
    GSPMD cannot see that from a global scatter (it replicates the (E, C, d)
    buffer; measured 14.7 TB/device of all-gather on deepseek-v3 train), so
    the dispatch is expressed explicitly with shard_map:
      * expert weights arrive ('model', fsdp)-sharded; the fsdp dim is
        all-gathered inside (the ordinary FSDP cost),
      * tokens with experts outside the column fall into a drop bucket,
      * partial outputs psum over 'model' (the same collective a dense TP
        FFN pays).
    Returns None when the layout does not apply (no ctx / E % model != 0).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed import ctx as _ctx

    c = _ctx.current()
    if c is None or "model" not in c.mesh.axis_names:
        return None
    n_model = dict(zip(c.mesh.axis_names,
                       c.mesh.devices.shape)).get("model", 1)
    E = moe.num_experts
    if n_model <= 1 or E % n_model:
        return None
    dp = tuple(a for a in ("pod", "data") if a in c.mesh.axis_names)
    T, d = x.shape
    ndp = 1
    for a in dp:
        ndp *= dict(zip(c.mesh.axis_names, c.mesh.devices.shape))[a]
    if T % ndp:
        return None
    E_loc = E // n_model
    T_loc = T // ndp
    C = capacity_for(T_loc, moe)

    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    fsdp_w = dp if (dp and wg.shape[1] % ndp == 0) else ()
    wspec_in = P("model", fsdp_w if fsdp_w else None, None)
    wdspec_in = P("model", None, fsdp_w if fsdp_w else None)

    def body(x_l, g_l, i_l, wg_l, wu_l, wd_l):
        j = jax.lax.axis_index("model")
        if fsdp_w:
            wg_l = jax.lax.all_gather(wg_l, fsdp_w, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, fsdp_w, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, fsdp_w, axis=2, tiled=True)
        il = i_l - j * E_loc
        valid = (il >= 0) & (il < E_loc)
        il = jnp.where(valid, il, E_loc)              # drop bucket
        gl = jnp.where(valid, g_l, 0.0)

        def experts(buf):                              # (E_loc+1, C, d)
            h = act(jnp.einsum("ecd,edf->ecf", buf[:E_loc], wg_l)) * \
                jnp.einsum("ecd,edf->ecf", buf[:E_loc], wu_l)
            from repro.models.common import act_clip as _ac
            h = _ac(h, act_tau)
            out = jnp.einsum("ecf,efd->ecd", h, wd_l)
            return jnp.concatenate(
                [out, jnp.zeros((1,) + out.shape[1:], out.dtype)], axis=0)

        y_part = dispatch_combine(x_l, gl, il, moe, experts,
                                  n_buckets=E_loc + 1, cap=C)
        return jax.lax.psum(y_part, "model")

    xspec = P(dp if dp else None, None)
    return shard_map(
        body, mesh=c.mesh,
        in_specs=(xspec, xspec, xspec, wspec_in, wspec_in, wdspec_in),
        out_specs=xspec, check_rep=False)(x, gates, idx, wg, wu, wd)


def capacity_for(T_local: int, moe: MoEConfig) -> int:
    c = int(moe.capacity_factor * T_local * moe.top_k / moe.num_experts)
    return max(8, -(-c // 8) * 8)
