"""Decoder-only / encoder-decoder transformer LM with stacked-layer scan.

Covers the dense/GQA, qk-norm, QKV-bias, sliding-window, MLA (DeepSeek-V3),
MoE (Mixtral / DeepSeek-V3) and whisper (enc-dec) variants of the assigned
pool. Parameters are stacked over the layer axis and the forward pass scans
over layers, keeping HLO size O(1) in depth (essential for the 95-layer
deepseek-67b dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.ctx import shard
from repro.models import moe as moe_lib
from repro.models.attention import (blockwise_attention, decode_attention,
                                    reference_attention)
from repro.models.common import (act_clip, activation, apply_rope, dense_init,
                                 dtype_of, embed_init, maybe_scan, rmsnorm,
                                 take_layer)

Params = Dict[str, Any]


def _cast(p, dt):
    """Cast f32 master weights to the compute dtype at point of use."""
    return jax.tree_util.tree_map(
        lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, p)


# ===================================================================== #
# Init
# ===================================================================== #
def _attn_params(key, cfg: ModelConfig, L: int, cross: bool = False) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 8)
    if cfg.mla is not None and not cross:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        p = {
            "wq_a": dense_init(ks[0], (L, d, m.q_lora_rank)),
            "q_norm_a": jnp.ones((L, m.q_lora_rank)),
            "wq_b": dense_init(ks[1], (L, m.q_lora_rank, H * qk_dim)),
            "wkv_a": dense_init(ks[2], (L, d, m.kv_lora_rank + m.qk_rope_head_dim)),
            "kv_norm_a": jnp.ones((L, m.kv_lora_rank)),
            "wkv_b": dense_init(ks[3], (L, m.kv_lora_rank,
                                        H * (m.qk_nope_head_dim + m.v_head_dim))),
            "wo": dense_init(ks[4], (L, H * m.v_head_dim, d)),
        }
        return p
    p = {
        "wq": dense_init(ks[0], (L, d, H * hd)),
        "wk": dense_init(ks[1], (L, d, KV * hd)),
        "wv": dense_init(ks[2], (L, d, KV * hd)),
        "wo": dense_init(ks[3], (L, H * hd, d)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((L, H * hd))
        p["bk"] = jnp.zeros((L, KV * hd))
        p["bv"] = jnp.zeros((L, KV * hd))
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((L, hd))
        p["k_norm"] = jnp.ones((L, hd))
    return p


def _ffn_params(key, cfg: ModelConfig, L: int) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.moe is not None:
        fe = cfg.moe.expert_d_ff or cfg.d_ff
        E = cfg.moe.num_experts
        p = {
            "router": dense_init(ks[0], (L, d, E)),
            "w_gate": dense_init(ks[1], (L, E, d, fe)),
            "w_up": dense_init(ks[2], (L, E, d, fe)),
            "w_down": dense_init(ks[3], (L, E, fe, d)),
        }
        if cfg.moe.num_shared_experts:
            fs = fe * cfg.moe.num_shared_experts
            p["shared_w_gate"] = dense_init(ks[4], (L, d, fs))
            p["shared_w_up"] = dense_init(ks[5], (L, d, fs))
            p["shared_w_down"] = dense_init(ks[6], (L, fs, d))
        return p
    return {
        "w_gate": dense_init(ks[0], (L, d, cfg.d_ff)),
        "w_up": dense_init(ks[1], (L, d, cfg.d_ff)),
        "w_down": dense_init(ks[2], (L, cfg.d_ff, d)),
    }


def _block_params(key, cfg: ModelConfig, L: int, cross: bool = False) -> Params:
    ka, kf, kc = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((L, cfg.d_model)),
        "ln2": jnp.ones((L, cfg.d_model)),
        "attn": _attn_params(ka, cfg, L),
        "ffn": _ffn_params(kf, cfg, L),
    }
    if cross:
        p["ln_cross"] = jnp.ones((L, cfg.d_model))
        p["cross"] = _attn_params(kc, cfg, L, cross=True)
    return p


def init_params(cfg: ModelConfig, rng) -> Params:
    keys = jax.random.split(rng, 8)
    L = cfg.num_layers
    params: Params = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model)),
        "blocks": _block_params(keys[1], cfg, L, cross=cfg.is_encoder_decoder),
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tied_embeddings:
        params["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab_size))
    if cfg.is_encoder_decoder:
        params["enc_blocks"] = _block_params(keys[3], cfg, cfg.enc_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,))
        params["enc_pos"] = embed_init(keys[4], (cfg.num_frames, cfg.d_model))
        params["dec_pos"] = embed_init(keys[6], (4096, cfg.d_model))
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": dense_init(keys[5], (2 * cfg.d_model, cfg.d_model)),
            "block": _block_params(keys[7], cfg, cfg.mtp_depth),
            "norm": jnp.ones((cfg.d_model,)),
        }
    return params


# ===================================================================== #
# Attention (one layer, expanded form for train/prefill)
# ===================================================================== #
def _gqa_qkv(p, h, cfg: ModelConfig, positions):
    B, S, _ = h.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mla_qkv(p, h, cfg: ModelConfig, positions):
    """MLA expanded form. Returns q,k,v with head dims (nope+rope / v)."""
    m = cfg.mla
    B, S, _ = h.shape
    H = cfg.num_heads
    qa = rmsnorm(h @ p["wq_a"], p["q_norm_a"], cfg.norm_eps)
    q = (qa @ p["wq_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = h @ p["wkv_a"]                                 # (B,S,kvr+rd)
    ckv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(ckv, p["kv_norm_a"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # shared head
    kv = (ckv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q, k, v, ckv, k_rope


def attention_block(p, h, cfg: ModelConfig, positions, *, causal=True,
                    attn_impl="blockwise_full", kv_override=None):
    """Self/cross attention sublayer (pre-norm residual outside)."""
    B, S, _ = h.shape
    if cfg.mla is not None and kv_override is None:
        q, k, v, _, _ = _mla_qkv(p, h, cfg, positions)
        o = blockwise_attention(q, k, v, causal=causal, window=cfg.attn_window,
                                impl=attn_impl)
        o = shard(o.reshape(B, S, -1), "batch", None, "heads")
        return o @ p["wo"]
    if kv_override is not None:                          # cross attention
        xk, xv = kv_override
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        q = (h @ p["wq"]).reshape(B, S, H, hd)
        o = blockwise_attention(q, xk, xv, causal=False)
        return o.reshape(B, S, -1) @ p["wo"]
    q, k, v = _gqa_qkv(p, h, cfg, positions)
    q = shard(q, "batch", None, "heads", None)
    o = blockwise_attention(q, k, v, causal=causal, window=cfg.attn_window,
                            impl=attn_impl)
    o = shard(o.reshape(B, S, -1), "batch", None, "heads")
    return o @ p["wo"]


def ffn_block(p, h, cfg: ModelConfig, act_tau=None):
    B, S, d = h.shape
    if cfg.moe is not None:
        y, aux = moe_lib.moe_ffn(h.reshape(B * S, d), p, cfg.moe, cfg.act, act_tau)
        return y.reshape(B, S, d), aux
    act = activation(cfg.act)
    h_in = act_clip(h, act_tau)
    g = act(h_in @ p["w_gate"]) * (h_in @ p["w_up"])
    g = shard(g, "batch", None, "ff")
    g = act_clip(g, act_tau)
    return g @ p["w_down"], 0.0


# ===================================================================== #
# Forward (train / prefill share this; scan over stacked layers)
# ===================================================================== #
def _make_block_fn(cfg: ModelConfig, positions, *, causal, attn_impl,
                   enc_out=None, remat: Optional[str] = None):
    def block(h, xs):
        p, taus = xs
        p = _cast(p, h.dtype)
        a_tau = taus.get("attn") if taus else None
        f_tau = taus.get("ffn") if taus else None
        h = shard(h, "batch", None, "embed")
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        x = act_clip(x, a_tau)
        h = h + attention_block(p["attn"], x, cfg, positions, causal=causal,
                                attn_impl=attn_impl)
        if enc_out is not None:
            x = rmsnorm(h, p["ln_cross"], cfg.norm_eps)
            h = h + attention_block(p["cross"], x, cfg, positions, causal=False,
                                    kv_override=enc_out)
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        y, aux = ffn_block(p["ffn"], x, cfg, f_tau)
        return h + y, aux

    if remat == "full":
        block = jax.checkpoint(block)
    elif remat == "dots":
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return block


def _scan_blocks(block_fn, h, stacked_params, stacked_taus, L):
    def body(carry, xs):
        h = carry
        h, aux = block_fn(h, xs)
        return h, aux

    taus = stacked_taus if stacked_taus else None
    xs = (stacked_params, taus) if taus else (stacked_params, None)

    if taus is None:
        h, auxs = maybe_scan(lambda c, p: body(c, (p, None)),
                               h, stacked_params, length=L)
    else:
        h, auxs = maybe_scan(body, h, xs, length=L)
    return h, jnp.sum(auxs)


def encode(cfg: ModelConfig, params, frames, *, remat=None):
    """Whisper encoder: frames (B, F, d) precomputed by the stub frontend."""
    h = frames.astype(dtype_of(cfg.dtype)) + params["enc_pos"][None].astype(
        dtype_of(cfg.dtype))
    positions = jnp.arange(frames.shape[1])
    block_fn = _make_block_fn(cfg, positions, causal=False,
                              attn_impl="blockwise_full", remat=remat)
    h, _ = _scan_blocks(block_fn, h, params["enc_blocks"], None, cfg.enc_layers)
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def lm_forward(cfg: ModelConfig, params, tokens, *, frames=None,
               sparsity=None, attn_impl="blockwise_full", remat=None,
               q_offset=0) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (hidden, logits, aux_loss). tokens: (B, S) int32."""
    dt = dtype_of(cfg.dtype)
    h = params["embed"].astype(dt)[tokens]
    h = shard(h, "batch", None, "embed")
    positions = q_offset + jnp.arange(tokens.shape[1])

    enc_out = None
    if cfg.is_encoder_decoder:
        assert frames is not None, "whisper needs frame embeddings"
        e = encode(cfg, params, frames, remat=remat)
        B, F, _ = e.shape
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        # Cross K/V computed per-layer from enc_out inside the scanned block.
        h = h + params["dec_pos"].astype(dt)[jnp.clip(positions, 0, 4095)]
        enc_out = e

    if enc_out is not None:
        # cross attention needs per-layer K/V from enc_out; wrap block fn
        def make(enc):
            def blk(h, xs):
                p, taus = xs
                B, S, _ = h.shape
                KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
                xk = (enc @ p["cross"]["wk"]).reshape(B, enc.shape[1], KV, hd)
                xv = (enc @ p["cross"]["wv"]).reshape(B, enc.shape[1], KV, hd)
                base = _make_block_fn(cfg, positions, causal=True,
                                      attn_impl=attn_impl, enc_out=(xk, xv))
                return base(h, xs)
            return jax.checkpoint(blk) if remat else blk
        block_fn = make(enc_out)
    else:
        block_fn = _make_block_fn(cfg, positions, causal=True,
                                  attn_impl=attn_impl, remat=remat)

    h, aux = _scan_blocks(block_fn, h, params["blocks"], sparsity, cfg.num_layers)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, h)
    return h, logits, aux


def unembed(cfg: ModelConfig, params, h):
    w = params["embed"].T if cfg.tied_embeddings else params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return shard(logits, "batch", None, "vocab")


# ===================================================================== #
# Loss (+ MTP)
# ===================================================================== #
def softmax_xent(logits, labels):
    """Numerically-stable CE in f32; logits (…, V), labels (…,) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def lm_loss(cfg: ModelConfig, params, batch, *, sparsity=None,
            attn_impl="blockwise_full", remat=None):
    """Full-sequence forward (keeps S a power of two); loss on S-1 shifts."""
    tokens = batch["tokens"]
    frames = batch.get("frames")
    h, logits, aux = lm_forward(cfg, params, tokens, frames=frames,
                                sparsity=sparsity, attn_impl=attn_impl,
                                remat=remat)
    loss = softmax_xent(logits[:, :-1], tokens[:, 1:]).mean()
    metrics = {"xent": loss, "aux": aux}

    if cfg.mtp_depth:                            # predict token t+2 from h_t
        dt = h.dtype
        nxt_emb = params["embed"].astype(dt)[jnp.roll(tokens, -1, axis=1)]
        z = jnp.concatenate([rmsnorm(h, params["mtp"]["norm"], cfg.norm_eps),
                             nxt_emb], axis=-1) @ params["mtp"]["proj"].astype(dt)
        positions = jnp.arange(z.shape[1])
        blk = _make_block_fn(cfg, positions, causal=True, attn_impl=attn_impl,
                             remat=remat)
        z, _ = _scan_blocks(blk, z, params["mtp"]["block"], None, cfg.mtp_depth)
        z = rmsnorm(z, params["final_norm"], cfg.norm_eps)
        mtp_logits = unembed(cfg, params, z[:, :-2])
        mtp_loss = softmax_xent(mtp_logits, tokens[:, 2:]).mean()
        metrics["mtp"] = mtp_loss
        loss = loss + 0.1 * mtp_loss
    return loss + aux, metrics


# ===================================================================== #
# Serving: prefill + single-token decode with KV caches
# ===================================================================== #
def init_cache(cfg: ModelConfig, B: int, S_max: int) -> Params:
    dt = dtype_of(cfg.dtype)
    L = cfg.num_layers
    eff = min(S_max, cfg.attn_window) if cfg.attn_window else S_max
    if cfg.mla is not None:
        m = cfg.mla
        cache = {
            "ckv": jnp.zeros((L, B, eff, m.kv_lora_rank), dt),
            "krope": jnp.zeros((L, B, eff, m.qk_rope_head_dim), dt),
        }
    else:
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache = {
            "k": jnp.zeros((L, B, eff, KV, hd), dt),
            "v": jnp.zeros((L, B, eff, KV, hd), dt),
        }
    cache["pos"] = jnp.zeros((B,), jnp.int32)     # true next position (rope)
    if cfg.is_encoder_decoder:
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["xk"] = jnp.zeros((L, B, cfg.num_frames, KV, hd), dt)
        cache["xv"] = jnp.zeros((L, B, cfg.num_frames, KV, hd), dt)
    return cache


def _cache_write(buf, new, lens):
    """buf (B,S,...), new (B,1,...): write at position lens[b] per sequence.

    Baseline: jnp.where over the full cache (reads+writes the whole buffer —
    2x cache HBM traffic). REPRO_CACHE_SCATTER=1 switches to a row scatter
    (writes only B rows) — a §Perf memory-term optimization whose before/after
    is recorded in EXPERIMENTS.md.
    """
    import os as _os
    if _os.environ.get("REPRO_CACHE_SCATTER", "0") == "1":
        B = buf.shape[0]
        return buf.at[jnp.arange(B), lens].set(new[:, 0].astype(buf.dtype))
    S = buf.shape[1]
    onehot = jnp.arange(S)[None, :] == lens[:, None]          # (B,S)
    oh = onehot.reshape(onehot.shape + (1,) * (buf.ndim - 2))
    return jnp.where(oh, new.astype(buf.dtype), buf)


def decode_step(cfg: ModelConfig, params, cache, token):
    """token: (B, 1) int32. Returns (logits (B,1,V), new_cache)."""
    dt = dtype_of(cfg.dtype)
    B = token.shape[0]
    h = params["embed"].astype(dt)[token]                     # (B,1,d)
    pos = cache["pos"]
    window = cfg.attn_window

    if cfg.is_encoder_decoder:
        h = h + params["dec_pos"].astype(dt)[jnp.clip(pos, 0, 4095)][:, None]

    def layer(h, xs):
        p, layer_cache = xs
        p = _cast(p, h.dtype)
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        if cfg.mla is not None:
            o, new_lc = _mla_decode_attn(p["attn"], x, cfg, layer_cache, pos)
        else:
            o, new_lc = _gqa_decode_attn(p["attn"], x, cfg, layer_cache, pos,
                                         window)
        h = h + o
        if cfg.is_encoder_decoder:
            x = rmsnorm(h, p["ln_cross"], cfg.norm_eps)
            q = (x @ p["cross"]["wq"]).reshape(B, 1, cfg.num_heads,
                                               cfg.resolved_head_dim)
            xo = decode_attention(q, layer_cache["xk"], layer_cache["xv"],
                                  jnp.full((B,), cfg.num_frames))
            h = h + xo.reshape(B, 1, -1) @ p["cross"]["wo"]
            new_lc["xk"], new_lc["xv"] = layer_cache["xk"], layer_cache["xv"]
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        y, _ = ffn_block(p["ffn"], x, cfg)
        return h + y, new_lc

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}

    # Carry the cache through the scan and update layer i in place
    # (dynamic_update_index): collecting per-layer caches as scan outputs
    # would stack them into a SECOND full-cache buffer, defeating donation
    # (measured 2x cache temp on the 67B decode cell — EXPERIMENTS.md §Perf).
    def layer_carry(carry, xs):
        h, caches = carry
        p, i = xs
        lc = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            caches)
        h, new_lc = layer(h, (p, lc))
        caches = jax.tree_util.tree_map(
            lambda a, n: jax.lax.dynamic_update_index_in_dim(
                a, n.astype(a.dtype), i, 0), caches, new_lc)
        return (h, caches), None

    (h, new_caches), _ = maybe_scan(
        layer_carry, (h, layer_caches),
        (params["blocks"], jnp.arange(cfg.num_layers)),
        length=cfg.num_layers)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, h)
    new_cache = dict(new_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _gqa_decode_attn(p, x, cfg, lc, pos, window):
    B = x.shape[0]
    KV, hd, H = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_heads
    q, k, v = _gqa_qkv(p, x, cfg, pos[:, None])
    S = lc["k"].shape[1]
    slot = pos % S                        # ring buffer (id when S covers pos)
    new_k = _cache_write(lc["k"], k, slot)
    new_v = _cache_write(lc["v"], v, slot)
    eff_len = jnp.minimum(pos + 1, S)
    o = decode_attention(q, new_k, new_v, eff_len)
    o = o.reshape(B, 1, H * hd)
    return o @ p["wo"], {"k": new_k, "v": new_v}


def _mla_decode_attn(p, x, cfg, lc, pos):
    """Absorbed-form MLA decode: cache latent ckv + shared k_rope."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    qa = rmsnorm(x @ p["wq_a"], p["q_norm_a"], cfg.norm_eps)
    q = (qa @ p["wq_b"]).reshape(B, 1, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)

    kv_a = x @ p["wkv_a"]
    ckv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    ckv = rmsnorm(ckv, p["kv_norm_a"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos[:, None], cfg.rope_theta)[:, :, 0]

    new_ckv = _cache_write(lc["ckv"], ckv, pos)               # (B,S,kvr)
    new_krope = _cache_write(lc["krope"], k_rope, pos)

    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    wk_b, wv_b = wkv_b[..., :m.qk_nope_head_dim], wkv_b[..., m.qk_nope_head_dim:]
    # absorb: q_eff = q_nope @ wk_b^T  -> latent space
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))              # (B,H,kvr)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhr,bsr->bhs", q_eff, new_ckv.astype(jnp.float32)) +
         jnp.einsum("bhn,bsn->bhs", q_rope[:, 0].astype(jnp.float32),
                    new_krope.astype(jnp.float32))) * scale
    S = new_ckv.shape[1]
    valid = jnp.arange(S)[None, :] < (pos + 1)[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", pr, new_ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", lat, wv_b.astype(jnp.float32))  # (B,H,v)
    o = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return o @ p["wo"], {"ckv": new_ckv, "krope": new_krope}


def prefill(cfg: ModelConfig, params, tokens, S_max: int, *, frames=None,
            attn_impl="blockwise_full", sparsity=None, prompt_lens=None):
    """Run the full prompt, build the cache. Returns (last_logits, cache).

    ``prompt_lens`` (B,) serves a ragged batch padded on the right to the
    chunk max: logits are gathered at each row's last real token
    (``lens[b] - 1``; causal attention never looks right, so the pad
    columns cannot leak in) and ``cache["pos"]`` starts at ``lens`` — the
    decode steps overwrite the pad rows' cache slots and mask past
    ``pos``, exactly the "pad to max then mask" batching discipline."""
    B, S = tokens.shape
    dt = dtype_of(cfg.dtype)
    cache = init_cache(cfg, B, S_max)
    h = params["embed"].astype(dt)[tokens]
    positions = jnp.arange(S)

    enc = None
    if cfg.is_encoder_decoder:
        enc = encode(cfg, params, frames)
        h = h + params["dec_pos"].astype(dt)[jnp.clip(positions, 0, 4095)]

    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    eff = cache["k"].shape[2] if "k" in cache else cache["ckv"].shape[2]
    assert S <= eff or S % eff == 0, (
        "ring-buffer slot arithmetic needs prompt len < cache or a multiple "
        f"of the window; got S={S}, eff={eff}")

    def _to_cache(a):
        """Keep the last ``eff`` positions; right-pad short prompts."""
        if a.shape[1] >= eff:
            return a[:, -eff:]
        pad = [(0, 0)] * a.ndim
        pad[1] = (0, eff - a.shape[1])
        return jnp.pad(a, pad)

    def layer(h, xs):
        p, taus = xs
        p = _cast(p, h.dtype)
        f_tau = taus.get("ffn") if taus else None
        a_tau = taus.get("attn") if taus else None
        x = rmsnorm(h, p["ln1"], cfg.norm_eps)
        x = act_clip(x, a_tau)
        if cfg.mla is not None:
            q, k, v, ckv, k_rope = _mla_qkv(p["attn"], x, cfg, positions)
            o = blockwise_attention(q, k, v, causal=True, impl=attn_impl)
            o = o.reshape(B, S, -1) @ p["attn"]["wo"]
            lc = {"ckv": _to_cache(ckv), "krope": _to_cache(k_rope[:, :, 0])}
        else:
            q, k, v = _gqa_qkv(p["attn"], x, cfg, positions)
            o = blockwise_attention(q, k, v, causal=True,
                                    window=cfg.attn_window, impl=attn_impl)
            o = o.reshape(B, S, -1) @ p["attn"]["wo"]
            lc = {"k": _to_cache(k), "v": _to_cache(v)}
        h = h + o
        if cfg.is_encoder_decoder:
            x = rmsnorm(h, p["ln_cross"], cfg.norm_eps)
            xk = (enc @ p["cross"]["wk"]).reshape(B, enc.shape[1], KV, hd)
            xv = (enc @ p["cross"]["wv"]).reshape(B, enc.shape[1], KV, hd)
            h = h + attention_block(p["cross"], x, cfg, positions, causal=False,
                                    kv_override=(xk, xv))
            lc["xk"], lc["xv"] = xk, xv
        x = rmsnorm(h, p["ln2"], cfg.norm_eps)
        y, _ = ffn_block(p["ffn"], x, cfg, f_tau)
        return h + y, lc

    xs = (params["blocks"], sparsity) if sparsity else (params["blocks"], None)
    if sparsity:
        h, layer_caches = maybe_scan(layer, h, xs, length=cfg.num_layers)
    else:
        h, layer_caches = maybe_scan(lambda c, p: layer(c, (p, None)),
                                       h, params["blocks"],
                                       length=cfg.num_layers)
    for k_, v_ in layer_caches.items():
        cache[k_] = v_
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if prompt_lens is None:
        cache["pos"] = jnp.full((B,), S, jnp.int32)
        return unembed(cfg, params, h[:, -1:]), cache
    assert S <= eff, (
        "ragged prefill (prompt_lens) needs the whole padded prompt "
        f"resident in the cache window; got S={S}, eff={eff}")
    lens = jnp.asarray(prompt_lens, jnp.int32)
    cache["pos"] = lens
    last = jnp.take_along_axis(h, (lens - 1)[:, None, None], axis=1)
    return unembed(cfg, params, last), cache
