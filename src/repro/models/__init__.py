"""Unified model API: ``build_model(cfg)`` returns family-appropriate fns.

All families expose the same surface:
    init(rng) -> params
    loss(params, batch, **kw) -> (scalar, metrics)       [train step core]
    prefill(params, tokens, S_max, **kw) -> (logits, cache/state)
    decode_step(params, cache, token) -> (logits, new_cache)
    init_cache(B, S_max) -> cache pytree (zeros / ShapeDtypeStruct template)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    loss: Callable
    prefill: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    init_cache: Optional[Callable] = None


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "cnn":
        from repro.models import cnn
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(cnn.init_params, cfg),
            loss=functools.partial(cnn.loss, cfg))
    if cfg.rwkv is not None:
        from repro.models import rwkv
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(rwkv.init_params, cfg),
            loss=functools.partial(rwkv.loss, cfg),
            prefill=functools.partial(rwkv.prefill, cfg),
            decode_step=functools.partial(rwkv.decode_step, cfg),
            init_cache=lambda B, S_max: rwkv.init_state(cfg, B))
    if cfg.ssm is not None:
        from repro.models import ssm
        return ModelAPI(
            cfg=cfg,
            init=functools.partial(ssm.init_params, cfg),
            loss=functools.partial(ssm.loss, cfg),
            prefill=functools.partial(ssm.prefill, cfg),
            decode_step=functools.partial(ssm.decode_step, cfg),
            init_cache=functools.partial(ssm.init_state, cfg))
    from repro.models import transformer as tfm
    return ModelAPI(
        cfg=cfg,
        init=functools.partial(tfm.init_params, cfg),
        loss=functools.partial(tfm.lm_loss, cfg),
        prefill=functools.partial(tfm.prefill, cfg),
        decode_step=functools.partial(tfm.decode_step, cfg),
        init_cache=functools.partial(tfm.init_cache, cfg))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell.

    train  -> {'tokens': (B, S)} (+frames for audio; images for cnn)
    prefill-> {'tokens': (B, S)} (+frames)
    decode -> {'token': (B, 1), 'cache': <pytree>}    (cache of size S)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(sh, dt=i32):
        return jax.ShapeDtypeStruct(sh, dt)

    if cfg.family == "cnn":
        return {"batch": {"images": sds((B, cfg.img_res, cfg.img_res, 3),
                                        jnp.bfloat16),
                          "labels": sds((B,))}}

    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((B, S))}
        if cfg.is_encoder_decoder:
            batch["frames"] = sds((B, cfg.num_frames, cfg.d_model), jnp.bfloat16)
        return {"batch": batch}

    # decode: one new token against a populated cache of logical length S
    api = build_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(B, S))
    return {"token": sds((B, 1)), "cache": cache}
