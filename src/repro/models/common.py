"""Shared model building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------- #
def dense_init(key, shape, in_axis=-2, scale=1.0, dtype=jnp.float32):
    """LeCun-normal over the contracted axis; stored in float32, cast at use."""
    fan_in = shape[in_axis]
    std = scale / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------- #
# Normalization / activations
# --------------------------------------------------------------------- #
def rmsnorm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                       # rwkv channel-mix
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# --------------------------------------------------------------------- #
# Rotary position embedding
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                         # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Activation clipping — the paper's SPE "clip" unit (§IV).
# Values with |x| < tau are zeroed at run time (dynamic activation sparsity).
# --------------------------------------------------------------------- #
def act_clip(x, tau):
    """tau: scalar or per-layer scalar. tau<=0 disables (identity)."""
    if tau is None:
        return x
    return jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))


def take_layer(stacked, i):
    """Slice layer i out of a stacked-parameter pytree."""
    return jax.tree_util.tree_map(lambda a: a[i], stacked)


# --------------------------------------------------------------------- #
# Scan wrapper with a global unroll switch.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
# count, so cost_analysis() on scanned programs under-reports FLOPs/bytes.
# The dry-run therefore uses an analytic cost model (analysis/flops_model.py)
# which tests validate against cost_analysis() of *unrolled* small configs —
# REPRO_UNROLL_SCANS=1 switches every model scan to a python loop.
# --------------------------------------------------------------------- #
import os as _os


def unroll_scans() -> bool:
    return _os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def maybe_scan(body, carry, xs, length=None):
    """jax.lax.scan, or an unrolled python loop under REPRO_UNROLL_SCANS=1."""
    if not unroll_scans():
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if length is not None else \
        jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked
