"""Attention implementations.

``blockwise_attention`` is a flash-style, memory-bounded attention written in
pure JAX (lax.scan over KV blocks with an online softmax). It keeps compiled
peak memory at O(S·d + S·block_k) instead of O(S^2) so the 32k prefill cells
lower with sane memory. FLOPs remain O(S^2) in the baseline ("blockwise_full");
the banded variant ("banded") skips fully-masked KV blocks via a static
(q-block, kv-block) pair table — the same static-schedule idea the paper uses
for weight tiles, applied to the causal/window structure. The banded variant is
a beyond-paper §Perf optimization and the default for sliding-window models.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.ctx import shard
from repro.models.common import maybe_scan

NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(Q, K) additive bias from causal/window structure."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF)


def reference_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                        kv_len: Optional[jnp.ndarray] = None):
    """Naive O(S^2)-memory oracle. q:(B,Sq,H,D) k,v:(B,Sk,KV,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    qq = q.reshape(B, Sq, KV, G, D)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qq.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    bias = _mask_bias(q_pos, k_pos, causal, window)
    if kv_len is not None:                       # per-sequence valid length
        valid = k_pos[None, :] < kv_len[:, None]             # (B, Sk)
        bias = bias[None] + jnp.where(valid, 0.0, NEG_INF)[:, None]
        s = s + bias[:, None, None]
    else:
        s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dv).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                        block_k=512, kv_len: Optional[jnp.ndarray] = None,
                        impl="blockwise_full"):
    """Flash-style attention. q:(B,Sq,H,D) k,v:(B,Sk,KV,D) -> (B,Sq,H,D).

    impl:
      blockwise_full  scan over every KV block, masking (baseline)
      banded          scan only KV blocks that intersect the causal/window band
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    if Sk <= block_k * 2:
        return reference_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, kv_len=kv_len)
    if Sk % block_k:                                  # pad ragged KV, mask tail
        pad = block_k - Sk % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.full((B,), Sk, jnp.int32)
        Sk = Sk + pad
    G = H // KV
    nkb = Sk // block_k
    qq = (q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
          / jnp.sqrt(D).astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq)

    if impl == "banded":
        # Static list of KV-block indices that intersect the band for ANY query.
        blocks = []
        q_lo, q_hi = q_offset, q_offset + Sq - 1
        for j in range(nkb):
            k_lo, k_hi = j * block_k, (j + 1) * block_k - 1
            if causal and k_lo > q_hi:
                continue
            if window > 0 and k_hi < q_lo - window + 1:
                continue
            blocks.append(j)
        block_ids = jnp.array(blocks, dtype=jnp.int32)
        nsteps = len(blocks)
    else:
        block_ids = jnp.arange(nkb, dtype=jnp.int32)
        nsteps = nkb

    def step(carry, j):
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * block_k, block_k, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block_k, block_k, axis=1)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qq, kj.astype(jnp.float32))
        # constrain the score block (and thereby its cotangent in the
        # transposed backward scan) to stay batch-sharded — see carry note
        s = shard(s, "batch", "kv_heads", None, None, None)
        k_pos = j * block_k + jnp.arange(block_k)
        bias = _mask_bias(q_pos, k_pos, causal, window)                 # (Sq, bk)
        if kv_len is not None:
            valid = k_pos[None, :] < kv_len[:, None]                    # (B, bk)
            bias = bias[None, None, None] + \
                jnp.where(valid, 0.0, NEG_INF)[:, None, None, None, :]
            s = s + bias
        else:
            s = s + bias[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + \
            jnp.einsum("bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    # The online-softmax carry MUST be explicitly batch-sharded: an unsharded
    # scan carry makes GSPMD replicate it, which all-gathers every f32 score
    # block across the batch axis (measured 825 GB/device/step on the whisper
    # train cell before this constraint — EXPERIMENTS.md §Perf).
    def _c(x):
        return shard(x, "batch", "kv_heads", *([None] * (x.ndim - 2)))

    m0 = _c(jnp.full((B, KV, G, Sq), NEG_INF, dtype=jnp.float32))
    l0 = _c(jnp.zeros((B, KV, G, Sq), dtype=jnp.float32))
    a0 = _c(jnp.zeros((B, KV, G, Sq, Dv), dtype=jnp.float32))

    def step_sharded(carry, j):
        (m, l, acc), ys = step(carry, j)
        return (_c(m), _c(l), _c(acc)), ys

    (m, l, acc), _ = maybe_scan(step_sharded, (m0, l0, a0), block_ids,
                                length=nsteps)
    o = acc / jnp.maximum(l, 1e-30)[..., None]                # (B,KV,G,Sq,Dv)
    o = jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, Dv)
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_len, *, window=0):
    """Single-token decode. q:(B,1,H,D); caches:(B,Smax,KV,D); kv_len:(B,).

    Attends to positions < kv_len (per sequence); with a window only the last
    ``window`` positions are valid. O(Smax) per step.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qq = q.reshape(B, KV, G, D).astype(jnp.float32) / jnp.sqrt(D).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qq, k_cache.astype(jnp.float32))
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None, :] < kv_len[:, None]
    if window > 0:
        valid &= pos[None, :] >= (kv_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)
