"""The paper's benchmark CNNs: ResNet-18/50, MobileNetV2, MobileNetV3-S/L.

Models are described as an explicit dataflow list of ``LayerSpec``s and
executed by a small interpreter, so the HASS search and the DSE consume
*exactly* the layers the forward pass runs (the paper's Fig. 4 ResNet-18
workload is the 16 3x3 convs this spec produces — matching the paper's count).
BatchNorm is folded into conv bias (standard for FPGA deployment flows;
fpgaConvNet folds BN as well).

Each spec names its input: ``input_from=None`` means "previous layer output";
``add`` layers sum their sequential input with ``residual_from``'s output.
This mirrors the dataflow-graph view of Fig. 3 (left) in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import act_clip, dense_init

INPUT = "__input__"


@dataclass(frozen=True)
class LayerSpec:
    name: str
    kind: str                 # conv | dwconv | linear | pool | gap | add | se
    cin: int = 0
    cout: int = 0
    k: int = 1
    stride: int = 1
    in_hw: int = 0
    out_hw: int = 0
    act: str = "relu"         # relu | hswish | none
    input_from: Optional[str] = None
    residual_from: Optional[str] = None
    se_ratio: float = 0.0

    @property
    def macs(self) -> int:
        """MACs per image — the paper's C_l (dense operation count)."""
        if self.kind == "conv":
            return self.cout * self.cin * self.k * self.k * self.out_hw ** 2
        if self.kind == "dwconv":
            return self.cout * self.k * self.k * self.out_hw ** 2
        if self.kind == "linear":
            return self.cin * self.cout
        if self.kind == "se":
            mid = max(8, int(self.cin * self.se_ratio))
            return 2 * self.cin * mid
        return 0

    @property
    def weights(self) -> int:
        if self.kind == "conv":
            return self.cout * self.cin * self.k * self.k
        if self.kind == "dwconv":
            return self.cout * self.k * self.k
        if self.kind == "linear":
            return self.cin * self.cout
        return 0

    @property
    def prunable(self) -> bool:
        # the paper prunes the DSP-heavy multipliers: convs and linears
        return self.kind in ("conv", "linear") and self.weights > 0


# --------------------------------------------------------------------- #
# Spec builders
# --------------------------------------------------------------------- #
def _resnet(depths, widths, bottleneck, res, num_classes) -> List[LayerSpec]:
    specs: List[LayerSpec] = []
    hw = res // 2
    specs.append(LayerSpec("stem", "conv", 3, 64, 7, 2, res, hw))
    hw //= 2
    specs.append(LayerSpec("maxpool", "pool", 64, 64, 3, 2, hw * 2, hw))
    cin, last = 64, "maxpool"
    for stage, (n, w) in enumerate(zip(depths, widths)):
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            out_hw = hw // stride
            tag = f"s{stage}b{b}"
            block_in = last
            if bottleneck:
                mid = w // 4
                specs.append(LayerSpec(f"{tag}c1", "conv", cin, mid, 1, 1, hw, hw))
                specs.append(LayerSpec(f"{tag}c2", "conv", mid, mid, 3, stride,
                                       hw, out_hw))
                specs.append(LayerSpec(f"{tag}c3", "conv", mid, w, 1, 1,
                                       out_hw, out_hw, act="none"))
                main = f"{tag}c3"
            else:
                specs.append(LayerSpec(f"{tag}c1", "conv", cin, w, 3, stride,
                                       hw, out_hw))
                specs.append(LayerSpec(f"{tag}c2", "conv", w, w, 3, 1,
                                       out_hw, out_hw, act="none"))
                main = f"{tag}c2"
            resid = block_in
            if stride != 1 or cin != w:
                specs.append(LayerSpec(f"{tag}proj", "conv", cin, w, 1, stride,
                                       hw, out_hw, act="none",
                                       input_from=block_in))
                resid = f"{tag}proj"
            specs.append(LayerSpec(f"{tag}add", "add", w, w, in_hw=out_hw,
                                   out_hw=out_hw, act="relu",
                                   input_from=main, residual_from=resid))
            cin, hw, last = w, out_hw, f"{tag}add"
    specs.append(LayerSpec("gap", "gap", cin, cin, in_hw=hw, out_hw=1))
    specs.append(LayerSpec("fc", "linear", cin, num_classes, act="none"))
    return specs


def _mbv2(res, num_classes) -> List[LayerSpec]:
    setting = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
               (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    specs: List[LayerSpec] = []
    hw = res // 2
    specs.append(LayerSpec("stem", "conv", 3, 32, 3, 2, res, hw))
    cin, last, bid = 32, "stem", 0
    for t, c, n, s in setting:
        for b in range(n):
            stride = s if b == 0 else 1
            out_hw = hw // stride
            mid = cin * t
            tag = f"b{bid}"
            block_in = last
            if t != 1:
                specs.append(LayerSpec(f"{tag}exp", "conv", cin, mid, 1, 1, hw, hw))
            specs.append(LayerSpec(f"{tag}dw", "dwconv", mid, mid, 3, stride,
                                   hw, out_hw))
            specs.append(LayerSpec(f"{tag}prj", "conv", mid, c, 1, 1,
                                   out_hw, out_hw, act="none"))
            last = f"{tag}prj"
            if stride == 1 and cin == c:
                specs.append(LayerSpec(f"{tag}add", "add", c, c, in_hw=out_hw,
                                       out_hw=out_hw, act="none",
                                       input_from=last, residual_from=block_in))
                last = f"{tag}add"
            cin, hw, bid = c, out_hw, bid + 1
    specs.append(LayerSpec("head", "conv", cin, 1280, 1, 1, hw, hw))
    specs.append(LayerSpec("gap", "gap", 1280, 1280, in_hw=hw, out_hw=1))
    specs.append(LayerSpec("fc", "linear", 1280, num_classes, act="none"))
    return specs


def _mbv3(small, res, num_classes) -> List[LayerSpec]:
    if small:
        setting = [(3, 16, 16, True, "relu", 2), (3, 72, 24, False, "relu", 2),
                   (3, 88, 24, False, "relu", 1), (5, 96, 40, True, "hswish", 2),
                   (5, 240, 40, True, "hswish", 1), (5, 240, 40, True, "hswish", 1),
                   (5, 120, 48, True, "hswish", 1), (5, 144, 48, True, "hswish", 1),
                   (5, 288, 96, True, "hswish", 2), (5, 576, 96, True, "hswish", 1),
                   (5, 576, 96, True, "hswish", 1)]
        head, fc_mid = 576, 1024
    else:
        setting = [(3, 16, 16, False, "relu", 1), (3, 64, 24, False, "relu", 2),
                   (3, 72, 24, False, "relu", 1), (5, 72, 40, True, "relu", 2),
                   (5, 120, 40, True, "relu", 1), (5, 120, 40, True, "relu", 1),
                   (3, 240, 80, False, "hswish", 2), (3, 200, 80, False, "hswish", 1),
                   (3, 184, 80, False, "hswish", 1), (3, 184, 80, False, "hswish", 1),
                   (3, 480, 112, True, "hswish", 1), (3, 672, 112, True, "hswish", 1),
                   (5, 672, 160, True, "hswish", 2), (5, 960, 160, True, "hswish", 1),
                   (5, 960, 160, True, "hswish", 1)]
        head, fc_mid = 960, 1280
    specs: List[LayerSpec] = []
    hw = res // 2
    specs.append(LayerSpec("stem", "conv", 3, 16, 3, 2, res, hw, act="hswish"))
    cin, last = 16, "stem"
    for bid, (k, exp, c, se, act, s) in enumerate(setting):
        out_hw = hw // s
        tag = f"b{bid}"
        block_in = last
        if exp != cin:
            specs.append(LayerSpec(f"{tag}exp", "conv", cin, exp, 1, 1, hw, hw,
                                   act=act))
        specs.append(LayerSpec(f"{tag}dw", "dwconv", exp, exp, k, s, hw, out_hw,
                               act=act))
        if se:
            specs.append(LayerSpec(f"{tag}se", "se", exp, exp, in_hw=out_hw,
                                   out_hw=out_hw, se_ratio=0.25))
        specs.append(LayerSpec(f"{tag}prj", "conv", exp, c, 1, 1, out_hw, out_hw,
                               act="none"))
        last = f"{tag}prj"
        if s == 1 and cin == c:
            specs.append(LayerSpec(f"{tag}add", "add", c, c, in_hw=out_hw,
                                   out_hw=out_hw, act="none",
                                   input_from=last, residual_from=block_in))
            last = f"{tag}add"
        cin, hw = c, out_hw
    specs.append(LayerSpec("head", "conv", cin, head, 1, 1, hw, hw, act="hswish"))
    specs.append(LayerSpec("gap", "gap", head, head, in_hw=hw, out_hw=1))
    specs.append(LayerSpec("fc2", "linear", head, fc_mid, act="hswish"))
    specs.append(LayerSpec("fc", "linear", fc_mid, num_classes, act="none"))
    return specs


def build_specs(cfg: ModelConfig) -> List[LayerSpec]:
    r, nc = cfg.img_res, cfg.num_classes
    if cfg.cnn_arch == "resnet18":
        return _resnet([2, 2, 2, 2], [64, 128, 256, 512], False, r, nc)
    if cfg.cnn_arch == "resnet50":
        return _resnet([3, 4, 6, 3], [256, 512, 1024, 2048], True, r, nc)
    if cfg.cnn_arch == "mobilenetv2":
        return _mbv2(r, nc)
    if cfg.cnn_arch == "mobilenetv3s":
        return _mbv3(True, r, nc)
    if cfg.cnn_arch == "mobilenetv3l":
        return _mbv3(False, r, nc)
    raise ValueError(cfg.cnn_arch)


# --------------------------------------------------------------------- #
# Interpreter
# --------------------------------------------------------------------- #
def init_params(cfg: ModelConfig, rng) -> Dict[str, Dict[str, jnp.ndarray]]:
    specs = build_specs(cfg)
    params = {}
    keys = jax.random.split(rng, len(specs))
    for key, s in zip(keys, specs):
        if s.kind == "conv":
            params[s.name] = {
                "w": dense_init(key, (s.k, s.k, s.cin, s.cout), in_axis=-2,
                                scale=1.0 / s.k),
                "b": jnp.zeros((s.cout,))}
        elif s.kind == "dwconv":
            params[s.name] = {
                "w": dense_init(key, (s.k, s.k, 1, s.cout), in_axis=-1,
                                scale=1.0 / s.k),
                "b": jnp.zeros((s.cout,))}
        elif s.kind == "linear":
            params[s.name] = {"w": dense_init(key, (s.cin, s.cout)),
                              "b": jnp.zeros((s.cout,))}
        elif s.kind == "se":
            mid = max(8, int(s.cin * s.se_ratio))
            k1, k2 = jax.random.split(key)
            params[s.name] = {"w1": dense_init(k1, (s.cin, mid)),
                              "b1": jnp.zeros((mid,)),
                              "w2": dense_init(k2, (mid, s.cin)),
                              "b2": jnp.zeros((s.cin,))}
    return params


def _act(x, name):
    if name == "relu":
        return jax.nn.relu(x)
    if name == "hswish":
        return jax.nn.hard_swish(x)
    return x


def forward(cfg: ModelConfig, params, images, *, sparsity=None,
            collect_stats=False, return_intermediates=False):
    """images: (B, H, W, 3). sparsity: {layer_name: tau_a}.

    Returns logits, or (logits, stats) with per-prunable-layer input zero
    fraction when collect_stats (feeds the paper's calibration pass), or
    (logits, outs) with every layer output when return_intermediates.
    """
    specs = build_specs(cfg)
    outs: Dict[str, jnp.ndarray] = {INPUT: images.astype(jnp.float32)}
    stats: Dict[str, jnp.ndarray] = {}
    last = INPUT
    for s in specs:
        x = outs[s.input_from or last]
        tau = sparsity.get(s.name) if sparsity else None
        if s.kind in ("conv", "dwconv"):
            x = act_clip(x, tau)
            if collect_stats and s.prunable:
                stats[s.name] = jnp.mean(x == 0.0)
            p = params[s.name]
            groups = s.cout if s.kind == "dwconv" else 1
            pad = (s.k - 1) // 2
            x = jax.lax.conv_general_dilated(
                x, p["w"], (s.stride, s.stride), [(pad, pad), (pad, pad)],
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=groups)
            x = _act(x + p["b"], s.act)
        elif s.kind == "pool":
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, s.k, s.k, 1),
                                      (1, s.stride, s.stride, 1), "SAME")
        elif s.kind == "gap":
            x = x.mean(axis=(1, 2))
        elif s.kind == "linear":
            x = act_clip(x, tau)
            if collect_stats and s.prunable:
                stats[s.name] = jnp.mean(x == 0.0)
            p = params[s.name]
            x = _act(x @ p["w"] + p["b"], s.act)
        elif s.kind == "se":
            p = params[s.name]
            z = x.mean(axis=(1, 2))
            z = jax.nn.relu(z @ p["w1"] + p["b1"])
            z = jax.nn.sigmoid(z @ p["w2"] + p["b2"])
            x = x * z[:, None, None, :]
        elif s.kind == "add":
            x = _act(x + outs[s.residual_from], s.act)
        outs[s.name] = x
        last = s.name
    logits = outs[last]
    if return_intermediates:
        return logits, outs
    return (logits, stats) if collect_stats else logits


def loss(cfg: ModelConfig, params, batch, *, sparsity=None, remat=None):
    from repro.models.transformer import softmax_xent
    logits = forward(cfg, params, batch["images"], sparsity=sparsity)
    l = softmax_xent(logits, batch["labels"]).mean()
    return l, {"xent": l}
