"""Deterministic synthetic data — tokens, frames, images.

Every batch is a pure function of (seed, step, shard), so a restarted or
re-sharded job regenerates exactly the stream it would have seen: the data
pipeline contributes zero state to checkpoints beyond the step counter, which
is what makes checkpoint/restart and elastic re-sharding exact.

The LM stream is a mixture of Zipfian unigrams and a first-order Markov chain
(repetition structure) so cross-entropy actually *decreases* under training —
pure-uniform tokens would give a flat loss and hide optimizer bugs.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _fold(seed: int, *xs: int) -> jax.Array:
    k = jax.random.PRNGKey(seed)
    for x in xs:
        k = jax.random.fold_in(k, x)
    return k


def lm_batch(cfg: ModelConfig, B: int, S: int, *, seed: int = 0,
             step: int = 0) -> Dict[str, jnp.ndarray]:
    k = _fold(seed, step)
    k1, k2, k3 = jax.random.split(k, 3)
    V = cfg.vocab_size
    # zipf-ish marginal via exp-transformed uniforms
    u = jax.random.uniform(k1, (B, S), minval=1e-6, maxval=1.0)
    zipf = jnp.minimum((u ** (-0.7) - 1.0).astype(jnp.int32), V - 1)
    # markov "copy previous token" structure with p=0.3
    copy = jax.random.bernoulli(k2, 0.3, (B, S))
    rolled = jnp.roll(zipf, 1, axis=1)
    tokens = jnp.where(copy, rolled, zipf).astype(jnp.int32)
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            k3, (B, cfg.num_frames, cfg.d_model), jnp.bfloat16)
    return batch


def image_batch(cfg: ModelConfig, B: int, *, seed: int = 0, step: int = 0,
                n_classes: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Gaussian class-cluster images: learnable but synthetic."""
    n_classes = n_classes or cfg.num_classes
    k = _fold(seed, step)
    k1, k2 = jax.random.split(k)
    labels = jax.random.randint(k1, (B,), 0, n_classes)
    protos = jax.random.normal(_fold(seed ^ 0x5eed),
                               (n_classes, 8, 8, 3)) * 2.0
    base = protos[labels]
    base = jax.image.resize(base, (B, cfg.img_res, cfg.img_res, 3), "nearest")
    noise = jax.random.normal(k2, (B, cfg.img_res, cfg.img_res, 3))
    return {"images": base + 0.5 * noise, "labels": labels}


def batch_for(cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
              step: int = 0) -> Dict[str, jnp.ndarray]:
    if cfg.family == "cnn":
        return image_batch(cfg, shape.global_batch, seed=seed, step=step)
    return lm_batch(cfg, shape.global_batch, shape.seq_len, seed=seed,
                    step=step)
