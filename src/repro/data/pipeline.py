"""Sharded, prefetching data pipeline over the synthetic generators.

``DataPipeline`` is an iterator of device-ready batches:
  * deterministic in (seed, step) — resume = set the cursor (see synthetic.py)
  * shard-aware: batches are placed with the mesh batch sharding so pjit
    consumes them without a resharding copy
  * background prefetch (double buffering) to overlap host generation with
    device compute — the host-side half of compute/comm overlap.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.synthetic import batch_for


class DataPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 start_step: int = 0, shardings: Optional[Any] = None,
                 prefetch: int = 2):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = start_step
        self.shardings = shardings
        self.prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- simple synchronous API ------------------------------------- #
    def batch_at(self, step: int) -> Dict[str, Any]:
        b = batch_for(self.cfg, self.shape, seed=self.seed, step=step)
        if self.shardings is not None:
            b = jax.device_put(b, self.shardings)
        return b

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        if self._thread is None and self.prefetch > 0:
            self._start()
        if self._thread is None:
            b = self.batch_at(self.step)
            self.step += 1
            return b
        return self._q.get()

    # -- background prefetch ----------------------------------------- #
    def _start(self):
        def worker():
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(self.step), timeout=0.5)
                    self.step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def close(self):
        self._stop.set()
