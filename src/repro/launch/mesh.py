"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests/benches must see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is pure
    data parallelism over DCI and composes with 'data' for gradient
    reductions (hierarchical: reduce-scatter intra-pod, all-reduce inter-pod).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — tests/examples."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
