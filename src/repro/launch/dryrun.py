import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding rules are coherent (GSPMD partitions the whole step),
  * the program fits (memory_analysis bytes/device),
  * and it yields the roofline terms (cost_analysis + HLO collective bytes)
    recorded into EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
Results append incrementally to experiments/dryrun.json (idempotent per key).
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import numpy as np

from repro.analysis.roofline import analytic_traffic, build_report
from repro.configs import (ASSIGNED, SHAPE_BY_NAME, SHAPES, cell_supported,
                           get_config)
from repro.core.perf_model import model_flops
from repro.distributed import ctx as shard_ctx
from repro.distributed.sharding import (batch_spec, cache_spec, param_specs)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, input_specs
from repro.obs.log import get_logger
from repro.train.optimizer import OptConfig
from repro.train.train_loop import (TrainConfig, make_train_step,
                                    train_state_shape)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun.json")

# level-filtered and capturable in tests (repro.obs.log.capture); emits
# the same "[dryrun] ..." lines the bare prints used to
_log = get_logger("dryrun")


def _tree_bytes(tree) -> float:
    return float(sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                     for l in jax.tree_util.tree_leaves(tree)))


def _bf16_params(shape_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype), shape_tree)


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def train_tcfg(arch: str) -> TrainConfig:
    # bf16 moments for the two largest configs (HBM fit — DESIGN.md §7)
    big = arch in ("deepseek-v3-671b", "deepseek-67b")
    return TrainConfig(
        opt=OptConfig(state_dtype="bfloat16" if big else "float32"),
        accum=8, remat="full", grad_dtype="bfloat16" if big else "float32")


# ------------------------------------------------------------------ #
# §Perf hillclimb tunings — applied with --tuned; baselines stay frozen
# under their original keys. Each field is one hypothesis->change from
# EXPERIMENTS.md §Perf.
# ------------------------------------------------------------------ #
class CellTuning:
    def __init__(self, accum=None, cast_bf16=False, no_fsdp=False,
                 embed_tp=False, opt_dtype=None, attn_impl=None,
                 cache_scatter=False, moe_shard_cap=False,
                 grad_dtype=None, dp_all=False, remat="keep",
                 moe_shardmap=False):
        self.accum, self.cast_bf16, self.no_fsdp = accum, cast_bf16, no_fsdp
        self.embed_tp, self.opt_dtype = embed_tp, opt_dtype
        self.attn_impl, self.cache_scatter = attn_impl, cache_scatter
        self.moe_shard_cap, self.grad_dtype = moe_shard_cap, grad_dtype
        self.remat = remat            # "keep" | None | "full" | "dots"
        self.moe_shardmap = moe_shardmap
        # dp_all: batch over EVERY mesh axis, replicated params, TP off —
        # the right layout for models far too small for 256-way TP
        self.dp_all = dp_all


TUNINGS = {
    # worst roofline fraction: tiny model over-sharded -> pure DP over all
    # 256 chips, one microbatch, bf16 grads
    ("whisper-base", "train_4k"): CellTuning(
        accum=1, cast_bf16=True, no_fsdp=True, grad_dtype="bfloat16",
        dp_all=True, remat="dots"),
    # most collective-bound + paper-representative: bf16 gathers, fewer
    # microbatches, data-sharded MoE capacity buffers, int8 moments, banded
    # attention. (embed_tp — d-sharded embedding — was tried and REFUTED: it
    # trips an XLA SPMD dynamic-slice bug on the token gather; see §Perf.)
    # (moe_shard_cap — capacity dim over data axes — was also REFUTED: the
    # dispatch scatter onto a 2-axis-sharded buffer replicates; see §Perf.)
    # (opt_dtype="int8" REFUTED at this scale: the dequant reshape between
    # block layout and the 4D expert layout forces 917 GB whole-tensor
    # re-gathers; a per-shard shard_map quantizer would be needed. §Perf.)
    ("deepseek-v3-671b", "train_4k"): CellTuning(
        accum=4, cast_bf16=True, moe_shardmap=True, grad_dtype="bfloat16"),
    # serving: TP-only weights (no per-token FSDP gather) + scatter cache
    ("deepseek-67b", "decode_32k"): CellTuning(
        no_fsdp=True, cache_scatter=True),
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               tuning: Optional[CellTuning] = None):
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return None, why
    mesh = make_production_mesh(multi_pod=multi_pod)
    api = build_model(cfg)
    specs = input_specs(cfg, shape)
    t = tuning or CellTuning()
    os.environ["REPRO_CACHE_SCATTER"] = "1" if t.cache_scatter else "0"
    os.environ["REPRO_MOE_SHARD_CAP"] = "1" if t.moe_shard_cap else "0"
    os.environ["REPRO_MOE_SHARDMAP"] = "1" if t.moe_shardmap else "0"
    spec_kw = dict(no_fsdp=t.no_fsdp, embed_tp=t.embed_tp)

    loss_fn = api.loss
    prefill_fn_base = api.prefill
    if t.attn_impl and cfg.family not in ("ssm", "cnn") and cfg.rwkv is None:
        import functools
        loss_fn = functools.partial(api.loss, attn_impl=t.attn_impl)
        prefill_fn_base = functools.partial(api.prefill, attn_impl=t.attn_impl)

    rules = None
    dp_axes = None
    if t.dp_all:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_axes = tuple(mesh.axis_names)
        while dp_axes and shape.global_batch % \
                int(np.prod([sizes[a] for a in dp_axes])):
            dp_axes = dp_axes[:-1]       # drop trailing axes until divisible
        rules = {"batch": dp_axes, "heads": None, "kv_heads": None,
                 "ff": None, "vocab": None, "experts": None}

    with shard_ctx.use_sharding(mesh, rules=rules):
        if shape.kind == "train":
            tcfg = train_tcfg(arch)
            import dataclasses as _dc
            if t.accum is not None:
                tcfg = _dc.replace(tcfg, accum=t.accum)
            if t.cast_bf16:
                tcfg = _dc.replace(tcfg, cast_params_bf16=True)
            if t.grad_dtype:
                tcfg = _dc.replace(tcfg, grad_dtype=t.grad_dtype)
            if t.opt_dtype:
                tcfg = _dc.replace(tcfg, opt=_dc.replace(
                    tcfg.opt, state_dtype=t.opt_dtype))
            if t.remat != "keep":
                tcfg = _dc.replace(tcfg, remat=t.remat)
            state_shape = train_state_shape(api.init, tcfg)
            if t.dp_all:
                spec_kw2 = dict(spec_kw)
                spec_kw2["no_fsdp"] = True
                state_specs = jax.tree_util.tree_map(
                    lambda _: jax.sharding.PartitionSpec(),
                    param_specs(mesh, state_shape, **spec_kw2),
                    is_leaf=lambda x: isinstance(x, P))
            else:
                state_specs = param_specs(mesh, state_shape, **spec_kw)
            b_specs = batch_spec(mesh, specs["batch"],
                                 dp_axes=dp_axes if t.dp_all else None)
            step = make_train_step(loss_fn, tcfg)
            fn = jax.jit(step,
                         in_shardings=(_ns(mesh, state_specs),
                                       _ns(mesh, b_specs)),
                         out_shardings=(_ns(mesh, state_specs), None),
                         donate_argnums=0)
            lowered = fn.lower(state_shape, specs["batch"])
            traffic = analytic_traffic(
                cfg, shape,
                params_bytes=_tree_bytes(state_shape["params"]),
                opt_bytes=_tree_bytes(state_shape["opt"]["m"]) +
                _tree_bytes(state_shape["opt"]["v"]),
                accum=tcfg.accum, remat=tcfg.remat is not None)
        elif shape.kind == "prefill":
            params_shape = _bf16_params(jax.eval_shape(
                lambda: api.init(jax.random.PRNGKey(0))))
            p_specs = param_specs(mesh, params_shape, **spec_kw)
            b_specs = batch_spec(mesh, specs["batch"])

            def prefill_fn(params, batch):
                kw = {}
                if "frames" in batch:
                    kw["frames"] = batch["frames"]
                return prefill_fn_base(params, batch["tokens"],
                                       shape.seq_len, **kw)

            fn = jax.jit(prefill_fn,
                         in_shardings=(_ns(mesh, p_specs),
                                       _ns(mesh, b_specs)))
            lowered = fn.lower(params_shape, specs["batch"])
            cache_shape = jax.eval_shape(
                lambda: api.init_cache(shape.global_batch, shape.seq_len))
            traffic = analytic_traffic(
                cfg, shape, params_bytes=_tree_bytes(params_shape),
                cache_bytes=_tree_bytes(cache_shape))
        else:  # decode
            params_shape = _bf16_params(jax.eval_shape(
                lambda: api.init(jax.random.PRNGKey(0))))
            p_specs = param_specs(mesh, params_shape, **spec_kw)
            c_specs = cache_spec(mesh, specs["cache"])
            t_spec = batch_spec(mesh, {"t": specs["token"]})["t"]

            def decode_fn(params, cache, token):
                return api.decode_step(params, cache, token)

            fn = jax.jit(decode_fn,
                         in_shardings=(_ns(mesh, p_specs),
                                       _ns(mesh, c_specs),
                                       NamedSharding(mesh, t_spec)),
                         out_shardings=(None, _ns(mesh, c_specs)),
                         donate_argnums=1)
            lowered = fn.lower(params_shape, specs["cache"], specs["token"])
            cache_traffic_scale = 1.0 if t.cache_scatter else 2.0
            traffic = analytic_traffic(
                cfg, shape, params_bytes=_tree_bytes(params_shape),
                cache_bytes=_tree_bytes(specs["cache"]) *
                cache_traffic_scale / 2.0)
    return lowered, "", traffic


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, tuned: bool = False) -> Dict[str, Any]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256
    key = f"{arch}|{shape_name}|{mesh_name}" + ("|tuned" if tuned else "")
    tuning = TUNINGS.get((arch, shape_name)) if tuned else None
    if tuned and tuning is None:
        return {"key": key, "status": "skipped", "note": "no tuning defined"}
    t0 = time.time()
    try:
        out = lower_cell(arch, shape_name, multi_pod, tuning=tuning)
        if out[0] is None:
            rec = {"key": key, "status": "skipped", "note": out[1]}
            if verbose:
                _log.info(f"SKIP {key}: {out[1]}")
            return rec
        lowered, note, traffic = out
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        from repro.analysis.hlo_costs import cost_analysis_dict
        cost = cost_analysis_dict(compiled)
        _log.info(f"{key} memory_analysis: {mem}")
        _log.info(f"{key} cost_analysis: "
                  f"flops={cost.get('flops')} bytes={cost.get('bytes accessed')}")
        hlo = compiled.as_text()
        cfg = get_config(arch)
        shape = SHAPE_BY_NAME[shape_name]
        rep = build_report(arch=arch, shape=shape_name, mesh_name=mesh_name,
                           chips=chips, cost=cost, mem=mem, hlo_text=hlo,
                           model_flops=model_flops(cfg, shape),
                           traffic=traffic, note=note)
        rec = {"key": key, "status": "ok", "lower_s": round(t_lower, 1),
               "compile_s": round(t_compile, 1), **rep.to_json()}
        if verbose:
            _log.info(f"OK {key} compute={rep.compute_s:.3e}s "
                      f"mem={rep.memory_s:.3e}s coll={rep.collective_s:.3e}s "
                      f"dominant={rep.dominant} hbm={rep.hbm_total_gib:.1f}GiB "
                      f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        return rec
    except Exception as e:                                     # noqa: BLE001
        traceback.print_exc()
        return {"key": key, "status": "error", "error": f"{type(e).__name__}: {e}"}


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, res: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="apply the §Perf hillclimb tunings (separate keys)")
    ap.add_argument("--out", default=os.path.abspath(RESULTS))
    args = ap.parse_args()

    meshes = []
    if args.multi_pod or not args.single_pod:
        meshes.append(True)
    if args.single_pod or not args.multi_pod:
        meshes.append(False)
    meshes = sorted(set(meshes))        # False (single) first

    archs = [args.arch] if args.arch else sorted(ASSIGNED)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]

    res = load_results(args.out)
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                key = f"{arch}|{shape_name}|" + \
                    ("pod2x16x16" if mp else "pod16x16") + \
                    ("|tuned" if args.tuned else "")
                if args.tuned and (arch, shape_name) not in TUNINGS:
                    continue
                if not args.force and res.get(key, {}).get("status") == "ok":
                    _log.info(f"cached {key}")
                    continue
                rec = run_cell(arch, shape_name, mp, tuned=args.tuned)
                res[key] = rec
                save_results(args.out, res)
    n_ok = sum(1 for r in res.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in res.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in res.values() if r.get("status") == "error")
    _log.info(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
