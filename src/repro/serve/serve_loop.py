"""Batched serving: prefill + decode steps with KV-cache management.

``make_serve_step`` builds the jitted single-token decode used by the serve
dry-run cells; ``ServeSession`` drives batched requests end-to-end for the
CPU examples and integration tests, two ways:

  * **closed-loop** — ``generate``/``replay_trace``: requests served back
    to back through fixed-slot continuous batching (tiny vLLM-style front
    end). Ragged prompts pad to the chunk max and mask (the transformer
    prefill takes ``prompt_lens``; recurrent families, whose state has no
    pad mask, split into equal-length sub-batches).
  * **open-loop** — ``serve_open_loop`` (DESIGN.md §14): a request queue
    keyed by trace arrival timestamps, admission into the running decode
    batch at bucket boundaries (the evaluators' ``bucket_sizes`` pad-up
    rule), and a virtual clock charging ``prefill_cycles`` per admission
    prefill and ``step_cycles`` per decode step per live group. The
    returned ``ServeReport`` carries per-request queueing/latency arrays
    comparable to ``SimReport``'s.
"""
from __future__ import annotations

import inspect
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ModelAPI
from repro.obs.trace import get_tracer
from repro.sim.trace import bucket_sizes

# decode-length buckets every serving layer shares (each a multiple of the
# smallest — the admission quantum), mirroring the evaluators' compiled
# batch shapes
DEFAULT_BUCKETS = (8, 16, 32, 64)


def _norm_step_schedule(step_schedule):
    """Normalize degradation breakpoints to sorted parallel lists
    ``(times, scales)``; scale is the rung's relative decode-step cost
    (1.0 = the base operating point). Shared by ``serve_open_loop`` and
    its timing twin ``fleet.open_loop_schedule``."""
    if not step_schedule:
        return [], []
    rows = sorted((float(bt), float(bs)) for bt, bs in step_schedule)
    if any(bs <= 0 for _, bs in rows):
        raise ValueError("step_schedule scales must be positive")
    return [bt for bt, _ in rows], [bs for _, bs in rows]


def make_serve_step(api: ModelAPI) -> Callable:
    """(params, cache, token (B,1)) -> (logits (B,1,V), cache)."""
    def serve_step(params, cache, token):
        return api.decode_step(params, cache, token)
    return serve_step


def make_prefill(api: ModelAPI, S_max: int) -> Callable:
    def prefill(params, tokens, **kw):
        return api.prefill(params, tokens, S_max, **kw)
    return prefill


@dataclass
class Request:
    """One serving request. ``arrival`` is the trace timestamp (cycles;
    0 for closed-loop use) and ``out`` collects the generated tokens —
    filled in place by ``generate``/``replay_trace``/``serve_open_loop``
    so callers get per-request outputs without positional bookkeeping.
    ``deadline`` is an absolute cycle timestamp: a request whose
    admission round opens after its deadline is *shed* (counted in
    ``ServeReport.shed``) instead of serving arbitrarily-late work."""
    prompt: np.ndarray
    max_new: int = 16
    arrival: float = 0.0
    deadline: float = float("inf")
    out: List[int] = field(default_factory=list)


def requests_from_trace(trace, *, vocab_size: int, prompt_len: int = 8,
                        seed: int = 0) -> List[Request]:
    """Materialize a simulator ``Trace`` (``repro.sim.trace``) into
    ``ServeSession`` requests: one request per trace entry, decoding as
    many new tokens as the entry's sample count and carrying the entry's
    arrival timestamp — the same seeded traffic the deployment simulator
    scores analytically can drive the real serving loop (DESIGN.md §13)."""
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab_size, size=prompt_len),
                    max_new=int(sz), arrival=float(at))
            for at, sz in zip(trace.arrivals, trace.sizes)]


@dataclass
class ServeReport:
    """Per-request accounting of one open-loop serving run. All times are
    virtual-clock cycles, so the arrays line up with ``SimReport``'s:
    ``latency = completions - arrivals`` and ``queue_wait = admissions -
    arrivals`` (time spent waiting for a batch slot). Shed requests
    (deadline passed before their admission round) carry
    ``completions = inf`` and are excluded from the latency percentiles;
    ``admissions == completions + shed`` by construction."""
    arrivals: np.ndarray          # (N,)
    admissions: np.ndarray        # (N,) prefill joined the running batch
    completions: np.ndarray       # (N,) bucket boundary the request left at
    latency: np.ndarray           # (N,) completions - arrivals
    queue_wait: np.ndarray        # (N,) admissions - arrivals
    outputs: List[List[int]]
    decode_steps: int = 0         # model decode calls issued
    prefills: int = 0             # admission prefill calls issued
    shed_mask: np.ndarray = None  # (N,) True = dropped at its deadline
    switch_stalls: int = 0        # degradation rung switches charged

    def __post_init__(self):
        if self.shed_mask is None:
            self.shed_mask = np.zeros(len(self.arrivals), dtype=bool)

    @property
    def completed(self) -> int:
        return int((~self.shed_mask).sum())

    @property
    def shed(self) -> int:
        return int(self.shed_mask.sum())

    @property
    def horizon(self) -> float:
        served = self.completions[~self.shed_mask]
        return float(served.max()) if len(served) else 0.0

    def latency_percentile(self, quantile: float) -> float:
        lat = self.latency[~self.shed_mask]
        if len(lat) == 0:
            raise ValueError(
                "latency_percentile on a report with zero completions")
        return float(np.percentile(lat, quantile))

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)


class ServeSession:
    """Fixed-slot continuous batching (tiny vLLM-style front end)."""

    def __init__(self, api: ModelAPI, params, *, batch_slots: int,
                 S_max: int, temperature: float = 0.0, seed: int = 0):
        self.api, self.params = api, params
        self.B, self.S_max = batch_slots, S_max
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t))
        try:
            sig = inspect.signature(api.prefill)
            self._ragged_ok = "prompt_lens" in sig.parameters
        except (TypeError, ValueError):          # builtins / C callables
            self._ragged_ok = False

    def generate(self, prompts: Sequence, max_new: int = 16,
                 frames: Optional[np.ndarray] = None) -> List[List[int]]:
        """Greedy/temperature generation. Ragged prompts pad to the chunk
        max and mask (see class docstring); ``max_new=0`` emits nothing.
        Entries may be ``Request`` objects — their ``out`` is filled in
        place (``max_new`` still comes from the argument)."""
        reqs = [p if isinstance(p, Request) else None for p in prompts]
        arrs = [np.asarray(p.prompt if isinstance(p, Request) else p)
                for p in prompts]
        outs: List[List[int]] = []
        for i in range(0, len(arrs), self.B):
            kw: Dict[str, Any] = {}
            if frames is not None:
                kw["frames"] = frames[i:i + self.B]
            outs.extend(self._generate_chunk(arrs[i:i + self.B], max_new, kw))
        for r, o in zip(reqs, outs):
            if r is not None:
                r.out[:] = o
        return outs

    def _generate_chunk(self, chunk: List[np.ndarray], max_new: int,
                        kw: Dict[str, Any]) -> List[List[int]]:
        logits, cache, splits = self._prefill_groups(chunk, kw)
        if max_new <= 0:
            return [[] for _ in chunk]
        if splits is not None:               # recurrent ragged fallback
            outs: List[Optional[List[int]]] = [None] * len(chunk)
            for idx, (lg, ch) in splits:
                for j, o in zip(idx, self._decode_tokens(lg, ch, max_new)):
                    outs[j] = o
            return outs
        return self._decode_tokens(logits, cache, max_new)

    def _prefill_groups(self, chunk: List[np.ndarray], kw: Dict[str, Any]):
        """Prefill one batch chunk. Returns (logits, cache, None) for a
        single batched prefill, or (None, None, groups) when a ragged
        chunk on a recurrent family (no pad mask in the state) must run
        as equal-length sub-batches: groups = [(row_idx, (logits, cache))]."""
        lens = [len(p) for p in chunk]
        pad_to = max(lens)
        ragged = min(lens) != pad_to
        if ragged and not self._ragged_ok:
            by_len: Dict[int, List[int]] = {}
            for j, n in enumerate(lens):
                by_len.setdefault(n, []).append(j)
            groups = []
            for n, idx in sorted(by_len.items()):
                sub_kw = dict(kw)
                if "frames" in kw:
                    sub_kw["frames"] = np.asarray(kw["frames"])[idx]
                lg, ch, _ = self._prefill_groups([chunk[j] for j in idx],
                                                 sub_kw)
                groups.append((idx, (lg, ch)))
            return None, None, groups
        toks = np.zeros((len(chunk), pad_to), dtype=np.int32)
        for j, p in enumerate(chunk):
            toks[j, :len(p)] = p
        if ragged:
            kw = dict(kw, prompt_lens=jnp.asarray(lens, jnp.int32))
        logits, cache = self.api.prefill(self.params, jnp.asarray(toks),
                                         self.S_max, **kw)
        return logits, cache, None

    def _decode_tokens(self, logits, cache, max_new: int) -> List[List[int]]:
        cur = self._sample(logits)
        gen = [cur]
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, cur)
            cur = self._sample(logits)
            gen.append(cur)
        seq = np.concatenate([np.asarray(g) for g in gen], axis=1)
        return [list(map(int, row)) for row in seq]

    def replay_trace(self, trace, *, vocab_size: int, prompt_len: int = 8,
                     seed: int = 0,
                     requests: Optional[List[Request]] = None
                     ) -> List[List[int]]:
        """Serve a simulator ``Trace``'s request *mix* closed-loop: the
        trace contributes the request count and per-request decode lengths
        (its size buckets), served back to back. Requests are grouped by
        decode length (ragged lengths would force per-request jit shapes)
        and each group runs through the continuous-batching ``generate``
        loop; outputs return in trace order and land in each request's
        ``out``. Arrival times — burstiness — are NOT replayed: that is
        ``serve_open_loop``'s job; this method shares the workload
        definition so the two score the same requests. Pass ``requests``
        to serve pre-materialized ``Request`` objects instead."""
        reqs = requests if requests is not None else requests_from_trace(
            trace, vocab_size=vocab_size, prompt_len=prompt_len, seed=seed)
        by_len: Dict[int, List[int]] = {}
        for i, r in enumerate(reqs):
            by_len.setdefault(r.max_new, []).append(i)
        outs: List[Optional[List[int]]] = [None] * len(reqs)
        for max_new, idx in sorted(by_len.items()):
            got = self.generate([reqs[i] for i in idx], max_new=max_new)
            for i, o in zip(idx, got):
                outs[i] = o
        return outs

    def serve_open_loop(self, requests: Sequence[Request], *,
                        step_cycles: float, prefill_cycles: float = 0.0,
                        buckets: Sequence[int] = DEFAULT_BUCKETS,
                        step_schedule: Optional[Sequence] = None,
                        switch_cycles: float = 0.0) -> ServeReport:
        """Open-loop continuous batching driven by arrival timestamps.

        Waiting requests are admitted into free batch slots only at
        bucket boundaries: every admission round issues one real prefill
        per admission group, each live group decodes in quanta of the
        smallest bucket, and a row retires (freeing its slot at the
        boundary) once the group has sampled its bucketed decode length
        (``bucket_sizes`` pad-up rule applied to ``max_new``). The
        virtual clock serializes the groups on one executor:
        ``prefill_cycles`` per admission prefill, ``step_cycles`` per
        decode step per group. On a backlogged trace whose ``max_new``
        equals a bucket this issues exactly ``generate``'s model-call
        sequence, so greedy outputs match bit for bit (property-tested).
        ``fleet.open_loop_schedule`` is this method's pure-timing twin —
        keep the two in lockstep.

        A request whose ``deadline`` has passed when its admission round
        opens is *shed* (no prefill, no slot; ``shed_mask`` set,
        ``completions = inf``) — stale work is dropped, not served late.

        ``step_schedule`` is the graceful-degradation hook (DESIGN.md
        §17): sorted ``(t, scale)`` breakpoints after which a decode step
        costs ``scale * step_cycles`` (a sparsity-frontier rung's relative
        step time). Crossing a breakpoint while actively serving charges
        ``switch_cycles`` once — the temporal partition-switch stall; an
        idle executor re-points silently."""
        reqs = list(requests)
        n = len(reqs)
        b = np.sort(np.asarray(list(buckets), dtype=np.int64))
        if len(b) == 0 or b[0] < 1 or np.any(b % b[0] != 0):
            raise ValueError("buckets must be multiples of the smallest "
                             "(the admission quantum)")
        quantum = int(b[0])
        order = sorted(range(n), key=lambda i: reqs[i].arrival)
        quota = np.zeros(n, dtype=np.int64)
        alive = [i for i in range(n) if reqs[i].max_new > 0]
        if alive:
            quota[alive] = bucket_sizes([reqs[i].max_new for i in alive], b)
        arrivals = np.array([r.arrival for r in reqs], dtype=np.float64)
        dl = np.array([r.deadline for r in reqs], dtype=np.float64)
        admissions = np.zeros(n, dtype=np.float64)
        completions = np.zeros(n, dtype=np.float64)
        done = np.zeros(n, dtype=bool)
        shed_mask = np.zeros(n, dtype=bool)
        outputs: List[List[int]] = [[] for _ in range(n)]
        waiting = deque(order)
        groups: List[dict] = []
        free = self.B
        t = 0.0
        decode_steps = prefills = 0
        sc_t, sc_v = _norm_step_schedule(step_schedule)
        si = 0
        eff_step = step_cycles
        switches = 0

        while waiting or groups:
            if not groups and waiting:
                t = max(t, reqs[waiting[0]].arrival)   # executor idles
                while si < len(sc_t) and sc_t[si] <= t:   # silent re-point
                    eff_step = step_cycles * sc_v[si]
                    si += 1
            # admission round: arrived requests into free slots; one real
            # prefill per admission group (ragged chunks may split).
            # Past-deadline requests shed here — before the prefill.
            admit: List[int] = []
            while waiting and free > 0 and reqs[waiting[0]].arrival <= t:
                i = waiting.popleft()
                if t > dl[i]:
                    admissions[i] = t
                    completions[i] = np.inf
                    done[i] = True
                    shed_mask[i] = True
                    continue
                admit.append(i)
                free -= 1
            if admit:
                chunk = [np.asarray(reqs[i].prompt) for i in admit]
                lg, ch, splits = self._prefill_groups(chunk, {})
                grouped = [(admit, (lg, ch))] if splits is None else \
                    [([admit[j] for j in idx], lc) for idx, lc in splits]
                for idx, (logits, cache) in grouped:
                    while si < len(sc_t) and sc_t[si] <= t:  # rung switch
                        eff_step = step_cycles * sc_v[si]
                        si += 1
                        t += switch_cycles
                        switches += 1
                    t += prefill_cycles
                    prefills += 1
                    cur = self._sample(logits)
                    toks = np.asarray(cur)                 # (g, 1)
                    for row, i in enumerate(idx):
                        admissions[i] = t
                        if quota[i] > 0:
                            outputs[i] = [int(toks[row, 0])]
                        else:                  # max_new=0: done at admission
                            completions[i] = t
                            done[i] = True
                            free += 1
                    if any(quota[i] > 0 for i in idx):
                        groups.append({"cache": cache, "cur": cur,
                                       "rows": list(idx), "taken": 1})
            # one decode round: each live group advances to its next bucket
            # boundary (quantum - 1 steps right after a prefill — the
            # prefill logits already produced the first sampled token)
            for g in groups:
                while si < len(sc_t) and sc_t[si] <= t:      # rung switch
                    eff_step = step_cycles * sc_v[si]
                    si += 1
                    t += switch_cycles
                    switches += 1
                cap = int(max(quota[i] for i in g["rows"])) - g["taken"]
                steps = quantum - (g["taken"] % quantum or quantum)
                steps = min(steps or quantum, cap)
                cur, cache = g["cur"], g["cache"]
                for _ in range(steps):
                    logits, cache = self._decode(self.params, cache, cur)
                    cur = self._sample(logits)
                    toks = np.asarray(cur)
                    for row, i in enumerate(g["rows"]):
                        if quota[i] > 0 and len(outputs[i]) < quota[i]:
                            outputs[i].append(int(toks[row, 0]))
                g["cur"], g["cache"] = cur, cache
                g["taken"] += steps
                decode_steps += steps
                t += steps * eff_step
                for i in g["rows"]:
                    if not done[i] and 0 < quota[i] <= g["taken"]:
                        completions[i] = t     # leaves at this boundary
                        done[i] = True
                        free += 1
            groups = [g for g in groups
                      if g["taken"] < max(quota[i] for i in g["rows"])]

        for i, r in enumerate(reqs):
            outputs[i] = outputs[i][:r.max_new]
            r.out[:] = outputs[i]
        # every request is accounted exactly once: served (finite
        # completion) or shed (inf) — admissions == completions + shed
        assert done.all() \
            and np.isfinite(completions[~shed_mask]).all() \
            and np.isinf(completions[shed_mask]).all(), \
            "open-loop accounting broken: admissions != completions + shed"
        tr = get_tracer()
        if tr.enabled:
            # counters accumulated as plain loop locals, published once
            tr.count("serve.runs")
            tr.count("serve.requests", n)
            tr.count("serve.decode_steps", decode_steps)
            tr.count("serve.prefills", prefills)
            tr.count("serve.rung_switches", switches)
            tr.count("serve.shed", int(shed_mask.sum()))
        return ServeReport(arrivals=arrivals, admissions=admissions,
                           completions=completions,
                           latency=completions - arrivals,
                           queue_wait=admissions - arrivals,
                           outputs=outputs, decode_steps=decode_steps,
                           prefills=prefills, shed_mask=shed_mask,
                           switch_stalls=switches)

    def _sample(self, logits) -> jnp.ndarray:
        logits = logits[:, -1]
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(
            k, logits / self.temperature, axis=-1).astype(jnp.int32)[:, None]
