"""Batched serving: prefill + decode steps with KV-cache management.

``make_serve_step`` builds the jitted single-token decode used by the serve
dry-run cells; ``ServeSession`` drives batched requests end-to-end (continuous
batching over a fixed slot count, greedy/temperature sampling) for the CPU
examples and integration tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ModelAPI


def make_serve_step(api: ModelAPI) -> Callable:
    """(params, cache, token (B,1)) -> (logits (B,1,V), cache)."""
    def serve_step(params, cache, token):
        return api.decode_step(params, cache, token)
    return serve_step


def make_prefill(api: ModelAPI, S_max: int) -> Callable:
    def prefill(params, tokens, **kw):
        return api.prefill(params, tokens, S_max, **kw)
    return prefill


@dataclass
class Request:
    prompt: np.ndarray
    max_new: int = 16
    out: List[int] = None


def requests_from_trace(trace, *, vocab_size: int, prompt_len: int = 8,
                        seed: int = 0) -> List[Request]:
    """Materialize a simulator ``Trace`` (``repro.sim.trace``) into
    ``ServeSession`` requests: one request per trace entry, decoding as
    many new tokens as the entry's sample count — the same seeded traffic
    the deployment simulator scores analytically can drive the real
    serving loop (DESIGN.md §13)."""
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab_size, size=prompt_len),
                    max_new=int(sz)) for sz in trace.sizes]


class ServeSession:
    """Fixed-slot continuous batching (tiny vLLM-style front end)."""

    def __init__(self, api: ModelAPI, params, *, batch_slots: int,
                 S_max: int, temperature: float = 0.0, seed: int = 0):
        self.api, self.params = api, params
        self.B, self.S_max = batch_slots, S_max
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(lambda p, c, t: api.decode_step(p, c, t))

    def generate(self, prompts: List[np.ndarray], max_new: int = 16,
                 frames: Optional[np.ndarray] = None) -> List[List[int]]:
        """Greedy/temperature generation for a list of equal-batch prompts.
        Prompts are left-aligned to the same length (synthetic benches use
        equal lengths; ragged batching = pad to max then mask)."""
        outs: List[List[int]] = []
        for i in range(0, len(prompts), self.B):
            chunk = prompts[i:i + self.B]
            pad_to = len(chunk[0])
            toks = np.stack([p[:pad_to] for p in chunk]).astype(np.int32)
            kw = {}
            if frames is not None:
                kw["frames"] = frames[i:i + self.B]
            logits, cache = self.api.prefill(self.params, jnp.asarray(toks),
                                             self.S_max, **kw)
            cur = self._sample(logits)
            gen = [cur]
            for _ in range(max_new - 1):
                logits, cache = self._decode(self.params, cache, cur)
                cur = self._sample(logits)
                gen.append(cur)
            seq = np.concatenate([np.asarray(g) for g in gen], axis=1)
            outs.extend([list(map(int, row)) for row in seq])
        return outs

    def replay_trace(self, trace, *, vocab_size: int, prompt_len: int = 8,
                     seed: int = 0) -> List[List[int]]:
        """Serve a simulator ``Trace``'s request *mix* closed-loop: the
        trace contributes the request count and per-request decode lengths
        (its size buckets), served back to back. Requests are grouped by
        decode length (ragged lengths would force per-request jit shapes)
        and each group runs through the continuous-batching ``generate``
        loop; outputs return in trace order. Arrival times — burstiness —
        are NOT replayed: open-loop admission timing is the deployment
        simulator's job (``repro.sim.engine``); this method shares the
        workload definition so the two score the same requests."""
        reqs = requests_from_trace(trace, vocab_size=vocab_size,
                                   prompt_len=prompt_len, seed=seed)
        by_len: Dict[int, List[int]] = {}
        for i, r in enumerate(reqs):
            by_len.setdefault(r.max_new, []).append(i)
        outs: List[Optional[List[int]]] = [None] * len(reqs)
        for max_new, idx in sorted(by_len.items()):
            got = self.generate([reqs[i].prompt for i in idx],
                                max_new=max_new)
            for i, o in zip(idx, got):
                outs[i] = o
        return outs

    def _sample(self, logits) -> jnp.ndarray:
        logits = logits[:, -1]
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(
            k, logits / self.temperature, axis=-1).astype(jnp.int32)[:, None]
