"""Fleet-scale open-loop serving: N replicas under an autoscaling policy.

The layer above ``ServeSession`` (DESIGN.md §14): a ``Trace``-shaped
request stream is split across up to ``max_replicas`` deployment replicas
by a deterministic online controller, and each replica's timing is scored
with ``open_loop_schedule`` — the *pure-timing twin* of
``ServeSession.serve_open_loop`` (same admission rounds, same bucket
boundaries, same virtual clock; the equality is pinned by a test, so a
simulated fleet schedule replays through the real serve path unchanged).

The controller is intentionally simple and fully seeded-deterministic:

  * **routing** — each arrival goes to the active replica with the least
    estimated outstanding work (JSQ on a work estimate that never peeks
    at exact completion times, so routing stays online/causal);
  * **admission threshold** — arrivals are *held* in a central queue
    while every active replica's estimated depth exceeds
    ``admit_depth``; held requests release at decision boundaries;
  * **autoscaling** — at every ``boundary_cycles`` decision boundary
    (the policy's batch-boundary slack) the controller compares the mean
    estimated backlog per active replica against the scale-up /
    scale-down thresholds and activates (after ``spinup_cycles``) or
    drains replicas between ``min_replicas`` and ``max_replicas``.

``replica_cycles`` integrates active-replica time — the cost axis the
autoscale policy search trades against tail latency
(``repro.sim.slo.autoscale_policy_search``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.serve_loop import DEFAULT_BUCKETS
from repro.sim.trace import Trace, bucket_sizes


def open_loop_schedule(arrivals: Sequence[float], max_new: Sequence[int], *,
                       batch_slots: int, step_cycles: float,
                       prefill_cycles: float = 0.0,
                       buckets: Sequence[int] = DEFAULT_BUCKETS):
    """Pure-timing twin of ``ServeSession.serve_open_loop``: the same
    admission rounds, bucket quanta, and virtual clock, with the model
    calls stripped out (one prefill per admission round — the uniform
    prompt-length case). Returns ``(admissions, completions)`` arrays in
    input order. Keep in lockstep with ``serve_open_loop``; the test
    suite asserts the two produce identical ``ServeReport`` timings."""
    n = len(arrivals)
    arr = np.asarray(arrivals, dtype=np.float64)
    b = np.sort(np.asarray(list(buckets), dtype=np.int64))
    if len(b) == 0 or b[0] < 1 or np.any(b % b[0] != 0):
        raise ValueError("buckets must be multiples of the smallest "
                         "(the admission quantum)")
    quantum = int(b[0])
    mn = np.asarray(max_new, dtype=np.int64)
    quota = np.zeros(n, dtype=np.int64)
    alive = mn > 0
    if alive.any():
        quota[alive] = bucket_sizes(mn[alive], b)
    order = sorted(range(n), key=lambda i: arr[i])
    admissions = np.zeros(n, dtype=np.float64)
    completions = np.zeros(n, dtype=np.float64)
    done = np.zeros(n, dtype=bool)
    waiting = deque(order)
    groups: List[dict] = []
    free = batch_slots
    t = 0.0
    while waiting or groups:
        if not groups and waiting:
            t = max(t, arr[waiting[0]])
        admit: List[int] = []
        while waiting and free > 0 and arr[waiting[0]] <= t:
            admit.append(waiting.popleft())
            free -= 1
        if admit:
            t += prefill_cycles
            for i in admit:
                admissions[i] = t
                if quota[i] == 0:
                    completions[i] = t
                    done[i] = True
                    free += 1
            if any(quota[i] > 0 for i in admit):
                groups.append({"rows": admit, "taken": 1})
        for g in groups:
            cap = int(max(quota[i] for i in g["rows"])) - g["taken"]
            steps = quantum - (g["taken"] % quantum or quantum)
            steps = min(steps or quantum, cap)
            g["taken"] += steps
            t += steps * step_cycles
            for i in g["rows"]:
                if not done[i] and 0 < quota[i] <= g["taken"]:
                    completions[i] = t
                    done[i] = True
                    free += 1
        groups = [g for g in groups
                  if g["taken"] < max(quota[i] for i in g["rows"])]
    return admissions, completions


@dataclass(frozen=True)
class AutoscalePolicy:
    """Knobs of the fleet controller (the autoscale search space).
    Backlog thresholds are estimated queued requests per active replica;
    ``boundary_cycles`` spaces the decision boundaries (batch-boundary
    slack); ``admit_depth`` is the admission threshold — the estimated
    per-replica depth beyond which arrivals wait in the central queue."""
    min_replicas: int = 1
    max_replicas: int = 4
    scale_up_backlog: float = 4.0
    scale_down_backlog: float = 0.5
    boundary_cycles: float = 1e5
    admit_depth: float = 1e9
    spinup_cycles: float = 0.0

    @classmethod
    def static(cls, replicas: int, boundary_cycles: float = 1e5
               ) -> "AutoscalePolicy":
        """A fixed replica count — the baseline the searched policy must
        beat (lower p99, or equal p99 at lower replica-cycles)."""
        return cls(min_replicas=replicas, max_replicas=replicas,
                   boundary_cycles=boundary_cycles)


@dataclass
class FleetReport:
    """What the fleet did with one trace. Per-request arrays are in trace
    order; ``latency`` runs from the original arrival (central-queue hold
    + spinup + per-replica queueing all included), so the percentiles
    compare directly against an ``SLO`` target and against a single
    replica's ``ServeReport``/``SimReport``."""
    arrivals: np.ndarray
    admissions: np.ndarray        # admission into the replica's batch
    completions: np.ndarray
    latency: np.ndarray
    assignment: np.ndarray        # (N,) replica index per request
    routed_at: np.ndarray         # (N,) when routing released the request
    replica_cycles: float         # integral of active replicas over time
    replicas_max: int
    timeline: List[Tuple[float, int]] = field(default_factory=list)

    @property
    def completed(self) -> int:
        return len(self.completions)

    @property
    def horizon(self) -> float:
        return float(self.completions.max()) if self.completed else 0.0

    def latency_percentile(self, quantile: float) -> float:
        return float(np.percentile(self.latency, quantile))

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)


def simulate_fleet(trace: Trace, policy: AutoscalePolicy, *,
                   batch_slots: int, step_cycles: float,
                   prefill_cycles: float = 0.0,
                   buckets: Sequence[int] = DEFAULT_BUCKETS) -> FleetReport:
    """Run ``trace`` through the fleet controller and score every replica
    with the exact open-loop timing model. Trace sizes are the decode
    lengths (``max_new``), as in ``requests_from_trace``."""
    n = len(trace)
    arr = np.asarray(trace.arrivals, dtype=np.float64)
    mn = np.asarray(trace.sizes, dtype=np.int64)
    b = np.sort(np.asarray(list(buckets), dtype=np.int64))
    quota = bucket_sizes(np.maximum(mn, 1), b)
    # online work estimate per request: one batch-amortized service time
    w = (prefill_cycles + quota * step_cycles) / max(batch_slots, 1)
    w_avg = float(w.mean()) if n else 1.0
    R = policy.max_replicas
    ready = np.zeros(R)            # estimated drain time per replica
    start = np.full(R, np.nan)     # current stint's activation time
    segs: List[List[Tuple[float, float]]] = [[] for _ in range(R)]
    avail = np.zeros(R)            # activation + spinup
    active = int(np.clip(policy.min_replicas, 1, R))
    for r in range(active):
        start[r] = 0.0
    assignment = np.full(n, -1, dtype=np.int64)
    routed_at = np.zeros(n)
    held: deque = deque()
    timeline: List[Tuple[float, int]] = [(0.0, active)]
    boundary = float(max(policy.boundary_cycles, 1.0))
    next_b = boundary

    def depth(r: int, t: float) -> float:
        return max(ready[r] - t, 0.0) / w_avg

    def route(i: int, t: float) -> None:
        cands = [r for r in range(active)]
        r = min(cands, key=lambda r: (max(ready[r], t, avail[r]), r))
        eff = max(arr[i], t, avail[r])
        ready[r] = max(ready[r], eff) + w[i]
        assignment[i] = r
        routed_at[i] = eff

    def scale_up(t: float) -> None:
        # reactive: runs at every arrival as well as at boundaries, so a
        # burst onset adds capacity before queueing builds (scale-down
        # stays boundary-gated — that is the hysteresis knob)
        nonlocal active
        per = (sum(depth(r, t) for r in range(active)) + len(held)) / active
        while per > policy.scale_up_backlog and active < R:
            start[active] = t
            avail[active] = t + policy.spinup_cycles
            active += 1
            timeline.append((t, active))
            per = (sum(depth(r, t) for r in range(active)) + len(held)) \
                / active

    def decide(t: float) -> None:
        nonlocal active
        scale_up(t)
        per = (sum(depth(r, t) for r in range(active)) + len(held)) / active
        while (per < policy.scale_down_backlog
               and active > max(policy.min_replicas, 1)
               and ready[active - 1] <= t):
            segs[active - 1].append((start[active - 1], t))
            start[active - 1] = np.nan
            active -= 1
            timeline.append((t, active))
            per = (sum(depth(r, t) for r in range(active)) + len(held)) \
                / active if active else 0.0
        while held and min(depth(r, t) for r in range(active)) \
                < policy.admit_depth:
            route(held.popleft(), t)

    for i in range(n):
        t = arr[i]
        while next_b <= t:
            decide(next_b)
            next_b += boundary
        scale_up(t)
        if held or min(depth(r, t) for r in range(active)) \
                >= policy.admit_depth:
            held.append(i)              # admission threshold: hold centrally
        else:
            route(i, t)
    t = arr[-1] if n else 0.0
    while held:
        next_b = max(next_b, t + boundary)
        decide(next_b)
        t = next_b
        next_b += boundary

    # exact per-replica open-loop timing on the final assignment
    admissions = np.zeros(n)
    completions = np.zeros(n)
    for r in range(R):
        idx = np.flatnonzero(assignment == r)
        if len(idx) == 0:
            continue
        adm, comp = open_loop_schedule(
            routed_at[idx], mn[idx], batch_slots=batch_slots,
            step_cycles=step_cycles, prefill_cycles=prefill_cycles,
            buckets=buckets)
        admissions[idx] = adm
        completions[idx] = comp
    horizon = float(completions.max()) if n else 0.0
    cost = 0.0
    for r in range(R):
        if not np.isnan(start[r]):       # still active: runs to the horizon
            segs[r].append((start[r], horizon))
        if not segs[r]:
            continue
        idx = np.flatnonzero(assignment == r)
        if len(idx):                     # drain past a scheduled stop: the
            s0, s1 = segs[r][-1]         # estimate said drained, exact
            segs[r][-1] = (s0, max(s1, float(completions[idx].max())))
        cost += sum(max(s1 - s0, 0.0) for s0, s1 in segs[r])
    return FleetReport(arrivals=arr, admissions=admissions,
                       completions=completions, latency=completions - arr,
                       assignment=assignment, routed_at=routed_at,
                       replica_cycles=cost,
                       replicas_max=int(max(c for _, c in timeline)),
                       timeline=timeline)
