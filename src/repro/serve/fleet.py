"""Fleet-scale open-loop serving: N replicas under an autoscaling policy.

The layer above ``ServeSession`` (DESIGN.md §14): a ``Trace``-shaped
request stream is split across up to ``max_replicas`` deployment replicas
by a deterministic online controller, and each replica's timing is scored
with ``open_loop_schedule`` — the *pure-timing twin* of
``ServeSession.serve_open_loop`` (same admission rounds, same bucket
boundaries, same virtual clock; the equality is pinned by a test, so a
simulated fleet schedule replays through the real serve path unchanged).

The controller is intentionally simple and fully seeded-deterministic:

  * **routing** — each arrival goes to the active replica with the least
    estimated outstanding work (JSQ on a work estimate that never peeks
    at exact completion times, so routing stays online/causal);
  * **admission threshold** — arrivals are *held* in a central queue
    while every active replica's estimated depth exceeds
    ``admit_depth``; held requests release at decision boundaries;
  * **autoscaling** — at every ``boundary_cycles`` decision boundary
    (the policy's batch-boundary slack) the controller compares the mean
    estimated backlog per active replica against the scale-up /
    scale-down thresholds and activates (after ``spinup_cycles``) or
    drains replicas between ``min_replicas`` and ``max_replicas``.

``replica_cycles`` integrates active-replica time — the cost axis the
autoscale policy search trades against tail latency
(``repro.sim.slo.autoscale_policy_search``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import get_tracer
from repro.serve.serve_loop import DEFAULT_BUCKETS, _norm_step_schedule
from repro.sim.faults import NEVER, FaultTrace
from repro.sim.trace import Trace, bucket_sizes


def _publish_fleet_obs(n: int, timeline, shed_mask=None, retries=None,
                       rung_tl=None) -> None:
    """End-of-run counter publication (DESIGN.md §18): everything here is
    derived from state the simulation already built, so the fleet loops
    carry zero per-event instrumentation cost in either tracer state."""
    tr = get_tracer()
    if not tr.enabled:
        return
    tr.count("fleet.runs")
    tr.count("fleet.requests", n)
    tr.count("fleet.scale_events", max(len(timeline) - 1, 0))
    if shed_mask is not None:
        tr.count("fleet.shed", int(shed_mask.sum()))
    if retries is not None:
        tr.count("fleet.retries", int(retries.sum()))
    if rung_tl is not None:
        tr.count("fleet.rung_transitions", max(len(rung_tl) - 1, 0))


def open_loop_schedule(arrivals: Sequence[float], max_new: Sequence[int], *,
                       batch_slots: int, step_cycles: float,
                       prefill_cycles: float = 0.0,
                       buckets: Sequence[int] = DEFAULT_BUCKETS,
                       deadlines: Optional[Sequence[float]] = None,
                       step_schedule: Optional[Sequence] = None,
                       switch_cycles: float = 0.0):
    """Pure-timing twin of ``ServeSession.serve_open_loop``: the same
    admission rounds, bucket quanta, and virtual clock, with the model
    calls stripped out (one prefill per admission round — the uniform
    prompt-length case). Returns ``(admissions, completions)`` arrays in
    input order. Keep in lockstep with ``serve_open_loop``; the test
    suite asserts the two produce identical ``ServeReport`` timings.

    ``deadlines`` (absolute cycles) sheds a request whose admission round
    opens past its deadline: its completion is ``inf`` and its admission
    records the shed time. ``step_schedule``/``switch_cycles`` are the
    degradation hook — sorted ``(t, scale)`` rung breakpoints scaling the
    decode-step cost, a partition-switch stall charged per breakpoint
    crossed while actively serving (idle crossings re-point silently) —
    mirroring ``serve_open_loop`` exactly (DESIGN.md §17)."""
    n = len(arrivals)
    arr = np.asarray(arrivals, dtype=np.float64)
    if batch_slots < 1:
        raise ValueError("batch_slots must be >= 1")
    b = np.sort(np.asarray(list(buckets), dtype=np.int64))
    if len(b) == 0 or b[0] < 1 or np.any(b % b[0] != 0):
        raise ValueError("buckets must be multiples of the smallest "
                         "(the admission quantum)")
    quantum = int(b[0])
    mn = np.asarray(max_new, dtype=np.int64)
    dl = (np.full(n, np.inf) if deadlines is None
          else np.asarray(deadlines, dtype=np.float64))
    quota = np.zeros(n, dtype=np.int64)
    alive = mn > 0
    if alive.any():
        quota[alive] = bucket_sizes(mn[alive], b)
    order = sorted(range(n), key=lambda i: arr[i])
    admissions = np.zeros(n, dtype=np.float64)
    completions = np.zeros(n, dtype=np.float64)
    done = np.zeros(n, dtype=bool)
    waiting = deque(order)
    groups: List[dict] = []
    free = batch_slots
    t = 0.0
    sc_t, sc_v = _norm_step_schedule(step_schedule)
    si = 0
    eff_step = step_cycles
    while waiting or groups:
        if not groups and waiting:
            t = max(t, arr[waiting[0]])
            while si < len(sc_t) and sc_t[si] <= t:       # silent re-point
                eff_step = step_cycles * sc_v[si]
                si += 1
        admit: List[int] = []
        while waiting and free > 0 and arr[waiting[0]] <= t:
            i = waiting.popleft()
            if t > dl[i]:
                admissions[i] = t
                completions[i] = np.inf
                done[i] = True
                continue
            admit.append(i)
            free -= 1
        if admit:
            while si < len(sc_t) and sc_t[si] <= t:          # rung switch
                eff_step = step_cycles * sc_v[si]
                si += 1
                t += switch_cycles
            t += prefill_cycles
            for i in admit:
                admissions[i] = t
                if quota[i] == 0:
                    completions[i] = t
                    done[i] = True
                    free += 1
            if any(quota[i] > 0 for i in admit):
                groups.append({"rows": admit, "taken": 1})
        for g in groups:
            while si < len(sc_t) and sc_t[si] <= t:          # rung switch
                eff_step = step_cycles * sc_v[si]
                si += 1
                t += switch_cycles
            cap = int(max(quota[i] for i in g["rows"])) - g["taken"]
            steps = quantum - (g["taken"] % quantum or quantum)
            steps = min(steps or quantum, cap)
            g["taken"] += steps
            t += steps * eff_step
            for i in g["rows"]:
                if not done[i] and 0 < quota[i] <= g["taken"]:
                    completions[i] = t
                    done[i] = True
                    free += 1
        groups = [g for g in groups
                  if g["taken"] < max(quota[i] for i in g["rows"])]
    return admissions, completions


@dataclass(frozen=True)
class AutoscalePolicy:
    """Knobs of the fleet controller (the autoscale search space).
    Backlog thresholds are estimated queued requests per active replica;
    ``boundary_cycles`` spaces the decision boundaries (batch-boundary
    slack); ``admit_depth`` is the admission threshold — the estimated
    per-replica depth beyond which arrivals wait in the central queue."""
    min_replicas: int = 1
    max_replicas: int = 4
    scale_up_backlog: float = 4.0
    scale_down_backlog: float = 0.5
    boundary_cycles: float = 1e5
    admit_depth: float = 1e9
    spinup_cycles: float = 0.0

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.scale_up_backlog <= 0:
            raise ValueError("scale_up_backlog must be positive")
        if not (0 <= self.scale_down_backlog < self.scale_up_backlog):
            raise ValueError("scale_down_backlog must be in "
                             "[0, scale_up_backlog)")
        if self.boundary_cycles <= 0:
            raise ValueError("boundary_cycles must be positive")
        if self.admit_depth <= 0:
            raise ValueError("admit_depth must be positive")
        if self.spinup_cycles < 0:
            raise ValueError("spinup_cycles must be >= 0")

    @classmethod
    def static(cls, replicas: int, boundary_cycles: float = 1e5
               ) -> "AutoscalePolicy":
        """A fixed replica count — the baseline the searched policy must
        beat (lower p99, or equal p99 at lower replica-cycles)."""
        return cls(min_replicas=replicas, max_replicas=replicas,
                   boundary_cycles=boundary_cycles)


@dataclass(frozen=True)
class RetryPolicy:
    """Failure-recovery knobs of the JSQ dispatcher (DESIGN.md §17).
    A request whose replica crashes mid-flight re-enqueues to the central
    hold queue and re-dispatches after a capped exponential backoff
    (``base * factor**(attempt-1)``, at most ``cap`` cycles); a request
    whose best candidate's estimated start lies more than
    ``timeout_cycles`` in the future is not parked on a hopeless replica
    but backs off the same way. ``max_retries`` re-dispatches later the
    request is *shed* — dropped and accounted, never silently lost."""
    max_retries: int = 2
    backoff_base: float = 1e4
    backoff_factor: float = 2.0
    backoff_cap: float = 1e6
    timeout_cycles: float = float("inf")

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base <= 0:
            raise ValueError("backoff_base must be positive")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1")
        if self.backoff_cap < self.backoff_base:
            raise ValueError("backoff_cap must be >= backoff_base")
        if self.timeout_cycles <= 0:
            raise ValueError("timeout_cycles must be positive")

    def backoff(self, attempt: int) -> float:
        """Re-dispatch delay before the ``attempt``-th retry (1-based)."""
        return min(self.backoff_base
                   * self.backoff_factor ** (attempt - 1),
                   self.backoff_cap)


@dataclass(frozen=True)
class DegradationPolicy:
    """Graceful degradation down the sparsity Pareto frontier (DESIGN.md
    §17). ``ladder`` holds relative decode-step costs per rung —
    ``ladder[0] == 1.0`` is the deployed operating point, deeper rungs
    are sparser/cheaper frontier designs (``core.dse.degradation_ladder``
    derives them from a stored ``ParetoFrontier``). On sustained queue
    growth or replica loss the controller steps one rung down (cheaper),
    on recovery one rung back up, each move separated by
    ``dwell_cycles`` and priced at ``switch_cycles`` — the temporal
    partition-switch stall each replica pays when it crosses the rung
    boundary while serving."""
    ladder: Tuple[float, ...] = (1.0,)
    degrade_backlog: float = 8.0
    recover_backlog: float = 1.0
    dwell_cycles: float = 1e5
    switch_cycles: float = 0.0

    def __post_init__(self):
        lad = tuple(float(v) for v in self.ladder)
        object.__setattr__(self, "ladder", lad)
        if not lad or lad[0] != 1.0:
            raise ValueError("ladder[0] must be 1.0 (the deployed "
                             "operating point)")
        if any(v <= 0 for v in lad):
            raise ValueError("ladder entries must be positive step-cycle "
                             "multipliers")
        if any(b > a for a, b in zip(lad, lad[1:])):
            raise ValueError("ladder must be nonincreasing (deeper rungs "
                             "are cheaper)")
        if not (0 <= self.recover_backlog < self.degrade_backlog):
            raise ValueError("need 0 <= recover_backlog < degrade_backlog")
        if self.dwell_cycles < 0 or self.switch_cycles < 0:
            raise ValueError("dwell_cycles/switch_cycles must be >= 0")


@dataclass
class FleetReport:
    """What the fleet did with one trace. Per-request arrays are in trace
    order; ``latency`` runs from the original arrival (central-queue hold
    + spinup + per-replica queueing all included), so the percentiles
    compare directly against an ``SLO`` target and against a single
    replica's ``ServeReport``/``SimReport``."""
    arrivals: np.ndarray
    admissions: np.ndarray        # admission into the replica's batch
    completions: np.ndarray
    latency: np.ndarray
    assignment: np.ndarray        # (N,) replica index per request
    routed_at: np.ndarray         # (N,) when routing released the request
    replica_cycles: float         # integral of active replicas over time
    replicas_max: int
    timeline: List[Tuple[float, int]] = field(default_factory=list)
    shed_mask: np.ndarray = None  # (N,) True = dropped (deadline/retries)
    retries: np.ndarray = None    # (N,) re-dispatch attempts per request
    rung_timeline: List[Tuple[float, int]] = field(default_factory=list)

    def __post_init__(self):
        if self.shed_mask is None:
            self.shed_mask = np.zeros(len(self.arrivals), dtype=bool)
        if self.retries is None:
            self.retries = np.zeros(len(self.arrivals), dtype=np.int64)

    @property
    def completed(self) -> int:
        return int((~self.shed_mask).sum())

    @property
    def shed(self) -> int:
        return int(self.shed_mask.sum())

    @property
    def horizon(self) -> float:
        served = self.completions[~self.shed_mask]
        return float(served.max()) if len(served) else 0.0

    def latency_percentile(self, quantile: float) -> float:
        lat = self.latency[~self.shed_mask]
        if len(lat) == 0:
            raise ValueError(
                "latency_percentile on a report with zero completions")
        return float(np.percentile(lat, quantile))

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)


def simulate_fleet(trace: Trace, policy: AutoscalePolicy, *,
                   batch_slots: int, step_cycles: float,
                   prefill_cycles: float = 0.0,
                   buckets: Sequence[int] = DEFAULT_BUCKETS,
                   faults: Optional[FaultTrace] = None,
                   retry: Optional[RetryPolicy] = None,
                   degradation: Optional[DegradationPolicy] = None,
                   deadline_cycles: Optional[float] = None) -> FleetReport:
    """Run ``trace`` through the fleet controller and score every replica
    with the exact open-loop timing model. Trace sizes are the decode
    lengths (``max_new``), as in ``requests_from_trace``.

    The chaos extensions (DESIGN.md §17) are all opt-in and leave the
    fault-free path untouched (bit-identity gated in ``chaos_bench``):

      * ``faults`` — a ``FaultTrace`` whose crash rows are replica
        crash/restart windows (unit = replica index). In-flight requests
        on a crashed replica re-enqueue to the central hold queue and
        re-dispatch under ``retry``'s capped exponential backoff;
        retries-exhausted requests are shed, never silently lost.
      * ``retry`` — ``RetryPolicy`` (defaults apply whenever ``faults``
        is given): retry budget, backoff, and the dispatch timeout.
      * ``degradation`` — ``DegradationPolicy``: on sustained backlog or
        replica loss the fleet steps down its sparsity-frontier ladder
        (cheaper decode steps, a switch stall per rung move), stepping
        back up on recovery; the rung schedule prices every replica's
        exact timing via ``open_loop_schedule(step_schedule=...)``.
      * ``deadline_cycles`` — per-request relative deadline: a request
        not admitted within this many cycles of its arrival is shed.
    """
    n = len(trace)
    if n == 0:
        raise ValueError("simulate_fleet needs a non-empty trace")
    if batch_slots < 1:
        raise ValueError("batch_slots must be >= 1")
    if deadline_cycles is not None and deadline_cycles <= 0:
        raise ValueError("deadline_cycles must be positive")
    chaos = ((faults is not None and not faults.empty)
             or retry is not None or degradation is not None
             or deadline_cycles is not None)
    if chaos:
        return _simulate_fleet_chaos(
            trace, policy, batch_slots=batch_slots, step_cycles=step_cycles,
            prefill_cycles=prefill_cycles, buckets=buckets,
            faults=faults if faults is not None else FaultTrace.none(),
            retry=retry if retry is not None else RetryPolicy(),
            degradation=degradation, deadline_cycles=deadline_cycles)
    arr = np.asarray(trace.arrivals, dtype=np.float64)
    mn = np.asarray(trace.sizes, dtype=np.int64)
    b = np.sort(np.asarray(list(buckets), dtype=np.int64))
    quota = bucket_sizes(np.maximum(mn, 1), b)
    # online work estimate per request: one batch-amortized service time
    w = (prefill_cycles + quota * step_cycles) / max(batch_slots, 1)
    w_avg = float(w.mean()) if n else 1.0
    R = policy.max_replicas
    ready = np.zeros(R)            # estimated drain time per replica
    start = np.full(R, np.nan)     # current stint's activation time
    segs: List[List[Tuple[float, float]]] = [[] for _ in range(R)]
    avail = np.zeros(R)            # activation + spinup
    active = int(np.clip(policy.min_replicas, 1, R))
    for r in range(active):
        start[r] = 0.0
    assignment = np.full(n, -1, dtype=np.int64)
    routed_at = np.zeros(n)
    held: deque = deque()
    timeline: List[Tuple[float, int]] = [(0.0, active)]
    boundary = float(max(policy.boundary_cycles, 1.0))
    next_b = boundary

    def depth(r: int, t: float) -> float:
        return max(ready[r] - t, 0.0) / w_avg

    def route(i: int, t: float) -> None:
        cands = [r for r in range(active)]
        r = min(cands, key=lambda r: (max(ready[r], t, avail[r]), r))
        eff = max(arr[i], t, avail[r])
        ready[r] = max(ready[r], eff) + w[i]
        assignment[i] = r
        routed_at[i] = eff

    def scale_up(t: float) -> None:
        # reactive: runs at every arrival as well as at boundaries, so a
        # burst onset adds capacity before queueing builds (scale-down
        # stays boundary-gated — that is the hysteresis knob)
        nonlocal active
        per = (sum(depth(r, t) for r in range(active)) + len(held)) / active
        while per > policy.scale_up_backlog and active < R:
            start[active] = t
            avail[active] = t + policy.spinup_cycles
            active += 1
            timeline.append((t, active))
            per = (sum(depth(r, t) for r in range(active)) + len(held)) \
                / active

    def decide(t: float) -> None:
        nonlocal active
        scale_up(t)
        per = (sum(depth(r, t) for r in range(active)) + len(held)) / active
        while (per < policy.scale_down_backlog
               and active > max(policy.min_replicas, 1)
               and ready[active - 1] <= t):
            segs[active - 1].append((start[active - 1], t))
            start[active - 1] = np.nan
            active -= 1
            timeline.append((t, active))
            per = (sum(depth(r, t) for r in range(active)) + len(held)) \
                / active if active else 0.0
        while held and min(depth(r, t) for r in range(active)) \
                < policy.admit_depth:
            route(held.popleft(), t)

    for i in range(n):
        t = arr[i]
        while next_b <= t:
            decide(next_b)
            next_b += boundary
        scale_up(t)
        if held or min(depth(r, t) for r in range(active)) \
                >= policy.admit_depth:
            held.append(i)              # admission threshold: hold centrally
        else:
            route(i, t)
    t = arr[-1] if n else 0.0
    while held:
        next_b = max(next_b, t + boundary)
        decide(next_b)
        t = next_b
        next_b += boundary

    # exact per-replica open-loop timing on the final assignment
    admissions = np.zeros(n)
    completions = np.zeros(n)
    for r in range(R):
        idx = np.flatnonzero(assignment == r)
        if len(idx) == 0:
            continue
        adm, comp = open_loop_schedule(
            routed_at[idx], mn[idx], batch_slots=batch_slots,
            step_cycles=step_cycles, prefill_cycles=prefill_cycles,
            buckets=buckets)
        admissions[idx] = adm
        completions[idx] = comp
    horizon = float(completions.max()) if n else 0.0
    cost = 0.0
    for r in range(R):
        if not np.isnan(start[r]):       # still active: runs to the horizon
            segs[r].append((start[r], horizon))
        if not segs[r]:
            continue
        idx = np.flatnonzero(assignment == r)
        if len(idx):                     # drain past a scheduled stop: the
            s0, s1 = segs[r][-1]         # estimate said drained, exact
            segs[r][-1] = (s0, max(s1, float(completions[idx].max())))
        cost += sum(max(s1 - s0, 0.0) for s0, s1 in segs[r])
    _publish_fleet_obs(n, timeline)
    return FleetReport(arrivals=arr, admissions=admissions,
                       completions=completions, latency=completions - arr,
                       assignment=assignment, routed_at=routed_at,
                       replica_cycles=cost,
                       replicas_max=int(max(c for _, c in timeline)),
                       timeline=timeline)


def _simulate_fleet_chaos(trace: Trace, policy: AutoscalePolicy, *,
                          batch_slots: int, step_cycles: float,
                          prefill_cycles: float, buckets: Sequence[int],
                          faults: FaultTrace, retry: RetryPolicy,
                          degradation: Optional[DegradationPolicy],
                          deadline_cycles: Optional[float]) -> FleetReport:
    """Fault-injected fleet controller (DESIGN.md §17). Same deterministic
    JSQ/threshold/autoscale machinery as the pristine path, run as one
    merged event stream (arrivals, decision boundaries, replica crashes
    and restarts, retry releases). A replica's serving history splits
    into *epochs* at its crashes: the exact open-loop schedule of the
    epoch's routed requests decides, at crash time, which completed
    before the crash (their clocks are final — later events cannot reach
    back) and which are crash victims that re-enqueue with backoff.
    Conservation is asserted on exit: every request either completes
    (finite clock) or is shed with its retry count accounted."""
    import heapq

    n = len(trace)
    arr = np.asarray(trace.arrivals, dtype=np.float64)
    mn = np.asarray(trace.sizes, dtype=np.int64)
    b = np.sort(np.asarray(list(buckets), dtype=np.int64))
    quota = bucket_sizes(np.maximum(mn, 1), b)
    w = (prefill_cycles + quota * step_cycles) / max(batch_slots, 1)
    w_avg = float(w.mean()) if n else 1.0
    dl = (np.full(n, np.inf) if deadline_cycles is None
          else arr + float(deadline_cycles))
    R = policy.max_replicas
    ready = np.zeros(R)
    start = np.full(R, np.nan)
    up = [True] * R
    segs: List[List[Tuple[float, float]]] = [[] for _ in range(R)]
    avail = np.zeros(R)
    active = int(np.clip(policy.min_replicas, 1, R))
    for r in range(active):
        start[r] = 0.0
    assignment = np.full(n, -1, dtype=np.int64)
    routed_at = np.zeros(n)
    admissions = np.zeros(n)
    completions = np.zeros(n)
    final = np.zeros(n, dtype=bool)       # clock recorded, never revisited
    shed_mask = np.zeros(n, dtype=bool)
    retries = np.zeros(n, dtype=np.int64)
    ep_idx: List[List[int]] = [[] for _ in range(R)]   # current epoch
    ep_rt: List[List[float]] = [[] for _ in range(R)]
    held: deque = deque()
    timeline: List[Tuple[float, int]] = [(0.0, active)]
    boundary = float(max(policy.boundary_cycles, 1.0))
    next_b = boundary

    ladder = degradation.ladder if degradation is not None else (1.0,)
    rung = 0
    rung_tl: List[Tuple[float, int]] = [(0.0, 0)]
    bps: List[Tuple[float, float]] = []   # (t, scale) rung breakpoints
    last_move = 0.0
    sw_cycles = degradation.switch_cycles if degradation is not None else 0.0

    def sched_kw(at_bps):
        return dict(batch_slots=batch_slots, step_cycles=step_cycles,
                    prefill_cycles=prefill_cycles, buckets=buckets,
                    step_schedule=list(at_bps) or None,
                    switch_cycles=sw_cycles)

    def shed(i: int, t: float) -> None:
        shed_mask[i] = True
        admissions[i] = t
        completions[i] = np.inf
        final[i] = True

    def depth(r: int, t: float) -> float:
        return max(ready[r] - t, 0.0) / w_avg

    def cands(t: float) -> List[int]:
        return [r for r in range(active) if up[r]]

    def route(i: int, t: float) -> bool:
        """Dispatch (or re-dispatch) request i. Returns False when the
        dispatch timed out and was pushed to the retry stream instead."""
        cs = cands(t)
        r = min(cs, key=lambda r: (max(ready[r], t, avail[r]), r))
        eff = max(arr[i], t, avail[r])
        if max(ready[r], eff) - max(arr[i], t) > retry.timeout_cycles:
            retries[i] += 1
            if retries[i] > retry.max_retries:
                shed(i, t)
            else:
                heapq.heappush(evq, (t + retry.backoff(int(retries[i])),
                                     2, i, i))
            return False
        ready[r] = max(ready[r], eff) + w[i]
        assignment[i] = r
        routed_at[i] = eff
        ep_idx[r].append(i)
        ep_rt[r].append(eff)
        return True

    def scale_up(t: float) -> None:
        nonlocal active
        per = (sum(depth(r, t) for r in range(active)) + len(held)) / active
        while per > policy.scale_up_backlog and active < R:
            start[active] = t
            avail[active] = max(avail[active],
                                t + policy.spinup_cycles)
            active += 1
            timeline.append((t, active))
            per = (sum(depth(r, t) for r in range(active)) + len(held)) \
                / active

    def move_rung(t: float, to: int) -> None:
        nonlocal rung, last_move
        rung = to
        bps.append((t, ladder[rung]))
        rung_tl.append((t, rung))
        last_move = t

    def degrade_eval(t: float) -> None:
        if degradation is None:
            return
        cs = cands(t)
        per = (sum(depth(r, t) for r in cs) + len(held)) / max(len(cs), 1)
        if t - last_move < degradation.dwell_cycles:
            return
        if ((per > degradation.degrade_backlog or not cs)
                and rung < len(ladder) - 1):
            move_rung(t, rung + 1)
        elif cs and per < degradation.recover_backlog and rung > 0:
            # recovery needs a live candidate: with every replica down the
            # empty backlog is vacuous, not a recovery signal
            move_rung(t, rung - 1)

    def decide(t: float) -> None:
        nonlocal active
        scale_up(t)
        per = (sum(depth(r, t) for r in range(active)) + len(held)) / active
        while (per < policy.scale_down_backlog
               and active > max(policy.min_replicas, 1)
               and ready[active - 1] <= t):
            if not np.isnan(start[active - 1]):
                segs[active - 1].append((start[active - 1], t))
                start[active - 1] = np.nan
            active -= 1
            timeline.append((t, active))
            per = (sum(depth(r, t) for r in range(active)) + len(held)) \
                / active if active else 0.0
        degrade_eval(t)
        while held:
            cs = cands(t)
            if not cs or min(depth(r, t) for r in cs) >= policy.admit_depth:
                break
            route(held.popleft(), t)

    def close_epoch(r: int, t_down: float) -> List[int]:
        """Finalize replica r's epoch at a crash: record the clocks that
        are already in the past, return the crash victims."""
        idx, rts = ep_idx[r], ep_rt[r]
        ep_idx[r], ep_rt[r] = [], []
        if not idx:
            return []
        adm, comp = open_loop_schedule(rts, mn[idx],
                                       deadlines=dl[idx], **sched_kw(bps))
        victims: List[int] = []
        for j, i in enumerate(idx):
            if np.isinf(comp[j]) and adm[j] <= t_down:
                shed(i, adm[j])           # deadline-shed before the crash
            elif comp[j] <= t_down:
                admissions[i] = adm[j]    # completed before the crash
                completions[i] = comp[j]
                final[i] = True
            else:
                victims.append(i)         # in flight or queued at the crash
        return victims

    # merged deterministic event stream: (t, kind, seq, payload) with
    # kind 0=restart, 1=crash, 2=retry release, 3=arrival — restarts
    # resolve before crashes before retries before arrivals at equal t
    evq: List[tuple] = [(arr[i], 3, i, i) for i in range(n)]
    for r in range(R):
        for t0, t1 in faults.down_windows(r):
            evq.append((t0, 1, r, (r, t1)))
            if t1 < NEVER:            # terminal crashes never restart
                evq.append((t1, 0, r, r))
    heapq.heapify(evq)

    def boundaries_quiescent(tb: float) -> bool:
        """True when no boundary decision in [tb, next event) can change
        state: every trigger's argument (replica backlog) is nonincreasing
        between events, so a condition false at ``tb`` stays false — the
        catch-up loop may fast-forward instead of stepping ``boundary`` at
        a time across a long event gap (e.g. a far-future restart)."""
        if held:
            return False
        if active > max(policy.min_replicas, 1):
            return False               # a later boundary may scale down
        if active < R:
            per = sum(depth(r, tb) for r in range(active)) / active
            if per > policy.scale_up_backlog:
                return False
        if degradation is not None:
            cs = cands(tb)
            if not cs:
                return rung >= len(ladder) - 1
            per = sum(depth(r, tb) for r in cs) / len(cs)
            if per > degradation.degrade_backlog and rung < len(ladder) - 1:
                return False
            if rung > 0 and degradation.recover_backlog > 0.0:
                return False           # backlog drains toward recovery
        return True

    t = 0.0
    while evq:
        te, kind, _, x = heapq.heappop(evq)
        while next_b <= te:
            decide(next_b)
            next_b += boundary
            if next_b <= te and boundaries_quiescent(next_b):
                skip = int((te - next_b) // boundary) + 1
                next_b += skip * boundary
        t = te
        if kind == 0:                                  # restart
            r = x
            up[r] = True
            avail[r] = max(avail[r], te)
            ready[r] = max(ready[r], te)
            if r < active and np.isnan(start[r]):
                start[r] = te
            decide(te)
        elif kind == 1:                                # crash
            r, t_up = x
            if not up[r]:
                continue
            up[r] = False
            avail[r] = t_up
            ready[r] = t_up
            victims = close_epoch(r, te)
            if not np.isnan(start[r]):
                segs[r].append((start[r], te))
                start[r] = np.nan
            for i in victims:
                retries[i] += 1
                if retries[i] > retry.max_retries:
                    shed(i, te)
                else:
                    heapq.heappush(
                        evq, (te + retry.backoff(int(retries[i])), 2, i, i))
            if degradation is not None and rung < len(ladder) - 1 \
                    and te - last_move >= degradation.dwell_cycles:
                move_rung(te, rung + 1)                # replica loss
            scale_up(te)
        elif kind == 2:                                # retry release
            i = x
            if final[i]:
                continue
            scale_up(te)
            if held or not cands(te) or \
                    min(depth(r, te) for r in cands(te)) \
                    >= policy.admit_depth:
                held.append(i)
            else:
                route(i, te)
        else:                                          # arrival
            i = x
            scale_up(te)
            if held or not cands(te) or \
                    min(depth(r, te) for r in cands(te)) \
                    >= policy.admit_depth:
                held.append(i)
            else:
                route(i, te)

    # drain the central hold queue (all crash/restart events are past)
    while held:
        if not any(up[r] for r in range(R)):
            while held:                   # dead fleet, nothing will restart
                i = held.popleft()
                retries[i] += 1
                shed(i, t)
            break
        if not cands(t):
            spare = next(r for r in range(active, R) if up[r])
            start[spare] = t
            avail[spare] = max(avail[spare], t + policy.spinup_cycles)
            active = spare + 1
            timeline.append((t, active))
        next_b = max(next_b, t + boundary)
        decide(next_b)
        t = next_b
        next_b += boundary

    # exact timing of every replica's final epoch, full rung schedule
    for r in range(R):
        idx, rts = ep_idx[r], ep_rt[r]
        if not idx:
            continue
        adm, comp = open_loop_schedule(rts, mn[idx],
                                       deadlines=dl[idx], **sched_kw(bps))
        for j, i in enumerate(idx):
            if np.isinf(comp[j]):
                shed(i, adm[j])
            else:
                admissions[i] = adm[j]
                completions[i] = comp[j]
                final[i] = True
    assert final.all() \
        and np.isfinite(completions[~shed_mask]).all() \
        and np.isinf(completions[shed_mask]).all(), \
        "fleet conservation broken: a request is neither completed nor shed"

    served = completions[~shed_mask]
    horizon = float(served.max()) if len(served) else t
    cost = 0.0
    for r in range(R):
        if not np.isnan(start[r]):       # still active: runs to the horizon
            segs[r].append((start[r], horizon))
        if not segs[r]:
            continue
        if ep_idx[r]:                    # drain past a scheduled stop
            fin = [completions[i] for i in ep_idx[r] if not shed_mask[i]]
            if fin:
                s0, s1 = segs[r][-1]
                segs[r][-1] = (s0, max(s1, float(max(fin))))
        cost += sum(max(s1 - s0, 0.0) for s0, s1 in segs[r])
    _publish_fleet_obs(n, timeline, shed_mask=shed_mask, retries=retries,
                       rung_tl=rung_tl)
    return FleetReport(arrivals=arr, admissions=admissions,
                       completions=completions, latency=completions - arr,
                       assignment=assignment, routed_at=routed_at,
                       replica_cycles=cost,
                       replicas_max=int(max(c for _, c in timeline)),
                       timeline=timeline, shed_mask=shed_mask,
                       retries=retries, rung_timeline=rung_tl)
