"""Whisper-base — encoder-decoder ASR backbone; conv frontend stubbed.

[arXiv:2212.04356; unverified]  6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865. ``input_specs()`` provides precomputed frame embeddings
(B, 1500, d_model) — the mel+conv frontend is a stub per the assignment.
Full attention enc-dec => long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    enc_layers=6,
    num_frames=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    tied_embeddings=True,
)
