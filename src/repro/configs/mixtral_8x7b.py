"""Mixtral-8x7B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA window 4096 => sub-quadratic; runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attn_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=14336, capacity_factor=1.25),
    tied_embeddings=False,
    rope_theta=1e6,
)
