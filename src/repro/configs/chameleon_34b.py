"""Chameleon-34B — early-fusion VLM over VQ image tokens.

[arXiv:2405.09818; unverified]  48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 (text + VQ-VAE image codes in one vocabulary). qk-norm per paper.
The modality frontend (VQ tokenizer) is a STUB: ``input_specs()`` provides the
precomputed token ids — for early fusion the VQ codes *are* vocabulary entries,
so the backbone input is an ordinary token stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,              # chameleon stabilizes early fusion with qk-norm
    tied_embeddings=False,
)
