"""RWKV6 "Finch" 1.6B — attention-free linear RNN with data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
O(1) per-token state => runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # d_model / rwkv.head_dim
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    act="relu2",               # rwkv channel-mix uses squared relu
    tied_embeddings=False,
)
