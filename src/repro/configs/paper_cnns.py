"""The paper's own benchmark models (Table II): ResNet-18/50, MobileNetV2/V3.

These power the paper-faithful benchmarks (Table II / Fig. 1 / 4 / 5 / 6
analogues). They are *additional* to the ten assigned LM architectures.
"""
from repro.configs.base import ModelConfig


def _cnn(name: str, arch: str) -> ModelConfig:
    return ModelConfig(name=name, family="cnn", cnn_arch=arch,
                       img_res=224, num_classes=1000, dtype="bfloat16")


RESNET18 = _cnn("resnet18", "resnet18")
RESNET50 = _cnn("resnet50", "resnet50")
MOBILENETV2 = _cnn("mobilenetv2", "mobilenetv2")
MOBILENETV3S = _cnn("mobilenetv3s", "mobilenetv3s")
MOBILENETV3L = _cnn("mobilenetv3l", "mobilenetv3l")

PAPER_CNNS = (RESNET18, RESNET50, MOBILENETV2, MOBILENETV3S, MOBILENETV3L)
