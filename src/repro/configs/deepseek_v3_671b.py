"""DeepSeek-V3 671B — MLA + 256-expert top-8 MoE + shared expert + MTP.

[arXiv:2412.19437; hf]  61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
Assigned config keeps every layer MoE (the public model has 3 leading dense
layers; the assigned spec lists a uniform MoE stack, which we follow).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: latent-compressed; logical head count
    head_dim=128,
    d_ff=2048,                 # routed-expert hidden dim
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                  expert_d_ff=2048, capacity_factor=1.25),
    mtp_depth=1,
    tied_embeddings=False,
    rope_theta=10000.0,
)
