"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig, RWKVConfig,
                                ShapeConfig, SSMConfig, SHAPES, SHAPE_BY_NAME,
                                SMOKE_SHAPES, cell_supported, reduce_config)

from repro.configs.deepseek_v3_671b import CONFIG as _deepseek_v3_671b
from repro.configs.mixtral_8x7b import CONFIG as _mixtral_8x7b
from repro.configs.qwen3_0_6b import CONFIG as _qwen3_0_6b
from repro.configs.stablelm_12b import CONFIG as _stablelm_12b
from repro.configs.qwen2_5_3b import CONFIG as _qwen2_5_3b
from repro.configs.deepseek_67b import CONFIG as _deepseek_67b
from repro.configs.chameleon_34b import CONFIG as _chameleon_34b
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6_1_6b
from repro.configs.whisper_base import CONFIG as _whisper_base
from repro.configs.zamba2_1_2b import CONFIG as _zamba2_1_2b
from repro.configs.paper_cnns import PAPER_CNNS

ASSIGNED: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _deepseek_v3_671b, _mixtral_8x7b, _qwen3_0_6b, _stablelm_12b,
        _qwen2_5_3b, _deepseek_67b, _chameleon_34b, _rwkv6_1_6b,
        _whisper_base, _zamba2_1_2b,
    )
}
REGISTRY: Dict[str, ModelConfig] = dict(ASSIGNED)
REGISTRY.update({c.name: c for c in PAPER_CNNS})


def _canon(name: str) -> str:
    """Registry keys use hyphens/dots ("deepseek-v3-671b", "qwen3-0.6b");
    CLI flags and module names use underscores ("deepseek_v3_671b").
    Canonicalize to bare alphanumerics so both spellings resolve."""
    return "".join(ch for ch in name.lower() if ch.isalnum())


def get_config(name: str) -> ModelConfig:
    if name in REGISTRY:
        return REGISTRY[name]
    by_canon = {_canon(k): v for k, v in REGISTRY.items()}
    if _canon(name) in by_canon:
        return by_canon[_canon(name)]
    raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")


def list_archs(assigned_only: bool = False) -> List[str]:
    return sorted(ASSIGNED if assigned_only else REGISTRY)


__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
    "ShapeConfig", "SHAPES", "SHAPE_BY_NAME", "SMOKE_SHAPES",
    "cell_supported", "reduce_config", "get_config", "list_archs",
    "ASSIGNED", "REGISTRY", "PAPER_CNNS",
]
