"""Zamba2-1.2B — Mamba2 backbone + one shared (weight-tied) attention block.

[arXiv:2411.15242; hf]  38L d_model=2048 32H (kv=32, MHA) d_ff=8192
ssm_state=64 vocab=32000. The shared transformer block is applied every 6
mamba layers (weight-tied across call sites). Hybrid => runs long_500k; the
shared-attention KV cache is windowed at 4096 for that cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,             # mamba2 layers
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_dim=4, chunk=64),
    hybrid_attn_every=6,
    tied_embeddings=True,
)
