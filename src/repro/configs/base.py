"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``ShapeConfig``. Configs are plain frozen dataclasses so they hash, compare and
serialize trivially (the checkpoint manager stores them as JSON).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0          # DeepSeek-style always-on experts
    expert_d_ff: int = 0                 # per-expert hidden dim (0 -> use d_ff)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2-style SSD block."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_dim: int = 4
    chunk: int = 64                      # chunked-scan length for training


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64                 # rank of the data-dependent decay MLP
    mix_lora: int = 32                   # rank of the token-shift mix MLP


@dataclass(frozen=True)
class ModelConfig:
    name: str = "unnamed"
    family: str = "dense"                # dense | moe | ssm | hybrid | audio | vlm | cnn
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0                    # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 256
    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: int = 0                 # 0 = full attention; >0 = sliding window (SWA)
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # state-space / rwkv
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (zamba2): one *shared* attention block applied every k ssm layers
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    num_frames: int = 0                  # encoder sequence length (precomputed frames)
    # multi-token prediction (deepseek-v3)
    mtp_depth: int = 0
    # misc
    tied_embeddings: bool = True
    norm_eps: float = 1e-5
    act: str = "silu"                    # silu | gelu | relu2 (rwkv)
    dtype: str = "bfloat16"
    # CNN-only (paper's own benchmark models)
    cnn_arch: str = ""                   # resnet18 | resnet50 | mobilenetv2 | mobilenetv3s | mobilenetv3l
    img_res: int = 224
    num_classes: int = 1000

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def is_encoder_decoder(self) -> bool:
        return self.enc_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode is feasible: O(1)/O(W) per-token state."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_window > 0      # SWA bounds the KV cache

    @property
    def has_decode(self) -> bool:
        return self.family != "cnn"      # all assigned archs autoregress (whisper: decoder side)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1, sort_keys=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


# The four assigned LM shape cells.
SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)
SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; reason when skipped."""
    if cfg.family == "cnn":
        return (shape.kind == "train", "CNNs: train-style shapes only")
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (False, "full quadratic attention: 500k KV cache/attn infeasible; "
                       "skipped per DESIGN.md (sub-quadratic archs only)")
    return (True, "")


# ---------------------------------------------------------------------- #
# Reduced ("smoke") configs: same family/topology, tiny dims. Used by the
# per-arch smoke tests and CPU examples; the full configs are exercised only
# through the dry-run (ShapeDtypeStruct, no allocation).
# ---------------------------------------------------------------------- #
def reduce_config(cfg: ModelConfig) -> ModelConfig:
    def _shrink(v, lo, hi):
        return max(lo, min(v, hi))

    kw = {}
    kw["num_layers"] = _shrink(cfg.num_layers, 2, 3 if cfg.hybrid_attn_every else 2)
    kw["d_model"] = 64
    kw["num_heads"] = 4
    kw["num_kv_heads"] = max(1, min(cfg.num_kv_heads, 2)) if cfg.num_kv_heads < cfg.num_heads else 4
    kw["head_dim"] = 16
    kw["d_ff"] = 128
    kw["vocab_size"] = 503              # prime-ish: catches padding bugs
    kw["num_frames"] = 12 if cfg.num_frames else 0
    kw["enc_layers"] = 2 if cfg.enc_layers else 0
    kw["attn_window"] = 8 if cfg.attn_window else 0
    kw["mtp_depth"] = cfg.mtp_depth
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2, expert_d_ff=32)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=8, head_dim=8, expand=2, conv_dim=4, chunk=8)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8, mix_lora=4)
    if cfg.hybrid_attn_every:
        kw["hybrid_attn_every"] = 2
        kw["num_layers"] = 5
    if cfg.family == "cnn":
        kw = {"img_res": 32, "num_classes": 11}
    return dataclasses.replace(cfg, **kw)


SMOKE_SHAPES = {
    "train": ShapeConfig("smoke_train", 32, 4, "train"),
    "prefill": ShapeConfig("smoke_prefill", 32, 2, "prefill"),
    "decode": ShapeConfig("smoke_decode", 48, 2, "decode"),
}
