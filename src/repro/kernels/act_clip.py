"""Fused activation clip + zero-count Pallas kernel — the SPE clip unit.

One VMEM pass produces (a) the clipped activations (|x| < tau -> 0, the
dynamic activation sparsity of §III) and (b) per-tile zero counts, which feed
the calibration statistics that drive both the perf model (S_a in Eq. 1) and
the buffer-sizing heuristic — on hardware this is the "dedicated counter"
next to the arbiter in Fig. 3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _kernel(x_ref, tau_ref, y_ref, cnt_ref):
    x = x_ref[...]
    tau = tau_ref[0, 0]
    y = jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))
    y_ref[...] = y
    cnt_ref[0, 0] = jnp.sum(y == 0.0).astype(jnp.int32)


def act_clip_count(x: jnp.ndarray, tau, *, bm: int = 256, bn: int = 256,
                   interpret: bool = False):
    """x: (M, N) -> (clipped (M, N), zero count per (bm, bn) tile).

    M, N must be multiples of the block sizes (``ops.act_clip`` pads).
    """
    M, N = x.shape
    assert M % bm == 0 and N % bn == 0, (x.shape, bm, bn)
    grid = (M // bm, N // bn)
    tau_arr = jnp.full((1, 1), tau, dtype=jnp.float32)

    y, cnt = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, N), x.dtype),
            jax.ShapeDtypeStruct((M // bm, N // bn), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, tau_arr)
    return y, cnt
