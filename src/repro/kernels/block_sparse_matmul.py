"""Block-sparse matmul Pallas TPU kernel — the MXU adaptation of the SPE.

The paper's SPE keeps every MAC busy by statically scheduling only non-zero
(weight, activation) pairs (arbiter + zero-filter, Fig. 3). A systolic MXU
cannot skip individual MACs, so the TPU-native equivalent operates at VMEM
tile granularity: weight sparsity is compile-time known, so for every output
tile column we *precompute the list of non-zero K-tiles* and the grid runs
exactly ``nnz`` steps per output tile — zero tiles are never DMA'd from HBM
nor multiplied. Eq. 1's t(S̄)=ceil((1-S̄)M/N) becomes
``steps = nnz_tiles(column)`` with M/N = K/bk tiles.

The schedule (counts, indices) is the arbiter; scalar-prefetch index maps are
the dispatch. Grid = (M/bm, N/bn, max_nnz); the trailing (sequential) axis
accumulates into the output tile.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _build_tile_schedule_ref(mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Reference per-column-loop schedule builder — kept as the equivalence
    oracle for the vectorized path (tests, kernels_bench)."""
    mask = np.asarray(mask, dtype=bool)
    Kt, Nt = mask.shape
    counts = mask.sum(axis=0).astype(np.int32)
    max_nnz = max(1, int(counts.max()) if counts.size else 1)
    indices = np.zeros((Nt, max_nnz), dtype=np.int32)
    for j in range(Nt):
        nz = np.nonzero(mask[:, j])[0]
        indices[j, :len(nz)] = nz
    return counts, indices


def tile_mask(w: np.ndarray, bk: int = 128, bn: int = 128) -> np.ndarray:
    """(K, N) weight -> (Kt, Nt) bool map of tiles with any non-zero entry.

    The bridge from a pruned weight to ``build_tile_schedule``: pattern
    pruning (tile / N:M / hierarchical, DESIGN.md §16) produces element
    zeros; the Pallas kernel skips at VMEM-tile granularity, so only tiles
    that pruning emptied *entirely* shorten the schedule.
    """
    w = np.asarray(w)
    K, N = w.shape
    assert K % bk == 0 and N % bn == 0, (w.shape, bk, bn)
    t = w.reshape(K // bk, bk, N // bn, bn)
    return (t != 0).any(axis=(1, 3))


# schedule memo: a weight is pruned once and multiplied every step, and
# several layers often share one mask shape+pattern (tile-structured
# pruning is deterministic), so schedules are cached per mask content
_SCHEDULE_CACHE: dict = {}
_SCHEDULE_CACHE_MAX = 256


def build_tile_schedule(mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """mask: (Kt, Nt) bool -> (counts (Nt,), indices (Nt, max_nnz)) int32.

    indices[j, s] is the K-tile id of the s-th non-zero tile in column j
    (padded with 0 past counts[j]; padded steps are masked in the kernel).
    This is the compile-time static schedule — the paper's arbiter, resolved
    ahead of time because weight sparsity is known at compile time (§III).

    Vectorized: one ``np.nonzero`` over the transposed mask yields every
    (column, K-tile) pair in column-major order, and a cumsum of the
    per-column counts scatters each pair into its step slot — O(nnz) flat
    numpy instead of the reference's per-column Python loop. Results are
    memoized on the mask bytes — rebuilding the schedule for an unchanged
    weight is a dict hit (``kernels_bench.py`` gates both).
    """
    mask = np.asarray(mask, dtype=bool)
    key = (mask.shape, mask.tobytes())
    hit = _SCHEDULE_CACHE.get(key)
    if hit is not None:
        return hit
    Kt, Nt = mask.shape
    if Kt == 0 or Nt == 0:
        return _build_tile_schedule_ref(mask)
    counts = mask.sum(axis=0).astype(np.int32)
    max_nnz = max(1, int(counts.max()) if counts.size else 1)
    flat = np.flatnonzero(np.ascontiguousarray(mask.T))
    cols, rows = np.divmod(flat, Kt)     # column-major: ascending rows
    starts = np.zeros(Nt, dtype=np.int64)     # within each column
    starts[1:] = np.cumsum(counts[:-1])
    slot = np.arange(len(rows), dtype=np.int64) - starts[cols]
    indices = np.zeros((Nt, max_nnz), dtype=np.int32)
    indices[cols, slot] = rows
    if len(_SCHEDULE_CACHE) >= _SCHEDULE_CACHE_MAX:
        _SCHEDULE_CACHE.clear()
    out = (counts, indices)
    _SCHEDULE_CACHE[key] = out
    return out


def _kernel(counts, indices, x_ref, w_ref, o_ref, *, bm, bn):
    i, j, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(s < counts[j])
    def _accum():
        o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                              preferred_element_type=jnp.float32)


def block_sparse_matmul(x: jnp.ndarray, w: jnp.ndarray,
                        counts: jnp.ndarray, indices: jnp.ndarray,
                        *, bm: int = 128, bk: int = 128, bn: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """x: (M, K) @ w: (K, N) skipping all-zero weight tiles.

    counts/indices from ``build_tile_schedule``. M, K, N must be multiples of
    the block sizes (``ops.block_sparse_dense`` pads). Returns f32 (M, N).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % bm == 0 and K % bk == 0 and N % bn == 0, \
        (x.shape, w.shape, bm, bk, bn)
    Nt = N // bn
    max_nnz = indices.shape[1]
    assert counts.shape == (Nt,) and indices.shape == (Nt, max_nnz)

    grid = (M // bm, Nt, max_nnz)

    def x_map(i, j, s, counts_ref, idx_ref):
        return (i, idx_ref[j, s])

    def w_map(i, j, s, counts_ref, idx_ref):
        return (idx_ref[j, s], j)

    def o_map(i, j, s, counts_ref, idx_ref):
        return (i, j)

    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, bn=bn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), x_map),
                pl.BlockSpec((bk, bn), w_map),
            ],
            out_specs=pl.BlockSpec((bm, bn), o_map),
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(counts, indices, x, w)
