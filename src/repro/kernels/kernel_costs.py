"""Seeded per-pattern decode microbench over block-sparse matmul schedules.

Closes the measured loop of DESIGN.md §16: the DSE's analytic t(S̄) model
(Eq. 1) assumes every skipped element is free, but the four sparsity
patterns pay different *decode* costs on real hardware — tile schedules
skip whole VMEM tiles (free once a tile empties), N:M decode gathers the
kept reduction rows (2:4-sparse-core style), hierarchical composes both,
and activation sparsity leaves weights dense. This module measures those
costs per pattern on a seeded synthetic workload and condenses them into

  * a cycles table (per pattern x sparsity level), and
  * ``decode_factors`` — per-pattern c_p >= 1 multipliers applied to the
    Eq. 1 numerator via ``LayerVectors.t_scale`` and the optional Eq. 6
    ``Lambdas.meas`` term.

Determinism contract (tests/test_kernel_costs.py): measurement is static
program analysis — Pallas/XLA lowering + ``analysis.hlo_costs`` roofline
cycles — never wall clock, and every mask is drawn from a fixed seed, so
two runs write byte-identical ``experiments/kernel_costs.json``. When the
backend cannot lower the Pallas TPU kernel (CPU CI) or reports no cost
counters, each probe independently falls back to a modeled estimate from
the schedule counts and records ``mode: "modeled"``.
"""
from __future__ import annotations

import functools
import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.pruning import NM_M
from repro.kernels.block_sparse_matmul import block_sparse_matmul

DEFAULT_PATH = os.path.join("experiments", "kernel_costs.json")
SCHEMA_VERSION = 1


@dataclass(frozen=True)
class MicrobenchConfig:
    """One decode-cost probe workload; part of the cache key."""
    m: int = 256            # activations rows
    k: int = 1024           # reduction dim
    n: int = 512            # output dim
    bm: int = 128
    bk: int = 128
    bn: int = 128
    nm_m: int = NM_M
    sparsities: Tuple[float, ...] = (0.25, 0.5, 0.75)
    flops_per_cycle: float = 2.0 * 128 * 128   # one MXU pass per cycle
    bytes_per_cycle: float = 128.0
    seed: int = 0


def cache_key(cfg: MicrobenchConfig) -> str:
    d = asdict(cfg)
    d["sparsities"] = list(cfg.sparsities)
    d["schema"] = SCHEMA_VERSION
    return json.dumps(d, sort_keys=True)


# ------------------------------------------------------------------ #
# probes — each returns (cycles, mode)

def _roofline(compiled, cfg: MicrobenchConfig) -> float:
    from repro.analysis.hlo_costs import compiled_cycles
    return compiled_cycles(compiled, flops_per_cycle=cfg.flops_per_cycle,
                           bytes_per_cycle=cfg.bytes_per_cycle)


def _dense_cycles(cfg: MicrobenchConfig) -> Tuple[float, str]:
    modeled = 2.0 * cfg.m * cfg.k * cfg.n / cfg.flops_per_cycle
    try:
        import jax
        import jax.numpy as jnp
        x = jax.ShapeDtypeStruct((cfg.m, cfg.k), jnp.float32)
        w = jax.ShapeDtypeStruct((cfg.k, cfg.n), jnp.float32)
        compiled = jax.jit(lambda a, b: a @ b).lower(x, w).compile()
        c = _roofline(compiled, cfg)
        if c > 0.0:
            return c, "hlo"
    except Exception:
        pass
    return modeled, "modeled"


def _tile_schedule(cfg: MicrobenchConfig, s_tile: float,
                   rng: np.random.Generator
                   ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Seeded (counts, indices) with exactly round(s_tile * Kt * Nt) zero
    tiles, plus the realized tile sparsity."""
    kt, nt = cfg.k // cfg.bk, cfg.n // cfg.bn
    n_zero = int(round(s_tile * kt * nt))
    flat = np.ones(kt * nt, dtype=bool)
    flat[rng.permutation(kt * nt)[:n_zero]] = False
    mask = flat.reshape(kt, nt)
    # never empty a whole column: the schedule pads to max_nnz >= 1 and an
    # all-zero column measures DMA of a zeroed tile, not decode cost
    for j in range(nt):
        if not mask[:, j].any():
            mask[rng.integers(0, kt), j] = True
    from repro.kernels.block_sparse_matmul import build_tile_schedule
    counts, indices = build_tile_schedule(mask)
    return counts, indices, 1.0 - mask.mean()


def _tile_cycles(cfg: MicrobenchConfig, counts: np.ndarray,
                 indices: np.ndarray) -> Tuple[float, str]:
    """Cycles of the Pallas block-sparse kernel under one schedule."""
    steps = float(np.sum(counts)) * (cfg.m // cfg.bm)
    modeled = steps * (2.0 * cfg.bm * cfg.bk * cfg.bn) / cfg.flops_per_cycle
    try:
        import jax
        import jax.numpy as jnp
        x = jax.ShapeDtypeStruct((cfg.m, cfg.k), jnp.float32)
        w = jax.ShapeDtypeStruct((cfg.k, cfg.n), jnp.float32)
        fn = functools.partial(block_sparse_matmul,
                               bm=cfg.bm, bk=cfg.bk, bn=cfg.bn)
        compiled = jax.jit(fn).lower(
            x, w, jnp.asarray(counts), jnp.asarray(indices)).compile()
        c = _roofline(compiled, cfg)
        if c > 0.0:
            # cost analysis cannot see inside the Mosaic custom call; the
            # schedule bound is the compute floor
            return max(c, modeled), "pallas"
    except Exception:
        pass
    return modeled, "modeled"


def _nm_cycles(cfg: MicrobenchConfig, n_keep: int,
               rng: np.random.Generator) -> Tuple[float, str]:
    """N:M decode proxy: compressed (M, Kc) x (Kc, N) matmul fed by a
    row-gather of the activations — the gather is the decode cost a
    structured-sparse MXU pays per kept group (metadata-indexed operand
    fetch). Lowers on the CPU backend, so CI measures this genuinely.
    """
    kc = max(cfg.bk, (cfg.k * n_keep // cfg.nm_m) // cfg.bk * cfg.bk)
    idx = np.sort(rng.permutation(cfg.k)[:kc]).astype(np.int32)
    gather_bytes = 4.0 * cfg.m * kc + 4.0 * kc
    modeled = (2.0 * cfg.m * kc * cfg.n / cfg.flops_per_cycle
               + gather_bytes / cfg.bytes_per_cycle)
    try:
        import jax
        import jax.numpy as jnp

        def f(a, w_c, i):
            return jnp.take(a, i, axis=1) @ w_c

        x = jax.ShapeDtypeStruct((cfg.m, cfg.k), jnp.float32)
        w = jax.ShapeDtypeStruct((kc, cfg.n), jnp.float32)
        ii = jax.ShapeDtypeStruct((kc,), jnp.int32)
        compiled = jax.jit(f).lower(x, w, ii).compile()
        del idx
        c = _roofline(compiled, cfg)
        if c > 0.0:
            return c, "hlo"
    except Exception:
        pass
    return modeled, "modeled"


# ------------------------------------------------------------------ #

def measure(cfg: Optional[MicrobenchConfig] = None) -> Dict:
    """Run every probe; returns the full (JSON-serializable) cost table."""
    cfg = cfg or MicrobenchConfig()
    m = cfg.nm_m
    dense, dense_mode = _dense_cycles(cfg)
    # a modeled probe counts only the compute leg, so it must be normalized
    # by the compute-leg dense — never by a memory-bound roofline dense —
    # or the ratio deflates below 1 and the decode overhead vanishes
    dense_modeled = 2.0 * cfg.m * cfg.k * cfg.n / cfg.flops_per_cycle

    def ref_for(mode: str) -> float:
        return dense_modeled if mode == "modeled" else dense

    table: Dict = {
        "schema": SCHEMA_VERSION,
        "config": json.loads(cache_key(cfg)),
        "dense": {"cycles": float(dense), "mode": dense_mode,
                  "modeled_cycles": float(dense_modeled)},
        "patterns": {},
    }

    unstructured = {}
    for s in cfg.sparsities:
        rng = np.random.default_rng((cfg.seed, int(s * 1000), 1))
        counts, indices, s_real = _tile_schedule(cfg, s, rng)
        cyc, mode = _tile_cycles(cfg, counts, indices)
        unstructured[f"{s:.4f}"] = {
            "cycles": float(cyc), "mode": mode, "s_eff": float(s_real),
            "dense_ref": float(ref_for(mode))}
    table["patterns"]["unstructured"] = unstructured

    nm = {}
    for s in cfg.sparsities:
        n_keep = int(np.clip(m - np.floor(s * m), 1, m))
        s_real = 1.0 - n_keep / m
        rng = np.random.default_rng((cfg.seed, n_keep, 2))
        cyc, mode = _nm_cycles(cfg, n_keep, rng)
        nm[f"{s:.4f}"] = {"cycles": float(cyc), "mode": mode,
                          "s_eff": float(s_real), "n_keep": n_keep,
                          "dense_ref": float(ref_for(mode))}
    table["patterns"]["nm"] = nm

    hier = {}
    for s in cfg.sparsities:
        # DESIGN.md §16 split: half the budget at tile level, residual N:M
        st = s / 2.0
        r = (s - st) / (1.0 - st)
        n_keep = int(np.clip(m - np.floor(r * m), 1, m))
        s_nm = 1.0 - n_keep / m
        rng = np.random.default_rng((cfg.seed, int(s * 1000), 3))
        counts, indices, st_real = _tile_schedule(cfg, st, rng)
        t_cyc, t_mode = _tile_cycles(cfg, counts, indices)
        n_cyc, n_mode = _nm_cycles(cfg, n_keep, rng)
        # compose multiplicatively: per-leg overheads vs that leg's ideal
        # (1 - s_leg) * dense scaling, each against its same-mode dense
        g_tile = t_cyc / max(1e-9, (1.0 - st_real) * ref_for(t_mode))
        g_nm = n_cyc / max(1e-9, (1.0 - s_nm) * ref_for(n_mode))
        s_real = 1.0 - (1.0 - st_real) * (1.0 - s_nm)
        cyc = dense * (1.0 - s_real) * g_tile * g_nm
        hier[f"{s:.4f}"] = {
            "cycles": float(cyc), "mode": f"{t_mode}+{n_mode}",
            "s_eff": float(s_real), "dense_ref": float(dense)}
    table["patterns"]["hierarchical"] = hier

    # activation sparsity leaves weights dense: the weight-side schedule is
    # the dense one at every level (zeros are skipped per-operand at the
    # SPE, not in the tile schedule)
    table["patterns"]["activation"] = {
        f"{s:.4f}": {"cycles": float(dense), "mode": dense_mode,
                     "s_eff": 0.0, "dense_ref": float(dense)}
        for s in cfg.sparsities}

    table["decode_factors"] = decode_factors(table)
    return table


def decode_factors(table: Dict) -> Dict[str, float]:
    """Per-pattern c_p = mean over levels of cycles / ((1 - s_eff) * dense),
    floored at 1.0 — the ``LayerVectors.t_scale`` multiplier: how many Eq. 1
    cycles the pattern pays per unit of ideally-skippable work."""
    dense = float(table["dense"]["cycles"])
    out: Dict[str, float] = {}
    for pat, levels in table["patterns"].items():
        ratios = []
        for rec in levels.values():
            ref = float(rec.get("dense_ref", dense))
            ideal = (1.0 - float(rec["s_eff"])) * ref
            if ideal > 0.0:
                ratios.append(float(rec["cycles"]) / ideal)
        out[pat] = float(max(1.0, np.mean(ratios))) if ratios else 1.0
    return out


def load_or_measure(path: Optional[str] = DEFAULT_PATH,
                    cfg: Optional[MicrobenchConfig] = None,
                    refresh: bool = False) -> Dict:
    """Cached ``measure``: reuse ``path`` when its embedded config matches
    ``cfg`` (else re-measure and rewrite). ``path=None`` skips the disk
    cache entirely. Writes are byte-deterministic (sorted keys, fixed
    float repr, no timestamps)."""
    cfg = cfg or MicrobenchConfig()
    want = json.loads(cache_key(cfg))
    if path and not refresh and os.path.exists(path):
        try:
            with open(path) as f:
                table = json.load(f)
            if table.get("config") == want and \
                    table.get("schema") == SCHEMA_VERSION:
                return table
        except (json.JSONDecodeError, OSError):
            pass
    table = measure(cfg)
    if path:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(table, f, indent=1, sort_keys=True)
            f.write("\n")
    return table
