"""Version-compat shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and back,
depending on the release line); the kernels in this package only ever pass
``dimension_semantics``, so a single factory hides the drift.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams", None)


def tpu_compiler_params(**kwargs):
    """Build the installed jax's TPU compiler-params object (or None if the
    class is absent entirely — pallas_call accepts compiler_params=None)."""
    if _COMPILER_PARAMS_CLS is None:
        return None
    return _COMPILER_PARAMS_CLS(**kwargs)
