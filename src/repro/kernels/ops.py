"""Jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, schedule construction from pruned weights,
and backend selection (``interpret=True`` executes the kernel bodies in
Python on CPU — the validation mode used by tests in this container; on a
real TPU ``interpret=False`` compiles via Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.act_clip import act_clip_count
from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                               build_tile_schedule)


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x: jnp.ndarray, m0: int, m1: int) -> jnp.ndarray:
    p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def weight_tile_mask(w: np.ndarray, bk: int = 128, bn: int = 128) -> np.ndarray:
    """(Kt, Nt) bool: which (bk, bn) tiles of a pruned weight are non-zero."""
    w = np.asarray(w)
    K, N = w.shape
    wp = np.pad(w, ((0, (-K) % bk), (0, (-N) % bn)))
    t = wp.reshape(wp.shape[0] // bk, bk, wp.shape[1] // bn, bn)
    return np.any(t != 0, axis=(1, 3))


class SparseWeight:
    """A pruned weight packaged with its static tile schedule (the paper's
    compile-time arbiter table). Build once after pruning, reuse per step."""

    def __init__(self, w, bk: int = 128, bn: int = 128):
        self.bk, self.bn = bk, bn
        self.shape = tuple(w.shape)
        mask = weight_tile_mask(np.asarray(w), bk, bn)
        counts, indices = build_tile_schedule(mask)
        self.mask = jnp.asarray(mask)
        self.counts = jnp.asarray(counts)
        self.indices = jnp.asarray(indices)
        self.w_padded = _pad_to(jnp.asarray(w), bk, bn)
        self.tile_density = float(mask.mean())

    def matmul(self, x: jnp.ndarray, *, bm: int = 128,
               interpret: Optional[bool] = None) -> jnp.ndarray:
        """x: (M, K) -> (M, N) f32, skipping all-zero weight tiles."""
        M, K = x.shape
        xp = _pad_to(x, bm, self.bk)
        out = block_sparse_matmul(xp, self.w_padded, self.counts, self.indices,
                                  bm=bm, bk=self.bk, bn=self.bn,
                                  interpret=_auto_interpret(interpret))
        return out[:M, :self.shape[1]]


def block_sparse_dense(x, w, *, bm=128, bk=128, bn=128, interpret=None):
    """One-shot convenience: build schedule from w's zeros and multiply."""
    return SparseWeight(w, bk, bn).matmul(x, bm=bm, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def _act_clip_jit(x2d, tau, bm, bn, interpret):
    return act_clip_count(x2d, tau, bm=bm, bn=bn, interpret=interpret)


def act_clip(x: jnp.ndarray, tau, *, bm: int = 256, bn: int = 256,
             interpret: Optional[bool] = None):
    """Clip |x| < tau to 0; returns (y, total zero count). Any shape."""
    shape = x.shape
    n = x.size
    cols = min(n, bn)
    x2 = x.reshape(-1, cols) if n % cols == 0 else \
        jnp.pad(x.reshape(-1), (0, (-n) % cols)).reshape(-1, cols)
    rows = x2.shape[0]
    bm_eff = min(bm, rows)
    x2 = _pad_to(x2, bm_eff, cols)
    y, cnt = _act_clip_jit(x2, jnp.float32(tau), bm_eff, cols,
                           _auto_interpret(interpret))
    pad_zeros = y.size - n          # padding contributes zeros to the count
    y = y.reshape(-1)[:n].reshape(shape)
    return y, cnt.sum() - pad_zeros
