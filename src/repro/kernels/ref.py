"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp


def expand_tile_mask(mask: jnp.ndarray, bk: int, bn: int,
                     K: int, N: int) -> jnp.ndarray:
    """(K/bk, N/bn) bool tile mask -> (K, N) elementwise mask."""
    m = jnp.repeat(jnp.repeat(mask, bk, axis=0), bn, axis=1)
    return m[:K, :N]


def block_sparse_matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
                            mask: jnp.ndarray, bk: int, bn: int
                            ) -> jnp.ndarray:
    """x: (M, K); w: (K, N); mask: (ceil(K/bk), ceil(N/bn)) bool.

    Semantics of the kernel: tiles with mask==False contribute exactly zero
    (they are never loaded), regardless of w's contents there.
    """
    K, N = w.shape
    wm = w * expand_tile_mask(mask, bk, bn, K, N).astype(w.dtype)
    return jnp.dot(x, wm, preferred_element_type=jnp.float32)


def act_clip_ref(x: jnp.ndarray, tau) -> jnp.ndarray:
    """Zero out |x| < tau (the SPE clip unit)."""
    return jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))


def act_clip_count_ref(x: jnp.ndarray, tau):
    y = act_clip_ref(x, tau)
    return y, jnp.sum(y == 0.0).astype(jnp.int32)
