"""Search flight recorder (DESIGN.md §18): one JSONL record per trial.

``hass_search`` / ``autoscale_policy_search`` / ``slo_partition_search``
take ``recorder=FlightRecorder(path)`` and emit

  * one **header** record — search kind, schema version, config;
  * one **trial** record per trial — proposal ``x``, score, metric terms,
    DSECache counter deltas (hit / warm_l1 / warm_l2 / cold_runs), engine
    dispatch deltas (flat / grouped / compiled / lockstep), and per-phase
    wall seconds (propose / evaluate / tell);
  * one **footer** record — trial count, best score, total wall seconds,
    and aggregate totals that equal the SUM of the per-trial deltas
    (round-trip-tested). Proposal-batched rounds attribute the round's
    shared work (phases, counter deltas) to the round's FIRST trial and
    zeros to the rest — each record carries ``round_size`` — so the sum
    convention holds there too.

Records are plain ``json`` lines; non-finite floats serialize as the
``json`` module's ``Infinity``/``NaN`` tokens, which round-trip through
``read_records`` (same library both ways). ``tools/trace_report.py``
summarizes and diffs recorded runs.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

SCHEMA_VERSION = 1


def _jsonable(v):
    """Best-effort conversion of numpy scalars/arrays for ``json``."""
    if hasattr(v, "tolist"):
        return v.tolist()
    if hasattr(v, "item"):
        return v.item()
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


class FlightRecorder:
    """Writes one search run to ``path`` as JSONL. The clock is injectable
    (fake-time tests); aggregate totals accumulate per-trial in write
    order, so the footer equals the left-to-right sum of the trial
    records bit-for-bit."""

    def __init__(self, path: str,
                 clock=time.perf_counter):
        self.path = path
        self._f = open(path, "w")
        self._clock = clock
        self._t0: Optional[float] = None
        self.n_trials = 0
        self._best = float("-inf")
        self._cache_tot: Dict[str, float] = {}
        self._engine_tot: Dict[str, float] = {}
        self._phase_tot: Dict[str, float] = {}
        self._closed = False

    # ----------------------------------------------------------------- #
    def _write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")

    def header(self, search: str, **config) -> None:
        self._t0 = self._clock()
        self._write({"record": "header", "schema": SCHEMA_VERSION,
                     "search": search, "config": _jsonable(config)})

    def trial(self, index: int, x, score: float, metrics: dict, *,
              cache: Optional[dict] = None, engine: Optional[dict] = None,
              phases: Optional[dict] = None, **extra) -> None:
        cache = {} if cache is None else cache
        engine = {} if engine is None else engine
        phases = {} if phases is None else phases
        for tot, d in ((self._cache_tot, cache),
                       (self._engine_tot, engine),
                       (self._phase_tot, phases)):
            for k, v in d.items():
                tot[k] = tot.get(k, 0) + v
        self.n_trials += 1
        if score > self._best:
            self._best = score
        self._write({"record": "trial", "i": int(index),
                     "x": _jsonable(x), "score": _jsonable(score),
                     "metrics": _jsonable(metrics),
                     "cache": _jsonable(cache), "engine": _jsonable(engine),
                     "phases": _jsonable(phases),
                     **{k: _jsonable(v) for k, v in extra.items()}})

    def footer(self, **extra) -> None:
        wall = (self._clock() - self._t0) if self._t0 is not None else 0.0
        self._write({"record": "footer", "n_trials": self.n_trials,
                     "best_score": _jsonable(self._best),
                     "wall_s": wall,
                     "totals": {"cache": dict(self._cache_tot),
                                "engine": dict(self._engine_tot),
                                "phases": dict(self._phase_tot)},
                     **{k: _jsonable(v) for k, v in extra.items()}})
        self._f.flush()

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._f.close()

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


# --------------------------------------------------------------------- #
def read_records(path: str) -> List[dict]:
    """Every JSONL record of one recorded run, in write order."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def load_run(path: str) -> dict:
    """One recorded run as ``{"header": ..., "trials": [...],
    "footer": ...}`` (header/footer ``None`` when absent — e.g. a run
    killed mid-flight still loads its trials)."""
    header = footer = None
    trials: List[dict] = []
    for rec in read_records(path):
        kind = rec.get("record")
        if kind == "header":
            header = rec
        elif kind == "footer":
            footer = rec
        elif kind == "trial":
            trials.append(rec)
    return {"header": header, "trials": trials, "footer": footer}
