"""Span tracing, counters, gauges, and histograms (DESIGN.md §18).

The process-global tracer defaults to ``NULL_TRACER``, whose ``enabled``
attribute is ``False`` — instrumented code guards every emission behind
``if tr.enabled`` (or never branches at all: the search loop keeps its
uninstrumented hot loop verbatim when tracing is off), so the disabled
path pays one attribute check and stays IEEE-bit-identical to the
pre-telemetry build. Instrumentation only *reads* clocks and counters; it
never touches a float any engine computes, so the enabled path is
bit-identical too (gated in ``benchmarks/obs_bench.py``).

The clock is injectable (``Tracer(clock=...)``) so tests run on fake time.
Exporters: ``to_chrome_trace``/``export_chrome_trace`` emit Chrome
trace-event JSON (``{"traceEvents": [...]}`` with "X" complete events —
loadable in Perfetto / ``chrome://tracing``); ``metrics``/
``export_metrics`` emit one flat JSON of counters, gauges, and histogram
summaries.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional


class Counters:
    """A named-counter bag: plain dict-backed integer counters with
    snapshot/delta support. Backs ``DSECache.stats()`` and any other
    always-on counter set — increments are one dict store, cheap enough
    to leave unguarded on decision paths that already cost an array
    compare."""

    __slots__ = ("_c",)

    def __init__(self, *names: str):
        self._c: Dict[str, int] = {n: 0 for n in names}

    def inc(self, name: str, n: int = 1) -> None:
        self._c[name] = self._c.get(name, 0) + n

    def get(self, name: str) -> int:
        return self._c.get(name, 0)

    def set(self, name: str, value: int) -> None:
        self._c[name] = value

    def as_dict(self) -> Dict[str, int]:
        return dict(self._c)

    def snapshot(self) -> Dict[str, int]:
        return dict(self._c)

    def delta_since(self, snap: Dict[str, int]) -> Dict[str, int]:
        return {k: v - snap.get(k, 0) for k, v in self._c.items()}


class _NullSpan:
    """Shared no-op context manager handed out by ``NullTracer.span`` —
    one singleton, so the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The process-global default: every method is a no-op and ``enabled``
    is ``False``, so instrumented code pays one attribute check."""

    enabled = False

    def now(self) -> float:
        return time.perf_counter()

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def add_span(self, name: str, t0: float, t1: float, depth: int = 0,
                 **args) -> None:
        pass

    def count(self, name: str, n: float = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def observe(self, name: str, value: float) -> None:
        pass

    def instant(self, name: str, **args) -> None:
        pass


NULL_TRACER = NullTracer()


class _Span:
    """An open span: records its end time and pops itself off the tracer's
    stack on ``__exit__``. Exceptions propagate (the span still closes)."""

    __slots__ = ("_tr", "name", "t0", "depth", "args")

    def __init__(self, tr: "Tracer", name: str, args: dict):
        self._tr = tr
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        self.depth = len(self._tr._stack)
        self._tr._stack.append(self)
        self.t0 = self._tr.now()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tr.now()
        self._tr._stack.pop()
        self._tr._finish(self.name, self.t0, t1, self.depth, self.args)
        return False


class Tracer:
    """Collects nested spans, counters, gauges, and histograms in-process.

    * ``span(name, **args)`` — context manager; nesting depth comes from
      the tracer's open-span stack, start/end from its clock.
    * ``add_span(name, t0, t1, depth=0, **args)`` — record a span whose
      times the caller already measured (the search loop reads the clock
      inline so its per-trial overhead is four clock reads, not four
      context-manager frames).
    * ``count(name, n)`` / ``gauge(name, v)`` / ``observe(name, v)`` —
      monotonic counters, last-value gauges, and min/max/sum/count
      histogram summaries. ``instant(name, **args)`` records a
      zero-duration marker event.

    Timestamps are whatever the injected ``clock`` returns (seconds by
    default); the Chrome exporter scales to microseconds.
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.events: List[dict] = []     # finished spans + instants
        self._stack: List[_Span] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Dict[str, float]] = {}

    # ----------------------------------------------------------------- #
    def now(self) -> float:
        return self._clock()

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def add_span(self, name: str, t0: float, t1: float, depth: int = 0,
                 **args) -> None:
        self._finish(name, t0, t1, depth, args)

    def _finish(self, name: str, t0: float, t1: float, depth: int,
                args: dict) -> None:
        self.events.append({"name": name, "t0": t0, "t1": t1,
                            "depth": depth, "args": args})

    def instant(self, name: str, **args) -> None:
        t = self.now()
        self.events.append({"name": name, "t0": t, "t1": t,
                            "depth": len(self._stack), "args": args})

    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        h = self.hists.get(name)
        if h is None:
            self.hists[name] = {"count": 1, "sum": value,
                                "min": value, "max": value}
        else:
            h["count"] += 1
            h["sum"] += value
            if value < h["min"]:
                h["min"] = value
            if value > h["max"]:
                h["max"] = value

    # ----------------------------------------------------------------- #
    def to_chrome_trace(self, pid: int = 0, tid: int = 0) -> dict:
        """Chrome trace-event JSON: one "X" (complete) event per finished
        span, "i" (instant) for zero-duration markers; ``ts``/``dur`` in
        microseconds as the format requires. Loadable in Perfetto."""
        out = []
        for e in self.events:
            ts = e["t0"] * 1e6
            dur = (e["t1"] - e["t0"]) * 1e6
            ev = {"name": e["name"], "ph": "X", "ts": ts, "dur": dur,
                  "pid": pid, "tid": tid}
            if e["args"]:
                ev["args"] = dict(e["args"])
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str, pid: int = 0,
                            tid: int = 0) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(pid=pid, tid=tid), f, indent=1,
                      sort_keys=True)
        return path

    def metrics(self) -> dict:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: dict(v) for k, v in self.hists.items()}}

    def export_metrics(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.metrics(), f, indent=1, sort_keys=True)
        return path


# --------------------------------------------------------------------- #
# process-global tracer
# --------------------------------------------------------------------- #
_TRACER = NULL_TRACER


def get_tracer():
    """The process-global tracer (``NULL_TRACER`` unless installed)."""
    return _TRACER


def set_tracer(tracer) -> object:
    """Install ``tracer`` (or ``None`` for the no-op default) process-wide;
    returns the previous tracer so callers can restore it."""
    global _TRACER
    prev = _TRACER
    _TRACER = NULL_TRACER if tracer is None else tracer
    return prev


@contextmanager
def use_tracer(tracer):
    """Install ``tracer`` for the duration of a ``with`` block."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
