"""Tiny level-filtered logger routed through the obs layer (DESIGN.md §18).

Replaces ad-hoc ``print()`` calls (``repro.launch.dryrun``): each logger
prefixes its name (``[dryrun] ...`` message text preserved), filters by
level, and writes through a swappable ``sink`` so tests capture output
without touching stdout. When a real tracer is installed, every emitted
line also bumps a ``log.<name>.<level>`` counter and records an instant
event — log volume shows up in the same trace as the spans.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Callable, Dict, List, Optional

from repro.obs.trace import get_tracer

LEVELS = {"debug": 10, "info": 20, "warning": 30, "error": 40}


class Logger:
    """Level-filtered, sink-swappable logger. ``sink`` is any
    ``callable(str)`` (``None`` = ``print``)."""

    def __init__(self, name: str, level: str = "info",
                 sink: Optional[Callable[[str], None]] = None):
        self.name = name
        self.level = level
        self.sink = sink

    def log(self, level: str, msg: str) -> None:
        if LEVELS[level] < LEVELS[self.level]:
            return
        line = f"[{self.name}] {msg}"
        (self.sink or print)(line)
        tr = get_tracer()
        if tr.enabled:
            tr.count(f"log.{self.name}.{level}")
            tr.instant(f"log.{self.name}", level=level, msg=msg)

    def debug(self, msg: str) -> None:
        self.log("debug", msg)

    def info(self, msg: str) -> None:
        self.log("info", msg)

    def warning(self, msg: str) -> None:
        self.log("warning", msg)

    def error(self, msg: str) -> None:
        self.log("error", msg)


_LOGGERS: Dict[str, Logger] = {}


def get_logger(name: str) -> Logger:
    """Shared per-name logger registry, so a test can retarget the sink of
    the logger production code already holds."""
    lg = _LOGGERS.get(name)
    if lg is None:
        lg = _LOGGERS[name] = Logger(name)
    return lg


@contextmanager
def capture(name: str):
    """Collect a named logger's lines for the duration of a block."""
    lines: List[str] = []
    lg = get_logger(name)
    old = lg.sink
    lg.sink = lines.append
    try:
        yield lines
    finally:
        lg.sink = old
