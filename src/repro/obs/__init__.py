"""Unified telemetry (DESIGN.md §18): span tracing, counters, and the
search flight recorder — zero-dependency, no-op by default.

``repro.obs.trace``    — ``Tracer`` (nested spans / counters / gauges /
                         histograms), the process-global no-op default,
                         Chrome trace-event + flat metrics exporters.
``repro.obs.recorder`` — ``FlightRecorder``: one structured JSONL record
                         per search trial plus run header/footer.
``repro.obs.log``      — tiny level-filtered logger routed through the
                         tracer (instant events when tracing is on).
"""
from repro.obs.trace import (NULL_TRACER, Counters, NullTracer, Tracer,
                             get_tracer, set_tracer, use_tracer)
from repro.obs.recorder import FlightRecorder, load_run, read_records

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "Counters", "get_tracer",
           "set_tracer", "use_tracer", "FlightRecorder", "read_records",
           "load_run"]
