"""Roofline extraction from compiled dry-run artifacts.

Conventions (important — everything is PER DEVICE):
  * ``compiled.cost_analysis()`` on an SPMD program reports per-partition
    flops / bytes, so terms divide by per-chip peaks only:
        compute_s    = flops / PEAK_FLOPS
        memory_s     = bytes_accessed / HBM_BW
        collective_s = collective_bytes / ICI_BW
  * collective_bytes sums the *result* shapes of every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute in the
    optimized HLO (the per-device receive payload; '-start' ops counted,
    '-done' skipped). This is the wire-byte proxy used throughout
    EXPERIMENTS.md — ring all-reduce moves ~2x this, noted there.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.perf_model import HBM_BW, ICI_BW, PEAK_FLOPS

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveOp:
    opcode: str
    bytes: int
    group_size: int = 0


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Sum per-device result bytes of every collective in optimized HLO."""
    out: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        m = re.search(
            r"=\s*(\(?[a-z0-9_,\[\]\{\}\s]*\)?)\s*"
            r"(all-reduce-start|all-gather-start|reduce-scatter|"
            r"all-to-all|collective-permute-start|all-reduce|all-gather|"
            r"collective-permute)\(", line)
        if not m:
            continue
        opcode = m.group(2).replace("-start", "")
        result = m.group(1)
        nbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(result))
        g = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        group = int(g.group(2)) if g else 0
        out.append(CollectiveOp(opcode=opcode, bytes=nbytes, group_size=group))
    return out


def collective_bytes(hlo_text: str) -> int:
    return sum(op.bytes for op in parse_collectives(hlo_text))


def collective_breakdown(hlo_text: str) -> Dict[str, Tuple[int, int]]:
    """{opcode: (count, total_bytes)}"""
    out: Dict[str, Tuple[int, int]] = {}
    for op in parse_collectives(hlo_text):
        c, b = out.get(op.opcode, (0, 0))
        out[op.opcode] = (c + 1, b + op.bytes)
    return out


def analytic_traffic(cfg, shape, *, params_bytes: float, opt_bytes: float = 0,
                     cache_bytes: float = 0, accum: int = 1,
                     remat: bool = True) -> Dict[str, float]:
    """Modeled per-step global HBM traffic (bytes), by component.

    Assumptions (stated in EXPERIMENTS.md): flash-style attention keeps
    per-block score temporaries in VMEM; weights are re-read from HBM per
    microbatch (fwd + remat-fwd + bwd); the baseline decode cache write is a
    full-cache jnp.where (read+write whole cache) — a deliberate baseline
    inefficiency that §Perf hillclimbs away.
    """
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    t: Dict[str, float] = {}
    if shape.kind == "train":
        reads_per_ub = 2 + (1 if remat else 0)           # fwd + bwd (+remat)
        t["weights"] = reads_per_ub * accum * params_bytes
        t["optimizer"] = 2 * params_bytes + 2 * opt_bytes     # p r/w + m,v r/w
        t["grads"] = 2 * accum * params_bytes                 # accum buffer r/w
        t["stash"] = 4.0 * tokens * d * L * 2                 # h save w+r (bf16)
        t["logits"] = 4.0 * tokens * V * 2                    # write + read, bf16
        if cfg.moe is not None:
            cap = cfg.moe.capacity_factor * cfg.moe.top_k
            t["moe_dispatch"] = 8.0 * cap * tokens * d * L    # in/out buf w+r
    elif shape.kind == "prefill":
        t["weights"] = params_bytes                      # bf16 serving weights
        t["cache_write"] = cache_bytes
        t["activations"] = 4.0 * tokens * d * L * 2
        t["logits"] = 2.0 * shape.global_batch * V * 2
    else:                                                # decode
        t["weights"] = params_bytes
        t["cache"] = 2.0 * cache_bytes                   # full r+w (baseline)
        t["logits"] = 2.0 * shape.global_batch * V * 2
        t["activations"] = 8.0 * shape.global_batch * d * L * 2
    t["total"] = sum(t.values())
    return t


@dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    bound_s: float
    model_flops: float
    useful_ratio: float          # MODEL_FLOPS / (flops_per_device * chips)
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0
    hbm_total_gib: float = 0.0
    fits_hbm: bool = True
    coll_by_op: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    note: str = ""
    roofline_frac: float = 0.0   # model-flops time / bound (the §Perf score)
    traffic: Dict[str, float] = field(default_factory=dict)
    xla_bytes_accessed: float = 0.0

    def to_json(self) -> dict:
        return asdict(self)


def build_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                 cost: Dict, mem, hlo_text: str, model_flops: float,
                 traffic: Optional[Dict[str, float]] = None,
                 note: str = "") -> CellReport:
    """Assemble a cell's roofline from the compiled artifact.

    * compute term: loop-aware MXU (dot/conv) FLOPs parsed from optimized HLO
      (hlo_costs.analyze — cost_analysis() undercounts while bodies),
      per-device = parsed (the HLO is already the per-partition program).
    * memory term: analytic HBM traffic model (global / chips); XLA 'bytes
      accessed' is recorded for reference but mixes VMEM-resident temporaries.
    * collective term: loop-aware per-device collective result bytes.
    """
    from repro.analysis.hlo_costs import analyze
    la = analyze(hlo_text)
    flops = float(la.flops)                       # per-device (SPMD program)
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    traffic = traffic or {}
    mem_bytes_dev = traffic.get("total", xla_bytes * chips) / chips
    cbytes = float(la.collective_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes_dev / HBM_BW
    collective_s = cbytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    arg = int(getattr(mem, "argument_size_in_bytes", 0) or 0)
    tmp = int(getattr(mem, "temp_size_in_bytes", 0) or 0)
    outb = int(getattr(mem, "output_size_in_bytes", 0) or 0)
    alias = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
    hbm = (arg + tmp + outb - alias) / 2 ** 30
    model_time = (model_flops / chips) / PEAK_FLOPS
    rep = CellReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=mem_bytes_dev,
        coll_bytes_per_device=cbytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, bound_s=bound,
        model_flops=model_flops,
        useful_ratio=model_flops / max(flops * chips, 1e-9),
        arg_bytes=arg, temp_bytes=tmp, out_bytes=outb,
        hbm_total_gib=hbm, fits_hbm=hbm <= 16.0,
        coll_by_op={k: (0, int(v)) for k, v in la.coll_by_op.items()},
        note=note,
        roofline_frac=model_time / max(bound, 1e-12),
    )
    rep.traffic = {k: float(v) for k, v in traffic.items()}
    rep.xla_bytes_accessed = xla_bytes
    return rep
