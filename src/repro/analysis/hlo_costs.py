"""Loop-aware cost extraction from optimized HLO text.

XLA's HloCostAnalysis counts while-loop bodies once, so scanned programs
(layer scans, KV-block scans, grad-accumulation scans) under-report FLOPs and
collective bytes. This module parses the optimized HLO, reconstructs the
computation call graph (while bodies, fusion calls, conditionals), extracts
each while loop's trip count from its condition, and sums

  * dot FLOPs  (2 * prod(result dims) * prod(contracting dims)),
  * convolution FLOPs (2 * prod(result dims) * kernel_elems * Cin/groups),
  * collective result bytes (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute)

scaled by the product of enclosing trip counts. Validated against
cost_analysis() of unrolled programs in tests/test_hlo_costs.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s*(?P<result>\(?[^=]*?\)?)\s*"
    r"(?P<op>all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)\(")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns one dict; newer jax returns a list with one dict per
    partition (length 1 for unsharded programs). Returns a single flat dict,
    summing shared keys across partitions.
    """
    ca = compiled.cost_analysis() if callable(
        getattr(compiled, "cost_analysis", None)) else compiled
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    out: Dict[str, float] = {}
    for part in ca:
        for k, v in part.items():
            out[k] = out.get(k, 0.0) + v
    return out


def compiled_cycles(compiled, *, flops_per_cycle: float = 2.0 * 128 * 128,
                    bytes_per_cycle: float = 128.0) -> float:
    """Roofline cycle estimate from a compiled program's cost analysis.

    Deterministic (static analysis, no wall clock): cycles are the max of
    the compute leg (flops / MXU flops-per-cycle) and the memory leg
    (bytes accessed / HBM bytes-per-cycle), floored at 1. Returns 0.0 when
    the backend reports no usable counters (caller falls back to a modeled
    estimate — kernels/kernel_costs.py).
    """
    d = cost_analysis_dict(compiled)
    flops = float(d.get("flops", 0.0))
    nbytes = float(d.get("bytes accessed", 0.0))
    if flops <= 0.0 and nbytes <= 0.0:
        return 0.0
    return max(1.0, flops / flops_per_cycle, nbytes / bytes_per_cycle)


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _shape_of(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(dt, _dims(dd)) for dt, dd in _SHAPE_RE.findall(type_str)]


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)   # %name -> type str


# type is everything (incl. tuple types with /*index=N*/ comments) up to the
# first `opcode(` token; lazy match keeps the opcode out of the type group.
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?(?P<name>%[\w\.\-]+)\s*=\s*(?P<type>.*?)"
    r"(?P<opcode>[\w\-]+)\((?P<args>.*)$")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        header = re.match(r"^\s*(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if header:
            cur = Computation(name=header.group(2))
            comps[cur.name] = cur
            if header.group(1):
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, tstr, opcode = m.group("name"), m.group("type").strip(), \
            m.group("opcode")
        args = m.group("args")
        operands = re.findall(r"%[\w\.\-]+", args.split("),")[0]) \
            if args else []
        instr = Instruction(name=name, result_type=tstr, opcode=opcode,
                            operands=operands, raw=line)
        cur.instructions.append(instr)
        cur.types[name] = tstr
    return comps


def _attr(raw: str, key: str) -> Optional[str]:
    m = re.search(key + r"=([%\w\.\-]+)", raw)
    return m.group(1) if m else None


def _attr_dims(raw: str, key: str) -> List[int]:
    m = re.search(key + r"=\{([0-9,]*)\}", raw)
    return _dims(m.group(1)) if m else []


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Extract the loop bound from a while condition.

    JAX lowers scan/fori to canonical `while i < N` loops; after optimization
    the compare may be wrapped in a fusion whose constant bound operand lives
    in the condition computation. The bound is the max integer constant
    reachable from the condition (0/1 step constants are dominated by N)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 0
    for ins in cond.instructions:
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
        elif ins.opcode in ("fusion", "call"):
            callee = _attr(ins.raw, "calls")
            if callee and callee in comps:
                for ins2 in comps[callee].instructions:
                    if ins2.opcode == "constant":
                        m = re.search(r"constant\((-?\d+)\)", ins2.raw)
                        if m:
                            best = max(best, int(m.group(1)))
    return max(1, best)


def _dot_flops(comp: Computation, ins: Instruction) -> float:
    out_elems = 1
    for _, dims in _shape_of(ins.result_type):
        for d in dims:
            out_elems *= d
    lhs = ins.operands[0] if ins.operands else None
    lhs_type = comp.types.get(lhs, "")
    lhs_shape = _shape_of(lhs_type)
    contract = _attr_dims(ins.raw, "lhs_contracting_dims")
    k = 1
    if lhs_shape:
        dims = lhs_shape[0][1]
        for c in contract:
            if c < len(dims):
                k *= dims[c]
    return 2.0 * out_elems * max(k, 1)


def _conv_flops(comp: Computation, ins: Instruction) -> float:
    out_elems = 1
    for _, dims in _shape_of(ins.result_type):
        for d in dims:
            out_elems *= d
    rhs = ins.operands[1] if len(ins.operands) > 1 else None
    rhs_shape = _shape_of(comp.types.get(rhs, ""))
    if not rhs_shape:
        return 0.0
    kelems = 1
    for d in rhs_shape[0][1]:
        kelems *= d
    # kernel = (spatial..., Cin/g, Cout): flops = 2*out*kelems/Cout
    cout = rhs_shape[0][1][-1] if rhs_shape[0][1] else 1
    return 2.0 * out_elems * kelems / max(cout, 1)


@dataclass
class LoopAwareCosts:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    collective_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = field(default_factory=list)

    @property
    def flops(self) -> float:
        return self.dot_flops + self.conv_flops


def analyze(text: str) -> LoopAwareCosts:
    comps = parse_hlo(text)
    out = LoopAwareCosts()
    entry = comps.get("__entry__")
    if entry is None:
        return out
    seen_whiles: List[int] = []

    def walk(comp: Computation, mult: float, depth: int = 0):
        if depth > 12:
            return
        for ins in comp.instructions:
            if ins.opcode == "dot":
                out.dot_flops += mult * _dot_flops(comp, ins)
            elif ins.opcode == "convolution":
                out.conv_flops += mult * _conv_flops(comp, ins)
            elif ins.opcode == "while":
                body = _attr(ins.raw, "body")
                cond = _attr(ins.raw, "condition")
                t = trip_count(comps, cond) if cond else 1
                out.n_while += 1
                out.trip_counts.append(t)
                if body and body in comps:
                    walk(comps[body], mult * t, depth + 1)
            elif ins.opcode in ("fusion", "call", "custom-call"):
                callee = _attr(ins.raw, "calls")
                if callee and callee in comps:
                    walk(comps[callee], mult, depth + 1)
            elif ins.opcode == "conditional":
                for key in ("true_computation", "false_computation"):
                    callee = _attr(ins.raw, key)
                    if callee and callee in comps:
                        walk(comps[callee], mult, depth + 1)
            m = _COLL_RE.search(ins.raw)
            if m:
                op = m.group("op").replace("-start", "")
                b = mult * _nbytes(m.group("result"))
                out.collective_bytes += b
                out.coll_by_op[op] = out.coll_by_op.get(op, 0.0) + b
    walk(entry, 1.0)
    return out
