"""Seeded, deterministic fault scenarios for the deployment layers
(DESIGN.md §17).

A ``FaultTrace`` is the failure-side twin of ``trace.Trace``: where a
``Trace`` is the offered load, a ``FaultTrace`` is the offered *damage* —
a fixed, replayable schedule of

  * **crashes** — ``(unit, t_down, t_up)`` windows during which a unit is
    gone. Consumed by ``simulate_fleet`` as replica crash/restart windows
    (in-flight requests re-enqueue to the central hold queue with a retry
    budget) and by ``simulate_partition`` as chip-preemption windows (the
    stage's server starts no new service inside the window; displaced
    time lands in ``SimReport.down``).
  * **slowdowns** — ``(unit, t0, t1, rate_mult)`` transient straggler
    windows: the unit's service *rate* is multiplied by ``rate_mult``
    (0.5 = half speed) for service begun inside the window. Concurrent
    windows on one unit compound multiplicatively.
  * **ici** — ``(hop, t0, t1, rate_mult)`` ICI-link degradation windows,
    applied to the hop servers of a spatial ``simulate_partition`` chain.

Every field is a plain float array, so a ``FaultTrace`` carries the same
reproducibility contract as the request traces: equal arrays ⇒ equal
simulations, byte for byte, on both event engines. ``inject_faults`` is
the seeded generator (Poisson fault arrivals, exponential outage/straggle
durations); ``zero_fault_trace``/``FaultTrace.none()`` is the explicit
no-op scenario — consuming it is bit-identical to passing ``faults=None``
(regression-gated in ``benchmarks/chaos_bench.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np


def _as_windows(rows, width: int, what: str) -> np.ndarray:
    """Normalize a window table to a sorted (K, width) float64 array."""
    a = np.asarray(rows if rows is not None else [], dtype=np.float64)
    if a.size == 0:
        return np.zeros((0, width), dtype=np.float64)
    a = np.atleast_2d(a)
    if a.shape[1] != width:
        raise ValueError(f"{what} rows must have {width} columns "
                         f"(got shape {a.shape})")
    if np.any(a[:, 0] < 0):
        raise ValueError(f"{what} unit indices must be >= 0")
    if np.any(a[:, 2] <= a[:, 1]):
        raise ValueError(f"{what} windows need t_end > t_start")
    if width == 4 and np.any(a[:, 3] <= 0):
        raise ValueError(f"{what} rate multipliers must be positive")
    # deterministic canonical order: (t_start, unit)
    order = np.lexsort((a[:, 0], a[:, 1]))
    return a[order]


@dataclass
class FaultTrace:
    """One deterministic fault scenario (see module docstring). ``kind``
    tags the generator for reports, mirroring ``Trace.kind``."""
    crashes: np.ndarray = None        # (K, 3) [unit, t_down, t_up]
    slowdowns: np.ndarray = None      # (J, 4) [unit, t0, t1, rate_mult]
    ici: np.ndarray = None            # (I, 4) [hop, t0, t1, rate_mult]
    kind: str = "replay"

    def __post_init__(self):
        self.crashes = _as_windows(self.crashes, 3, "crashes")
        self.slowdowns = _as_windows(self.slowdowns, 4, "slowdowns")
        self.ici = _as_windows(self.ici, 4, "ici")

    @property
    def empty(self) -> bool:
        """True iff the scenario injects nothing — consumers take their
        exact pre-fault code paths (bit-identity contract)."""
        return (len(self.crashes) == 0 and len(self.slowdowns) == 0
                and len(self.ici) == 0)

    @classmethod
    def none(cls) -> "FaultTrace":
        return cls(kind="none")

    def down_windows(self, unit: int) -> List[Tuple[float, float]]:
        """Merged, sorted crash windows of one unit."""
        rows = self.crashes[self.crashes[:, 0] == unit]
        return _merge([(float(a), float(b)) for _, a, b in rows])

    def slow_windows(self, unit: int) -> List[Tuple[float, float, float]]:
        rows = self.slowdowns[self.slowdowns[:, 0] == unit]
        return [(float(a), float(b), float(m)) for _, a, b, m in rows]

    def ici_windows(self, hop: int) -> List[Tuple[float, float, float]]:
        rows = self.ici[self.ici[:, 0] == hop]
        return [(float(a), float(b), float(m)) for _, a, b, m in rows]


def _merge(ws: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """Merge overlapping [a, b) windows (input sorted by start)."""
    out: List[Tuple[float, float]] = []
    for a, b in ws:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


#: Restart-time sentinel for "never comes back": crash windows whose
#: ``t_up`` is at or beyond this are terminal — ``simulate_fleet`` emits
#: no restart event for them (held requests shed at drain instead of
#: completing at an astronomical clock).
NEVER = 1e30


def zero_fault_trace() -> FaultTrace:
    """The explicit no-op scenario; consuming it is bit-identical to
    ``faults=None`` (gated in ``chaos_bench``)."""
    return FaultTrace.none()


def replica_loss(unit: int, t_down: float,
                 t_up: float = float("inf")) -> FaultTrace:
    """The canonical chaos scenario: one unit crashes at ``t_down`` and
    (optionally) restarts at ``t_up`` — e.g. one replica lost at peak
    load, the configuration the failure-aware SLO search is gated on."""
    if not np.isfinite(t_up):
        t_up = NEVER       # terminal: never restarts, still a window
    return FaultTrace(crashes=[[float(unit), float(t_down), float(t_up)]],
                      kind="replica_loss")


def inject_faults(n_units: int, horizon: float, *,
                  crash_rate: float = 0.0, restart_mean: float = 1e6,
                  slow_rate: float = 0.0, slow_mean: float = 1e6,
                  slow_factor: float = 0.5,
                  n_hops: int = 0, ici_rate: float = 0.0,
                  ici_mean: float = 1e6, ici_factor: float = 0.5,
                  seed: int = 0) -> FaultTrace:
    """Seeded fault generator: per-unit Poisson fault arrivals over
    ``[0, horizon)`` with exponential outage/straggle durations —
    deterministic in ``seed`` (same reproducibility contract as the
    request-trace generators). ``*_rate`` are events per cycle per unit;
    ``*_mean`` the mean window length; ``slow_factor``/``ici_factor`` the
    service-rate multiplier inside a straggler/ICI window."""
    if n_units < 1:
        raise ValueError("n_units must be >= 1")
    if horizon <= 0:
        raise ValueError("horizon must be positive")
    if min(restart_mean, slow_mean, ici_mean) <= 0:
        raise ValueError("mean window lengths must be positive")
    if not (0 < slow_factor) or not (0 < ici_factor):
        raise ValueError("rate factors must be positive")
    rng = np.random.default_rng(seed)
    crashes, slows, ici = [], [], []
    for u in range(n_units):
        t = 0.0
        while crash_rate > 0:
            t += rng.exponential(1.0 / crash_rate)
            if t >= horizon:
                break
            crashes.append([u, t, t + rng.exponential(restart_mean)])
            t = crashes[-1][2]
        t = 0.0
        while slow_rate > 0:
            t += rng.exponential(1.0 / slow_rate)
            if t >= horizon:
                break
            slows.append([u, t, t + rng.exponential(slow_mean), slow_factor])
            t = slows[-1][2]
    for h in range(n_hops):
        t = 0.0
        while ici_rate > 0:
            t += rng.exponential(1.0 / ici_rate)
            if t >= horizon:
                break
            ici.append([h, t, t + rng.exponential(ici_mean), ici_factor])
            t = ici[-1][2]
    return FaultTrace(crashes=crashes, slowdowns=slows, ici=ici,
                      kind="injected")


class NodeFaults:
    """Per-node fault evaluator for the chain engines: down windows delay
    the start of service begun inside them (the displaced cycles are the
    node's ``down`` time), straggler windows divide the base service time
    by the product of the rate multipliers active at the *effective*
    start. Both engines call it with the same ``(node, t, base_dt)``
    triples, so faulted runs stay bit-identical heap-vs-calendar — the
    same contract the fault-free engines carry."""

    def __init__(self, down: Sequence[List[Tuple[float, float]]],
                 slow: Sequence[List[Tuple[float, float, float]]]):
        self.down = [list(w) for w in down]
        self.slow = [list(w) for w in slow]

    @classmethod
    def for_chain(cls, faults: FaultTrace, n_stages: int,
                  mode: str) -> "NodeFaults":
        """Map a ``FaultTrace`` onto ``simulate_partition``'s node chain.
        Spatial mode interleaves stages and ICI hops (stage ``s`` at node
        ``2s``, hop ``h`` at node ``2h+1``): crashes/slowdowns hit their
        stage's server, ``ici`` windows hit the hop servers. Temporal mode
        has one executor: every unit's crash and slowdown windows apply to
        it (the single resident program shares the chip); hop windows do
        not (switch stalls are priced analytically)."""
        if mode == "temporal":
            down = [_merge(sorted(
                (float(a), float(b)) for _, a, b in faults.crashes))]
            slow = [[(float(a), float(b), float(m))
                     for _, a, b, m in faults.slowdowns]]
            return cls(down, slow)
        M = 2 * n_stages - 1
        down: List[List[Tuple[float, float]]] = [[] for _ in range(M)]
        slow: List[List[Tuple[float, float, float]]] = [[] for _ in range(M)]
        for s in range(n_stages):
            down[2 * s] = faults.down_windows(s)
            slow[2 * s] = faults.slow_windows(s)
        for h in range(n_stages - 1):
            slow[2 * h + 1] = faults.ici_windows(h)
        return cls(down, slow)

    def __call__(self, m: int, t: float, base_dt: float
                 ) -> Tuple[float, float]:
        """(total occupation, down part) for service begun at ``t``."""
        t0 = t
        down = 0.0
        moved = True
        while moved:             # a delayed start may land in a later window
            moved = False
            for a, b in self.down[m]:
                if a <= t0 < b:
                    down += b - t0
                    t0 = b
                    moved = True
        mult = 1.0
        for a, b, r in self.slow[m]:
            if a <= t0 < b:
                mult *= r
        dt = base_dt if mult == 1.0 else base_dt / mult
        return down + dt, down
