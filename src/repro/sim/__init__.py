"""Discrete-event deployment simulator (DESIGN.md §13).

The layer between search and serving: seeded request traces
(``sim.trace``), an event-driven simulator of a partitioned multi-chip
dataflow deployment (``sim.engine``), and SLO-aware partition selection
(``sim.slo`` — wired into ``partition_pipeline(objective="slo")`` and the
``hass_search`` Eq. 6 lambdas).
"""
from repro.sim.engine import (SIM_TOL, SimReport, saturation_throughput,
                              simulate_partition)
from repro.sim.faults import (FaultTrace, inject_faults, replica_loss,
                              zero_fault_trace)
from repro.sim.slo import (SLO, SimLatencyEvaluator,
                           autoscale_policy_search, latency_percentile,
                           slo_partition_search)
from repro.sim.trace import (Trace, backlogged_trace, bucket_sizes,
                             diurnal_trace, mmpp_trace, poisson_trace,
                             replay_trace, request_rate)

__all__ = [
    "SIM_TOL", "SimReport", "saturation_throughput", "simulate_partition",
    "FaultTrace", "inject_faults", "replica_loss", "zero_fault_trace",
    "SLO", "SimLatencyEvaluator", "autoscale_policy_search",
    "latency_percentile",
    "slo_partition_search", "Trace", "backlogged_trace", "bucket_sizes",
    "diurnal_trace", "mmpp_trace", "poisson_trace", "replay_trace",
    "request_rate",
]
