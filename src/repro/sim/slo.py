"""SLO-aware partition selection: simulation in the search loop (DESIGN.md §13).

Percentile latency under real traffic is not decomposable over pipeline
prefixes, so no exact DP can optimize it directly. Instead
``slo_partition_search`` closes the loop the cheap way the analytic
objectives already paid for: the per-P sum-form and max-min DP picks span
the rate/latency trade-off (max-min maximizes the steady rate and happily
takes more hops; sum minimizes total batch cycles and so avoids expensive
boundaries), every candidate is simulated against the *same* trace, and
the winner is the SLO-feasible candidate with the highest remaining
*capacity* — its analytic ``steady_throughput`` (ties: lowest simulated
tail latency, then fewer cuts). When the SLO does not bind this reduces to
the max-min pick; when it binds (the rate-optimal partition's simulated
tail violates the target) the search walks down the capacity order to the
fastest deployment that still meets it. When no candidate meets the SLO
the least-violating one is returned — degraded, not undefined. All candidates share one ``DSECache``, so the extra objective
sweeps re-read segment frontiers instead of re-searching them.

``SimLatencyEvaluator`` pushes the same term into the HASS loop itself: it
wraps an Eq. 6 evaluator, partitions + simulates each proposal's sparse
stack, and adds ``lat`` (tail latency / SLO target) to the metric dict —
scored by ``hass_search`` through ``Lambdas.lat``, so the TPE can trade
accuracy and throughput against serving latency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

import numpy as np

from repro.core.dse import DSECache, PartitionResult, partition_pipeline
from repro.core.perf_model import HardwareModel, LayerCost, TPUModel
from repro.obs.trace import get_tracer
from repro.sim.engine import SimReport, simulate_partition
from repro.sim.faults import FaultTrace
from repro.sim.trace import Trace


def _fault_set(faults) -> List[FaultTrace]:
    """Normalize a ``faults=`` argument — None, one ``FaultTrace``, or a
    sequence of them — to a list of non-empty scenarios."""
    if faults is None:
        return []
    if isinstance(faults, FaultTrace):
        faults = [faults]
    return [f for f in faults if not f.empty]


@dataclass(frozen=True)
class SLO:
    """A tail-latency service-level objective: the ``quantile`` (percentile
    in 0..100) of per-request latency must stay at or below ``target``
    cycles."""
    target: float
    quantile: float = 99.0

    @classmethod
    def p99_ms(cls, ms: float, hw: HardwareModel) -> "SLO":
        """p99 target given in milliseconds of the model's clock."""
        return cls(target=ms * 1e-3 * hw.freq, quantile=99.0)


def latency_percentile(report: SimReport, quantile: float = 99.0) -> float:
    """The ``latency_percentile`` objective term: tail latency (cycles) of
    one simulated deployment."""
    return report.latency_percentile(quantile)


def slo_partition_search(layers: Sequence[LayerCost], hw: HardwareModel,
                         budget: float, *, slo, trace: Trace,
                         n_parts: int, batch: int = 256,
                         reconfig_cycles: float = 5e7,
                         dse_iters: int = 300,
                         cut_points: Optional[Sequence[int]] = None,
                         cache: Optional[DSECache] = None,
                         chip_budgets: Optional[Sequence[float]] = None,
                         q_depth: int = 8,
                         mode: str = "auto",
                         faults=None,
                         recorder=None) -> PartitionResult:
    """``partition_pipeline(objective="slo")``: pick the partitioning whose
    *simulated* deployment meets the latency SLO (see module docstring for
    the candidate set and selection rule). ``slo`` is an ``SLO`` or a bare
    p99 target in cycles; ``trace`` is the offered load. The returned
    ``PartitionResult`` has ``objective="slo"`` and carries the winning
    candidate's ``sim_report``.

    ``faults`` (a ``FaultTrace`` or a sequence of them) makes the search
    *failure-aware*: every candidate is additionally simulated under each
    fault scenario and its feasibility latency becomes the WORST p99 over
    {nominal} ∪ scenarios — the winner is the max-capacity candidate whose
    tail survives the whole fault set, not just clear weather. The winner's
    per-scenario reports come back in ``fault_reports`` (nominal stays in
    ``sim_report``).

    ``recorder`` (a ``repro.obs.FlightRecorder``) emits one JSONL record
    per simulated candidate — cuts, tail latency, capacity, feasibility,
    and simulate-phase wall time; when the process tracer is enabled each
    candidate also gets a span. Neither changes any returned value."""
    if trace is None:
        raise ValueError("objective='slo' needs trace= (the offered load)")
    if slo is None:
        raise ValueError("objective='slo' needs slo= (an SLO or a p99 "
                         "target in cycles)")
    if not isinstance(slo, SLO):
        slo = SLO(target=float(slo))
    multi_chip = isinstance(hw, TPUModel) and hw.chips > 1
    cache = DSECache() if cache is None else cache
    kw = dict(batch=batch, reconfig_cycles=reconfig_cycles,
              dse_iters=dse_iters, cut_points=cut_points, cache=cache,
              chip_budgets=chip_budgets)
    objectives = ("sum", "maxmin") if multi_chip else ("sum",)
    cands: List[PartitionResult] = []
    seen = set()
    for p in range(1, max(int(n_parts), 1) + 1):
        for obj in objectives:
            c = partition_pipeline(layers, hw, budget, n_parts=p,
                                   objective=obj, **kw)
            if tuple(c.cuts) not in seen:
                seen.add(tuple(c.cuts))
                cands.append(c)
    tr = get_tracer()
    obs = tr.enabled or recorder is not None
    clk = tr.now if tr.enabled else time.perf_counter
    if recorder is not None:
        recorder.header("slo_partition_search", n_parts=n_parts,
                        n_candidates=len(cands), slo_target=slo.target,
                        slo_quantile=slo.quantile, batch=batch,
                        dse_iters=dse_iters, mode=mode,
                        n_faults=len(_fault_set(faults)))
    scenarios = _fault_set(faults)
    sims: List[SimReport] = []
    fsims: List[List[SimReport]] = []
    durs: List[float] = []
    for k, c in enumerate(cands):
        t0 = clk() if obs else 0.0
        sims.append(simulate_partition(layers, hw, c, trace, q_depth=q_depth,
                                       reconfig_cycles=reconfig_cycles,
                                       mode=mode))
        fsims.append([simulate_partition(layers, hw, c, trace,
                                         q_depth=q_depth,
                                         reconfig_cycles=reconfig_cycles,
                                         mode=mode, faults=f)
                      for f in scenarios])
        t1 = clk() if obs else 0.0
        durs.append(t1 - t0)
        if tr.enabled:
            tr.add_span("slo.candidate", t0, t1, depth=0, i=k,
                        cuts=[int(v) for v in c.cuts])
    lats = [max([latency_percentile(r, slo.quantile)]
                + [latency_percentile(fr, slo.quantile) for fr in frs])
            for r, frs in zip(sims, fsims)]

    def capacity(c: PartitionResult) -> float:
        # the schedule's analytic saturation rate: spatial steady rate on a
        # multi-chip slice, amortized temporal rate otherwise
        return c.steady_throughput if sims[0].mode == "spatial" \
            else c.throughput

    feasible = [k for k in range(len(cands)) if lats[k] <= slo.target]
    if feasible:
        # capacity first (analytic — deterministic, unlike the drain-time
        # noise in a finite trace's achieved rate), then tail latency, then
        # fewer chips
        best = max(capacity(cands[k]) for k in feasible)
        tied = [k for k in feasible
                if capacity(cands[k]) >= best * (1 - 1e-12)]
        win = min(tied, key=lambda k: (lats[k], len(cands[k].cuts), k))
    else:
        win = min(range(len(cands)), key=lambda k: (lats[k], k))
    if recorder is not None:
        # scores only exist once the shared-trace sims are in, so the
        # per-candidate records land here rather than inside the sim loop
        for k, c in enumerate(cands):
            recorder.trial(index=k, x=[int(v) for v in c.cuts],
                           score=-lats[k],
                           metrics={"p99": lats[k],
                                    "capacity": capacity(c),
                                    "feasible": bool(lats[k] <= slo.target)},
                           phases={"simulate": durs[k]},
                           objective=c.objective)
        recorder.footer(winner=win, n_feasible=len(feasible))
    if tr.enabled:
        tr.count("slo.candidates", len(cands))
        tr.count("slo.feasible", len(feasible))
    out = replace(cands[win], objective="slo")
    out.sim_report = sims[win]
    if scenarios:
        out.fault_reports = fsims[win]
    return out


def autoscale_policy_search(trace: Trace, *, batch_slots: int,
                            step_cycles: float, prefill_cycles: float = 0.0,
                            buckets=None, max_replicas: int = 4,
                            slo=None, n_trials: int = 48, seed: int = 0,
                            faults=None, retry=None, degradation=None,
                            deadline_cycles=None, recorder=None):
    """TPE over fleet autoscaling-policy knobs (DESIGN.md §14).

    The search space is ``repro.serve.fleet.AutoscalePolicy``'s knobs —
    replica floor (the count schedule's lower bound; the ceiling is
    ``max_replicas``), scale-up/scale-down backlog thresholds, admission
    threshold (``admit_depth``), and batch-boundary slack
    (``boundary_cycles``). Every candidate is scored by ``simulate_fleet``
    against the offered ``trace`` (typically a scaled diurnal or MMPP
    trace) and compared with the best *static* replica count, which is
    simulated first with the same machinery so modeling quirks cancel:

        score = -(replica_cycles / static_cost)
                - 100 * max(0, p99 / static_p99 - 1)       (maximized)

    i.e. spend as few replica-cycles as possible without giving up any
    tail latency versus the static fleet; an optional ``slo`` adds the
    same hinge against its absolute target. Returns ``(policy, report,
    baselines)`` where ``baselines`` maps each static replica count to its
    ``(p99, replica_cycles)`` and ``"static_best"`` to the winning count.
    The returned policy is the *feasible* trial (p99 no worse than the
    best static, and within the SLO when given) with the lowest cost;
    when no trial is feasible, the lowest-p99 trial — degraded, not
    undefined, mirroring ``slo_partition_search``.

    ``faults``/``retry``/``degradation``/``deadline_cycles`` pass through
    to every ``simulate_fleet`` call — static baselines and TPE trials
    alike, so the comparison stays apples-to-apples under the same fault
    scenario. With a deadline the scoring turns shed-aware: trials pay
    ``1000 * excess_shed_fraction`` versus the static best and feasibility
    additionally requires shedding no more than it, so the winner is the
    cheapest policy whose tail AND completion rate both survive the fault
    set (failure-aware SLO search, DESIGN.md §17).

    ``recorder`` (a ``repro.obs.FlightRecorder``) logs one JSONL record
    per TPE trial — knob vector, score, p99/cost/shed, per-phase wall
    time — plus a footer carrying the baselines and the winner; when the
    process tracer is enabled each trial also gets propose/evaluate/tell
    spans. Neither changes any returned value."""
    from repro.core.tpe import TPE
    from repro.serve.fleet import AutoscalePolicy, simulate_fleet
    from repro.serve.serve_loop import DEFAULT_BUCKETS

    buckets = DEFAULT_BUCKETS if buckets is None else buckets
    if slo is not None and not isinstance(slo, SLO):
        slo = SLO(target=float(slo))
    kw = dict(batch_slots=batch_slots, step_cycles=step_cycles,
              prefill_cycles=prefill_cycles, buckets=buckets,
              faults=faults, retry=retry, degradation=degradation,
              deadline_cycles=deadline_cycles)
    max_replicas = max(int(max_replicas), 1)
    n_req = len(trace.arrivals)
    tr = get_tracer()
    obs = tr.enabled or recorder is not None
    clk = tr.now if tr.enabled else time.perf_counter
    if recorder is not None:
        recorder.header("autoscale_policy_search", n_trials=n_trials,
                        seed=seed, max_replicas=max_replicas,
                        batch_slots=batch_slots, n_requests=n_req,
                        slo_target=(slo.target if slo is not None else None))

    def p99_of(rep) -> float:
        # a chaos trial that sheds every request has no latency sample;
        # treat it as infinitely slow rather than erroring the search
        return rep.p99 if rep.completed else float("inf")

    baselines = {}
    sheds = {}
    for r in range(1, max_replicas + 1):
        rep = simulate_fleet(trace, AutoscalePolicy.static(r), **kw)
        baselines[r] = (p99_of(rep), rep.replica_cycles)
        sheds[r] = rep.shed
    static_best = min(baselines, key=lambda r: (sheds[r], baselines[r][0],
                                                baselines[r][1], r))
    p99_s, cost_s = baselines[static_best]
    shed_s = sheds[static_best]
    baselines["static_best"] = static_best

    quantum_cycles = max(float(np.sort(np.asarray(list(buckets)))[0])
                         * step_cycles, 1.0)
    # knobs in log space where the scale is multiplicative
    lo = np.array([np.log(0.02), np.log(0.05), np.log(0.25 * quantum_cycles),
                   np.log(1.0), 1.0])
    hi = np.array([np.log(16.0), np.log(0.95), np.log(64.0 * quantum_cycles),
                   np.log(512.0), float(max_replicas) + 0.999])

    def decode(x) -> AutoscalePolicy:
        up = float(np.exp(x[0]))
        return AutoscalePolicy(
            min_replicas=int(np.clip(int(x[4]), 1, max_replicas)),
            max_replicas=max_replicas,
            scale_up_backlog=up,
            scale_down_backlog=float(np.exp(x[1])) * up,
            boundary_cycles=float(np.exp(x[2])),
            admit_depth=float(np.exp(x[3])))

    opt = TPE(lo, hi, seed=seed)
    trials = []
    for i in range(max(int(n_trials), 1)):
        t0 = clk() if obs else 0.0
        x = opt.ask()
        t1 = clk() if obs else 0.0
        pol = decode(x)
        rep = simulate_fleet(trace, pol, **kw)
        t2 = clk() if obs else 0.0
        p99_t = p99_of(rep)
        hinge = max(0.0, p99_t / p99_s - 1.0)
        if slo is not None:
            hinge += max(0.0, p99_t / slo.target - 1.0)
        shed_pen = 10.0 * max(0, rep.shed - shed_s) / max(n_req, 1)
        score = -(rep.replica_cycles / cost_s) - 100.0 * hinge \
            - 100.0 * shed_pen
        opt.tell(x, score)
        trials.append((pol, rep))
        t3 = clk() if obs else 0.0
        if tr.enabled:
            tr.add_span("trial", t0, t3, depth=0, i=i)
            tr.add_span("propose", t0, t1, depth=1)
            tr.add_span("evaluate", t1, t2, depth=1)
            tr.add_span("tell", t2, t3, depth=1)
        if recorder is not None:
            recorder.trial(index=i, x=x, score=score,
                           metrics={"p99": p99_t,
                                    "replica_cycles": rep.replica_cycles,
                                    "shed": rep.shed},
                           phases={"propose": t1 - t0, "evaluate": t2 - t1,
                                   "tell": t3 - t2})
    feasible = [k for k, (_, rep) in enumerate(trials)
                if p99_of(rep) <= p99_s and rep.shed <= shed_s
                and (slo is None or p99_of(rep) <= slo.target)]
    if feasible:
        win = min(feasible, key=lambda k: (trials[k][1].replica_cycles, k))
    else:
        win = min(range(len(trials)),
                  key=lambda k: (p99_of(trials[k][1]), k))
    policy, report = trials[win]
    if tr.enabled:
        tr.count("autoscale.trials", len(trials))
        tr.count("autoscale.feasible", len(feasible))
    if recorder is not None:
        recorder.footer(winner=win, n_feasible=len(feasible),
                        static_best=static_best,
                        static_p99=p99_s, static_cost=cost_s)
    return policy, report, baselines


class SimLatencyEvaluator:
    """Wrap an Eq. 6 evaluator (``LMEvaluator``/``CNNEvaluator``) with a
    simulated serving-latency term. Each proposal's sparse stack is
    partitioned (one shared ``DSECache`` across all proposals) and
    simulated against a fixed trace; the metric dict gains

      * ``lat``        — tail latency / SLO target (dimensionless; > 1
        means the proposal violates the SLO), subtracted by ``hass_search``
        as ``lambdas.lat * lat``;
      * ``lat_cycles`` — the raw simulated percentile, for reports.

    Everything else (``n_search``, ``sparse_layers``, ``lambdas`` sync)
    passes through to the wrapped evaluator."""

    def __init__(self, base, hw: HardwareModel, budget: float, *, trace:
                 Trace, slo, n_parts: int, batch: int = 64,
                 dse_iters: int = 200,
                 cut_points: Optional[Sequence[int]] = None,
                 objective: str = "auto", q_depth: int = 8,
                 reconfig_cycles: float = 5e7):
        self.base = base
        self.hw, self.budget = hw, budget
        self.trace = trace
        self.slo = slo if isinstance(slo, SLO) else SLO(target=float(slo))
        self.n_parts, self.batch = n_parts, batch
        self.dse_iters, self.cut_points = dse_iters, cut_points
        self.objective, self.q_depth = objective, q_depth
        self.reconfig_cycles = reconfig_cycles
        self.cache = DSECache(materialize_designs=True)

    @property
    def lambdas(self):
        return self.base.lambdas

    @lambdas.setter
    def lambdas(self, v) -> None:
        # hass_search installs its own Eq. 6 weights for the duration of a
        # hardware-aware search; the wrapped evaluator's frontier-point
        # selection must see them
        self.base.lambdas = v

    def __getattr__(self, name):
        return getattr(self.base, name)

    def _lat_terms(self, x) -> dict:
        layers = self.base.sparse_layers(x)
        p = partition_pipeline(layers, self.hw, self.budget,
                               n_parts=self.n_parts, batch=self.batch,
                               reconfig_cycles=self.reconfig_cycles,
                               dse_iters=self.dse_iters,
                               cut_points=self.cut_points,
                               objective=self.objective, cache=self.cache)
        rep = simulate_partition(layers, self.hw, p, self.trace,
                                 q_depth=self.q_depth,
                                 reconfig_cycles=self.reconfig_cycles)
        lat = latency_percentile(rep, self.slo.quantile)
        return {"lat": lat / self.slo.target, "lat_cycles": lat}

    def __call__(self, x) -> dict:
        return {**dict(self.base(x)), **self._lat_terms(x)}

    def evaluate_batch(self, xs) -> List[dict]:
        """Keeps the wrapped evaluator's vectorized batch path (one vmapped
        prune+forward per round on the CNN evaluator) and adds the
        simulated-latency terms per proposal."""
        eval_batch = getattr(self.base, "evaluate_batch", None)
        ms = eval_batch(xs) if eval_batch is not None and len(xs) > 1 \
            else [self.base(x) for x in xs]
        return [{**dict(m), **self._lat_terms(x)} for x, m in zip(xs, ms)]
