"""Seeded request-trace generators for the deployment simulator.

A ``Trace`` is the offered load of a serving deployment: request arrival
times (in accelerator cycles — the unit the whole perf model speaks) plus
a per-request *size* in samples (tokens for LM stacks, images for CNNs).
Generators cover the standard traffic shapes:

  * ``poisson_trace``  — memoryless steady traffic;
  * ``mmpp_trace``     — bursty: a two-state Markov-modulated Poisson
    process alternating a base rate and a burst rate with exponential
    dwell times;
  * ``diurnal_trace``  — a smooth peak/trough ramp (nonhomogeneous Poisson
    by thinning), one period = one "day";
  * ``replay_trace``   — replay recorded arrival/size arrays.

Every generator is deterministic in ``seed``. Per-request sizes follow the
serving stack's batch-shape discipline: ``bucket_sizes`` pads raw sizes up
to the nearest compiled bucket — the same pad-up rule
``CNNEvaluator.evaluate_batch`` applies to ragged proposal batches
(DESIGN.md §8), so simulated service is charged on the shapes an executor
would actually run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Union

import numpy as np

SizeSpec = Union[int, Sequence[int], tuple]


@dataclass
class Trace:
    """A request stream: ``arrivals`` (cycles, nondecreasing) + ``sizes``
    (samples per request). ``kind`` tags the generator for reports."""
    arrivals: np.ndarray
    sizes: np.ndarray
    kind: str = "replay"

    def __post_init__(self):
        self.arrivals = np.asarray(self.arrivals, dtype=np.float64)
        self.sizes = np.asarray(self.sizes, dtype=np.int64)
        if self.sizes.shape != self.arrivals.shape:
            raise ValueError("arrivals and sizes must have equal length")
        if len(self.arrivals) and np.any(np.diff(self.arrivals) < 0):
            raise ValueError("arrivals must be nondecreasing")
        if np.any(self.sizes < 1):
            raise ValueError("request sizes must be >= 1 sample")

    def __len__(self) -> int:
        return len(self.arrivals)

    @property
    def total_samples(self) -> int:
        return int(self.sizes.sum())

    @property
    def span(self) -> float:
        """Cycles from the first to the last arrival."""
        return float(self.arrivals[-1] - self.arrivals[0]) if len(self) \
            else 0.0

    @property
    def offered_load(self) -> float:
        """Mean offered samples/cycle over the arrival span (inf for a
        backlogged trace whose arrivals coincide)."""
        return self.total_samples / self.span if self.span > 0 \
            else float("inf")

    def bucketize(self, buckets: Sequence[int]) -> "Trace":
        """Pad every request size up to its serving bucket (see
        ``bucket_sizes``)."""
        return Trace(self.arrivals.copy(), bucket_sizes(self.sizes, buckets),
                     kind=self.kind)

    def scaled(self, load_factor: float) -> "Trace":
        """The same request sequence offered ``load_factor`` x as fast
        (arrival axis compressed; sizes untouched)."""
        if load_factor <= 0:
            raise ValueError("load_factor must be positive")
        return Trace(self.arrivals / load_factor, self.sizes.copy(),
                     kind=self.kind)


def _draw_sizes(rng: np.random.Generator, n: int, sizes: SizeSpec) -> np.ndarray:
    """Size spec -> (n,) int64: a constant, a uniform choice over shapes,
    or a ``(shapes, probs)`` weighted choice."""
    if isinstance(sizes, (int, np.integer)):
        return np.full(n, int(sizes), dtype=np.int64)
    if (isinstance(sizes, tuple) and len(sizes) == 2
            and not isinstance(sizes[0], (int, np.integer))
            and len(sizes[0]) == len(sizes[1])):
        shapes, probs = sizes
        probs = np.asarray(probs, dtype=np.float64)
        return rng.choice(np.asarray(shapes, dtype=np.int64), size=n,
                          p=probs / probs.sum())
    return rng.choice(np.asarray(list(sizes), dtype=np.int64), size=n)


def bucket_sizes(sizes: np.ndarray, buckets: Sequence[int]) -> np.ndarray:
    """Pad each size up to the smallest bucket that holds it — the
    evaluator's batch-shape rule (a ragged batch pads up to an
    already-compiled shape; DESIGN.md §8). Sizes above the largest bucket
    are served as whole chunks of the largest bucket."""
    b = np.sort(np.asarray(list(buckets), dtype=np.int64))
    if len(b) == 0 or b[0] < 1:
        raise ValueError("buckets must be a nonempty list of sizes >= 1")
    s = np.asarray(sizes, dtype=np.int64)
    idx = np.searchsorted(b, s, side="left")
    out = b[np.minimum(idx, len(b) - 1)]
    over = idx >= len(b)
    out = np.where(over, -(-s // b[-1]) * b[-1], out)
    return out.astype(np.int64)


def request_rate(steady_throughput: float, utilization: float,
                 mean_size: float) -> float:
    """Requests/cycle that offer ``utilization`` of a deployment's steady
    sample rate with ``mean_size`` samples per request."""
    return utilization * steady_throughput / mean_size


def replay_trace(arrivals: Sequence[float], sizes: SizeSpec = 1) -> Trace:
    """Replay recorded arrivals; scalar ``sizes`` broadcasts."""
    arr = np.asarray(arrivals, dtype=np.float64)
    if isinstance(sizes, (int, np.integer)):
        sz = np.full(len(arr), int(sizes), dtype=np.int64)
    else:
        sz = np.asarray(list(sizes), dtype=np.int64)
    return Trace(arr, sz, kind="replay")


def backlogged_trace(n: int, size: int) -> Trace:
    """All requests queued at t=0 — the saturation-measurement workload."""
    return Trace(np.zeros(n), np.full(n, int(size), dtype=np.int64),
                 kind="backlogged")


def poisson_trace(n: int, rate: float, *, sizes: SizeSpec = 1,
                  seed: int = 0) -> Trace:
    """Memoryless arrivals at ``rate`` requests/cycle."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return Trace(arr, _draw_sizes(rng, n, sizes), kind="poisson")


def mmpp_trace(n: int, rate_base: float, rate_burst: float, *,
               dwell_base: float, dwell_burst: float,
               sizes: SizeSpec = 1, seed: int = 0) -> Trace:
    """Two-state Markov-modulated Poisson process: exponential dwell in a
    base state (``rate_base``) and a burst state (``rate_burst``), the
    standard bursty-traffic model. Dwells are mean cycles per visit."""
    if min(rate_base, rate_burst) <= 0 or min(dwell_base, dwell_burst) <= 0:
        raise ValueError("rates and dwells must be positive")
    rng = np.random.default_rng(seed)
    arr = np.empty(n, dtype=np.float64)
    t = 0.0
    burst = False
    t_switch = rng.exponential(dwell_base)
    k = 0
    while k < n:
        rate = rate_burst if burst else rate_base
        nxt = t + rng.exponential(1.0 / rate)
        if nxt >= t_switch:
            # no arrival before the state flips; restart the clock there
            # (exponential interarrivals are memoryless)
            t = t_switch
            burst = not burst
            t_switch = t + rng.exponential(dwell_burst if burst
                                           else dwell_base)
            continue
        t = nxt
        arr[k] = t
        k += 1
    return Trace(arr, _draw_sizes(rng, n, sizes), kind="mmpp")


def diurnal_trace(n: int, rate_trough: float, rate_peak: float,
                  period: float, *, sizes: SizeSpec = 1,
                  seed: int = 0) -> Trace:
    """Smooth diurnal ramp: a nonhomogeneous Poisson process whose rate
    swings sinusoidally between trough and peak once per ``period`` cycles
    (generated by thinning against the peak rate)."""
    if not (0 < rate_trough <= rate_peak) or period <= 0:
        raise ValueError("need 0 < rate_trough <= rate_peak and period > 0")
    rng = np.random.default_rng(seed)
    arr = np.empty(n, dtype=np.float64)
    t = 0.0
    k = 0
    while k < n:
        t += rng.exponential(1.0 / rate_peak)
        rate = rate_trough + (rate_peak - rate_trough) * \
            0.5 * (1.0 - np.cos(2.0 * np.pi * t / period))
        if rng.uniform() * rate_peak <= rate:
            arr[k] = t
            k += 1
    return Trace(arr, _draw_sizes(rng, n, sizes), kind="diurnal")
