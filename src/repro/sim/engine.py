"""Event-driven simulator of a partitioned dataflow deployment (DESIGN.md §13).

``simulate_partition`` replays a request ``Trace`` through the deployment a
``PartitionResult`` describes, as a chain of serial servers with finite
FIFO queues and blocking-after-service backpressure:

  * **spatial** mode (multi-chip ``TPUModel``): one server per resident
    stage (service time = request samples / the stage's DSE rate),
    interleaved with one server per ICI hop (service time = samples x the
    cut's per-sample transfer cycles — the same expression whose
    reciprocal ``partition_pipeline`` min's into ``steady_throughput``).
    Every internal queue holds at most ``q_depth`` waiting requests; a
    server that cannot hand off downstream *blocks* and stalls its own
    upstream — finite activation buffers, not infinite queues.
  * **temporal** mode (single-chip / FPGA reconfiguration schedule): one
    executor runs the partitions back to back per request and stalls for
    every partition *switch* (``reconfig_cycles``, or the ICI batch
    transfer on a multi-chip model forced temporal). A single resident
    partition incurs zero switch stalls — the same accounting
    ``partition_pipeline`` charges (P - 1 switches, none for P = 1).

The simulator is deterministic: all randomness lives in the (seeded)
trace, and simultaneous events resolve in FIFO insertion order.

**Sim-vs-analytic contract** (the subsystem's bit-exactness-style gate,
property-tested in ``tests/test_sim.py`` and gated in
``benchmarks/sim_bench.py``): under a backlogged trace the simulator's
steady completion rate equals the analytic model within ``SIM_TOL`` —
``steady_throughput`` in spatial mode, and the amortized temporal
``throughput`` in temporal mode when request size equals the partition
batch. Deterministic service admits no looser answer: the bottleneck
server is never starved or blocked at saturation, so windowed completion
spacing telescopes to the analytic bottleneck rate up to float
accumulation.
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.dse import PartitionResult, boundary_activations
from repro.core.perf_model import (ACT_BYTES, HardwareModel, LayerCost,
                                   TPUModel)
from repro.sim.trace import Trace, backlogged_trace

# Documented sim-vs-analytic saturation tolerance (relative). Measured
# deviations are float-accumulation level (~1e-12); the slack is margin,
# not permission for modeling drift.
SIM_TOL = 1e-6


@dataclass
class SimReport:
    """What one simulated deployment did. Times are cycles; node arrays
    are indexed by ``node_names`` (stages and ICI links interleaved in
    pipeline order; a single ``executor`` node in temporal mode). The
    queue in front of node 0 is the unbounded admission queue — its
    occupancy is the request backlog."""
    mode: str
    node_names: List[str]
    arrivals: np.ndarray          # (N,)
    sizes: np.ndarray             # (N,) samples per request
    completions: np.ndarray       # (N,)
    latency: np.ndarray           # (N,) completion - arrival
    busy: np.ndarray              # (M,) service cycles per node
    blocked: np.ndarray           # (M,) backpressure-blocked cycles
    queue_mean: np.ndarray        # (M,) time-weighted mean occupancy
    queue_max: np.ndarray         # (M,) peak occupancy
    switch_stalls: int = 0        # partition switches charged (temporal)
    switch_stall_cycles: float = 0.0

    @property
    def completed(self) -> int:
        return len(self.completions)

    @property
    def total_samples(self) -> int:
        return int(self.sizes.sum())

    @property
    def horizon(self) -> float:
        """Cycles from t=0 to the last completion."""
        return float(self.completions.max()) if self.completed else 0.0

    @property
    def achieved_throughput(self) -> float:
        """Samples completed per cycle over the whole horizon (includes
        warmup fill and final drain — the deployment's actual rate)."""
        h = self.horizon
        return self.total_samples / h if h > 0 else 0.0

    @property
    def utilization(self) -> np.ndarray:
        """Per-node busy fraction of the horizon."""
        h = self.horizon
        return self.busy / h if h > 0 else np.zeros_like(self.busy)

    def latency_percentile(self, quantile: float) -> float:
        """Per-request latency percentile, ``quantile`` in 0..100."""
        return float(np.percentile(self.latency, quantile))

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)

    def windowed_throughput(self, warmup: float = 0.5) -> float:
        """Steady completion rate: samples/cycle between the completion at
        the ``warmup`` fraction of the request count and the last one —
        drops pipeline-fill transients, the saturation measurement the
        sim-vs-analytic contract gates. Traces with fewer than two
        completions have no window; fall back to the whole-horizon rate."""
        if self.completed < 2:
            return self.achieved_throughput
        order = np.argsort(self.completions, kind="stable")
        C = self.completions[order]
        S = self.sizes[order].astype(np.float64)
        k0 = min(max(int(len(C) * warmup), 0), len(C) - 2)
        dt = float(C[-1] - C[k0])
        return float(S[k0 + 1:].sum()) / dt if dt > 0 else float("inf")


def _simulate_chain(arrivals: np.ndarray, sizes: np.ndarray,
                    service: Sequence[Callable[[int], float]],
                    caps: Sequence[int]):
    """Core event loop: a chain of M serial servers, FIFO queues of
    capacity ``caps[m]`` in front of each (``caps[0]`` is the unbounded
    admission queue), blocking-after-service handoff. Returns
    (completions, busy, blocked, queue_mean, queue_max)."""
    N, M = len(arrivals), len(service)
    queue = [deque() for _ in range(M)]
    serving: List[Optional[int]] = [None] * M
    held: List[Optional[int]] = [None] * M    # finished, blocked downstream
    block_t = [0.0] * M
    busy = [0.0] * M
    blocked = [0.0] * M
    completions = np.zeros(N, dtype=np.float64)
    q_int = [0.0] * M          # time-weighted occupancy integral
    q_t = [0.0] * M
    q_max = [0] * M

    # (time, seq, node, request): arrivals pre-seeded with node=-1 and
    # seq=request index; FINISH events get monotonically later seqs, so
    # simultaneous events resolve deterministically in insertion order
    events = [(float(arrivals[i]), i, -1, i) for i in range(N)]
    heapq.heapify(events)
    seq = N

    def q_touch(m: int, t: float) -> None:
        q_int[m] += len(queue[m]) * (t - q_t[m])
        q_t[m] = t

    def q_push(m: int, t: float, i: int) -> None:
        q_touch(m, t)
        queue[m].append(i)
        if len(queue[m]) > q_max[m]:
            q_max[m] = len(queue[m])

    def try_start(m: int, t: float) -> None:
        nonlocal seq
        if serving[m] is not None or held[m] is not None or not queue[m]:
            return
        q_touch(m, t)
        i = queue[m].popleft()
        serving[m] = i
        dt = service[m](int(sizes[i]))
        busy[m] += dt
        heapq.heappush(events, (t + dt, seq, m, i))
        seq += 1
        if m > 0:
            unblock(m - 1, t)      # the pop freed a slot in queue[m]

    def unblock(m: int, t: float) -> None:
        if held[m] is None or len(queue[m + 1]) >= caps[m + 1]:
            return
        i = held[m]
        held[m] = None
        blocked[m] += t - block_t[m]
        q_push(m + 1, t, i)
        try_start(m + 1, t)
        try_start(m, t)

    while events:
        t, _, m, i = heapq.heappop(events)
        if m == -1:                               # arrival
            q_push(0, t, i)
            try_start(0, t)
            continue
        serving[m] = None                         # node m finished item i
        if m == M - 1:
            completions[i] = t
            try_start(m, t)
            continue
        if len(queue[m + 1]) < caps[m + 1]:
            q_push(m + 1, t, i)
            try_start(m + 1, t)
            try_start(m, t)
        else:
            held[m] = i                           # backpressure
            block_t[m] = t

    horizon = float(completions.max()) if N else 0.0
    for m in range(M):
        q_touch(m, horizon)
    q_mean = [q_int[m] / horizon if horizon > 0 else 0.0 for m in range(M)]
    return completions, busy, blocked, q_mean, q_max


def simulate_partition(layers: Sequence[LayerCost], hw: HardwareModel,
                       partition: PartitionResult, trace: Trace, *,
                       q_depth: int = 8, reconfig_cycles: float = 5e7,
                       mode: str = "auto") -> SimReport:
    """Simulate ``trace`` through the deployment ``partition`` describes
    (stage rates from its per-stage DSE designs, ICI hops priced at the
    cuts' boundary activations). ``mode="auto"`` picks spatial for a
    multi-chip ``TPUModel`` — the schedule such a slice actually runs —
    and temporal otherwise; ``reconfig_cycles`` is the temporal switch
    stall, matching ``partition_pipeline``'s accounting."""
    rates = [float(r) for r in partition.part_throughput]
    cuts = list(partition.cuts)
    if not rates or min(rates) <= 0:
        raise ValueError("partition must carry positive part_throughput")
    if q_depth < 1:
        raise ValueError("q_depth must be >= 1")
    multi_chip = isinstance(hw, TPUModel) and hw.chips > 1
    if mode == "auto":
        mode = "spatial" if multi_chip else "temporal"
    if mode not in ("spatial", "temporal"):
        raise ValueError(f"unknown mode {mode!r}")

    arrivals = np.asarray(trace.arrivals, dtype=np.float64)
    sizes = np.asarray(trace.sizes, dtype=np.int64)
    N = len(arrivals)
    switch_stalls = 0
    stall_cycles = 0.0

    if mode == "spatial":
        service: List[Callable[[int], float]] = []
        names: List[str] = []
        for s, r in enumerate(rates):
            service.append(lambda sz, r=r: sz / r)
            names.append(f"stage{s}")
            if s < len(rates) - 1:
                hop = hw.ici_transfer_cycles(
                    boundary_activations(layers, cuts[s]) * ACT_BYTES)
                service.append(lambda sz, hop=hop: sz * hop)
                names.append(f"ici{s}")
        caps = [N + 1] + [q_depth] * (len(service) - 1)
    else:
        def switch_of(sz: int) -> float:
            if multi_chip:
                return sum(hw.ici_transfer_cycles(
                    sz * boundary_activations(layers, c) * ACT_BYTES)
                    for c in cuts)
            return sum(reconfig_cycles for _ in cuts)

        def service_one(sz: int) -> float:
            # same fold order as partition_pipeline's time_per_batch:
            # sum of stage times, then the sum of switch stalls
            return sum(sz / r for r in rates) + switch_of(sz)

        service = [service_one]
        names = ["executor"]
        caps = [N + 1]
        if cuts:
            switch_stalls = len(cuts) * N
            stall_cycles = float(sum(switch_of(int(s)) for s in sizes))

    completions, busy, blocked, q_mean, q_max = _simulate_chain(
        arrivals, sizes, service, caps)
    return SimReport(mode=mode, node_names=names, arrivals=arrivals,
                     sizes=sizes, completions=completions,
                     latency=completions - arrivals,
                     busy=np.asarray(busy), blocked=np.asarray(blocked),
                     queue_mean=np.asarray(q_mean),
                     queue_max=np.asarray(q_max, dtype=np.int64),
                     switch_stalls=switch_stalls,
                     switch_stall_cycles=stall_cycles)


def saturation_throughput(layers: Sequence[LayerCost], hw: HardwareModel,
                          partition: PartitionResult, *,
                          n_requests: int = 96, size: Optional[int] = None,
                          q_depth: int = 8, reconfig_cycles: float = 5e7,
                          mode: str = "auto", warmup: float = 0.5) -> float:
    """The simulator's saturation rate: drive a backlogged trace (every
    request queued at t=0) and measure the post-warmup completion rate.
    This is the left side of the sim-vs-analytic contract: within
    ``SIM_TOL`` of ``partition.steady_throughput`` (spatial) or of
    ``partition.throughput`` when ``size`` is the partition batch
    (temporal)."""
    sz = int(partition.batch if size is None else size)
    rep = simulate_partition(layers, hw, partition,
                             backlogged_trace(n_requests, sz),
                             q_depth=q_depth,
                             reconfig_cycles=reconfig_cycles, mode=mode)
    return rep.windowed_throughput(warmup)
