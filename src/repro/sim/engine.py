"""Event-driven simulator of a partitioned dataflow deployment (DESIGN.md §13).

``simulate_partition`` replays a request ``Trace`` through the deployment a
``PartitionResult`` describes, as a chain of serial servers with finite
FIFO queues and blocking-after-service backpressure:

  * **spatial** mode (multi-chip ``TPUModel``): one server per resident
    stage (service time = request samples / the stage's DSE rate),
    interleaved with one server per ICI hop (service time = samples x the
    cut's per-sample transfer cycles — the same expression whose
    reciprocal ``partition_pipeline`` min's into ``steady_throughput``).
    Every internal queue holds at most ``q_depth`` waiting requests; a
    server that cannot hand off downstream *blocks* and stalls its own
    upstream — finite activation buffers, not infinite queues.
  * **temporal** mode (single-chip / FPGA reconfiguration schedule): one
    executor runs the partitions back to back per request and stalls for
    every partition *switch* (``reconfig_cycles``, or the ICI batch
    transfer on a multi-chip model forced temporal). A single resident
    partition incurs zero switch stalls — the same accounting
    ``partition_pipeline`` charges (P - 1 switches, none for P = 1).

The simulator is deterministic: all randomness lives in the (seeded)
trace, and simultaneous events resolve in FIFO insertion order.

**Sim-vs-analytic contract** (the subsystem's bit-exactness-style gate,
property-tested in ``tests/test_sim.py`` and gated in
``benchmarks/sim_bench.py``): under a backlogged trace the simulator's
steady completion rate equals the analytic model within ``SIM_TOL`` —
``steady_throughput`` in spatial mode, and the amortized temporal
``throughput`` in temporal mode when request size equals the partition
batch. Deterministic service admits no looser answer: the bottleneck
server is never starved or blocked at saturation, so windowed completion
spacing telescopes to the analytic bottleneck rate up to float
accumulation.
"""
from __future__ import annotations

import heapq
from bisect import insort
from collections import deque
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.dse import PartitionResult, boundary_activations
from repro.core.perf_model import (ACT_BYTES, HardwareModel, LayerCost,
                                   TPUModel)
from repro.obs.trace import get_tracer
from repro.sim.faults import FaultTrace, NodeFaults
from repro.sim.trace import Trace, backlogged_trace

# Documented sim-vs-analytic saturation tolerance (relative). Measured
# deviations are float-accumulation level (~1e-12); the slack is margin,
# not permission for modeling drift.
SIM_TOL = 1e-6


@dataclass
class SimReport:
    """What one simulated deployment did. Times are cycles; node arrays
    are indexed by ``node_names`` (stages and ICI links interleaved in
    pipeline order; a single ``executor`` node in temporal mode). The
    queue in front of node 0 is the unbounded admission queue — its
    occupancy is the request backlog."""
    mode: str
    node_names: List[str]
    arrivals: np.ndarray          # (N,)
    sizes: np.ndarray             # (N,) samples per request
    completions: np.ndarray       # (N,)
    latency: np.ndarray           # (N,) completion - arrival
    busy: np.ndarray              # (M,) service cycles per node
    blocked: np.ndarray           # (M,) backpressure-blocked cycles
    idle: np.ndarray              # (M,) neither serving nor blocked
    queue_mean: np.ndarray        # (M,) time-weighted mean occupancy
    queue_max: np.ndarray         # (M,) peak occupancy
    switch_stalls: int = 0        # partition switches charged (temporal)
    switch_stall_cycles: float = 0.0
    down: np.ndarray = None       # (M,) fault-displaced cycles (0 if no faults)

    def __post_init__(self):
        if self.down is None:
            self.down = np.zeros_like(self.busy)

    @property
    def completed(self) -> int:
        return len(self.completions)

    @property
    def total_samples(self) -> int:
        return int(self.sizes.sum())

    @property
    def horizon(self) -> float:
        """Cycles from t=0 to the last completion."""
        return float(self.completions.max()) if self.completed else 0.0

    @property
    def achieved_throughput(self) -> float:
        """Samples completed per cycle over the whole horizon (includes
        warmup fill and final drain — the deployment's actual rate)."""
        h = self.horizon
        return self.total_samples / h if h > 0 else 0.0

    @property
    def utilization(self) -> np.ndarray:
        """Per-node busy fraction of the horizon."""
        h = self.horizon
        return self.busy / h if h > 0 else np.zeros_like(self.busy)

    def latency_percentile(self, quantile: float) -> float:
        """Per-request latency percentile, ``quantile`` in 0..100."""
        if len(self.latency) == 0:
            raise ValueError(
                "latency_percentile on a report with zero completions")
        return float(np.percentile(self.latency, quantile))

    @property
    def p50(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99(self) -> float:
        return self.latency_percentile(99.0)

    def windowed_throughput(self, warmup: float = 0.5) -> float:
        """Steady completion rate: samples/cycle between the completion at
        the ``warmup`` fraction of the request count and the last one —
        drops pipeline-fill transients, the saturation measurement the
        sim-vs-analytic contract gates. Traces with fewer than two
        completions have no window; fall back to the whole-horizon rate."""
        if self.completed < 2:
            return self.achieved_throughput
        order = np.argsort(self.completions, kind="stable")
        C = self.completions[order]
        S = self.sizes[order].astype(np.float64)
        k0 = min(max(int(len(C) * warmup), 0), len(C) - 2)
        dt = float(C[-1] - C[k0])
        return float(S[k0 + 1:].sum()) / dt if dt > 0 else float("inf")


def _simulate_chain(arrivals: np.ndarray, sizes: np.ndarray,
                    service: Sequence[Callable[[int], float]],
                    caps: Sequence[int], engine: str = "calendar",
                    fx: Optional[Callable] = None):
    """Simulate a chain of M serial servers, FIFO queues of capacity
    ``caps[m]`` in front of each (``caps[0]`` is the unbounded admission
    queue), blocking-after-service handoff. Returns
    (completions, busy, blocked, idle, queue_mean, queue_max, down).

    ``fx`` is the optional fault hook (``faults.NodeFaults``): called as
    ``fx(node, t, base_dt) -> (occupation, down_part)`` at every service
    start, it injects crash/preemption windows (the displaced cycles land
    in ``down``) and straggler rate multipliers. Base service time stays
    a pure function of size, so the calendar engine's per-size memo keeps
    caching it; both engines call ``fx`` with identical triples, so
    faulted runs carry the same bit-identity contract as fault-free ones.
    ``fx=None`` leaves every pre-fault code path untouched (bit-identity
    with pre-fault builds is regression-gated in ``chaos_bench``).

    Two engines compute the identical schedule:

      * ``"heap"``     — the reference binary-heap event loop;
      * ``"calendar"`` — the fast path (default). The arrival stream IS
        the calendar: it is pre-sorted, so instead of seeding N heap
        entries the loop consumes it lazily through a cursor and keeps
        only the <= M in-flight finish events in a tiny sorted list.
        Single-server chains (temporal mode — the fleet policy search's
        hot path) drop to a vectorized busy-period scan; with faults the
        schedule is time-dependent, so M == 1 runs the general calendar
        loop instead.

    Bit-identity between the two is a hard contract (fuzz-gated in
    ``tests/test_sim.py`` and ``benchmarks/fleet_bench.py``): every float
    the calendar engine accumulates is produced by the same IEEE ops in
    the same order as the heap engine's, and simultaneous events resolve
    in the same deterministic insertion order."""
    if engine == "heap":
        return _simulate_chain_heap(arrivals, sizes, service, caps, fx)
    if engine != "calendar":
        raise ValueError(f"unknown engine {engine!r}")
    if len(service) == 1 and fx is None:
        return _simulate_single_server(arrivals, sizes, service)
    return _simulate_chain_calendar(arrivals, sizes, service, caps, fx)


def _simulate_chain_heap(arrivals: np.ndarray, sizes: np.ndarray,
                         service: Sequence[Callable[[int], float]],
                         caps: Sequence[int], fx: Optional[Callable] = None):
    """Reference event loop: one binary heap holding every pending event."""
    N, M = len(arrivals), len(service)
    queue = [deque() for _ in range(M)]
    serving: List[Optional[int]] = [None] * M
    held: List[Optional[int]] = [None] * M    # finished, blocked downstream
    block_t = [0.0] * M
    busy = [0.0] * M
    down = [0.0] * M
    blocked = [0.0] * M
    idle = [0.0] * M
    idle_t = [0.0] * M         # when the node last went idle
    is_idle = [True] * M       # nodes start idle at t=0
    completions = np.zeros(N, dtype=np.float64)
    q_int = [0.0] * M          # time-weighted occupancy integral
    q_t = [0.0] * M
    q_max = [0] * M

    # (time, seq, node, request): arrivals pre-seeded with node=-1 and
    # seq=request index; FINISH events get monotonically later seqs, so
    # simultaneous events resolve deterministically in insertion order
    events = [(float(arrivals[i]), i, -1, i) for i in range(N)]
    heapq.heapify(events)
    seq = N

    def q_touch(m: int, t: float) -> None:
        q_int[m] += len(queue[m]) * (t - q_t[m])
        q_t[m] = t

    def q_push(m: int, t: float, i: int) -> None:
        q_touch(m, t)
        queue[m].append(i)
        if len(queue[m]) > q_max[m]:
            q_max[m] = len(queue[m])

    def try_start(m: int, t: float) -> None:
        nonlocal seq
        if serving[m] is not None or held[m] is not None:
            return
        if not queue[m]:
            if not is_idle[m]:     # free with nothing to do -> idle
                is_idle[m] = True
                idle_t[m] = t
            return
        if is_idle[m]:
            idle[m] += t - idle_t[m]
            is_idle[m] = False
        q_touch(m, t)
        i = queue[m].popleft()
        serving[m] = i
        dt = service[m](int(sizes[i]))
        if fx is not None:
            dt, dn = fx(m, t, dt)
            busy[m] += dt - dn
            down[m] += dn
        else:
            busy[m] += dt
        heapq.heappush(events, (t + dt, seq, m, i))
        seq += 1
        if m > 0:
            unblock(m - 1, t)      # the pop freed a slot in queue[m]

    def unblock(m: int, t: float) -> None:
        if held[m] is None or len(queue[m + 1]) >= caps[m + 1]:
            return
        i = held[m]
        held[m] = None
        blocked[m] += t - block_t[m]
        q_push(m + 1, t, i)
        try_start(m + 1, t)
        try_start(m, t)

    while events:
        t, _, m, i = heapq.heappop(events)
        if m == -1:                               # arrival
            q_push(0, t, i)
            try_start(0, t)
            continue
        serving[m] = None                         # node m finished item i
        if m == M - 1:
            completions[i] = t
            try_start(m, t)
            continue
        if len(queue[m + 1]) < caps[m + 1]:
            q_push(m + 1, t, i)
            try_start(m + 1, t)
            try_start(m, t)
        else:
            held[m] = i                           # backpressure
            block_t[m] = t

    horizon = float(completions.max()) if N else 0.0
    for m in range(M):
        q_touch(m, horizon)
        if held[m] is not None:    # flush an interval still open at the end
            blocked[m] += horizon - block_t[m]
            held[m] = None
        elif serving[m] is None and is_idle[m]:
            idle[m] += horizon - idle_t[m]
            idle_t[m] = horizon
    q_mean = [q_int[m] / horizon if horizon > 0 else 0.0 for m in range(M)]
    return completions, busy, blocked, idle, q_mean, q_max, down


def _simulate_single_server(arrivals: np.ndarray, sizes: np.ndarray,
                            service: Sequence[Callable[[int], float]]):
    """M == 1 calendar fast path: one FIFO server, no blocking possible,
    so the whole schedule is the busy-period recurrence
    ``S[i] = max(A[i], F[i-1]); F[i] = S[i] + svc[i]`` — evaluated one
    busy period at a time with ``np.add.accumulate``, whose elementwise
    partial sums are the *same sequential float adds* the event loop
    performs (bit-exact; ``np.sum``'s pairwise tree would not be)."""
    N = len(arrivals)
    if N == 0:
        return (np.zeros(0, dtype=np.float64),
                [0.0], [0.0], [0.0], [0.0], [0], [0.0])
    A = np.asarray(arrivals, dtype=np.float64)
    uniq, inv = np.unique(np.asarray(sizes, dtype=np.int64),
                          return_inverse=True)
    svc_fn = service[0]
    svc = np.array([svc_fn(int(s)) for s in uniq], dtype=np.float64)[inv]

    S = np.empty(N)
    F = np.empty(N)
    i0 = 0
    while i0 < N:
        # assume the busy period starting at i0 never ends, then cut at
        # the first arrival strictly later than the running F. Seeding
        # the accumulate with A[i0] keeps every add in the engine's
        # left-to-right order (A + s0) + s1 ..., not A + (s0 + s1).
        Fc = np.add.accumulate(
            np.concatenate([A[i0:i0 + 1], svc[i0:]]))[1:]
        gap = A[i0 + 1:] > Fc[:-1]
        k = int(np.argmax(gap)) + i0 + 1 if gap.any() else N
        S[i0] = A[i0]
        S[i0 + 1:k] = Fc[:k - i0 - 1]
        F[i0:k] = Fc[:k - i0]
        i0 = k
    horizon = float(F[-1])
    busy = float(np.add.accumulate(svc)[-1])
    # idle accrues at each service start that follows a gap; S - F_prev is
    # +0.0 within a busy period, and adding +0.0 to a non-negative
    # accumulator is a bitwise no-op, so the skips need no masking
    idle = float(np.add.accumulate(
        np.concatenate([S[:1], S[1:] - F[:-1]]))[-1])

    # queue-occupancy integral in exact engine touch order, reconstructed
    # by counting rather than sorting. A pop lands inside its own arrival
    # cascade (push_j then immediately pop_j) iff the server was strictly
    # free at A[j]; otherwise it belongs to the triggering finish event,
    # which sorts after every same-time arrival push (arrival seqs < N <=
    # finish seqs in the heap engine). Pops are FIFO, so pop j has exactly
    # j pops before it; searchsorted supplies the push/pop interleaving.
    own = np.empty(N, dtype=bool)
    own[0] = True
    own[1:] = A[1:] > F[:-1]
    pushes_before_pop = np.where(
        own, np.arange(N) + 1, np.searchsorted(A, S, side="right"))
    own_before = np.concatenate([[0], np.cumsum(own)])[:-1]
    pops_before_push = own_before + np.searchsorted(S[~own], A, side="left")
    idx_pop = np.arange(N) + pushes_before_pop
    idx_push = np.arange(N) + pops_before_push
    times = np.empty(2 * N)
    deltas = np.empty(2 * N, dtype=np.int64)
    times[idx_push] = A
    times[idx_pop] = S
    deltas[idx_push] = 1
    deltas[idx_pop] = -1
    occ = np.cumsum(deltas)
    occ_before = np.concatenate([[0], occ[:-1]])
    dt = np.concatenate([[0.0], np.diff(times)])
    q_int = float(np.add.accumulate(occ_before * dt)[-1])
    q_mean = q_int / horizon if horizon > 0 else 0.0
    return F, [busy], [0.0], [idle], [q_mean], [int(occ.max())], [0.0]


def _simulate_chain_calendar(arrivals: np.ndarray, sizes: np.ndarray,
                             service: Sequence[Callable[[int], float]],
                             caps: Sequence[int],
                             fx: Optional[Callable] = None):
    """General-M calendar engine. The heap held N pre-seeded arrivals plus
    <= M finish events; here the sorted arrival array is consumed through
    a cursor and only the finish events live in a bisect-insort'd list.
    The heap's ``try_start``/``unblock`` cascades are inlined with their
    provable no-ops dropped: ``unblock``'s ``try_start(m+1)`` fires right
    after node m+1 started serving (no-op), and an upstream ripple can
    only propagate toward node 0. Bookkeeping ops (and therefore every
    accumulated float) stay in the heap engine's exact order."""
    N, M = len(arrivals), len(service)
    arr = arrivals.tolist() if hasattr(arrivals, "tolist") else list(arrivals)
    szs = sizes.tolist() if hasattr(sizes, "tolist") else [int(s) for s in sizes]
    svc_memo: List[dict] = [dict() for _ in range(M)]

    queue = [deque() for _ in range(M)]
    q_append = [q.append for q in queue]
    q_popleft = [q.popleft for q in queue]
    qlen = [0] * M
    serving = [False] * M
    held = [-1] * M            # request index, -1 = not held
    block_t = [0.0] * M
    busy = [0.0] * M
    down = [0.0] * M
    blocked = [0.0] * M
    idle = [0.0] * M
    idle_t = [0.0] * M
    is_idle = [True] * M
    completions = [0.0] * N
    q_int = [0.0] * M
    q_t = [0.0] * M
    q_max = [0] * M

    pend: List[tuple] = []     # sorted in-flight finish events, <= M
    seq = N
    caps_l = list(caps)
    last = M - 1
    ai = 0
    INF = float("inf")

    while True:
        at = arr[ai] if ai < N else INF
        if pend and pend[0][0] < at:
            t, _, m, i = pend.pop(0)
            serving[m] = False
            if m == last:
                completions[i] = t
                if qlen[m] and held[m] < 0:        # try_start(m)
                    q_int[m] += qlen[m] * (t - q_t[m])
                    q_t[m] = t
                    j = q_popleft[m]()
                    qlen[m] -= 1
                    serving[m] = True
                    sz = szs[j]
                    memo = svc_memo[m]
                    dt = memo.get(sz)
                    if dt is None:
                        dt = memo[sz] = service[m](sz)
                    if fx is not None:
                        dt, dn = fx(m, t, dt)
                        busy[m] += dt - dn
                        down[m] += dn
                    else:
                        busy[m] += dt
                    insort(pend, (t + dt, seq, m, j))
                    seq += 1
                    w = m
                    while w > 0:                   # upstream ripple
                        k = w - 1
                        if held[k] < 0 or qlen[w] >= caps_l[w]:
                            break
                        h = held[k]
                        held[k] = -1
                        blocked[k] += t - block_t[k]
                        q_int[w] += qlen[w] * (t - q_t[w])
                        q_t[w] = t
                        q_append[w](h)
                        qlen[w] += 1
                        if qlen[w] > q_max[w]:
                            q_max[w] = qlen[w]
                        if qlen[k]:
                            q_int[k] += qlen[k] * (t - q_t[k])
                            q_t[k] = t
                            j = q_popleft[k]()
                            qlen[k] -= 1
                            serving[k] = True
                            sz = szs[j]
                            memo = svc_memo[k]
                            dt = memo.get(sz)
                            if dt is None:
                                dt = memo[sz] = service[k](sz)
                            if fx is not None:
                                dt, dn = fx(k, t, dt)
                                busy[k] += dt - dn
                                down[k] += dn
                            else:
                                busy[k] += dt
                            insort(pend, (t + dt, seq, k, j))
                            seq += 1
                            w = k
                        else:                      # unheld, nothing queued
                            is_idle[k] = True
                            idle_t[k] = t
                            break
                else:
                    is_idle[m] = True
                    idle_t[m] = t
                continue
            n = m + 1
            if qlen[n] < caps_l[n]:                # q_push(n) handoff
                q_int[n] += qlen[n] * (t - q_t[n])
                q_t[n] = t
                q_append[n](i)
                qlen[n] += 1
                if qlen[n] > q_max[n]:
                    q_max[n] = qlen[n]
                if not serving[n] and held[n] < 0:  # try_start(n)
                    if is_idle[n]:
                        idle[n] += t - idle_t[n]
                        is_idle[n] = False
                    q_int[n] += qlen[n] * (t - q_t[n])
                    q_t[n] = t
                    j = q_popleft[n]()
                    qlen[n] -= 1
                    serving[n] = True
                    sz = szs[j]
                    memo = svc_memo[n]
                    dt = memo.get(sz)
                    if dt is None:
                        dt = memo[sz] = service[n](sz)
                    if fx is not None:
                        dt, dn = fx(n, t, dt)
                        busy[n] += dt - dn
                        down[n] += dn
                    else:
                        busy[n] += dt
                    insort(pend, (t + dt, seq, n, j))
                    seq += 1
                    # unblock(m): held[m] < 0 on a finish event -> no-op
                if qlen[m] and held[m] < 0:        # try_start(m)
                    q_int[m] += qlen[m] * (t - q_t[m])
                    q_t[m] = t
                    j = q_popleft[m]()
                    qlen[m] -= 1
                    serving[m] = True
                    sz = szs[j]
                    memo = svc_memo[m]
                    dt = memo.get(sz)
                    if dt is None:
                        dt = memo[sz] = service[m](sz)
                    if fx is not None:
                        dt, dn = fx(m, t, dt)
                        busy[m] += dt - dn
                        down[m] += dn
                    else:
                        busy[m] += dt
                    insort(pend, (t + dt, seq, m, j))
                    seq += 1
                    w = m
                    while w > 0:                   # upstream ripple
                        k = w - 1
                        if held[k] < 0 or qlen[w] >= caps_l[w]:
                            break
                        h = held[k]
                        held[k] = -1
                        blocked[k] += t - block_t[k]
                        q_int[w] += qlen[w] * (t - q_t[w])
                        q_t[w] = t
                        q_append[w](h)
                        qlen[w] += 1
                        if qlen[w] > q_max[w]:
                            q_max[w] = qlen[w]
                        if qlen[k]:
                            q_int[k] += qlen[k] * (t - q_t[k])
                            q_t[k] = t
                            j = q_popleft[k]()
                            qlen[k] -= 1
                            serving[k] = True
                            sz = szs[j]
                            memo = svc_memo[k]
                            dt = memo.get(sz)
                            if dt is None:
                                dt = memo[sz] = service[k](sz)
                            if fx is not None:
                                dt, dn = fx(k, t, dt)
                                busy[k] += dt - dn
                                down[k] += dn
                            else:
                                busy[k] += dt
                            insort(pend, (t + dt, seq, k, j))
                            seq += 1
                            w = k
                        else:
                            is_idle[k] = True
                            idle_t[k] = t
                            break
                else:
                    is_idle[m] = True
                    idle_t[m] = t
            else:
                held[m] = i                        # backpressure
                block_t[m] = t
        elif ai < N:                               # arrival -> q_push(0)
            t = at
            i = ai
            ai += 1
            q_int[0] += qlen[0] * (t - q_t[0])
            q_t[0] = t
            q_append[0](i)
            qlen[0] += 1
            if qlen[0] > q_max[0]:
                q_max[0] = qlen[0]
            if not serving[0] and held[0] < 0:     # try_start(0)
                if is_idle[0]:
                    idle[0] += t - idle_t[0]
                    is_idle[0] = False
                q_int[0] += qlen[0] * (t - q_t[0])
                q_t[0] = t
                j = q_popleft[0]()
                qlen[0] -= 1
                serving[0] = True
                sz = szs[j]
                memo = svc_memo[0]
                dt = memo.get(sz)
                if dt is None:
                    dt = memo[sz] = service[0](sz)
                if fx is not None:
                    dt, dn = fx(0, t, dt)
                    busy[0] += dt - dn
                    down[0] += dn
                else:
                    busy[0] += dt
                insort(pend, (t + dt, seq, 0, j))
                seq += 1
        else:
            break

    completions = np.asarray(completions, dtype=np.float64)
    horizon = float(completions.max()) if N else 0.0
    for m in range(M):
        q_int[m] += qlen[m] * (horizon - q_t[m])
        q_t[m] = horizon
        if held[m] >= 0:           # flush an interval still open at the end
            blocked[m] += horizon - block_t[m]
            held[m] = -1
        elif not serving[m] and is_idle[m]:
            idle[m] += horizon - idle_t[m]
            idle_t[m] = horizon
    q_mean = [q_int[m] / horizon if horizon > 0 else 0.0 for m in range(M)]
    return completions, busy, blocked, idle, q_mean, q_max, down


def simulate_partition(layers: Sequence[LayerCost], hw: HardwareModel,
                       partition: PartitionResult, trace: Trace, *,
                       q_depth: int = 8, reconfig_cycles: float = 5e7,
                       mode: str = "auto", engine: str = "calendar",
                       faults: Optional[FaultTrace] = None) -> SimReport:
    """Simulate ``trace`` through the deployment ``partition`` describes
    (stage rates from its per-stage DSE designs, ICI hops priced at the
    cuts' boundary activations). ``mode="auto"`` picks spatial for a
    multi-chip ``TPUModel`` — the schedule such a slice actually runs —
    and temporal otherwise; ``reconfig_cycles`` is the temporal switch
    stall, matching ``partition_pipeline``'s accounting. ``engine``
    selects the event engine (``"calendar"`` default, ``"heap"``
    reference — bit-identical by contract, see ``_simulate_chain``).

    ``faults`` injects a deterministic ``FaultTrace`` (DESIGN.md §17):
    stage crash/preemption windows park the server (displaced cycles in
    ``SimReport.down``), straggler windows divide its rate, ``ici`` rows
    degrade the hop servers (spatial mode). ``None`` — or an *empty*
    trace — leaves every pre-fault code path untouched."""
    rates = [float(r) for r in partition.part_throughput]
    cuts = list(partition.cuts)
    if not rates or min(rates) <= 0:
        raise ValueError("partition must carry positive part_throughput")
    if q_depth < 1:
        raise ValueError("q_depth must be >= 1")
    multi_chip = isinstance(hw, TPUModel) and hw.chips > 1
    if mode == "auto":
        mode = "spatial" if multi_chip else "temporal"
    if mode not in ("spatial", "temporal"):
        raise ValueError(f"unknown mode {mode!r}")

    arrivals = np.asarray(trace.arrivals, dtype=np.float64)
    sizes = np.asarray(trace.sizes, dtype=np.int64)
    N = len(arrivals)
    switch_stalls = 0
    stall_cycles = 0.0

    if mode == "spatial":
        service: List[Callable[[int], float]] = []
        names: List[str] = []
        for s, r in enumerate(rates):
            service.append(lambda sz, r=r: sz / r)
            names.append(f"stage{s}")
            if s < len(rates) - 1:
                hop = hw.ici_transfer_cycles(
                    boundary_activations(layers, cuts[s]) * ACT_BYTES)
                service.append(lambda sz, hop=hop: sz * hop)
                names.append(f"ici{s}")
        caps = [N + 1] + [q_depth] * (len(service) - 1)
    else:
        def switch_of(sz: int) -> float:
            if multi_chip:
                return sum(hw.ici_transfer_cycles(
                    sz * boundary_activations(layers, c) * ACT_BYTES)
                    for c in cuts)
            return sum(reconfig_cycles for _ in cuts)

        def service_one(sz: int) -> float:
            # same fold order as partition_pipeline's time_per_batch:
            # sum of stage times, then the sum of switch stalls
            return sum(sz / r for r in rates) + switch_of(sz)

        service = [service_one]
        names = ["executor"]
        caps = [N + 1]
        if cuts:
            switch_stalls = len(cuts) * N
            stall_cycles = float(sum(switch_of(int(s)) for s in sizes))

    fx = None
    if faults is not None and not faults.empty:
        fx = NodeFaults.for_chain(faults, len(rates), mode)
    completions, busy, blocked, idle, q_mean, q_max, down = _simulate_chain(
        arrivals, sizes, service, caps, engine=engine, fx=fx)
    tr = get_tracer()
    if tr.enabled:
        # no per-event cost even when tracing: a full chain serves every
        # request once per node, so the event count (N arrivals + N*M
        # service finishes) is derivable after the fact
        M = len(service)
        fast = engine == "calendar" and M == 1 and fx is None
        tr.count("sim.runs")
        tr.count(f"sim.mode.{mode}")
        tr.count("sim.engine.single_server" if fast
                 else f"sim.engine.{engine}")
        tr.count("sim.requests", N)
        tr.count("sim.events", N * (M + 1))
    return SimReport(mode=mode, node_names=names, arrivals=arrivals,
                     sizes=sizes, completions=completions,
                     latency=completions - arrivals,
                     busy=np.asarray(busy), blocked=np.asarray(blocked),
                     idle=np.asarray(idle),
                     queue_mean=np.asarray(q_mean),
                     queue_max=np.asarray(q_max, dtype=np.int64),
                     switch_stalls=switch_stalls,
                     switch_stall_cycles=stall_cycles,
                     down=np.asarray(down, dtype=np.float64))


def saturation_throughput(layers: Sequence[LayerCost], hw: HardwareModel,
                          partition: PartitionResult, *,
                          n_requests: int = 96, size: Optional[int] = None,
                          q_depth: int = 8, reconfig_cycles: float = 5e7,
                          mode: str = "auto", warmup: float = 0.5) -> float:
    """The simulator's saturation rate: drive a backlogged trace (every
    request queued at t=0) and measure the post-warmup completion rate.
    This is the left side of the sim-vs-analytic contract: within
    ``SIM_TOL`` of ``partition.steady_throughput`` (spatial) or of
    ``partition.throughput`` when ``size`` is the partition batch
    (temporal)."""
    sz = int(partition.batch if size is None else size)
    rep = simulate_partition(layers, hw, partition,
                             backlogged_trace(n_requests, sz),
                             q_depth=q_depth,
                             reconfig_cycles=reconfig_cycles, mode=mode)
    return rep.windowed_throughput(warmup)
