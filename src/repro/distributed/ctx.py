"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names
(``shard(x, "batch", "seq", "embed")``); a context installed by the launcher
maps logical names to physical mesh axes and applies
``with_sharding_constraint``. Outside any context the calls are identity, so
the same model code runs on 1 CPU device (tests) and on a 512-chip mesh
(dry-run / production) unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

Axis = Union[None, str, Tuple[str, ...]]

# Default logical -> physical rules (physical axes: pod, data, model).
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": None,                 # sequence sharding enabled per-config ("model")
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,
    "fsdp": ("pod", "data"),     # parameter sharding over the data axes
}


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            phys = self.rules.get(name)
            if phys is None:
                axes.append(None)
            else:
                # drop axes absent from the mesh (e.g. "pod" on single-pod)
                if isinstance(phys, tuple):
                    phys = tuple(a for a in phys if a in self.mesh.axis_names)
                    phys = phys if phys else None
                elif phys not in self.mesh.axis_names:
                    phys = None
                axes.append(phys)
        return P(*axes)


def current() -> Optional[ShardingCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Optional[Dict[str, Axis]] = None):
    prev = current()
    _state.ctx = ShardingCtx(mesh, rules)
    try:
        yield _state.ctx
    finally:
        _state.ctx = prev


def _axis_size(mesh: Mesh, phys) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if phys is None:
        return 1
    if isinstance(phys, tuple):
        n = 1
        for a in phys:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(phys, 1)


def shard(x, *logical: Optional[str]):
    """Constrain ``x`` to the logical spec under the active context (else id).

    Axes whose size does not divide the dimension are dropped: a non-dividing
    constraint (e.g. 8 KV heads on a 16-way model axis) makes GSPMD pad and
    then 'involuntarily rematerialize' — i.e. all-gather — around it.
    """
    ctx = current()
    if ctx is None:
        return x
    spec = ctx.spec(*logical)
    clean = []
    for dim, phys in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        n = _axis_size(ctx.mesh, phys)
        clean.append(phys if (n > 1 and dim % n == 0) or n == 1 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*clean)))


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    ctx = current()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, ctx.spec(*logical))
