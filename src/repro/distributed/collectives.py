"""Distributed-optimization collectives.

``compressed_psum``: int8 + error-feedback gradient all-reduce, expressed with
shard_map so the wire format really is int8 (8x fewer collective bytes than
f32). Error feedback keeps the quantization bias out of the trajectory
(EF-SGD style): e_{t+1} = x_t + e_t - Q^{-1}(Q(x_t + e_t)).

Inside a pjit/GSPMD train step gradients are already summed by the partitioner,
so the quantize/EF numerics are also exposed standalone (``ef_quantize``) and
the train step can model them; the shard_map collective is exercised directly
by tests and by the DDP-style example.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x: jnp.ndarray, block: int = 256):
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale, shape


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def ef_quantize(x: jnp.ndarray, err: jnp.ndarray, block: int = 256):
    """Quantize (x + err) to int8; return (dequantized, new_err)."""
    y = x + err
    q, s, shape = quantize_int8(y, block)
    deq = dequantize_int8(q, s, shape)
    return deq, y - deq


def compressed_psum(x: jnp.ndarray, err: jnp.ndarray, mesh: Mesh,
                    axis: str = "data", block: int = 256):
    """Mean-all-reduce stacked per-device contributions with an int8 wire
    format + error feedback.

    x, err: (n_devices_on_axis, *shape) sharded P(axis) — row i is device i's
    local gradient. Returns (mean (n, *shape) — identical rows, new_err).
    """
    def body(x_loc, e_loc):
        y = x_loc + e_loc
        q, s, shape = quantize_int8(y, block)
        deq_local = dequantize_int8(q, s, shape)
        new_err = y - deq_local
        # The value entering the collective is exactly the int8-representable
        # payload (q*s); a production runtime sums q with per-block rescale.
        # Roofline accounting for this path uses the int8 payload size.
        total = jax.lax.psum(deq_local, axis)
        n = jax.lax.psum(jnp.ones(()), axis)
        return total / n, new_err

    return shard_map(body, mesh=mesh, in_specs=(P(axis), P(axis)),
                     out_specs=(P(axis), P(axis)))(x, err)
