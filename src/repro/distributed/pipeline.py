"""Pipeline parallelism (GPipe-style) via shard_map + collective_permute.

The paper's architecture IS a layer pipeline (Fig. 3); on a TPU mesh the
equivalent is stage parallelism: layers are partitioned into S stages mapped
to a 'stage' mesh axis, microbatches flow stage-to-stage over ICI with
``jax.lax.ppermute``, and the bubble fraction is (S-1)/(S-1+M) for M
microbatches. The HASS DSE's rate balancing (Eq. 4-5) chooses the layer->
stage assignment so per-stage (sparsity-scaled) work is even — exported here
as ``balanced_stage_assignment``.

Stages run the *same* scanned-block program with their own parameter shard —
layer-stacked params make a stage just a contiguous slice of the stack.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.perf_model import LayerCost


def balanced_stage_assignment(costs: Sequence[float], n_stages: int
                              ) -> List[int]:
    """Contiguous partition of layers into stages minimizing the max stage
    cost (the pipeline bottleneck, Eq. 3). DP over prefix sums; costs are the
    sparsity-scaled per-layer times from the HASS perf model."""
    L = len(costs)
    n_stages = min(n_stages, L)
    pre = np.concatenate([[0.0], np.cumsum(costs)])

    def seg(i, j):
        return pre[j] - pre[i]

    dp = np.full((n_stages + 1, L + 1), np.inf)
    cut = np.zeros((n_stages + 1, L + 1), dtype=int)
    dp[0, 0] = 0.0
    for s in range(1, n_stages + 1):
        for j in range(1, L + 1):
            for i in range(s - 1, j):
                v = max(dp[s - 1, i], seg(i, j))
                if v < dp[s, j]:
                    dp[s, j], cut[s, j] = v, i
    bounds = [L]
    for s in range(n_stages, 0, -1):
        bounds.append(int(cut[s, bounds[-1]]))
    bounds = bounds[::-1]
    assign = []
    for s in range(n_stages):
        assign += [s] * (bounds[s + 1] - bounds[s])
    return assign


def make_pipelined_fn(stage_fn: Callable, mesh: Mesh, *, n_stages: int,
                      n_microbatches: int, stage_axis: str = "stage"):
    """Wrap ``stage_fn(stage_params, x) -> x`` into a GPipe loop.

    stage_params: leading axis = stage (sharded over stage_axis).
    x: (n_microbatches, mb, ...) replicated; returns same shape.
    Schedule: T = n_microbatches + n_stages - 1 ticks; at tick t, stage s
    processes microbatch t - s; activations hop s -> s+1 via ppermute.
    """
    S, M = n_stages, n_microbatches

    def pipelined(stage_params, x):
        def body(params_local, xs):
            params_local = jax.tree_util.tree_map(lambda a: a[0], params_local)
            sid = jax.lax.axis_index(stage_axis)
            state = jnp.zeros_like(xs[0])                  # stage input buffer
            outs = jnp.zeros_like(xs)

            def tick(carry, t):
                state, outs = carry
                mb_idx = t - sid
                feed = jnp.where(sid == 0,
                                 xs[jnp.clip(t, 0, M - 1)], state)
                y = stage_fn(params_local, feed)
                valid = (mb_idx >= 0) & (mb_idx < M)
                # last stage writes its result at mb_idx
                outs = jax.lax.cond(
                    valid & (sid == S - 1),
                    lambda o: o.at[jnp.clip(mb_idx, 0, M - 1)].set(y),
                    lambda o: o, outs)
                # hop to next stage (ring; last->first carries garbage, unused)
                nxt = jax.lax.ppermute(
                    y, stage_axis, [(i, (i + 1) % S) for i in range(S)])
                return (nxt, outs), None

            (_, outs), _ = jax.lax.scan(tick, (state, outs),
                                        jnp.arange(S + M - 1))
            return outs[None]                    # (1, M, mb, ...) per stage

        specs_p = jax.tree_util.tree_map(
            lambda _: P(stage_axis), stage_params)
        stacked = shard_map(body, mesh=mesh,
                            in_specs=(specs_p, P()),
                            out_specs=P(stage_axis),
                            check_rep=False)(stage_params, x)
        return stacked[-1]                       # the last stage's outputs

    return pipelined


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_stages - 1 + n_microbatches)
