"""Parameter / state / batch sharding rules, rank-polymorphic in axis names.

Strategy (2D "hybrid FSDP x TP", extended by a pure-DP 'pod' axis):
  * TP ('model'): attention heads, FFN hidden, vocab, experts.
  * FSDP ('pod','data'): the non-TP matrix dimension of every large weight,
    plus optimizer moments — ZeRO-3-style, parameters are all-gathered on use
    by GSPMD and gradients reduce-scattered.
  * Activations: batch over ('pod','data'); heads/ff/vocab over 'model'.

Rules are *patterns over flattened param paths*, so one table covers every
architecture in the pool. Dims that do not divide the axis size fall back to
replication for that dim (GSPMD would pad; we prefer predictable layouts).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# (regex over path, spec template) — template entries name *logical* axes:
#   "tp" -> 'model';  "fsdp" -> ('pod','data');  None -> replicated
# Templates are right-aligned to the array rank (leading dims replicated), so
# stacked-layer arrays (leading L) need no special casing.
RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / heads
    (r"embed$", ("tp", "fsdp")),
    (r"(lm_head|unembed)$", ("fsdp", "tp")),
    (r"(enc_pos|dec_pos)$", (None, None)),
    # attention (GQA + cross): column-parallel in, row-parallel out
    (r"attn/w[qkv]$|cross/w[qkv]$", ("fsdp", "tp")),
    (r"attn/wo$|cross/wo$", ("tp", "fsdp")),
    (r"attn/b[qkv]$", ("tp",)),
    # MLA
    (r"wq_a$|wkv_a$", ("fsdp", None)),
    (r"wq_b$|wkv_b$", (None, "tp")),
    # dense FFN
    (r"ffn/w_gate$|ffn/w_up$|shared_w_gate$|shared_w_up$", ("fsdp", "tp")),
    (r"ffn/w_down$|shared_w_down$", ("tp", "fsdp")),
    # MoE experts: shard experts when divisible (checked at apply time),
    # otherwise shard the hidden dim
    (r"ffn/(w_gate|w_up)$", ("experts", "fsdp", "tp")),      # 4D case (L,E,d,f)
    (r"ffn/w_down$", ("experts", "tp", "fsdp")),             # 4D case (L,E,f,d)
    (r"router$", ("fsdp", None)),
    # rwkv
    (r"blocks/(wr|wk|wv|wg)$", ("fsdp", "tp")),
    (r"blocks/wo$", ("tp", "fsdp")),
    (r"cm_wk$", ("fsdp", "tp")),
    (r"cm_wv$", ("tp", "fsdp")),
    (r"cm_wr$", ("fsdp", "tp")),
    (r"mix_w1$", ("fsdp", None)),
    (r"mix_w2$", (None, None, "fsdp")),
    (r"decay_a$", ("fsdp", None)),
    (r"decay_b$", (None, "fsdp")),
    # mamba
    (r"in_proj$", ("fsdp", "tp")),
    (r"out_proj$", ("tp", "fsdp")),
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    (r"(A_log|D|dt_bias)$", ("tp",)),
    (r"out_norm$", ("tp",)),
    # zamba shared block extras
    (r"shared_proj$", ("fsdp", "tp")),
    # mtp
    (r"mtp/proj$", ("fsdp", "tp")),
    # cnn
    (r"/w$", (None, None, None, "tp")),
    (r"/w1$", (None, "tp")),
    (r"/w2$", ("tp", None)),
)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, a) for a in name]))
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _resolve(mesh: Mesh, logical: Optional[str], no_fsdp: bool = False):
    if logical is None:
        return None
    if logical in ("tp", "experts"):
        return "model" if "model" in mesh.axis_names else None
    if logical == "fsdp":
        if no_fsdp:
            return None
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if axes else None
    return logical if logical in mesh.axis_names else None


def spec_for(mesh: Mesh, path: str, shape: Tuple[int, ...],
             no_fsdp: bool = False) -> P:
    """Right-align the first matching rule template; drop non-divisible axes."""
    ndim = len(shape)
    for pat, template in RULES:
        if not re.search(pat, path):
            continue
        if len(template) > ndim:
            continue
        # 4D expert rule must not hijack 3D dense ffn (and vice versa): take
        # the first template whose length <= ndim AND which, right-aligned,
        # divides. Expert rules are listed after dense so 3D matches dense.
        axes = [None] * (ndim - len(template)) + list(template)
        spec = []
        for dim, logical in zip(shape, axes):
            phys = _resolve(mesh, logical, no_fsdp)
            if phys is None or dim % _axis_size(mesh, phys) != 0:
                spec.append(None)
            else:
                spec.append(phys)
        # avoid duplicate mesh axes in one spec (illegal): keep first use
        used = set()
        clean = []
        for s in spec:
            flat = s if isinstance(s, tuple) else (s,) if s else ()
            if any(a in used for a in flat):
                clean.append(None)
            else:
                used.update(flat)
                clean.append(s)
        return P(*clean)
    return P(*([None] * ndim))


def _moe_aware_path_fix(path: str, shape) -> str:
    return path


def param_specs(mesh: Mesh, params_shape: Any, *, no_fsdp: bool = False,
                embed_tp: bool = False) -> Any:
    """Pytree of PartitionSpec matching a (possibly eval_shape'd) params tree.

    no_fsdp: replicate the data axes (TP-only / pure-DP) — serving layouts
    and small models where per-step weight all-gathers dominate (§Perf).
    embed_tp: shard the embedding table on d_model over 'model' instead of
    vocab — avoids GSPMD's replicate-fallback on the token gather (§Perf).
    """
    from repro.core.pruning import _flatten, _unflatten
    from repro.train.optimizer import Packed8
    flat = _flatten(params_shape)
    specs = {}
    for path, leaf in flat.items():
        if isinstance(leaf, Packed8):
            # int8 block-quantized moment: children q (nblk, blk), s (nblk, 1)
            # — moments join no matmul, so shard the block dim over EVERY
            # mesh axis (fsdp-only sharding left 1.35 TB spread 16-way; §Perf)
            all_axes = tuple(mesh.axis_names) if not no_fsdp else \
                tuple(a for a in mesh.axis_names if a == "model")
            nblk = leaf.q.shape[0]
            if all_axes and nblk % _axis_size(mesh, all_axes) == 0:
                specs[path] = P(all_axes)
            else:
                specs[path] = P()
            continue
        shape = tuple(leaf.shape)
        if embed_tp and re.search(r"(^|/)embed$", path) and len(shape) == 2:
            tp = "model" if "model" in mesh.axis_names else None
            ok = tp and shape[1] % _axis_size(mesh, tp) == 0
            specs[path] = P(None, tp if ok else None)
            continue
        # disambiguate 3D dense-FFN vs 4D expert weights: both match
        # r"ffn/w_gate$" — the template is right-aligned, so the 3-entry
        # expert template on a 3D (L,d,f) dense weight would wrongly shard L.
        if re.search(r"ffn/(w_gate|w_up|w_down)$", path) and len(shape) == 4:
            tmpl = ("experts", "fsdp", "tp") if path.endswith(("w_gate", "w_up")) \
                else ("experts", "tp", "fsdp")
            axes = [None] * (len(shape) - 3) + list(tmpl)
            spec = []
            used = set()
            for dim, logical in zip(shape, axes):
                phys = _resolve(mesh, logical, no_fsdp)
                flat_axes = phys if isinstance(phys, tuple) else \
                    (phys,) if phys else ()
                if phys is None or dim % _axis_size(mesh, phys) != 0 or \
                        any(a in used for a in flat_axes):
                    spec.append(None)
                else:
                    used.update(flat_axes)
                    spec.append(phys)
            specs[path] = P(*spec)
        else:
            specs[path] = spec_for(mesh, path, shape, no_fsdp)
    return _unflatten(specs)


def shardings_for(mesh: Mesh, tree_shape: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(mesh, tree_shape),
        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, batch_shape: Any, dp_axes=None) -> Any:
    """tokens/images/labels: batch dim over ('pod','data') when divisible.
    dp_axes overrides the data-parallel axes (dp_all layouts)."""
    dp = dp_axes or tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = _axis_size(mesh, dp)

    def one(leaf):
        shape = tuple(leaf.shape)
        if shape and shape[0] % dp_size == 0 and dp_size > 1:
            return P(dp, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))
    return jax.tree_util.tree_map(one, batch_shape)


def cache_spec(mesh: Mesh, cache_shape: Any, batch_axis: int = 1) -> Any:
    """KV caches / recurrent states: shard batch if divisible, else the
    longest remaining dim that divides (sequence for long-context B=1)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = _axis_size(mesh, dp)
    tp_size = _axis_size(mesh, "model")

    def one(leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if not shape:
            return P()
        used_dp = False
        for i, dim in enumerate(shape):
            if not used_dp and dim % dp_size == 0 and dp_size > 1 and \
                    i >= min(batch_axis, len(shape) - 1) and dim >= dp_size:
                spec[i] = dp
                used_dp = True
                break
        if "model" in mesh.axis_names and tp_size > 1:
            # shard the largest not-yet-sharded trailing dim divisible by tp
            # (sequence for long caches). NOTE §Perf: sharding head_dim instead
            # was tried and refuted — it makes every decode attention contract
            # over a sharded axis (psum of scores per layer per token).
            cands = [(dim, i) for i, dim in enumerate(shape)
                     if spec[i] is None and dim % tp_size == 0
                     and dim >= tp_size and i > 0]
            if cands:
                _, i = max(cands)
                spec[i] = "model"
        return P(*spec)
    return jax.tree_util.tree_map(one, cache_shape)
