"""HASS: Hardware-Aware Sparsity Search (§V-B) — the paper's main loop.

TPE proposes per-layer (S_w, S_a) targets; we one-shot prune, calibrate, run
the DSE (rate balancing + incrementing) under a resource budget, and score

    f = f_acc + λ1 f_spa + λ2 f_thr − λ3 f_dsp        (Eq. 6)

``hardware_aware=False`` drops the hardware terms (λ2 = λ3 = 0) — the
"software metrics only" baseline of Fig. 5.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning
from repro.core.dse import incremental_dse
from repro.core.perf_model import (FPGAModel, HardwareModel, LayerCost,
                                   TPUModel, lm_layer_costs, pair_sparsity,
                                   tile_quantize_sparsity)
from repro.core.tpe import TPE


@dataclass
class Lambdas:
    """Eq. 6 normalizing hyper-parameters (heuristic, per the paper).
    thr=0.5 keeps the hardware term subordinate to accuracy — with thr=1.0
    a 10-iteration search can prefer a degenerate zero-accuracy corner."""
    spa: float = 0.3
    thr: float = 0.5
    dsp: float = 0.3


@dataclass
class Trial:
    x: np.ndarray
    score: float
    metrics: Dict[str, float]


@dataclass
class SearchResult:
    best_x: np.ndarray
    best_score: float
    best_metrics: Dict[str, float]
    trials: List[Trial] = field(default_factory=list)

    def history(self, key: str) -> List[float]:
        return [t.metrics.get(key, float("nan")) for t in self.trials]

    def running_best(self, key: str) -> List[float]:
        """Metric of the best-scoring trial so far, per iteration (Fig. 5)."""
        out, best, bestscore = [], float("nan"), -np.inf
        for t in self.trials:
            if t.score > bestscore:
                bestscore, best = t.score, t.metrics.get(key, float("nan"))
            out.append(best)
        return out


def hass_search(evaluate: Callable[[np.ndarray], Dict[str, float]],
                n_layers: int, *, iters: int = 96,
                hardware_aware: bool = True,
                lambdas: Lambdas = Lambdas(),
                s_max: float = 0.95, seed: int = 0,
                include_act: bool = True,
                batch_size: Optional[int] = None) -> SearchResult:
    """Search per-layer sparsity targets.

    evaluate(x) must return a dict with keys:
      acc   in [0,1] — accuracy proxy (agreement with the dense model)
      spa   in [0,1] — achieved average sparsity
      thr   >0       — modeled throughput (samples/s), normalized by caller
      dsp   >0       — resource utilization fraction in [0,1]
    x layout: [s_w_0..s_w_{L-1}] (+ [s_a_0..s_a_{L-1}] when include_act).

    When the evaluator exposes a ``lambdas`` attribute (``CNNEvaluator``), a
    hardware-aware search installs a copy of its own ``lambdas`` for the
    duration of the search (restored afterwards) so that frontier-point
    selection and trial scoring share one set of Eq. 6 weights.

    ``batch_size`` switches to the batched frontier (DESIGN.md §8): each
    round asks the TPE for a batch of proposals and scores them through
    ``evaluate.evaluate_batch(xs)`` when the evaluator provides it (one
    vmapped prune+forward instead of one jit call per trial), falling back
    to per-proposal ``evaluate(x)``. Size-1 rounds always use plain
    ``evaluate`` — vmap-of-1 and jit numerics may differ in the last float
    bits — so ``batch_size=1`` replays the serial search trial-for-trial at
    a fixed seed for ANY evaluator; ``None`` keeps the serial loop.
    """
    dim = n_layers * (2 if include_act else 1)
    opt = TPE(lo=np.zeros(dim), hi=np.full(dim, s_max), seed=seed)
    result = SearchResult(best_x=np.zeros(dim), best_score=-np.inf,
                          best_metrics={})
    def record(x: np.ndarray, m: Dict[str, float]) -> float:
        score = m["acc"] + lambdas.spa * m["spa"]
        if hardware_aware:
            score += lambdas.thr * m["thr_norm"] - lambdas.dsp * m["dsp"]
        m["score"] = score
        result.trials.append(Trial(x=x, score=score, metrics=m))
        if score > result.best_score:
            result.best_score, result.best_x, result.best_metrics = score, x, m
        return score

    # align the evaluator's frontier-point selection with this search's
    # Eq. 6 weights for the duration of the search (a COPY — never alias the
    # shared default-arg instance — and restored afterwards, so a later
    # software-only baseline on the same evaluator scores at the evaluator's
    # own trade-off point)
    sync_lam = hardware_aware and hasattr(evaluate, "lambdas")
    old_lam = evaluate.lambdas if sync_lam else None
    if sync_lam:
        evaluate.lambdas = replace(lambdas)
    try:
        if batch_size is None:
            for it in range(iters):
                x = opt.ask()
                m = dict(evaluate(x))
                opt.tell(x, record(x, m))
            return result

        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        eval_batch = getattr(evaluate, "evaluate_batch", None)
        done = 0
        while done < iters:
            k = min(batch_size, iters - done)
            xs = opt.ask_batch(k)
            ms = [dict(m) for m in eval_batch(xs)] \
                if eval_batch is not None and k > 1 \
                else [dict(evaluate(x)) for x in xs]
            opt.tell_batch(xs, [record(x, m) for x, m in zip(xs, ms)])
            done += k
        return result
    finally:
        if sync_lam:
            evaluate.lambdas = old_lam


# --------------------------------------------------------------------- #
# LM evaluator (the TPU-side setting: deep lm_layer_costs stacks, analytic
# Eq. 6 scoring — DESIGN.md §11)
# --------------------------------------------------------------------- #
def _gaussian_energy_curve(n_grid: int = 257, n_draws: int = 1 << 15,
                           seed: int = 0) -> np.ndarray:
    """``curve[k]`` = fraction of L2 weight energy removed by magnitude-
    pruning the smallest ``k/(n_grid-1)`` fraction of an i.i.d. Gaussian
    weight tensor. Computed once from a fixed-seed sample (no scipy in the
    container, so no closed-form erfinv); interpolated by the evaluator."""
    w2 = np.sort(np.random.default_rng(seed).standard_normal(n_draws) ** 2)
    cum = np.concatenate([[0.0], np.cumsum(w2)]) / w2.sum()
    return np.interp(np.linspace(0.0, 1.0, n_grid),
                     np.arange(n_draws + 1) / n_draws, cum)


@dataclass
class LMEvaluator:
    """Eq. 6 metric dict for one sparsity proposal on an LM layer stack.

    The LM path is fully *analytic* (DESIGN.md §11): there are no 671B
    weights in-container, so instead of prune-and-forward the evaluator
    scores

      * ``acc``  — an energy-based proxy: ``exp(-alpha * E)`` where ``E`` is
        the weight-fraction-weighted L2 energy removed by pruning, summed
        over prunable layers. Element-wise magnitude pruning on a Gaussian
        tensor removes the ``_gaussian_energy_curve`` fraction; a
        tile-structured pruner (TPU backend) removes energy ~ proportionally
        to the tile fraction. Monotone decreasing in every sparsity target.
      * ``spa``  — weight-count-weighted mean of (s_w + s_a)/2 (the CNN
        evaluator's convention).
      * ``thr``/``thr_norm``/``dsp``/``eff`` — exactly the CNN path: ONE
        ``incremental_dse`` over the sparse stack, Eq. 6-optimal frontier
        point (λthr·thr_norm − λdsp·dsp) under the budget.

    On a ``TPUModel`` the searched target is realized tile-granularly:
    ``s_w`` snaps to the largest achievable whole-tile fraction
    (``tile_quantize_sparsity``) and drives ``s_w_tile`` — the MXU skips
    whole tiles only (DESIGN.md §6). Activation sparsity never skips MXU
    compute, so on TPU ``s_a`` costs accuracy without buying throughput;
    searches there usually run ``include_act=False``.

    ``tie="kind"`` shares one search variable across all blocks per matrix
    kind (wq/wo/moe_up/..., ~10 variables for a 550-entry stack — the TPE
    stays low-dimensional on hundreds-of-matmul pipelines); ``tie="none"``
    searches every prunable layer independently, the paper's CNN granularity.
    ``n_search`` is the per-(s_w|s_a) dimension callers pass to
    ``hass_search``.
    """
    cfg: object
    hw: HardwareModel
    budget: float
    seq_len: int = 1              # sample = token; seq_len scales attn only
    dse_iters: int = 300
    tie: str = "kind"             # kind | none
    alpha: float = 4.0            # acc-proxy decay per unit energy removed
    act_weight: float = 0.5       # relative acc cost of activation clipping
    lambdas: Lambdas = field(default_factory=Lambdas)

    def __post_init__(self):
        if self.tie not in ("kind", "none"):
            raise ValueError(f"unknown tie mode {self.tie!r}")
        self.layers = lm_layer_costs(self.cfg, seq_len=self.seq_len)
        self.prunable = [l for l in self.layers if l.prunable]
        kinds: List[str] = []
        self._group: List[int] = []      # prunable-layer -> search variable
        for l in self.prunable:
            key = l.name.split(".", 1)[-1] if self.tie == "kind" else l.name
            if key not in kinds:
                kinds.append(key)
            self._group.append(kinds.index(key))
        self.group_names = kinds
        self.n_search = len(kinds)
        self.tiled = isinstance(self.hw, TPUModel)
        self._energy = _gaussian_energy_curve()
        wc = np.array([l.weight_count for l in self.prunable], dtype=np.float64)
        self._wfrac = wc / max(wc.sum(), 1.0)
        dense = incremental_dse(self.layers, self.hw, self.budget,
                                max_iters=self.dse_iters)
        self.dense_thr = dense.throughput * self.hw.freq

    # ------------------------------------------------------------------ #
    def _split(self, x: np.ndarray):
        """Search vector -> per-prunable-layer (s_w, s_a) targets."""
        g = np.asarray(self._group)
        x = np.asarray(x, dtype=np.float64)
        s_w = x[:self.n_search][g]
        s_a = x[self.n_search:2 * self.n_search][g] \
            if len(x) >= 2 * self.n_search else np.zeros(len(g))
        return s_w, s_a

    def sparse_layers(self, x: np.ndarray) -> List[LayerCost]:
        """The sparse LayerCost stack one proposal realizes (tile-quantized
        on TPU). Feeds the partitioned multi-chip DP directly."""
        s_w, s_a = self._split(x)
        out: List[LayerCost] = []
        i = 0
        for l in self.layers:
            if not l.prunable:
                out.append(l)
                continue
            sw, sa = float(s_w[i]), float(s_a[i])
            i += 1
            if self.tiled:
                sw = tile_quantize_sparsity(sw, l.m_dot, l.weight_count)
                out.append(LayerCost(**{**l.__dict__, "s_w": sw, "s_a": sa,
                                        "s_w_tile": sw}))
            else:
                out.append(LayerCost(**{**l.__dict__, "s_w": sw, "s_a": sa}))
        return out

    def _hw_terms(self, res: np.ndarray, thr: np.ndarray):
        """Identical shape to ``CNNEvaluator._hw_terms`` (log-compressed
        speedup vs the dense-stack DSE; dsp = resource fraction)."""
        thr_s = thr * self.hw.freq
        thr_norm = np.log2(1.0 + thr_s / max(self.dense_thr, 1e-9)) / 4.0
        return thr_s, thr_norm, res / max(self.budget, 1e-9)

    def _eq6_hw_score(self, res: np.ndarray, thr: np.ndarray) -> np.ndarray:
        _, thr_norm, dsp = self._hw_terms(res, thr)
        return self.lambdas.thr * thr_norm - self.lambdas.dsp * dsp

    def __call__(self, x: np.ndarray) -> Dict[str, float]:
        layers = self.sparse_layers(x)
        sparse = [l for l in layers if l.prunable]
        sw = np.array([l.s_w for l in sparse])
        sa = np.array([l.s_a for l in sparse])
        # energy removed: tile pruning drops whole tiles (~uniform energy ->
        # fraction == sw); element pruning drops the smallest-|w| tail
        e_w = sw if self.tiled else \
            np.interp(sw, np.linspace(0.0, 1.0, len(self._energy)),
                      self._energy)
        e_a = np.interp(sa, np.linspace(0.0, 1.0, len(self._energy)),
                        self._energy)
        acc = float(np.exp(-self.alpha *
                           np.dot(self._wfrac, e_w + self.act_weight * e_a)))
        spa = float(np.dot(self._wfrac, (sw + sa) / 2.0))
        dse = incremental_dse(layers, self.hw, self.budget,
                              max_iters=self.dse_iters)
        f = dse.frontier
        k = f.select(self._eq6_hw_score)
        thr_pts, thr_norm_pts, dsp_pts = self._hw_terms(f.res, f.thr)
        return {"acc": acc, "spa": spa,
                "thr": float(thr_pts[k]),
                "thr_norm": float(thr_norm_pts[k]),
                "dsp": float(dsp_pts[k]),
                "eff": float(thr_pts[k]) / max(float(f.res[k]), 1e-9)}

    def evaluate_batch(self, xs: Sequence[np.ndarray]) -> List[Dict[str, float]]:
        """Analytic path: no forward pass to vmap, so a batch is a plain
        loop — the hook exists so ``hass_search(batch_size=...)`` amortizes
        TPE modeling cost over each batch identically to the CNN path."""
        return [self(x) for x in xs]


# --------------------------------------------------------------------- #
# CNN evaluator (the paper's own setting: ImageNet CNNs on the FPGA model)
# --------------------------------------------------------------------- #
@dataclass
class CNNEvaluator:
    """Builds the Eq. 6 metric dict for one (S_w, S_a) proposal on a CNN.

    Accuracy proxy: top-1 agreement with the dense reference on a calibration
    batch (no ImageNet in-container; the search structure is unchanged —
    documented in DESIGN.md §5).
    """
    cfg: object
    params: dict
    images: jnp.ndarray
    hw: HardwareModel
    budget: float
    dse_iters: int = 400
    cost_cfg: object = None     # full-res config for C_l (accuracy runs can
                                # use a reduced img_res; layer names match)
    lambdas: Lambdas = field(default_factory=Lambdas)  # Eq. 6 weights used
                                # to pick the frontier trade-off point

    def __post_init__(self):
        from repro.core.perf_model import cnn_layer_costs
        from repro.models import cnn
        self._cnn = cnn
        self.layers = [l for l in cnn_layer_costs(self.cost_cfg or self.cfg)]
        self.prunable = [l for l in self.layers if l.prunable]
        self.names = [l.name for l in self.prunable]
        self.dense_logits = np.asarray(
            cnn.forward(self.cfg, self.params, self.images))
        self.dense_pred = jnp.asarray(self.dense_logits.argmax(-1))
        # activation magnitude samples per prunable layer (for tau_a quantiles)
        self._act_q = jnp.asarray(
            np.stack([self._collect_act_samples()[n] for n in self.names]))
        dense = incremental_dse(self.layers, self.hw, self.budget,
                                max_iters=self.dse_iters)
        self.dense_thr = dense.throughput * self.hw.freq

        def _eval(params, s_w, s_a):
            pruned = dict(params)
            achieved = []
            taus = {}
            for i, n in enumerate(self.names):
                w = params[n]["w"]
                tau_w = pruning.threshold_for_sparsity(w, s_w[i])
                w2 = pruning.prune_tensor(w, tau_w)
                pruned[n] = dict(params[n], w=w2)
                achieved.append(jnp.mean(w2 == 0.0))
                qidx = jnp.clip((s_a[i] * self._act_q.shape[1]).astype(jnp.int32),
                                0, self._act_q.shape[1] - 1)
                taus[n] = self._act_q[i, qidx]
            logits, stats = cnn.forward(self.cfg, pruned, self.images,
                                        sparsity=taus, collect_stats=True)
            acc = jnp.mean(logits.argmax(-1) == self.dense_pred)
            s_a_meas = jnp.stack([stats[n] for n in self.names])
            return acc, jnp.stack(achieved), s_a_meas

        self._eval = jax.jit(_eval)
        # batched frontier: one vmapped prune+forward for a whole batch of
        # proposals (compiled once per batch shape) instead of B jit calls
        self._eval_batch = jax.jit(jax.vmap(_eval, in_axes=(None, 0, 0)))
        # batch-shape bucketing state: ``batch_shapes`` records every batch
        # shape actually handed to the vmapped executable (== compiles);
        # ragged batches pad up to an already-compiled shape when one is
        # close enough (see ``evaluate_batch``)
        self.batch_shapes: set = set()
        self.padded_batches: int = 0

    def _collect_act_samples(self) -> Dict[str, np.ndarray]:
        """|activation| quantiles at each prunable layer's input (dense run):
        the calibration pass that maps target S_a -> clip threshold tau_a."""
        from repro.models import cnn
        _, outs = cnn.forward(self.cfg, self.params, self.images,
                              return_intermediates=True)
        specs = cnn.build_specs(self.cfg)
        last = cnn.INPUT
        samples = {}
        for s in specs:
            inp_name = s.input_from or last
            if s.prunable:
                flat = np.abs(np.asarray(outs[inp_name],
                                         dtype=np.float32)).reshape(-1)
                samples[s.name] = np.quantile(flat, np.linspace(0, 0.999, 256))
            last = s.name
        return samples

    def _split(self, x: np.ndarray):
        L = len(self.prunable)
        s_w = jnp.asarray(x[:L])
        s_a = jnp.asarray(x[L:2 * L]) if len(x) >= 2 * L else jnp.zeros(L)
        return s_w, s_a

    def _sparse_layers(self, sw_meas: np.ndarray, sa_meas: np.ndarray):
        """Measured per-layer sparsity -> LayerCost pipeline + avg sparsity."""
        layers = []
        spa_num = spa_den = 0.0
        i = 0
        for l in self.layers:
            if l.prunable:
                sw, sa = float(sw_meas[i]), float(sa_meas[i])
                i += 1
                layers.append(LayerCost(**{**l.__dict__, "s_w": sw, "s_a": sa}))
                spa_num += (sw + sa) / 2 * l.weight_count
                spa_den += l.weight_count
            else:
                layers.append(l)
        return layers, spa_num / max(spa_den, 1e-9)

    def sparse_layers(self, x: np.ndarray):
        """The measured sparse LayerCost pipeline for one proposal (one
        jitted prune+forward). Feeds the partitioned multi-chip DSE demo."""
        s_w, s_a = self._split(x)
        _, sw_meas, sa_meas = map(np.asarray,
                                  self._eval(self.params, s_w, s_a))
        return self._sparse_layers(sw_meas, sa_meas)[0]

    def _hw_terms(self, res: np.ndarray, thr: np.ndarray):
        """(thr in samples/s, thr_norm, dsp) for frontier points, vectorized.
        thr_norm is the log-compressed speedup: Eq. 6's lambda-normalization
        heuristic keeps the hardware terms commensurate with acc in [0, 1]."""
        thr_s = thr * self.hw.freq
        thr_norm = np.log2(1.0 + thr_s / max(self.dense_thr, 1e-9)) / 4.0
        return thr_s, thr_norm, res / max(self.budget, 1e-9)

    def _eq6_hw_score(self, res: np.ndarray, thr: np.ndarray) -> np.ndarray:
        """The Eq. 6 hardware combination used to pick the frontier point."""
        _, thr_norm, dsp = self._hw_terms(res, thr)
        return self.lambdas.thr * thr_norm - self.lambdas.dsp * dsp

    def _metrics(self, acc: float, sw_meas: np.ndarray,
                 sa_meas: np.ndarray) -> Dict[str, float]:
        """Measured per-layer sparsity -> perf model (Eq. 1-3) -> one DSE ->
        pick the Eq. 6-optimal point on its frontier -> the metric dict.

        A single DSE run yields the whole (resource, throughput) frontier;
        the hardware terms of Eq. 6 are scored at the frontier point
        maximizing lambda_thr*thr_norm - lambda_dsp*dsp under the budget,
        instead of always paying the full-budget endpoint's dsp."""
        layers, spa = self._sparse_layers(sw_meas, sa_meas)
        dse = incremental_dse(layers, self.hw, self.budget,
                              max_iters=self.dse_iters)
        f = dse.frontier
        k = f.select(self._eq6_hw_score)
        thr_pts, thr_norm_pts, dsp_pts = self._hw_terms(f.res, f.thr)
        return {"acc": acc,
                "spa": spa,
                "thr": float(thr_pts[k]),
                "thr_norm": float(thr_norm_pts[k]),
                "dsp": float(dsp_pts[k]),
                "eff": float(thr_pts[k]) / max(float(f.res[k]), 1e-9)}

    def __call__(self, x: np.ndarray) -> Dict[str, float]:
        # 1-2) one-shot prune + accuracy proxy + measured act sparsity (jitted)
        s_w, s_a = self._split(x)
        acc, sw_meas, sa_meas = map(np.asarray,
                                    self._eval(self.params, s_w, s_a))
        return self._metrics(float(acc), sw_meas, sa_meas)

    def evaluate_batch(self, xs: Sequence[np.ndarray]) -> List[Dict[str, float]]:
        """Score a batch of proposals with ONE vmapped prune+forward call;
        the (fast, vectorized) DSE then runs per proposal on the measured
        sparsities. Feeds ``hass_search(batch_size=...)``.

        Batch-shape bucketing: a ragged batch (a search's tail round) is
        padded up to the nearest already-compiled batch shape by repeating
        the last proposal. Padded rows are dropped before returning, so they
        never reach ``tell_batch`` — a whole fixed-size search compiles
        exactly one vmapped executable."""
        if len(xs) == 0:
            return []
        B = len(xs)
        split = [self._split(x) for x in xs]
        s_w = jnp.stack([s for s, _ in split])
        s_a = jnp.stack([a for _, a in split])
        # bucket rule: pad up to the smallest already-compiled shape in
        # [B, 2B] (a one-time compile beats repeated >2x padding waste, e.g.
        # a later smaller-batch search on a shared evaluator); otherwise
        # compile this exact size
        bigger = [s for s in self.batch_shapes if B <= s <= 2 * B]
        target = min(bigger) if bigger else B
        if B < target:
            pad = target - B
            s_w = jnp.concatenate(
                [s_w, jnp.broadcast_to(s_w[-1], (pad,) + s_w.shape[1:])])
            s_a = jnp.concatenate(
                [s_a, jnp.broadcast_to(s_a[-1], (pad,) + s_a.shape[1:])])
            self.padded_batches += 1
        self.batch_shapes.add(int(s_w.shape[0]))
        accs, sw_meas, sa_meas = map(
            np.asarray, self._eval_batch(self.params, s_w, s_a))
        return [self._metrics(float(accs[b]), sw_meas[b], sa_meas[b])
                for b in range(B)]
