"""HASS: Hardware-Aware Sparsity Search (§V-B) — the paper's main loop.

TPE proposes per-layer (S_w, S_a) targets; we one-shot prune, calibrate, run
the DSE (rate balancing + incrementing) under a resource budget, and score

    f = f_acc + λ1 f_spa + λ2 f_thr − λ3 f_dsp        (Eq. 6)

``hardware_aware=False`` drops the hardware terms (λ2 = λ3 = 0) — the
"software metrics only" baseline of Fig. 5.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning
from repro.core.dse import (DSECache, ParetoFrontier, engine_dispatch_stats,
                            incremental_dse)
from repro.obs.trace import get_tracer
from repro.core.perf_model import (FPGAModel, HardwareModel, LayerCost,
                                   TPUModel, lm_layer_costs, pair_sparsity,
                                   tile_quantize_sparsity)
from repro.core.tpe import TPE


@dataclass
class Lambdas:
    """Eq. 6 normalizing hyper-parameters (heuristic, per the paper).
    thr=0.5 keeps the hardware term subordinate to accuracy — with thr=1.0
    a 10-iteration search can prefer a degenerate zero-accuracy corner.

    ``lat`` weights the simulated serving-latency term (DESIGN.md §13):
    when an evaluator reports ``lat`` (tail latency / SLO target, e.g.
    ``repro.sim.slo.SimLatencyEvaluator``) a hardware-aware search
    subtracts ``lat * m["lat"]``. The default 0.0 leaves every existing
    search bit-identical.

    ``meas`` weights the measured-kernel-cost term (DESIGN.md §16): when an
    evaluator is built with ``pattern_costs`` (decode factors from the
    ``kernels.kernel_costs`` microbench) it reports ``meas`` — the
    weight-fraction-weighted measured relative cycle estimate of the
    realized pattern assignment — and a hardware-aware search subtracts
    ``meas * m["meas"]``. Default 0.0 = modeled Eq. 1 costs only,
    bit-identical to the pre-pattern search."""
    spa: float = 0.3
    thr: float = 0.5
    dsp: float = 0.3
    lat: float = 0.0
    meas: float = 0.0


@dataclass
class Trial:
    x: np.ndarray
    score: float
    metrics: Dict[str, float]


@dataclass
class SearchResult:
    best_x: np.ndarray
    best_score: float
    best_metrics: Dict[str, float]
    trials: List[Trial] = field(default_factory=list)

    def history(self, key: str) -> List[float]:
        return [t.metrics.get(key, float("nan")) for t in self.trials]

    def running_best(self, key: str) -> List[float]:
        """Metric of the best-scoring trial so far, per iteration (Fig. 5)."""
        out, best, bestscore = [], float("nan"), -np.inf
        for t in self.trials:
            if t.score > bestscore:
                bestscore, best = t.score, t.metrics.get(key, float("nan"))
            out.append(best)
        return out


def hass_search(evaluate: Callable[[np.ndarray], Dict[str, float]],
                n_layers: int, *, iters: int = 96,
                hardware_aware: bool = True,
                lambdas: Optional[Lambdas] = None,
                s_max: float = 0.95, seed: int = 0,
                include_act: bool = True,
                batch_size: Optional[int] = None,
                liar: Optional[str] = "min",
                x0: Optional[np.ndarray] = None,
                recorder=None) -> SearchResult:
    """Search per-layer sparsity targets.

    evaluate(x) must return a dict with keys:
      acc   in [0,1] — accuracy proxy (agreement with the dense model)
      spa   in [0,1] — achieved average sparsity
      thr   >0       — modeled throughput (samples/s), normalized by caller
      dsp   >0       — resource utilization fraction in [0,1]
    and may report ``lat`` (simulated tail latency / SLO target, e.g. from
    ``repro.sim.slo.SimLatencyEvaluator``) — subtracted with weight
    ``lambdas.lat`` in a hardware-aware search (DESIGN.md §13).
    x layout: [s_w_0..s_w_{L-1}] (+ [s_a_0..s_a_{L-1}] when include_act)
    (+ [pattern_0..pattern_{P-1}] categorical dims when the evaluator
    exposes ``n_pattern_dims > 0`` — DESIGN.md §16).

    When the evaluator exposes a ``lambdas`` attribute (``CNNEvaluator``), a
    hardware-aware search installs a copy of its own ``lambdas`` for the
    duration of the search (restored afterwards) so that frontier-point
    selection and trial scoring share one set of Eq. 6 weights.

    ``batch_size`` switches to the batched frontier (DESIGN.md §8): each
    round asks the TPE for a batch of proposals and scores them through
    ``evaluate.evaluate_batch(xs)`` when the evaluator provides it (one
    vmapped prune+forward instead of one jit call per trial), falling back
    to per-proposal ``evaluate(x)``. Size-1 rounds always use plain
    ``evaluate`` — vmap-of-1 and jit numerics may differ in the last float
    bits — so ``batch_size=1`` replays the serial search trial-for-trial at
    a fixed seed for ANY evaluator; ``None`` keeps the serial loop.

    ``liar`` selects the batch proposal protocol (``TPE.ask_batch``):
    ``"min"`` (default) runs constant-liar parallel TPE — batch members
    are proposed sequentially against provisional worst-score tells, so one
    round covers distinct basins instead of resampling one mode
    (DESIGN.md §12); ``None`` restores the independent-draw batch.
    ``lambdas`` defaults to a fresh ``Lambdas()`` per call — pass an
    instance to override Eq. 6 weights (concurrent searches never alias
    each other's weights).

    ``x0`` anchors the search: the point is evaluated as trial 0 (consuming
    one of ``iters``) and told to the TPE before any proposal is drawn, so
    a known-good configuration (e.g. the dense network, ``np.zeros(dim)``)
    is always in the trial set and the guided phase explores around it.
    ``None`` (default) changes nothing — proposal streams stay bit-identical.

    ``recorder`` (an ``repro.obs.FlightRecorder``) emits one structured
    JSONL record per trial — proposal, score, metric terms, DSECache and
    engine-dispatch counter deltas, per-phase timings — plus run
    header/footer (DESIGN.md §18). Spans land in the process-global tracer
    when one is installed (``repro.obs.use_tracer``). With neither, the
    loop below is the literal uninstrumented seed path; with either,
    instrumentation only reads clocks and counters, so the trial
    transcript stays bit-identical in every state (gated in
    ``benchmarks/obs_bench.py``).
    """
    lambdas = Lambdas() if lambdas is None else lambdas
    dim = n_layers * (2 if include_act else 1)
    # pattern axis (DESIGN.md §16): an evaluator with >1 sparsity pattern
    # exposes n_pattern_dims tied categorical variables; they ride at the
    # END of x as TPE categorical dims so the search picks each matrix
    # kind's pattern jointly with its sparsity level. n_pattern_dims == 0
    # (no patterns, or the single-pattern degenerate axis) constructs the
    # exact pre-pattern TPE — bit-identical proposal stream.
    n_pat = int(getattr(evaluate, "n_pattern_dims", 0) or 0)
    if n_pat:
        n_cats = len(evaluate.patterns)
        opt = TPE(
            lo=np.zeros(dim + n_pat),
            hi=np.concatenate([np.full(dim, s_max),
                               np.full(n_pat, float(n_cats))]),
            seed=seed,
            cats=np.concatenate([np.zeros(dim, np.int64),
                                 np.full(n_pat, n_cats, np.int64)]))
        dim += n_pat
    else:
        opt = TPE(lo=np.zeros(dim), hi=np.full(dim, s_max), seed=seed)
    result = SearchResult(best_x=np.zeros(dim), best_score=-np.inf,
                          best_metrics={})
    def record(x: np.ndarray, m: Dict[str, float]) -> float:
        score = m["acc"] + lambdas.spa * m["spa"]
        if hardware_aware:
            score += lambdas.thr * m["thr_norm"] - lambdas.dsp * m["dsp"]
            if lambdas.lat and "lat" in m:
                score -= lambdas.lat * m["lat"]
            if lambdas.meas and "meas" in m:
                score -= lambdas.meas * m["meas"]
        m["score"] = score
        result.trials.append(Trial(x=x, score=score, metrics=m))
        if score > result.best_score:
            result.best_score, result.best_x, result.best_metrics = score, x, m
        return score

    # align the evaluator's frontier-point selection with this search's
    # Eq. 6 weights for the duration of the search (a COPY — never alias the
    # shared default-arg instance — and restored afterwards, so a later
    # software-only baseline on the same evaluator scores at the evaluator's
    # own trade-off point)
    sync_lam = hardware_aware and hasattr(evaluate, "lambdas")
    old_lam = evaluate.lambdas if sync_lam else None
    if sync_lam:
        evaluate.lambdas = replace(lambdas)

    # observability (DESIGN.md §18). ``obs`` off keeps the literal seed
    # loops below; on, the instrumented twins time each phase and snapshot
    # counter deltas — reads only, never a float the search computes.
    tr = get_tracer()
    obs = tr.enabled or recorder is not None
    clk = tr.now if tr.enabled else time.perf_counter
    cache = getattr(evaluate, "dse_cache", None)

    def _snap():
        return (dict(cache.stats()) if cache is not None else {},
                engine_dispatch_stats())

    def _observe(k, t0, t1, t2, t3, snap, first=True, round_size=1):
        """Record trial ``result.trials[k]``. Batched rounds pass the whole
        round's window to every member but attribute the shared phase time
        and counter deltas to the FIRST trial only (zeros elsewhere), so
        footer totals stay the sum of per-trial records."""
        if tr.enabled:
            tr.add_span("trial", t0, t3, depth=0, i=k)
            if first:
                tr.add_span("propose", t0, t1, depth=1)
                tr.add_span("evaluate", t1, t2, depth=1)
                tr.add_span("tell", t2, t3, depth=1)
        if recorder is not None:
            c1, e1 = _snap()
            zero = {"propose": 0.0, "evaluate": 0.0, "tell": 0.0}
            t = result.trials[k]
            recorder.trial(
                index=k, x=t.x, score=t.score, metrics=t.metrics,
                cache={key: c1[key] - snap[0].get(key, 0) for key in c1}
                if first else {},
                engine={key: e1[key] - snap[1].get(key, 0) for key in e1}
                if first else {},
                phases={"propose": t1 - t0, "evaluate": t2 - t1,
                        "tell": t3 - t2} if first else zero,
                round_size=round_size)

    def _finish_obs():
        if tr.enabled:
            tr.count("search.trials", len(result.trials))
            if cache is not None:
                for key, v in cache.stats().items():
                    tr.gauge(f"search.dse_cache.{key}", v)
        if recorder is not None:
            recorder.footer(best_score=result.best_score)

    if obs and recorder is not None:
        recorder.header(
            "hass_search", n_layers=n_layers, iters=iters, dim=dim,
            seed=seed, hardware_aware=hardware_aware, s_max=s_max,
            include_act=include_act, batch_size=batch_size, liar=liar,
            evaluator=type(evaluate).__name__)
    try:
        n0 = 0
        if x0 is not None:
            xa = np.asarray(x0, dtype=np.float64).copy()
            if len(xa) != dim:
                raise ValueError(
                    f"x0 has {len(xa)} dims, search space has {dim}")
            if obs:
                snap = _snap()
                t0 = clk()
            m = dict(evaluate(xa))
            opt.tell(xa, record(xa, m))
            if obs:
                t3 = clk()
                _observe(0, t0, t0, t3, t3, snap)
            n0 = 1
        if batch_size is None:
            if not obs:
                for it in range(max(iters - n0, 0)):
                    x = opt.ask()
                    m = dict(evaluate(x))
                    opt.tell(x, record(x, m))
                return result
            for it in range(max(iters - n0, 0)):
                snap = _snap()
                t0 = clk()
                x = opt.ask()
                t1 = clk()
                m = dict(evaluate(x))
                t2 = clk()
                opt.tell(x, record(x, m))
                t3 = clk()
                _observe(len(result.trials) - 1, t0, t1, t2, t3, snap)
            _finish_obs()
            return result

        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        eval_batch = getattr(evaluate, "evaluate_batch", None)
        done = n0
        while done < iters:
            k = min(batch_size, iters - done)
            if obs:
                snap = _snap()
                t0 = clk()
            xs = opt.ask_batch(k, liar=liar)
            if obs:
                t1 = clk()
            ms = [dict(m) for m in eval_batch(xs)] \
                if eval_batch is not None and k > 1 \
                else [dict(evaluate(x)) for x in xs]
            if obs:
                t2 = clk()
            opt.tell_batch(xs, [record(x, m) for x, m in zip(xs, ms)])
            if obs:
                t3 = clk()
                base = len(result.trials) - k
                for j in range(k):
                    _observe(base + j, t0, t1, t2, t3, snap,
                             first=(j == 0), round_size=k)
            done += k
        if obs:
            _finish_obs()
        return result
    finally:
        if sync_lam:
            evaluate.lambdas = old_lam


def frontier_hw_metrics(ev, f: ParetoFrontier) -> Dict[str, float]:
    """Eq. 6 hardware terms read off a DSE frontier, shared by both
    evaluators (DESIGN.md §12).

    ``ev.frontier_mode == "point"``: the pre-PR-4 semantics — score at the
    single frontier point maximizing λthr·thr_norm − λdsp·dsp.

    ``"budgets"``: per-budget scalarization of the WHOLE frontier. For each
    deployment budget ``frac·budget`` (``ev.budget_fracs``) take the point
    actually deployable there (``best_under``) and report the MEAN of the
    per-budget thr_norm and of the per-budget resource fraction
    ``res/budget`` (utilization of the AVAILABLE device, the paper's f_dsp
    — NOT of the frac slice, where every greedy design saturates and the
    λdsp term stops discriminating between proposals). Eq. 6 is linear in
    (thr_norm, dsp), so the search score becomes the mean of the
    per-budget Eq. 6 hardware scores — a proposal wins by being good
    across the budget sweep, not at one cherry-picked trade-off (closes
    the ROADMAP frontier-aware-TPE item). ``thr``/``eff`` stay the
    full-budget point's values for reporting.
    """
    thr_pts, thr_norm_pts, dsp_pts = ev._hw_terms(f.res, f.thr)
    if ev.frontier_mode == "point":
        k = f.select(ev._eq6_hw_score)
        return {"thr": float(thr_pts[k]),
                "thr_norm": float(thr_norm_pts[k]),
                "dsp": float(dsp_pts[k]),
                "eff": float(thr_pts[k]) / max(float(f.res[k]), 1e-9)}
    if ev.frontier_mode != "budgets":
        raise ValueError(f"unknown frontier_mode {ev.frontier_mode!r}")
    tn = []
    dp = []
    for frac in ev.budget_fracs:
        k = f.best_under(frac * ev.budget)
        k = 0 if k is None else k       # infeasible budget: the resource-
        tn.append(float(thr_norm_pts[k]))   # minimal design still runs
        dp.append(float(dsp_pts[k]))
    k = f.best_under(ev.budget)
    k = 0 if k is None else k
    return {"thr": float(thr_pts[k]),
            "thr_norm": float(np.mean(tn)),
            "dsp": float(np.mean(dp)),
            "eff": float(thr_pts[k]) / max(float(f.res[k]), 1e-9)}


# --------------------------------------------------------------------- #
# LM evaluator (the TPU-side setting: deep lm_layer_costs stacks, analytic
# Eq. 6 scoring — DESIGN.md §11)
# --------------------------------------------------------------------- #
def _gaussian_energy_curve(n_grid: int = 257, n_draws: int = 1 << 15,
                           seed: int = 0) -> np.ndarray:
    """``curve[k]`` = fraction of L2 weight energy removed by magnitude-
    pruning the smallest ``k/(n_grid-1)`` fraction of an i.i.d. Gaussian
    weight tensor. Computed once from a fixed-seed sample (no scipy in the
    container, so no closed-form erfinv); interpolated by the evaluator."""
    w2 = np.sort(np.random.default_rng(seed).standard_normal(n_draws) ** 2)
    cum = np.concatenate([[0.0], np.cumsum(w2)]) / w2.sum()
    return np.interp(np.linspace(0.0, 1.0, n_grid),
                     np.arange(n_draws + 1) / n_draws, cum)


def _nm_energy_curve(m: int = pruning.NM_M, n_draws: int = 1 << 13,
                     seed: int = 0):
    """``(s_grid, removed)`` over the N:M grid s = 1 - n/m: fraction of L2
    weight energy removed when every m-group of an i.i.d. Gaussian tensor
    keeps only its top-n magnitudes. Groupwise top-n removes MORE energy
    than unconstrained global magnitude pruning at equal sparsity (the
    structure tax) but far less than tile pruning's uniform fraction — the
    accuracy-side half of the pattern trade-off (DESIGN.md §16). Fixed-seed
    Monte Carlo, like ``_gaussian_energy_curve``; evaluators interpolate,
    and every realizable sparsity lands exactly on a grid node."""
    g = np.random.default_rng(seed).standard_normal((n_draws, m)) ** 2
    g = -np.sort(-g, axis=1)                       # descending per group
    cum = np.cumsum(g, axis=1)                     # top-n kept energy
    kept = np.concatenate([[0.0], cum.sum(axis=0)]) / cum[:, -1].sum()
    removed = (1.0 - kept)[::-1]                   # index: n = m .. 0
    s_grid = 1.0 - np.arange(m, -1, -1) / m        # ascending 0 .. 1
    return s_grid, removed


@dataclass
class LMEvaluator:
    """Eq. 6 metric dict for one sparsity proposal on an LM layer stack.

    The LM path is fully *analytic* (DESIGN.md §11): there are no 671B
    weights in-container, so instead of prune-and-forward the evaluator
    scores

      * ``acc``  — an energy-based proxy: ``exp(-alpha * E)`` where ``E`` is
        the weight-fraction-weighted L2 energy removed by pruning, summed
        over prunable layers. Element-wise magnitude pruning on a Gaussian
        tensor removes the ``_gaussian_energy_curve`` fraction; a
        tile-structured pruner (TPU backend) removes energy ~ proportionally
        to the tile fraction. Monotone decreasing in every sparsity target.
      * ``spa``  — weight-count-weighted mean of (s_w + s_a)/2 (the CNN
        evaluator's convention).
      * ``thr``/``thr_norm``/``dsp``/``eff`` — exactly the CNN path: ONE
        ``incremental_dse`` over the sparse stack, Eq. 6-optimal frontier
        point (λthr·thr_norm − λdsp·dsp) under the budget.

    On a ``TPUModel`` the searched target is realized tile-granularly:
    ``s_w`` snaps to the largest achievable whole-tile fraction
    (``tile_quantize_sparsity``) and drives ``s_w_tile`` — the MXU skips
    whole tiles only (DESIGN.md §6). Activation sparsity never skips MXU
    compute, so on TPU ``s_a`` costs accuracy without buying throughput;
    searches there usually run ``include_act=False``.

    ``tie="kind"`` shares one search variable across all blocks per matrix
    kind (wq/wo/moe_up/..., ~10 variables for a 550-entry stack — the TPE
    stays low-dimensional on hundreds-of-matmul pipelines); ``tie="none"``
    searches every prunable layer independently, the paper's CNN granularity.
    ``n_search`` is the per-(s_w|s_a) dimension callers pass to
    ``hass_search``.

    ``accel=True`` (default) runs the search-loop acceleration subsystem
    (DESIGN.md §12): proposals are realized as a vectorized ``s_eff`` swap
    on one ``LayerVectors`` template (no per-call LayerCost churn) and the
    DSE goes through a per-evaluator ``DSECache`` — bit-identical metrics
    to ``accel=False`` (property-tested). ``frontier_mode`` selects Eq. 6
    frontier scoring (``frontier_hw_metrics``): ``"budgets"`` (default)
    scalarizes the whole frontier over ``budget_fracs`` deployment budgets;
    ``"point"`` is the single-point pre-PR-4 semantics. ``dse_engine``
    pins the greedy engine ("flat" reproduces seed-path wall-clock).
    """
    cfg: object
    hw: HardwareModel
    budget: float
    seq_len: int = 1              # sample = token; seq_len scales attn only
    dse_iters: int = 300
    tie: str = "kind"             # kind | none
    alpha: float = 4.0            # acc-proxy decay per unit energy removed
    act_weight: float = 0.5       # relative acc cost of activation clipping
    lambdas: Lambdas = field(default_factory=Lambdas)
    accel: bool = True            # DSECache + vectorized stack realization
    frontier_mode: str = "budgets"    # Eq. 6 frontier scoring (see
    budget_fracs: tuple = (0.25, 0.5, 0.75, 1.0)   # frontier_hw_metrics)
    dse_engine: str = "auto"      # greedy engine (flat pins seed behavior)
    batch_dse: bool = True        # proposal-batched DSE in evaluate_batch
    #                               (False pins the serial per-proposal loop)
    patterns: Optional[tuple] = None   # sparsity-pattern axis, a subset of
    #                               pruning.PATTERNS (DESIGN.md §16). None
    #                               keeps the literal pre-pattern code path;
    #                               ("unstructured",) routes through the
    #                               pattern realization pinned to the seed
    #                               rule (bit-identical metrics, property-
    #                               tested); >1 entries add one tied
    #                               categorical TPE variable per matrix kind
    pattern_costs: Optional[dict] = None   # pattern -> measured decode
    #                               factor c_p >= 1 (kernels.kernel_costs.
    #                               decode_factors). Enables t_scale decode
    #                               cost in Eq. 1 AND the ``meas`` metric

    def __post_init__(self):
        if self.tie not in ("kind", "none"):
            raise ValueError(f"unknown tie mode {self.tie!r}")
        if self.patterns is not None:
            self.patterns = tuple(self.patterns)
            bad = [p for p in self.patterns if p not in pruning.PATTERNS]
            if bad or not self.patterns:
                raise ValueError(f"unknown patterns {bad or self.patterns}")
        self.layers = lm_layer_costs(self.cfg, seq_len=self.seq_len)
        self.prunable = [l for l in self.layers if l.prunable]
        kinds: List[str] = []
        self._group: List[int] = []      # prunable-layer -> search variable
        for l in self.prunable:
            key = l.name.split(".", 1)[-1] if self.tie == "kind" else l.name
            if key not in kinds:
                kinds.append(key)
            self._group.append(kinds.index(key))
        self.group_names = kinds
        self.n_search = len(kinds)
        self.tiled = isinstance(self.hw, TPUModel)
        self._energy = _gaussian_energy_curve()
        wc = np.array([l.weight_count for l in self.prunable], dtype=np.float64)
        self._wfrac = wc / max(wc.sum(), 1.0)
        # vectorized realization state (DESIGN.md §12): the workload
        # constants of the stack never change across proposals, so one
        # LayerVectors template + a per-proposal s_eff swap replaces
        # rebuilding the LayerCost list and re-deriving every constant
        self.dse_cache = DSECache(materialize_designs=False) \
            if self.accel else None
        self._lv0 = self.hw.layer_vectors(self.layers)
        self._prunable_idx = np.array(
            [i for i, l in enumerate(self.layers) if l.prunable], np.int64)
        import math

        from repro.core.perf_model import MXU_TILE
        # same tile count tile_quantize_sparsity derives — one constant
        # (needed off-TPU too: hierarchical patterns tile-quantize their
        # tile-level half on any backend)
        self._n_tiles = np.array(
            [math.ceil(l.m_dot / MXU_TILE) *
             math.ceil(max(1, l.weight_count // l.m_dot) / MXU_TILE)
             for l in self.prunable], np.float64)
        # pattern axis state (DESIGN.md §16)
        self.n_pattern_dims = self.n_search \
            if self.patterns is not None and len(self.patterns) > 1 else 0
        self._pattern_factors = {p: 1.0 for p in pruning.PATTERNS}
        if self.pattern_costs:
            self._pattern_factors.update(
                {k: float(v) for k, v in self.pattern_costs.items()})
        if self.patterns is not None:
            self._nm_s_grid, self._nm_curve = _nm_energy_curve()
            self._egrid = np.linspace(0.0, 1.0, len(self._energy))
        dense = incremental_dse(self.layers, self.hw, self.budget,
                                max_iters=self.dse_iters)
        self.dense_thr = dense.throughput * self.hw.freq

    # ------------------------------------------------------------------ #
    def _split(self, x: np.ndarray):
        """Search vector -> per-prunable-layer (s_w, s_a) targets. Pattern
        dims ride at the END of x and are stripped first, so the
        include_act length test below never misreads a categorical dim as
        an activation target."""
        g = np.asarray(self._group)
        x = np.asarray(x, dtype=np.float64)
        if self.n_pattern_dims and len(x) > self.n_search:
            x = x[:-self.n_pattern_dims]
        s_w = x[:self.n_search][g]
        s_a = x[self.n_search:2 * self.n_search][g] \
            if len(x) >= 2 * self.n_search else np.zeros(len(g))
        return s_w, s_a

    def _pattern_codes(self, x: np.ndarray) -> np.ndarray:
        """Per-prunable-layer index into ``self.patterns`` for one proposal
        (all zeros when the axis is degenerate — a single pattern adds no
        search dims, every layer is pinned to it)."""
        g = np.asarray(self._group)
        if self.n_pattern_dims == 0:
            return np.zeros(len(g), np.int64)
        raw = np.asarray(x, dtype=np.float64)[-self.n_pattern_dims:]
        codes = np.clip(raw.astype(np.int64), 0, len(self.patterns) - 1)
        return codes[g]

    def _realize_pattern(self, x: np.ndarray):
        """Pattern-aware realization (DESIGN.md §16): proposal -> realized
        per-prunable (s_w, s_a), energy removed, effective sparsity, tile
        fraction, decode t_scale, and pattern codes.

        Per-pattern rules (``"unstructured"`` reproduces ``_realize``'s
        floats exactly — the default-pattern bit-identity contract):

          unstructured   tile-quantized s_w on TPU (whole-tile skips, e_w
                         linear in the tile fraction), raw s_w elsewhere
                         (Gaussian magnitude energy curve)
          nm             s_w snaps to the N:M grid floor(s*M)/M; full
                         element sparsity counts on TPU (structured decode
                         a la 2:4 sparse cores) at decode cost c_nm;
                         energy from the groupwise top-n curve
          hierarchical   tile-quantized HALF the budget at tile level, the
                         residual as intra-tile N:M (HighLight-style);
                         energy/e_eff compose multiplicatively
          activation     weights stay dense; the searched s_w converts to
                         extra realized activation sparsity
                         1-(1-s_a)(1-s_w) — free accuracy-wise on the
                         weight side, but buys nothing on a TPU (the MXU
                         never skips dynamic zeros)
        """
        s_w, s_a = self._split(x)
        codes = self._pattern_codes(x)
        L = len(codes)
        M = pruning.NM_M
        sw_c = np.clip(s_w, 0.0, 1.0)
        sw_real = np.zeros(L)
        sa_real = np.array(s_a, dtype=np.float64)
        e_w = np.zeros(L)
        swt = np.zeros(L)                        # tile-level fraction
        tsc = np.ones(L)
        for k, pname in enumerate(self.patterns):
            ii = np.flatnonzero(codes == k)
            if ii.size == 0:
                continue
            if pname == "unstructured":
                if self.tiled:
                    q = np.floor(sw_c[ii] * self._n_tiles[ii]) \
                        / self._n_tiles[ii]
                    sw_real[ii] = q
                    e_w[ii] = q
                    swt[ii] = q
                else:
                    sw_real[ii] = s_w[ii]
                    e_w[ii] = np.interp(s_w[ii], self._egrid, self._energy)
            elif pname == "nm":
                s_nm = np.minimum(np.floor(sw_c[ii] * M), M - 1) / M
                sw_real[ii] = s_nm
                e_w[ii] = np.interp(s_nm, self._nm_s_grid, self._nm_curve)
                tsc[ii] = self._pattern_factors["nm"]
            elif pname == "hierarchical":
                st = np.floor(sw_c[ii] / 2.0 * self._n_tiles[ii]) \
                    / self._n_tiles[ii]
                r = np.clip((sw_c[ii] - st) / np.maximum(1.0 - st, 1e-12),
                            0.0, 1.0)
                s_nm = np.minimum(np.floor(r * M), M - 1) / M
                sw_real[ii] = 1.0 - (1.0 - st) * (1.0 - s_nm)
                e_w[ii] = st + (1.0 - st) * \
                    np.interp(s_nm, self._nm_s_grid, self._nm_curve)
                swt[ii] = st
                tsc[ii] = self._pattern_factors["hierarchical"]
            else:                                # activation
                sa_real[ii] = pruning.act_realize_pattern(sw_c[ii], s_a[ii])
        # effective sparsity: full element s_w on TPU for structured-decode
        # patterns, whole-tile fraction for unstructured/activation; pair
        # sparsity on element-granular (FPGA SPE) backends
        if self.tiled:
            s_eff_p = np.where(
                np.isin(codes, [k for k, p in enumerate(self.patterns)
                                if p in ("nm", "hierarchical")]),
                sw_real, swt)
        else:
            s_eff_p = 1.0 - (1.0 - sw_real) * (1.0 - sa_real)
        s_eff = np.zeros(len(self.layers), dtype=np.float64)
        s_eff[self._prunable_idx] = s_eff_p
        t_full = None
        if np.any(tsc != 1.0):
            t_full = np.ones(len(self.layers), dtype=np.float64)
            t_full[self._prunable_idx] = tsc
        return sw_real, sa_real, e_w, s_eff_p, swt, tsc, s_eff, t_full, codes

    def _realize(self, x: np.ndarray):
        """Proposal -> (realized per-prunable s_w, s_a, full-stack s_eff).

        Vectorized equivalent of reading ``hw.effective_sparsity`` off
        ``sparse_layers(x)`` (bit-identical floats, property-tested):
        tile-quantized ``s_w`` on TPU (whole-tile skips only), pair
        sparsity elsewhere."""
        s_w, s_a = self._split(x)
        if self.tiled:
            s_w = np.floor(np.clip(s_w, 0.0, 1.0) * self._n_tiles) \
                / self._n_tiles
            s_eff_p = s_w
        else:
            s_eff_p = 1.0 - (1.0 - s_w) * (1.0 - s_a)
        s_eff = np.zeros(len(self.layers), dtype=np.float64)
        s_eff[self._prunable_idx] = s_eff_p
        return s_w, s_a, s_eff

    def sparse_layers(self, x: np.ndarray) -> List[LayerCost]:
        """The sparse LayerCost stack one proposal realizes (tile-quantized
        on TPU). Feeds the partitioned multi-chip DP directly. With a
        pattern axis the stack carries each layer's realized pattern and
        decode ``t_scale`` so ``hw.layer_vectors`` reproduces exactly the
        effective sparsity the accelerated path scored."""
        if self.patterns is not None:
            sw_real, sa_real, _, _, swt, tsc, _, _, codes = \
                self._realize_pattern(x)
            out: List[LayerCost] = []
            i = 0
            for l in self.layers:
                if not l.prunable:
                    out.append(l)
                    continue
                out.append(LayerCost(**{
                    **l.__dict__, "s_w": float(sw_real[i]),
                    "s_a": float(sa_real[i]), "s_w_tile": float(swt[i]),
                    "pattern": self.patterns[codes[i]],
                    "t_scale": float(tsc[i])}))
                i += 1
            return out
        s_w, s_a = self._split(x)
        out = []
        i = 0
        for l in self.layers:
            if not l.prunable:
                out.append(l)
                continue
            sw, sa = float(s_w[i]), float(s_a[i])
            i += 1
            if self.tiled:
                sw = tile_quantize_sparsity(sw, l.m_dot, l.weight_count)
                out.append(LayerCost(**{**l.__dict__, "s_w": sw, "s_a": sa,
                                        "s_w_tile": sw}))
            else:
                out.append(LayerCost(**{**l.__dict__, "s_w": sw, "s_a": sa}))
        return out

    def _hw_terms(self, res: np.ndarray, thr: np.ndarray):
        """Identical shape to ``CNNEvaluator._hw_terms`` (log-compressed
        speedup vs the dense-stack DSE; dsp = resource fraction)."""
        thr_s = thr * self.hw.freq
        thr_norm = np.log2(1.0 + thr_s / max(self.dense_thr, 1e-9)) / 4.0
        return thr_s, thr_norm, res / max(self.budget, 1e-9)

    def _eq6_hw_score(self, res: np.ndarray, thr: np.ndarray) -> np.ndarray:
        _, thr_norm, dsp = self._hw_terms(res, thr)
        return self.lambdas.thr * thr_norm - self.lambdas.dsp * dsp

    def __call__(self, x: np.ndarray) -> Dict[str, float]:
        if self.patterns is not None:
            return self._call_pattern(x)
        if self.accel:
            sw, sa, s_eff = self._realize(x)
            lv = replace(self._lv0, s_eff=s_eff)
            dse = self.dse_cache.dse_vec(lv, self.hw, self.budget,
                                         max_iters=self.dse_iters,
                                         engine=self.dse_engine)
        else:
            layers = self.sparse_layers(x)
            sparse = [l for l in layers if l.prunable]
            sw = np.array([l.s_w for l in sparse])
            sa = np.array([l.s_a for l in sparse])
            dse = incremental_dse(layers, self.hw, self.budget,
                                  max_iters=self.dse_iters,
                                  engine=self.dse_engine)
        return self._finish(sw, sa, dse)

    def _call_pattern(self, x: np.ndarray) -> Dict[str, float]:
        """Pattern-axis scoring path: realize per-pattern, thread the decode
        ``t_scale`` through the DSE (``LayerVectors.t_scale`` — identical
        Eq. 1 mapping in every engine), finish with per-pattern energies."""
        rz = self._realize_pattern(x)
        sw_real, sa_real, e_w, s_eff_p, _, tsc, s_eff, t_full, _ = rz
        if self.accel:
            lv = replace(self._lv0, s_eff=s_eff, t_scale=t_full)
            dse = self.dse_cache.dse_vec(lv, self.hw, self.budget,
                                         max_iters=self.dse_iters,
                                         engine=self.dse_engine)
        else:
            dse = incremental_dse(self.sparse_layers(x), self.hw,
                                  self.budget, max_iters=self.dse_iters,
                                  engine=self.dse_engine)
        return self._finish_pattern(sw_real, sa_real, e_w, s_eff_p, tsc, dse)

    def _finish_pattern(self, sw_real, sa_real, e_w, s_eff_p, tsc,
                        dse) -> Dict[str, float]:
        """Per-pattern ``_finish``: energies come pre-computed from
        ``_realize_pattern`` (each pattern has its own accuracy curve).
        ``meas`` — the measured relative cycle estimate
        sum_l wfrac_l * c_l * (1 - s_eff_l) — is reported ONLY when
        ``pattern_costs`` was provided, so a cost-less pattern evaluator
        emits exactly the seed metric dict (Eq. 6 term gating,
        ``Lambdas.meas``)."""
        e_a = np.interp(sa_real, np.linspace(0.0, 1.0, len(self._energy)),
                        self._energy)
        acc = float(np.exp(-self.alpha *
                           np.dot(self._wfrac,
                                  e_w + self.act_weight * e_a)))
        spa = float(np.dot(self._wfrac, (sw_real + sa_real) / 2.0))
        m = {"acc": acc, "spa": spa,
             **frontier_hw_metrics(self, dse.frontier)}
        if self.pattern_costs is not None:
            m["meas"] = float(np.dot(self._wfrac, tsc * (1.0 - s_eff_p)))
        return m

    def _finish(self, sw: np.ndarray, sa: np.ndarray, dse) -> Dict[str, float]:
        """Realized sparsity + DSE result -> the Eq. 6 metric dict (shared
        by the serial and the proposal-batched path, so both produce the
        same floats by construction)."""
        # energy removed: tile pruning drops whole tiles (~uniform energy ->
        # fraction == sw); element pruning drops the smallest-|w| tail
        e_w = sw if self.tiled else \
            np.interp(sw, np.linspace(0.0, 1.0, len(self._energy)),
                      self._energy)
        e_a = np.interp(sa, np.linspace(0.0, 1.0, len(self._energy)),
                        self._energy)
        acc = float(np.exp(-self.alpha *
                           np.dot(self._wfrac, e_w + self.act_weight * e_a)))
        spa = float(np.dot(self._wfrac, (sw + sa) / 2.0))
        return {"acc": acc, "spa": spa,
                **frontier_hw_metrics(self, dse.frontier)}

    def evaluate_batch(self, xs: Sequence[np.ndarray]) -> List[Dict[str, float]]:
        """Proposal-batched path (DESIGN.md §15): realize every proposal's
        ``s_eff`` row, then score the whole wave through
        ``DSECache.dse_vec_batch`` — cache rows resolve in row order and
        ALL cold rows advance in ONE batched-engine invocation instead of
        k serial greedy runs. Bit-identical to ``[self(x) for x in xs]``
        (batch-engine exactness + certificate soundness, property-tested).
        A non-``auto`` ``dse_engine`` pins a specific serial engine, so it
        keeps the plain loop.

        With a pattern axis, rows are grouped by their decode ``t_scale``
        vector (one ``LayerVectors`` template per distinct pattern
        assignment's constants) and each group batches through
        ``dse_vec_batch`` — rows are independent, so grouping preserves
        per-row results exactly; patterned groups take the batch
        dispatcher's explicit lockstep route (DESIGN.md §16)."""
        if len(xs) < 2 or not self.accel or not self.batch_dse \
                or self.dse_engine != "auto":
            return [self(x) for x in xs]
        if self.patterns is not None:
            rz = [self._realize_pattern(x) for x in xs]
            keys = [None if r[7] is None else r[7].tobytes() for r in rz]
            out: List[Optional[Dict[str, float]]] = [None] * len(xs)
            seen: List = []
            for key in keys:
                if key not in seen:
                    seen.append(key)
            for key in seen:
                rows = [i for i, k2 in enumerate(keys) if k2 == key]
                lv = self._lv0 if key is None else \
                    replace(self._lv0, t_scale=rz[rows[0]][7])
                S = np.stack([rz[i][6] for i in rows])
                dses = self.dse_cache.dse_vec_batch(
                    lv, self.hw, self.budget, S, max_iters=self.dse_iters)
                for i, dse in zip(rows, dses):
                    sw_real, sa_real, e_w, s_eff_p, _, tsc = rz[i][:6]
                    out[i] = self._finish_pattern(sw_real, sa_real, e_w,
                                                  s_eff_p, tsc, dse)
            return out
        realized = [self._realize(x) for x in xs]
        S = np.stack([s_eff for _, _, s_eff in realized])
        dses = self.dse_cache.dse_vec_batch(self._lv0, self.hw, self.budget,
                                            S, max_iters=self.dse_iters)
        return [self._finish(sw, sa, dse)
                for (sw, sa, _), dse in zip(realized, dses)]


# --------------------------------------------------------------------- #
# CNN evaluator (the paper's own setting: ImageNet CNNs on the FPGA model)
# --------------------------------------------------------------------- #
@dataclass
class CNNEvaluator:
    """Builds the Eq. 6 metric dict for one (S_w, S_a) proposal on a CNN.

    Accuracy proxy: top-1 agreement with the dense reference on a calibration
    batch (no ImageNet in-container; the search structure is unchanged —
    documented in DESIGN.md §5).

    ``accel=True`` (default) enables the search-loop acceleration subsystem
    (DESIGN.md §12): per-layer sorted-|w| tables turn every tau_w quantile
    into a bit-identical O(1) gather (weights are constant across a search;
    the seed path re-sorts them inside every jit call), and the DSE runs
    through a per-evaluator ``DSECache``. ``frontier_mode``/``budget_fracs``
    select the Eq. 6 frontier scoring (``frontier_hw_metrics``).

    On a ``TPUModel`` the pruner is tile-structured (``pruning.tile_prune``,
    128-aligned all-zero tiles — the only pattern the MXU skips) and
    ``LayerCost.s_w_tile`` is MEASURED from the actually pruned weights
    instead of a synthetic target.
    """
    cfg: object
    params: dict
    images: jnp.ndarray
    hw: HardwareModel
    budget: float
    dse_iters: int = 400
    cost_cfg: object = None     # full-res config for C_l (accuracy runs can
                                # use a reduced img_res; layer names match)
    lambdas: Lambdas = field(default_factory=Lambdas)  # Eq. 6 weights used
                                # to pick the frontier trade-off point
    accel: bool = True          # presorted tau tables + DSECache
    frontier_mode: str = "budgets"    # Eq. 6 frontier scoring (see
    budget_fracs: tuple = (0.25, 0.5, 0.75, 1.0)   # frontier_hw_metrics)
    dse_engine: str = "auto"    # greedy engine (flat pins seed behavior)
    batch_dse: bool = True      # proposal-batched DSE in evaluate_batch
    patterns: Optional[tuple] = None   # sparsity-pattern axis (DESIGN.md
    #                             §16): None = literal pre-pattern path;
    #                             ("unstructured",) pins every layer to the
    #                             seed pruner (bit-identical by routing
    #                             through the SAME jitted closure); >1
    #                             entries add one categorical TPE variable
    #                             per prunable layer, realized by a traced
    #                             lax.switch pruner (one compile for all
    #                             pattern assignments)
    pattern_costs: Optional[dict] = None   # pattern -> measured decode
    #                             factor (kernels.kernel_costs);
    #                             enables t_scale + the ``meas`` metric

    def __post_init__(self):
        from repro.core.perf_model import cnn_layer_costs
        from repro.models import cnn
        self._cnn = cnn
        if self.patterns is not None:
            self.patterns = tuple(self.patterns)
            bad = [p for p in self.patterns if p not in pruning.PATTERNS]
            if bad or not self.patterns:
                raise ValueError(f"unknown patterns {bad or self.patterns}")
        self.layers = [l for l in cnn_layer_costs(self.cost_cfg or self.cfg)]
        self.prunable = [l for l in self.layers if l.prunable]
        self.names = [l.name for l in self.prunable]
        self.tiled = isinstance(self.hw, TPUModel)
        self.dense_logits = np.asarray(
            cnn.forward(self.cfg, self.params, self.images))
        self.dense_pred = jnp.asarray(self.dense_logits.argmax(-1))
        # activation magnitude samples per prunable layer (for tau_a quantiles)
        self._act_q = jnp.asarray(
            np.stack([self._collect_act_samples()[n] for n in self.names]))
        self.dse_cache = DSECache() if self.accel else None
        dense = incremental_dse(self.layers, self.hw, self.budget,
                                max_iters=self.dse_iters)
        self.dense_thr = dense.throughput * self.hw.freq
        # accel: weights never change across a search, so each layer's
        # sorted |w| is computed ONCE here and every proposal's tau_w is a
        # bit-identical O(1) gather instead of jnp.quantile's O(n log n)
        # re-sort per layer per call (the seed path's dominant cost;
        # DESIGN.md §12)
        self._asort = {n: pruning.sorted_abs(self.params[n]["w"])
                       for n in self.names} \
            if self.accel and not self.tiled else None

        def _eval(params, s_w, s_a):
            pruned = dict(params)
            achieved = []
            tile_fracs = []
            taus = {}
            for i, n in enumerate(self.names):
                w = params[n]["w"]
                if self.tiled:
                    # TPU path: tile-structured pruning; the MXU can only
                    # skip whole 128-aligned all-zero tiles, so s_w_tile is
                    # MEASURED on the actually pruned weights
                    w2, swt = pruning.tile_prune(w, s_w[i])
                    tile_fracs.append(swt)
                else:
                    tau_w = pruning.threshold_for_sparsity_sorted(
                        self._asort[n], s_w[i]) if self.accel else \
                        pruning.threshold_for_sparsity(w, s_w[i])
                    w2 = pruning.prune_tensor(w, tau_w)
                pruned[n] = dict(params[n], w=w2)
                achieved.append(jnp.mean(w2 == 0.0))
                qidx = jnp.clip((s_a[i] * self._act_q.shape[1]).astype(jnp.int32),
                                0, self._act_q.shape[1] - 1)
                taus[n] = self._act_q[i, qidx]
            logits, stats = cnn.forward(self.cfg, pruned, self.images,
                                        sparsity=taus, collect_stats=True)
            acc = jnp.mean(logits.argmax(-1) == self.dense_pred)
            s_a_meas = jnp.stack([stats[n] for n in self.names])
            swt = jnp.stack(tile_fracs) if self.tiled \
                else jnp.zeros(len(self.names))
            return acc, jnp.stack(achieved), s_a_meas, swt

        self._eval = jax.jit(_eval)
        # batched frontier: one vmapped prune+forward for a whole batch of
        # proposals (compiled once per batch shape) instead of B jit calls
        self._eval_batch = jax.jit(jax.vmap(_eval, in_axes=(None, 0, 0)))

        # pattern axis state (DESIGN.md §16)
        self.n_pattern_dims = len(self.prunable) \
            if self.patterns is not None and len(self.patterns) > 1 else 0
        self._pattern_factors = {p: 1.0 for p in pruning.PATTERNS}
        if self.pattern_costs:
            self._pattern_factors.update(
                {k: float(v) for k, v in self.pattern_costs.items()})
        # the degenerate ("unstructured",) axis routes through the seed
        # closure itself (codes are all zero and unstructured IS the seed
        # pruner), so default-pattern searches share the compiled program
        # and the floats bit-for-bit; any other axis needs the traced
        # per-layer pattern dispatch below
        self._needs_pattern_eval = self.patterns is not None and \
            self.patterns != ("unstructured",)
        if self._needs_pattern_eval:
            act_code = self.patterns.index("activation") \
                if "activation" in self.patterns else -1

            def _branches_for(n):
                """Per-layer pruner branch list, ``self.patterns``-ordered.
                Pattern codes are TRACED so one compile covers every
                assignment the TPE proposes (under vmap the switch becomes
                a select over all branches)."""
                def b_unstructured(w, s):
                    if self.tiled:
                        w2, swt = pruning.tile_prune(w, s)
                        return w2, jnp.asarray(swt, jnp.float32)
                    tau = pruning.threshold_for_sparsity_sorted(
                        self._asort[n], s) if self._asort is not None \
                        else pruning.threshold_for_sparsity(w, s)
                    return pruning.prune_tensor(w, tau), jnp.float32(0.0)

                def b_nm(w, s):
                    return pruning.nm_prune(
                        w, pruning.nm_keep_for_sparsity(s)), jnp.float32(0.0)

                def b_hier(w, s):
                    # half the budget tile-level, residual intra-tile N:M
                    wt, swt = pruning.tile_prune(w, s / 2.0)
                    r = jnp.clip(s / (2.0 - s), 0.0, 1.0)
                    w2 = pruning.nm_prune(wt, pruning.nm_keep_for_sparsity(r))
                    return w2, jnp.asarray(swt, jnp.float32)

                def b_act(w, s):
                    return w, jnp.float32(0.0)   # weights stay dense

                table = {"unstructured": b_unstructured, "nm": b_nm,
                         "hierarchical": b_hier, "activation": b_act}
                return [table[p] for p in self.patterns]

            def _eval_p(params, s_w, s_a, codes):
                pruned = dict(params)
                achieved = []
                tile_fracs = []
                taus = {}
                for i, n in enumerate(self.names):
                    w = params[n]["w"]
                    sw_i, code_i = s_w[i], codes[i]
                    sa_i = s_a[i]
                    if act_code >= 0:
                        # activation pattern: the weight budget converts to
                        # extra realized activation sparsity
                        sa_i = jnp.where(
                            code_i == act_code,
                            1.0 - (1.0 - sa_i) *
                            (1.0 - jnp.clip(sw_i, 0.0, 1.0)),
                            sa_i)
                    w2, swt = jax.lax.switch(code_i, _branches_for(n),
                                             w, sw_i)
                    pruned[n] = dict(params[n], w=w2)
                    achieved.append(jnp.mean(w2 == 0.0))
                    tile_fracs.append(swt)
                    qidx = jnp.clip(
                        (sa_i * self._act_q.shape[1]).astype(jnp.int32),
                        0, self._act_q.shape[1] - 1)
                    taus[n] = self._act_q[i, qidx]
                logits, stats = cnn.forward(self.cfg, pruned, self.images,
                                            sparsity=taus,
                                            collect_stats=True)
                acc = jnp.mean(logits.argmax(-1) == self.dense_pred)
                s_a_meas = jnp.stack([stats[n] for n in self.names])
                return (acc, jnp.stack(achieved), s_a_meas,
                        jnp.stack(tile_fracs))

            self._eval_p = jax.jit(_eval_p)
            self._eval_p_batch = jax.jit(
                jax.vmap(_eval_p, in_axes=(None, 0, 0, 0)))
        # batch-shape bucketing state: ``batch_shapes`` records every batch
        # shape actually handed to the vmapped executable (== compiles);
        # ragged batches pad up to an already-compiled shape when one is
        # close enough (see ``evaluate_batch``)
        self.batch_shapes: set = set()
        self.padded_batches: int = 0

    def _collect_act_samples(self) -> Dict[str, np.ndarray]:
        """|activation| quantiles at each prunable layer's input (dense run):
        the calibration pass that maps target S_a -> clip threshold tau_a."""
        from repro.models import cnn
        _, outs = cnn.forward(self.cfg, self.params, self.images,
                              return_intermediates=True)
        specs = cnn.build_specs(self.cfg)
        last = cnn.INPUT
        samples = {}
        for s in specs:
            inp_name = s.input_from or last
            if s.prunable:
                flat = np.abs(np.asarray(outs[inp_name],
                                         dtype=np.float32)).reshape(-1)
                samples[s.name] = np.quantile(flat, np.linspace(0, 0.999, 256))
            last = s.name
        return samples

    def _split(self, x: np.ndarray):
        L = len(self.prunable)
        x = np.asarray(x, dtype=np.float64)
        if self.n_pattern_dims and len(x) > L:
            x = x[:-self.n_pattern_dims]    # pattern dims ride at the END
        s_w = jnp.asarray(x[:L])
        s_a = jnp.asarray(x[L:2 * L]) if len(x) >= 2 * L else jnp.zeros(L)
        return s_w, s_a

    def _pattern_codes(self, x: np.ndarray) -> np.ndarray:
        """Per-prunable-layer index into ``self.patterns`` (all zeros for
        the degenerate single-pattern axis)."""
        L = len(self.prunable)
        if self.n_pattern_dims == 0:
            return np.zeros(L, np.int64)
        raw = np.asarray(x, dtype=np.float64)[-self.n_pattern_dims:]
        return np.clip(raw.astype(np.int64), 0, len(self.patterns) - 1)

    def _sparse_layers(self, sw_meas: np.ndarray, sa_meas: np.ndarray,
                       swt_meas: Optional[np.ndarray] = None,
                       codes: Optional[np.ndarray] = None):
        """Measured per-layer sparsity -> LayerCost pipeline + avg sparsity.
        ``swt_meas`` (TPU path) carries the measured all-zero-tile fraction
        of the actually pruned weights into ``LayerCost.s_w_tile``.
        ``codes`` (pattern axis) stamps each layer's realized pattern and
        decode ``t_scale`` so the perf model prices it per-pattern."""
        layers = []
        spa_num = spa_den = 0.0
        i = 0
        for l in self.layers:
            if l.prunable:
                sw, sa = float(sw_meas[i]), float(sa_meas[i])
                swt = float(swt_meas[i]) if swt_meas is not None else 0.0
                extra = {}
                if codes is not None:
                    pname = self.patterns[int(codes[i])]
                    extra = {"pattern": pname,
                             "t_scale": self._pattern_factors[pname]}
                i += 1
                layers.append(LayerCost(**{**l.__dict__, "s_w": sw,
                                           "s_a": sa, "s_w_tile": swt,
                                           **extra}))
                spa_num += (sw + sa) / 2 * l.weight_count
                spa_den += l.weight_count
            else:
                layers.append(l)
        return layers, spa_num / max(spa_den, 1e-9)

    def _eval_any(self, x: np.ndarray):
        """One jitted prune+forward for one proposal, routed through the
        pattern dispatch when the axis needs it. Returns
        (acc, sw_meas, sa_meas, swt_meas, codes) as numpy."""
        s_w, s_a = self._split(x)
        if self.patterns is not None and self._needs_pattern_eval:
            codes = self._pattern_codes(x)
            out = self._eval_p(self.params, s_w, s_a,
                               jnp.asarray(codes, jnp.int32))
        else:
            codes = self._pattern_codes(x) if self.patterns is not None \
                else None
            out = self._eval(self.params, s_w, s_a)
        acc, sw_meas, sa_meas, swt_meas = map(np.asarray, out)
        return acc, sw_meas, sa_meas, swt_meas, codes

    def sparse_layers(self, x: np.ndarray):
        """The measured sparse LayerCost pipeline for one proposal (one
        jitted prune+forward). Feeds the partitioned multi-chip DSE demo."""
        acc, sw_meas, sa_meas, swt_meas, codes = self._eval_any(x)
        return self._sparse_layers(sw_meas, sa_meas,
                                   swt_meas if self.tiled else None,
                                   codes=codes)[0]

    def _hw_terms(self, res: np.ndarray, thr: np.ndarray):
        """(thr in samples/s, thr_norm, dsp) for frontier points, vectorized.
        thr_norm is the log-compressed speedup: Eq. 6's lambda-normalization
        heuristic keeps the hardware terms commensurate with acc in [0, 1]."""
        thr_s = thr * self.hw.freq
        thr_norm = np.log2(1.0 + thr_s / max(self.dense_thr, 1e-9)) / 4.0
        return thr_s, thr_norm, res / max(self.budget, 1e-9)

    def _eq6_hw_score(self, res: np.ndarray, thr: np.ndarray) -> np.ndarray:
        """The Eq. 6 hardware combination used to pick the frontier point."""
        _, thr_norm, dsp = self._hw_terms(res, thr)
        return self.lambdas.thr * thr_norm - self.lambdas.dsp * dsp

    def _meas_term(self, layers) -> float:
        """Measured relative cycle estimate of one realized assignment:
        weight-fraction-weighted c_l * (1 - s_eff_l) over prunable layers
        (Eq. 6 ``meas``, subtracted with ``Lambdas.meas``)."""
        num = den = 0.0
        for l in layers:
            if not l.prunable:
                continue
            num += l.weight_count * l.t_scale * \
                (1.0 - self.hw.effective_sparsity(l))
            den += l.weight_count
        return num / max(den, 1e-9)

    def _metrics(self, acc: float, sw_meas: np.ndarray, sa_meas: np.ndarray,
                 swt_meas: Optional[np.ndarray] = None,
                 codes: Optional[np.ndarray] = None) -> Dict[str, float]:
        """Measured per-layer sparsity -> perf model (Eq. 1-3) -> one DSE
        (through the ``DSECache`` when accelerated) -> Eq. 6 hardware terms
        off the frontier (``frontier_hw_metrics``) -> the metric dict."""
        layers, spa = self._sparse_layers(sw_meas, sa_meas, swt_meas,
                                          codes=codes)
        if self.dse_cache is not None:
            dse = self.dse_cache.dse(layers, self.hw, self.budget,
                                     max_iters=self.dse_iters,
                                     engine=self.dse_engine)
        else:
            dse = incremental_dse(layers, self.hw, self.budget,
                                  max_iters=self.dse_iters,
                                  engine=self.dse_engine)
        m = {"acc": acc, "spa": spa,
             **frontier_hw_metrics(self, dse.frontier)}
        if codes is not None and self.pattern_costs is not None:
            m["meas"] = self._meas_term(layers)
        return m

    def _metrics_batch(self, accs: np.ndarray, sw_meas: np.ndarray,
                       sa_meas: np.ndarray,
                       swt_meas: Optional[np.ndarray],
                       codes_rows: Optional[np.ndarray] = None
                       ) -> List[Dict[str, float]]:
        """Batched ``_metrics`` tail: one ``dse_vec_batch`` call scores all
        measured-sparsity rows (the workload constants are per-layer dense
        facts — identical across rows — so one ``LayerVectors`` template +
        the stacked ``s_eff`` rows is the whole batch state). Bit-identical
        to the per-row ``_metrics`` loop (property-tested). Pattern rows
        whose decode ``t_scale`` vectors differ are grouped — one template
        per distinct vector — because ``t_scale`` is a template constant,
        not a per-row input; rows are independent, so grouping preserves
        each row's result exactly."""
        B = len(accs)
        rows = [self._sparse_layers(sw_meas[b], sa_meas[b],
                                    swt_meas[b] if swt_meas is not None
                                    else None,
                                    codes=codes_rows[b]
                                    if codes_rows is not None else None)
                for b in range(B)]
        lvs = [self.hw.layer_vectors(layers) for layers, _ in rows]
        keys = [None if lv.t_scale is None else lv.t_scale.tobytes()
                for lv in lvs]
        dses: List = [None] * B
        if len(set(keys)) == 1:
            S = np.stack([lv.s_eff for lv in lvs])
            dses = self.dse_cache.dse_vec_batch(lvs[0], self.hw,
                                                self.budget, S,
                                                max_iters=self.dse_iters)
        else:
            seen: List = []
            for key in keys:
                if key not in seen:
                    seen.append(key)
            for key in seen:
                grp = [b for b in range(B) if keys[b] == key]
                S = np.stack([lvs[b].s_eff for b in grp])
                for b, dse in zip(grp, self.dse_cache.dse_vec_batch(
                        lvs[grp[0]], self.hw, self.budget, S,
                        max_iters=self.dse_iters)):
                    dses[b] = dse
        out = []
        for b in range(B):
            m = {"acc": float(accs[b]), "spa": rows[b][1],
                 **frontier_hw_metrics(self, dses[b].frontier)}
            if codes_rows is not None and self.pattern_costs is not None:
                m["meas"] = self._meas_term(rows[b][0])
            out.append(m)
        return out

    def __call__(self, x: np.ndarray) -> Dict[str, float]:
        # 1-2) one-shot prune + accuracy proxy + measured act sparsity (jitted)
        acc, sw_meas, sa_meas, swt_meas, codes = self._eval_any(x)
        return self._metrics(float(acc), sw_meas, sa_meas,
                             swt_meas if self.tiled else None,
                             codes=codes)

    def evaluate_batch(self, xs: Sequence[np.ndarray]) -> List[Dict[str, float]]:
        """Score a batch of proposals with ONE vmapped prune+forward call;
        the (fast, vectorized) DSE then runs per proposal on the measured
        sparsities. Feeds ``hass_search(batch_size=...)``.

        Batch-shape bucketing: a ragged batch (a search's tail round) is
        padded up to the nearest already-compiled batch shape by repeating
        the last proposal. Padded rows are dropped before returning, so they
        never reach ``tell_batch`` — a whole fixed-size search compiles
        exactly one vmapped executable."""
        if len(xs) == 0:
            return []
        B = len(xs)
        split = [self._split(x) for x in xs]
        s_w = jnp.stack([s for s, _ in split])
        s_a = jnp.stack([a for _, a in split])
        pattern_eval = self.patterns is not None and self._needs_pattern_eval
        codes_rows = np.stack([self._pattern_codes(x) for x in xs]) \
            if self.patterns is not None else None
        # bucket rule: pad up to the smallest already-compiled shape in
        # [B, 2B] (a one-time compile beats repeated >2x padding waste, e.g.
        # a later smaller-batch search on a shared evaluator); otherwise
        # compile this exact size
        bigger = [s for s in self.batch_shapes if B <= s <= 2 * B]
        target = min(bigger) if bigger else B
        codes_j = jnp.asarray(codes_rows, jnp.int32) if pattern_eval else None
        if B < target:
            pad = target - B
            s_w = jnp.concatenate(
                [s_w, jnp.broadcast_to(s_w[-1], (pad,) + s_w.shape[1:])])
            s_a = jnp.concatenate(
                [s_a, jnp.broadcast_to(s_a[-1], (pad,) + s_a.shape[1:])])
            if pattern_eval:
                codes_j = jnp.concatenate(
                    [codes_j, jnp.broadcast_to(codes_j[-1],
                                               (pad,) + codes_j.shape[1:])])
            self.padded_batches += 1
        self.batch_shapes.add(int(s_w.shape[0]))
        if pattern_eval:
            accs, sw_meas, sa_meas, swt_meas = map(
                np.asarray,
                self._eval_p_batch(self.params, s_w, s_a, codes_j))
        else:
            accs, sw_meas, sa_meas, swt_meas = map(
                np.asarray, self._eval_batch(self.params, s_w, s_a))
        if B > 1 and self.dse_cache is not None and self.batch_dse \
                and self.dse_engine == "auto":
            return self._metrics_batch(accs[:B], sw_meas[:B], sa_meas[:B],
                                       swt_meas[:B] if self.tiled else None,
                                       codes_rows=codes_rows)
        return [self._metrics(float(accs[b]), sw_meas[b], sa_meas[b],
                              swt_meas[b] if self.tiled else None,
                              codes=codes_rows[b]
                              if codes_rows is not None else None)
                for b in range(B)]
