"""Compiled backend for the proposal-batched DSE engine (DESIGN.md §15).

The batched greedy's per-step work is ~a hundred scalar float ops on a
handful of layers — far below the dispatch cost of *any* array runtime
(measured: numpy lockstep ~1x vs the grouped serial engine, XLA-CPU
0.2–0.5x; per-call/per-thunk overhead floors both). The only way to beat
the serial engines by the integer factors a batched `ask_batch(k)` wave
wants is to run the scalar recurrence at native speed: this module embeds
a C port of the serial engines — both ``_run_incremental`` (flat) and
``_run_incremental_grouped`` (class-grouped, wave-batched), with the same
per-proposal ``auto`` dispatch rule — and drives it over the B proposals
of a batch in one call through ``ctypes``.

Build strategy: the C source is compiled on first use with the system C
compiler (``cc``/``gcc``/``clang``) into a shared object cached under
``_build/`` next to this file, keyed by a hash of the source + compile
flags, so rebuilds happen only when the kernel changes. No compiler, a
failed compile, or ``REPRO_DSE_CKERNEL=0`` in the environment all degrade
gracefully: ``get_lib()`` returns None and callers fall back to the pure
numpy lockstep engine (``dse.py`` dispatches on availability).

Pattern rows (DESIGN.md §16): batches whose ``LayerVectors.t_scale`` is
set never reach this kernel — the dynamics-class key below compares the
six pre-pattern per-layer constants only, so ``_run_batch_dispatch``
routes patterned rows to the numpy lockstep engine (which consumes the
host-scaled ``omsm`` and stays bit-exact vs the serial engines).

Float contract — why the kernel is bit-exact vs the Python engines:

  * every float expression is the serial engine's, in the serial engine's
    evaluation order (``rate_of`` mirrors ``thr_of``/``rates_pre``; the
    ``(1 - s_eff) * m_dot`` numerator is precomputed by the *caller* in
    numpy so even that product's rounding is shared);
  * integer design state is int64; all int products stay < 2**53 (the
    ``throughput_vec`` invariant), so int->double conversions are exact
    and C's ``(double)s * md`` equals Python's exact-int-then-divide;
  * compiled with ``-ffp-contract=off``: GCC's default contraction would
    fuse ``a * b - c`` into FMA (one rounding instead of two) and break
    equality with numpy, which never fuses. No ``-ffast-math`` for the
    same reason.
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import shutil
import subprocess
import tempfile
from typing import Optional

import numpy as np

_C_SRC = r"""
#include <math.h>
#include <stdlib.h>
#include <string.h>

typedef long long i64;
typedef unsigned char u8;

/* Eq. 1-2 for one layer: the serial engines' thr_of / the numpy engine's
   rates_pre, scalar-for-scalar. om = (1 - s_eff) * m_dot (precomputed by
   the caller in numpy so its rounding is shared with the Python path). */
static double rate_of(double om, double md, double mc, i64 s, i64 nn) {
    double t;
    if (mc == 0.0) return INFINITY;
    t = ceil(om / (double)nn);
    if (t < 1.0) t = 1.0;
    return ((double)s * md) / (mc * t);
}

/* ------------------------------------------------------------------ */
/* Flat engine: 1:1 port of dse.py _run_incremental                   */
/* ------------------------------------------------------------------ */

#define SYNC(i) do { \
    thr[i] = rate_of(om[i], m_dot[i], macs[i], spe[i], n[i]); \
    r_nh[i] = rate_of(om[i], m_dot[i], macs[i], spe[i], \
                      n[i] > 1 ? n[i] / 2 : 1); \
    r_sh[i] = rate_of(om[i], m_dot[i], macs[i], \
                      spe[i] > 1 ? spe[i] / 2 : 1, n[i]); \
} while (0)

/* One Eq. 4-5 pass against fixed lo — the flat engine's balance():
   ascending-layer scan, entry via the maintained one-halving rates,
   n-halvings-then-spe-halvings shrink chain (with the reference's
   retry-n-after-spe order), res_total accumulated per changed layer in
   ascending layer order. Appends (i, new_s, new_n) mutation rows and
   records (i, old_s, old_n) into ch_* for budget reverts.
   Returns changed count, or -1 on mutation-buffer overflow. */
static i64 f_balance(i64 L, double lo, i64 skip_idx, const u8 *skip_mask,
                     const double *om, const double *m_dot,
                     const double *macs, const double *unit,
                     i64 *spe, i64 *n,
                     double *thr, double *r_nh, double *r_sh,
                     double *res_total,
                     i64 *ch_i, i64 *ch_s, i64 *ch_n,
                     i64 *mut_pos, i64 *mut_s, i64 *mut_n,
                     i64 *mp, i64 M) {
    i64 nch = 0, i;
    for (i = 0; i < L; i++) {
        i64 s_i, n_i;
        if (skip_mask ? skip_mask[i] : (i == skip_idx)) continue;
        if (!((n[i] > 1 && r_nh[i] >= lo) || (spe[i] > 1 && r_sh[i] >= lo)))
            continue;
        s_i = spe[i];
        n_i = n[i];
        ch_i[nch] = i; ch_s[nch] = s_i; ch_n[nch] = n_i; nch++;
        for (;;) {
            if (n_i > 1 &&
                rate_of(om[i], m_dot[i], macs[i], s_i, n_i / 2) >= lo) {
                n_i /= 2;
                continue;
            }
            if (s_i > 1 &&
                rate_of(om[i], m_dot[i], macs[i], s_i / 2, n_i) >= lo) {
                s_i /= 2;
                continue;
            }
            break;
        }
        *res_total += (double)(s_i * n_i - spe[i] * n[i]) * unit[i];
        spe[i] = s_i;
        n[i] = n_i;
        SYNC(i);
        if (*mp >= M) return -1;
        mut_pos[*mp] = i; mut_s[*mp] = s_i; mut_n[*mp] = n_i; (*mp)++;
    }
    return nch;
}

static int run_flat(i64 L, i64 max_iters, double budget,
                    const double *om, const double *m_dot,
                    const double *macs, const double *unit,
                    const i64 *max_n, const i64 *max_spe,
                    i64 *spe, i64 *n,
                    double *res_out, double *fthr_out, double *theta_out,
                    double *trr, double *trc, i64 *tr_len,
                    i64 *mpos, i64 *ms, i64 *mn, i64 *mc, i64 M,
                    double *thr, double *r_nh, double *r_sh,
                    i64 *ch_i, i64 *ch_s, i64 *ch_n, u8 *prot) {
    i64 i, it, nch, row_mp, nrows = 0, mp = 0;
    double res_total = 0.0, theta, hi, f_thr;
    int broke = 0;
    for (i = 0; i < L; i++) {
        spe[i] = 1;
        n[i] = 1;
        thr[i] = rate_of(om[i], m_dot[i], macs[i], 1, 1);
        r_nh[i] = thr[i];
        r_sh[i] = thr[i];
        res_total += unit[i];   /* float(sum(unit)), same add order */
    }
    for (it = 0; it < max_iters; it++) {
        double cur_thr, cur_res, best_score, m_after, res_before, u;
        i64 slow, sl_s, sl_n, b_s, b_n;
        int have;
        cur_thr = thr[0];
        slow = 0;
        for (i = 1; i < L; i++)           /* first-minimum: thr.index(min) */
            if (thr[i] < cur_thr) { cur_thr = thr[i]; slow = i; }
        trr[it] = res_total;
        trc[it] = cur_thr;
        row_mp = mp;
        sl_s = spe[slow];
        sl_n = n[slow];
        u = unit[slow];
        cur_res = (double)(sl_s * sl_n) * u;
        have = 0;
        b_s = 0; b_n = 0; best_score = 0.0;
        if (sl_n < max_n[slow]) {         /* n-doubling first: wins ties */
            i64 n2 = sl_n * 2;
            double dres, sc;
            if (n2 > max_n[slow]) n2 = max_n[slow];
            dres = (double)(sl_s * n2) * u - cur_res;
            if (dres < 1e-9) dres = 1e-9;
            sc = (rate_of(om[slow], m_dot[slow], macs[slow], sl_s, n2)
                  - cur_thr) / dres;
            have = 1; b_s = sl_s; b_n = n2; best_score = sc;
        }
        if (sl_s < max_spe[slow]) {
            i64 s2 = sl_s * 2;
            double dres, sc;
            if (s2 > max_spe[slow]) s2 = max_spe[slow];
            dres = (double)(s2 * sl_n) * u - cur_res;
            if (dres < 1e-9) dres = 1e-9;
            sc = (rate_of(om[slow], m_dot[slow], macs[slow], s2, sl_n)
                  - cur_thr) / dres;
            if (!have || sc > best_score) {
                have = 1; b_s = s2; b_n = sl_n; best_score = sc;
            }
        }
        if (!have) {                      /* saturated: row stays, no muts */
            mc[it] = 0;
            nrows = it + 1;
            broke = 1;
            break;
        }
        res_before = res_total;
        res_total += (double)(b_s * b_n - sl_s * sl_n) * u;
        spe[slow] = b_s;
        n[slow] = b_n;
        SYNC(slow);
        if (mp >= M) return 1;
        mpos[mp] = slow; ms[mp] = b_s; mn[mp] = b_n; mp++;
        m_after = thr[0];
        for (i = 1; i < L; i++) if (thr[i] < m_after) m_after = thr[i];
        nch = f_balance(L, m_after * (1 + 1e-9), slow, 0,
                        om, m_dot, macs, unit, spe, n, thr, r_nh, r_sh,
                        &res_total, ch_i, ch_s, ch_n,
                        mpos, ms, mn, &mp, M);
        if (nch < 0) return 1;
        if (res_total > budget) {         /* revert growth + balance */
            i64 j;
            spe[slow] = sl_s;
            n[slow] = sl_n;
            SYNC(slow);
            for (j = 0; j < nch; j++) {
                i = ch_i[j];
                spe[i] = ch_s[j];
                n[i] = ch_n[j];
                SYNC(i);
            }
            res_total = res_before;
            mp = row_mp;                  /* muts[-1] = [] */
            mc[it] = 0;
            nrows = it + 1;
            broke = 1;
            break;
        }
        mc[it] = mp - row_mp;
    }
    if (!broke) nrows = max_iters;
    /* final literal Eq. 4 pass: trim, protect the bottleneck set */
    theta = thr[0];
    for (i = 1; i < L; i++) if (thr[i] < theta) theta = thr[i];
    hi = theta * (1 + 1e-9);
    for (i = 0; i < L; i++) prot[i] = (u8)(thr[i] <= hi);
    row_mp = mp;
    nch = f_balance(L, theta * (1 - 1e-12), -1, prot,
                    om, m_dot, macs, unit, spe, n, thr, r_nh, r_sh,
                    &res_total, ch_i, ch_s, ch_n, mpos, ms, mn, &mp, M);
    if (nch < 0) return 1;
    mc[nrows] = mp - row_mp;
    f_thr = thr[0];
    for (i = 1; i < L; i++) if (thr[i] < f_thr) f_thr = thr[i];
    *res_out = res_total;
    *fthr_out = f_thr;
    *theta_out = theta;
    *tr_len = nrows;
    return 0;
}

/* ------------------------------------------------------------------ */
/* Grouped engine: 1:1 port of dse.py _run_incremental_grouped        */
/* ------------------------------------------------------------------ */

typedef struct { i64 start, cnt, s, n; double r, rnh, rsh; } Grp;

typedef struct {
    i64 L, C;
    const i64 *pos;       /* member positions, class-major; class c is
                             pos[coff[c] .. coff[c+1]) ascending */
    const i64 *coff;      /* C+1 class offsets (also group-arena offsets) */
    const double *om_c, *md_c, *mc_c, *u_c;   /* class constants */
    const i64 *mn_c, *ms_c;
    Grp *ga;              /* group arena; class c's groups at coff[c].. */
    i64 *gcnt;            /* live group count per class */
    Grp *gsave;           /* iter_log: saved segments (same offsets) */
    i64 *scnt;
    u8 *touched;          /* iter_log membership */
    i64 *tlist, nt;
    i64 *u_p, *u_s, *u_n, nu;     /* undo: flat-mirror (p, old_s, old_n) */
    i64 *up_p;            /* balance updates: position */
    double *up_d;         /*                  delta    */
    u8 *bal_t;            /* classes touched by the current balance pass */
    i64 *bt_list;
    i64 *spe_l, *n_l;     /* flat per-layer design mirror */
    double res;
    i64 *mpos, *ms, *mn, mp, M;
} GCtx;

static double g_rate(const GCtx *g, i64 c, i64 s, i64 nn) {
    return rate_of(g->om_c[c], g->md_c[c], g->mc_c[c], s, nn);
}

static void g_setrates(const GCtx *g, i64 c, Grp *p) {
    p->r = g_rate(g, c, p->s, p->n);
    p->rnh = g_rate(g, c, p->s, p->n > 1 ? p->n / 2 : 1);
    p->rsh = g_rate(g, c, p->s > 1 ? p->s / 2 : 1, p->n);
}

static void g_touch(GCtx *g, i64 c) {
    if (!g->touched[c]) {
        g->touched[c] = 1;
        g->tlist[g->nt++] = c;
        g->scnt[c] = g->gcnt[c];
        memcpy(g->gsave + g->coff[c], g->ga + g->coff[c],
               (size_t)g->gcnt[c] * sizeof(Grp));
    }
}

static void g_compact(GCtx *g, i64 c) {
    Grp *seg = g->ga + g->coff[c];
    i64 nold = g->gcnt[c], j, out = 0;
    for (j = 1; j < nold; j++) {
        if (seg[out].s == seg[j].s && seg[out].n == seg[j].n) {
            seg[out].cnt += seg[j].cnt;
        } else {
            out++;
            if (out != j) seg[out] = seg[j];
        }
    }
    g->gcnt[c] = nold ? out + 1 : 0;
}

/* (min rate, argmin slot, strict second) in one pass; rate ties break by
   lowest member position — the flat engine's thr.index(min). */
static void g_scanmin(const GCtx *g, double *cur_out, i64 *bc_out,
                      i64 *bg_out, double *second_out) {
    double cur = INFINITY, second = INFINITY;
    i64 best_c = -1, best_g = -1, best_pos = g->L, c, gi;
    for (c = 0; c < g->C; c++) {
        const Grp *seg = g->ga + g->coff[c];
        for (gi = 0; gi < g->gcnt[c]; gi++) {
            double r = seg[gi].r;
            if (r < cur) {
                second = cur;
                cur = r;
                best_c = c;
                best_g = gi;
                best_pos = g->pos[g->coff[c] + seg[gi].start];
            } else if (r == cur) {
                second = cur;
                {
                    i64 p = g->pos[g->coff[c] + seg[gi].start];
                    if (p < best_pos) {
                        best_c = c;
                        best_g = gi;
                        best_pos = p;
                    }
                }
            } else if (r < second) {
                second = r;
            }
        }
    }
    *cur_out = cur;
    *bc_out = best_c;
    *bg_out = best_g;
    *second_out = second;
}

/* One Eq. 4-5 pass at fixed lo over all groups; skip one group (skip_c,
   skip_g) or a per-slot protected mask. Shrink chains are per-group; res
   deltas are then applied in ascending copy-position order — the flat
   engine's float summation, term for term (updates.sort() in Python).
   mc_row accumulates this row's mutation count. Returns 0 / -1 overflow. */
static int g_balance(GCtx *g, double lo, i64 skip_c, i64 skip_g,
                     const u8 *prot) {
    i64 c, gi, j, nupd = 0, nbt = 0;
    for (c = 0; c < g->C; c++) {
        Grp *seg = g->ga + g->coff[c];
        for (gi = 0; gi < g->gcnt[c]; gi++) {
            Grp *grp = seg + gi;
            i64 s = grp->s, nn = grp->n, s_i, n_i;
            double delta;
            if (prot ? prot[g->coff[c] + gi]
                     : (c == skip_c && gi == skip_g)) continue;
            if (!((nn > 1 && grp->rnh >= lo) || (s > 1 && grp->rsh >= lo)))
                continue;
            g_touch(g, c);
            s_i = s;
            n_i = nn;
            for (;;) {
                if (n_i > 1 && g_rate(g, c, s_i, n_i / 2) >= lo) {
                    n_i /= 2;
                    continue;
                }
                if (s_i > 1 && g_rate(g, c, s_i / 2, n_i) >= lo) {
                    s_i /= 2;
                    continue;
                }
                break;
            }
            delta = (double)(s_i * n_i - s * nn) * g->u_c[c];
            for (j = grp->start; j < grp->start + grp->cnt; j++) {
                i64 p = g->pos[g->coff[c] + j];
                g->up_p[nupd] = p;
                g->up_d[nupd] = delta;
                nupd++;
                g->u_p[g->nu] = p;
                g->u_s[g->nu] = g->spe_l[p];
                g->u_n[g->nu] = g->n_l[p];
                g->nu++;
                if (g->mp >= g->M) return -1;
                g->mpos[g->mp] = p;
                g->ms[g->mp] = s_i;
                g->mn[g->mp] = n_i;
                g->mp++;
                g->spe_l[p] = s_i;
                g->n_l[p] = n_i;
            }
            grp->s = s_i;
            grp->n = n_i;
            g_setrates(g, c, grp);
            if (!g->bal_t[c]) {
                g->bal_t[c] = 1;
                g->bt_list[nbt++] = c;
            }
        }
    }
    /* ascending-position application of the deltas (updates.sort()) */
    for (j = 1; j < nupd; j++) {          /* insertion sort by position */
        i64 kp = g->up_p[j], i2 = j - 1;
        double kd = g->up_d[j];
        while (i2 >= 0 && g->up_p[i2] > kp) {
            g->up_p[i2 + 1] = g->up_p[i2];
            g->up_d[i2 + 1] = g->up_d[i2];
            i2--;
        }
        g->up_p[i2 + 1] = kp;
        g->up_d[i2 + 1] = kd;
    }
    for (j = 0; j < nupd; j++) g->res += g->up_d[j];
    for (j = 0; j < nbt; j++) {
        g_compact(g, g->bt_list[j]);
        g->bal_t[g->bt_list[j]] = 0;
    }
    return 0;
}

static int run_grouped(GCtx *g, i64 max_iters, double budget,
                       double *res_out, double *fthr_out, double *theta_out,
                       double *trr, double *trc, i64 *tr_len,
                       i64 *mc, u8 *prot) {
    i64 c, gi, j, it = 0, row = 0, row_mp;
    double theta, hi, f_thr;
    int broke = 0;
    for (c = 0; c < g->C; c++) {          /* all groups at the (1,1) floor */
        Grp *grp = g->ga + g->coff[c];
        grp->start = 0;
        grp->cnt = g->coff[c + 1] - g->coff[c];
        grp->s = 1;
        grp->n = 1;
        g_setrates(g, c, grp);
        g->gcnt[c] = 1;
        g->touched[c] = 0;
        g->bal_t[c] = 0;
    }
    while (it < max_iters && !broke) {
        double cur_thr, second, cur_res, best_score, grown_rate, dgrow;
        double m_after, res_before;
        i64 slow_c, slow_gi, s, nn, b_s, b_n, wave, p_grown, start0;
        i64 grown_gi;
        Grp *slow_g, *grown;
        int have;
        g_scanmin(g, &cur_thr, &slow_c, &slow_gi, &second);
        slow_g = g->ga + g->coff[slow_c] + slow_gi;
        s = slow_g->s;
        nn = slow_g->n;
        cur_res = (double)(s * nn) * g->u_c[slow_c];
        have = 0;
        b_s = 0; b_n = 0; best_score = 0.0;
        if (nn < g->mn_c[slow_c]) {
            i64 n2 = nn * 2;
            double dres, sc;
            if (n2 > g->mn_c[slow_c]) n2 = g->mn_c[slow_c];
            dres = (double)(s * n2) * g->u_c[slow_c] - cur_res;
            if (dres < 1e-9) dres = 1e-9;
            sc = (g_rate(g, slow_c, s, n2) - cur_thr) / dres;
            have = 1; b_s = s; b_n = n2; best_score = sc;
        }
        if (s < g->ms_c[slow_c]) {
            i64 s2 = s * 2;
            double dres, sc;
            if (s2 > g->ms_c[slow_c]) s2 = g->ms_c[slow_c];
            dres = (double)(s2 * nn) * g->u_c[slow_c] - cur_res;
            if (dres < 1e-9) dres = 1e-9;
            sc = (g_rate(g, slow_c, s2, nn) - cur_thr) / dres;
            if (!have || sc > best_score) { have = 1; b_s = s2; b_n = nn; }
        }
        if (!have) {                      /* saturated: row stays, no muts */
            trr[row] = g->res;
            trc[row] = cur_thr;
            mc[row] = 0;
            row++;
            break;
        }
        grown_rate = g_rate(g, slow_c, b_s, b_n);
        dgrow = (double)(b_s * b_n - s * nn) * g->u_c[slow_c];
        /* wave width: identical lagging copies whose growth + no-op
           balance collapse into bookkeeping (see the Python engine) */
        wave = 0;
        if (slow_g->cnt > 1 && grown_rate > cur_thr && cur_thr < second) {
            double lo_w = cur_thr * (1 + 1e-9);
            double g_nh = g_rate(g, slow_c, b_s, b_n > 1 ? b_n / 2 : 1);
            double g_sh = g_rate(g, slow_c, b_s > 1 ? b_s / 2 : 1, b_n);
            if (!((b_n > 1 && g_nh >= lo_w) || (b_s > 1 && g_sh >= lo_w))) {
                wave = slow_g->cnt - 2;   /* last copy takes a real round */
                if (wave > max_iters - it - 1) wave = max_iters - it - 1;
            }
        }
        for (j = 0; j < g->nt; j++) g->touched[g->tlist[j]] = 0;
        g->nt = 0;                        /* iter_log.clear() */
        g->nu = 0;                        /* undo.clear() */
        res_before = g->res;
        g_touch(g, slow_c);
        trr[row] = g->res;
        trc[row] = cur_thr;
        row_mp = g->mp;
        /* split the first (lowest-position) copy off the argmin group and
           grow it — the flat engine grows exactly that layer index */
        if (slow_g->cnt == 1) {
            grown_gi = slow_gi;
        } else {
            Grp *seg = g->ga + g->coff[slow_c];
            memmove(seg + slow_gi + 1, seg + slow_gi,
                    (size_t)(g->gcnt[slow_c] - slow_gi) * sizeof(Grp));
            g->gcnt[slow_c]++;
            grown_gi = slow_gi;
            seg[grown_gi].cnt = 1;
            seg[grown_gi + 1].start += 1;
            seg[grown_gi + 1].cnt -= 1;
            slow_g = seg + grown_gi + 1;
        }
        grown = g->ga + g->coff[slow_c] + grown_gi;
        g->res += dgrow;
        grown->s = b_s;
        grown->n = b_n;
        g_setrates(g, slow_c, grown);
        start0 = grown->start;
        p_grown = g->pos[g->coff[slow_c] + start0];
        g->u_p[g->nu] = p_grown;
        g->u_s[g->nu] = g->spe_l[p_grown];
        g->u_n[g->nu] = g->n_l[p_grown];
        g->nu++;
        if (g->mp >= g->M) return 1;
        g->mpos[g->mp] = p_grown;
        g->ms[g->mp] = b_s;
        g->mn[g->mp] = b_n;
        g->mp++;
        g->spe_l[p_grown] = b_s;
        g->n_l[p_grown] = b_n;
        /* min(thr) after the growth, without a rescan (see Python) */
        if (grown_gi == slow_gi && grown == slow_g)
            m_after = second < grown_rate ? second : grown_rate;
        else
            m_after = cur_thr;
        if (g_balance(g, m_after * (1 + 1e-9), slow_c, grown_gi, 0) < 0)
            return 1;
        g_compact(g, slow_c);
        it++;
        if (g->res > budget) {            /* revert the whole iteration */
            for (j = 0; j < g->nt; j++) {
                c = g->tlist[j];
                g->gcnt[c] = g->scnt[c];
                memcpy(g->ga + g->coff[c], g->gsave + g->coff[c],
                       (size_t)g->scnt[c] * sizeof(Grp));
            }
            for (j = g->nu - 1; j >= 0; j--) {
                g->spe_l[g->u_p[j]] = g->u_s[j];
                g->n_l[g->u_p[j]] = g->u_n[j];
            }
            g->mp = row_mp;               /* muts[-1] = [] */
            mc[row] = 0;
            row++;
            g->res = res_before;
            break;
        }
        mc[row] = g->mp - row_mp;
        row++;
        if (!wave) continue;
        /* batched wave steps: compact() may have merged the grown
           singleton into an adjacent same-state accumulator group, so
           re-locate the LIVE groups holding the grown copy (acc) and the
           lagging remainder (always the next slot: states differ) */
        {
            Grp *seg = g->ga + g->coff[slow_c];
            Grp *acc = 0;
            i64 w;
            for (gi = 0; gi < g->gcnt[slow_c]; gi++)
                if (seg[gi].start <= start0 &&
                    start0 < seg[gi].start + seg[gi].cnt) {
                    acc = seg + gi;
                    break;
                }
            slow_g = acc + 1;
            for (w = 0; w < wave; w++) {
                double res_wave = g->res;
                i64 p = g->pos[g->coff[slow_c] + slow_g->start];
                trr[row] = g->res;
                trc[row] = cur_thr;
                row_mp = g->mp;
                slow_g->start++;
                slow_g->cnt--;
                acc->cnt++;
                g->res += dgrow;
                if (g->mp >= g->M) return 1;
                g->mpos[g->mp] = p;
                g->ms[g->mp] = b_s;
                g->mn[g->mp] = b_n;
                g->mp++;
                g->spe_l[p] = b_s;
                g->n_l[p] = b_n;
                it++;
                if (g->res > budget) {
                    slow_g->start--;
                    slow_g->cnt++;
                    acc->cnt--;
                    g->spe_l[p] = s;
                    g->n_l[p] = nn;
                    g->mp = row_mp;
                    mc[row] = 0;
                    row++;
                    g->res = res_wave;
                    broke = 1;
                    break;
                }
                mc[row] = 1;
                row++;
            }
        }
    }
    /* final literal Eq. 4 pass: trim, protect the bottleneck set */
    {
        double cur, second;
        i64 bc, bg;
        g_scanmin(g, &cur, &bc, &bg, &second);
        theta = cur;
    }
    hi = theta * (1 + 1e-9);
    for (c = 0; c < g->C; c++)
        for (gi = 0; gi < g->gcnt[c]; gi++)
            prot[g->coff[c] + gi] =
                (u8)(g->ga[g->coff[c] + gi].r <= hi);
    row_mp = g->mp;
    g->nu = 0;
    if (g_balance(g, theta * (1 - 1e-12), -1, -1, prot) < 0) return 1;
    mc[row] = g->mp - row_mp;
    {
        double cur, second;
        i64 bc, bg;
        g_scanmin(g, &cur, &bc, &bg, &second);
        f_thr = cur;
    }
    *res_out = g->res;
    *fthr_out = f_thr;
    *theta_out = theta;
    *tr_len = row;
    return 0;
}

/* ------------------------------------------------------------------ */
/* Batch driver: per proposal, build dynamics classes and dispatch     */
/* grouped/flat by the serial auto rule; identical outputs either way. */
/* ------------------------------------------------------------------ */

int dse_run_batch(i64 B, i64 L, i64 max_iters, double budget,
                  const double *omsm, const double *s_eff,
                  const double *m_dot, const double *macs,
                  const double *unit,
                  const i64 *max_n, const i64 *max_spe,
                  i64 *spe_out, i64 *n_out,
                  double *res_out, double *fthr_out, double *theta_out,
                  double *tr_res, double *tr_cur, i64 *tr_len,
                  i64 *mut_pos, i64 *mut_s, i64 *mut_n, i64 *mut_cnt,
                  i64 M) {
    i64 b, i, c;
    int rc = 0;
    /* one workspace arena for everything per-proposal */
    size_t sz_i = (size_t)(L + (L + 1) + L + 2 * L      /* cls,coff,pos,mn/ms_c */
                           + 2 * L                      /* gcnt,scnt */
                           + 2 * L                      /* tlist,bt_list */
                           + 3 * (2 * L + 4)            /* undo */
                           + L                          /* up_p */
                           + 3 * L) * sizeof(i64);      /* ch_i/s/n */
    size_t sz_d = (size_t)(4 * L                        /* om/md/mc/u_c */
                           + L                          /* up_d */
                           + 3 * L) * sizeof(double);   /* thr,r_nh,r_sh */
    size_t sz_g = 2 * (size_t)L * sizeof(Grp);          /* ga, gsave */
    size_t sz_b = 3 * (size_t)L + 8;                    /* touched,bal_t,prot */
    char *ws = (char *)malloc(sz_i + sz_d + sz_g + sz_b);
    i64 *cls, *coff, *pos, *mn_c, *ms_c, *gcnt, *scnt, *tlist, *bt_list;
    i64 *u_p, *u_s, *u_n, *up_p, *ch_i, *ch_s, *ch_n;
    double *om_c, *md_c, *mc_c, *u_c, *up_d, *thr, *r_nh, *r_sh;
    Grp *ga, *gsave;
    u8 *touched, *bal_t, *prot;
    if (!ws) return 2;
    {
        char *q = ws;
        cls = (i64 *)q; q += L * sizeof(i64);
        coff = (i64 *)q; q += (L + 1) * sizeof(i64);
        pos = (i64 *)q; q += L * sizeof(i64);
        mn_c = (i64 *)q; q += L * sizeof(i64);
        ms_c = (i64 *)q; q += L * sizeof(i64);
        gcnt = (i64 *)q; q += L * sizeof(i64);
        scnt = (i64 *)q; q += L * sizeof(i64);
        tlist = (i64 *)q; q += L * sizeof(i64);
        bt_list = (i64 *)q; q += L * sizeof(i64);
        u_p = (i64 *)q; q += (2 * L + 4) * sizeof(i64);
        u_s = (i64 *)q; q += (2 * L + 4) * sizeof(i64);
        u_n = (i64 *)q; q += (2 * L + 4) * sizeof(i64);
        up_p = (i64 *)q; q += L * sizeof(i64);
        ch_i = (i64 *)q; q += L * sizeof(i64);
        ch_s = (i64 *)q; q += L * sizeof(i64);
        ch_n = (i64 *)q; q += L * sizeof(i64);
        om_c = (double *)q; q += L * sizeof(double);
        md_c = (double *)q; q += L * sizeof(double);
        mc_c = (double *)q; q += L * sizeof(double);
        u_c = (double *)q; q += L * sizeof(double);
        up_d = (double *)q; q += L * sizeof(double);
        thr = (double *)q; q += L * sizeof(double);
        r_nh = (double *)q; q += L * sizeof(double);
        r_sh = (double *)q; q += L * sizeof(double);
        ga = (Grp *)q; q += L * sizeof(Grp);
        gsave = (Grp *)q; q += L * sizeof(Grp);
        touched = (u8 *)q; q += L;
        bal_t = (u8 *)q; q += L;
        prot = (u8 *)q;
    }
    for (b = 0; b < B && rc == 0; b++) {
        const double *om = omsm + b * L;
        const double *se = s_eff + b * L;
        i64 C = 0;
        i64 *rep = scnt;                /* borrow: free until run_grouped */
        i64 *cnt = gcnt;
        /* dynamics classes: first-appearance order, key equality on the
           six per-layer constants (== compares; the Python dict key) */
        for (i = 0; i < L; i++) {
            for (c = 0; c < C; c++) {
                i64 r = rep[c];
                if (macs[i] == macs[r] && m_dot[i] == m_dot[r] &&
                    se[i] == se[r] && max_n[i] == max_n[r] &&
                    max_spe[i] == max_spe[r] && unit[i] == unit[r])
                    break;
            }
            cls[i] = c;
            if (c == C) {
                rep[c] = i;
                cnt[c] = 0;
                C++;
            }
            cnt[c]++;
        }
        {
            i64 acc = 0;
            for (c = 0; c < C; c++) {   /* counts -> offsets */
                coff[c] = acc;
                acc += cnt[c];
            }
            coff[C] = acc;
        }
        {
            i64 *fill = tlist;          /* borrow as per-class cursor */
            for (c = 0; c < C; c++) fill[c] = coff[c];
            for (i = 0; i < L; i++) pos[fill[cls[i]]++] = i;
        }
        for (c = 0; c < C; c++) {
            i64 r = rep[c];
            om_c[c] = om[r];
            md_c[c] = m_dot[r];
            mc_c[c] = macs[r];
            u_c[c] = unit[r];
            mn_c[c] = max_n[r];
            ms_c[c] = max_spe[r];
        }
        if (L >= 16 && 2 * C <= L) {    /* the serial auto dispatch rule */
            GCtx g;
            double res0 = 0.0;
            g.L = L;
            g.C = C;
            g.pos = pos;
            g.coff = coff;
            g.om_c = om_c;
            g.md_c = md_c;
            g.mc_c = mc_c;
            g.u_c = u_c;
            g.mn_c = mn_c;
            g.ms_c = ms_c;
            g.ga = ga;
            g.gcnt = gcnt;
            g.gsave = gsave;
            g.scnt = scnt;
            g.touched = touched;
            g.tlist = tlist;
            g.nt = 0;
            g.u_p = u_p;
            g.u_s = u_s;
            g.u_n = u_n;
            g.nu = 0;
            g.up_p = up_p;
            g.up_d = up_d;
            g.bal_t = bal_t;
            g.bt_list = bt_list;
            g.spe_l = spe_out + b * L;
            g.n_l = n_out + b * L;
            for (i = 0; i < L; i++) {
                g.spe_l[i] = 1;
                g.n_l[i] = 1;
                res0 += unit[i];        /* float(sum(unit)), same order */
            }
            g.res = res0;
            g.mpos = mut_pos + b * M;
            g.ms = mut_s + b * M;
            g.mn = mut_n + b * M;
            g.mp = 0;
            g.M = M;
            rc = run_grouped(&g, max_iters, budget,
                             res_out + b, fthr_out + b, theta_out + b,
                             tr_res + b * max_iters, tr_cur + b * max_iters,
                             tr_len + b, mut_cnt + b * (max_iters + 1),
                             prot);
        } else {
            rc = run_flat(L, max_iters, budget, om, m_dot, macs, unit,
                          max_n, max_spe, spe_out + b * L, n_out + b * L,
                          res_out + b, fthr_out + b, theta_out + b,
                          tr_res + b * max_iters, tr_cur + b * max_iters,
                          tr_len + b,
                          mut_pos + b * M, mut_s + b * M, mut_n + b * M,
                          mut_cnt + b * (max_iters + 1), M,
                          thr, r_nh, r_sh, ch_i, ch_s, ch_n, prot);
        }
    }
    free(ws);
    return rc;
}

/* Replay one proposal's mutation log, materializing the kept frontier
   rows: row j < n_rows-1 is the state BEFORE muts[j] (trace rows record
   state at iteration start); the last row is the state AFTER the final
   Eq. 4 pass. keep_rows must be ascending; snapshots land in keep order. */
void dse_replay(i64 L, i64 n_rows,
                const i64 *mut_pos, const i64 *mut_s, const i64 *mut_n,
                const i64 *mut_cnt,
                i64 n_keep, const i64 *keep_rows,
                i64 *out_spe, i64 *out_n, i64 *w_spe, i64 *w_n) {
    i64 i, j, t, off = 0, k = 0;
    for (i = 0; i < L; i++) { w_spe[i] = 1; w_n[i] = 1; }
    for (j = 0; j < n_rows; j++) {
        i64 c = mut_cnt[j];
        if (j < n_rows - 1) {
            if (k < n_keep && keep_rows[k] == j) {
                memcpy(out_spe + k * L, w_spe, (size_t)L * sizeof(i64));
                memcpy(out_n + k * L, w_n, (size_t)L * sizeof(i64));
                k++;
            }
            for (t = 0; t < c; t++) {
                w_spe[mut_pos[off + t]] = mut_s[off + t];
                w_n[mut_pos[off + t]] = mut_n[off + t];
            }
        } else {
            for (t = 0; t < c; t++) {
                w_spe[mut_pos[off + t]] = mut_s[off + t];
                w_n[mut_pos[off + t]] = mut_n[off + t];
            }
            if (k < n_keep && keep_rows[k] == j) {
                memcpy(out_spe + k * L, w_spe, (size_t)L * sizeof(i64));
                memcpy(out_n + k * L, w_n, (size_t)L * sizeof(i64));
                k++;
            }
        }
        off += c;
    }
}
"""

# -ffp-contract=off is load-bearing: GCC contracts a*b-c into FMA by
# default, which rounds once where numpy rounds twice. No -ffast-math.
_CFLAGS = ["-O2", "-fPIC", "-shared", "-std=c99", "-ffp-contract=off"]

# raw pointers, not np.ctypeslib.ndpointer: ndpointer's from_param runs
# dtype/flag checks per argument per call (~0.3ms/wave of pure overhead on
# the hot path). The ONLY call sites are ``dse._run_incremental_batch_c``,
# which allocates every array itself with the right dtype and C order —
# pass ``arr.ctypes.data``.
_i64p = ctypes.c_void_p
_f64p = ctypes.c_void_p

_lib: Optional[ctypes.CDLL] = None
_tried = False


def _build_dir() -> str:
    return os.environ.get("REPRO_CKERNEL_DIR") or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "_build")


def _compiler() -> Optional[str]:
    for cand in ("cc", "gcc", "clang"):
        path = shutil.which(cand)
        if path:
            return path
    return None


def _load() -> Optional[ctypes.CDLL]:
    if os.environ.get("REPRO_DSE_CKERNEL", "1") in ("0", "off", "false"):
        return None
    tag = hashlib.sha256(
        (_C_SRC + "\x00" + " ".join(_CFLAGS)).encode()).hexdigest()[:16]
    bdir = _build_dir()
    so = os.path.join(bdir, f"dse_kernel_{tag}.so")
    if not os.path.exists(so):
        cc = _compiler()
        if cc is None:
            return None
        try:
            os.makedirs(bdir, exist_ok=True)
            with tempfile.TemporaryDirectory(dir=bdir) as td:
                src = os.path.join(td, "dse_kernel.c")
                tmp_so = os.path.join(td, "dse_kernel.so")
                with open(src, "w") as f:
                    f.write(_C_SRC)
                subprocess.run([cc, *_CFLAGS, src, "-o", tmp_so, "-lm"],
                               check=True, capture_output=True, timeout=120)
                os.replace(tmp_so, so)   # atomic publish; races converge
        except (OSError, subprocess.SubprocessError):
            return None
    try:
        lib = ctypes.CDLL(so)
    except OSError:
        return None
    lib.dse_run_batch.restype = ctypes.c_int
    lib.dse_run_batch.argtypes = [
        ctypes.c_longlong, ctypes.c_longlong, ctypes.c_longlong,
        ctypes.c_double,
        _f64p, _f64p, _f64p, _f64p, _f64p, _i64p, _i64p,
        _i64p, _i64p, _f64p, _f64p, _f64p,
        _f64p, _f64p, _i64p,
        _i64p, _i64p, _i64p, _i64p, ctypes.c_longlong]
    lib.dse_replay.restype = None
    lib.dse_replay.argtypes = [
        ctypes.c_longlong, ctypes.c_longlong,
        _i64p, _i64p, _i64p, _i64p,
        ctypes.c_longlong, _i64p, _i64p, _i64p, _i64p, _i64p]
    return lib


def get_lib() -> Optional[ctypes.CDLL]:
    """The compiled kernel, built/loaded on first call; None when the
    environment can't provide it (no compiler, failed build, or disabled
    via ``REPRO_DSE_CKERNEL=0``) — callers fall back to numpy."""
    global _lib, _tried
    if not _tried:
        _tried = True
        _lib = _load()
    return _lib


def reset() -> None:
    """Forget the cached load attempt (tests toggle the env kill switch)."""
    global _lib, _tried
    _lib = None
    _tried = False
