"""Accelerator Design-Space Exploration (§V-A of the paper).

Implements, verbatim in structure:
  1. performance modeling (Eq. 1–3, in ``core.perf_model``),
  2. resource-constrained rate balancing (Eq. 4–5),
  3. resource-constrained incrementing (start minimal; repeatedly grow the
     slowest layer, then re-balance, until the budget R is exhausted),
  4. partitioning & reconfiguration (exact DP over pipeline split points on
     a memoized per-segment Pareto-frontier table; on TPU "full
     reconfiguration" = switching the mesh program between partitions —
     or, multi-chip, the ICI boundary transfer — amortized by batch size;
     the paper's SA loop is retained as ``partition_pipeline_sa``).

Every search also returns its full (resource, throughput) ``ParetoFrontier``
with materializable per-point design state (DESIGN.md §10).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.annealing import simulated_annealing
from repro.core.perf_model import (ACT_BYTES, DesignPoint, HardwareModel,
                                   LayerCost, LayerVectors, TPUModel,
                                   pipeline_throughput, t_cycles)


@dataclass
class ParetoFrontier:
    """The non-dominated (resource, throughput) set traced by one DSE run.

    Both arrays are sorted strictly increasing, so the frontier *is* the
    budget -> throughput function of the search: ``best_under(b)`` is a
    binary search, and ``materialize(k)`` rebuilds the concrete per-layer
    ``DesignPoint`` list of point ``k`` from the captured design state —
    no re-run of the greedy loop. Interior points are as-searched states
    on the growth path (strict-balanced); the last point is the final
    Eq. 4-trimmed search result, so ``best_under(search_budget)`` equals
    the ``DSEResult`` exactly (DESIGN.md §10).
    """
    res: np.ndarray               # (K,) float64, strictly increasing
    thr: np.ndarray               # (K,) float64, strictly increasing
    spe: np.ndarray               # (K, L) int64 design-state snapshots
    n: np.ndarray                 # (K, L) int64

    def __len__(self) -> int:
        return len(self.res)

    def point(self, k: int) -> Tuple[float, float]:
        return float(self.res[k]), float(self.thr[k])

    def best_under(self, budget: float) -> Optional[int]:
        """Index of the max-throughput point with resource <= budget, or
        None when even the cheapest point exceeds the budget."""
        k = int(np.searchsorted(self.res, budget, side="right")) - 1
        return k if k >= 0 else None

    def select(self, score: Callable[[np.ndarray, np.ndarray], np.ndarray]) -> int:
        """Argmax of a vectorized ``score(res, thr)`` over frontier points —
        how Eq. 6 consumers pick a trade-off point without re-searching."""
        return int(np.argmax(score(self.res, self.thr)))

    def materialize(self, k: int) -> List[DesignPoint]:
        return _designs_from(self.spe[k], self.n[k])


def _build_frontier(res_pts: List[float], thr_pts: List[float],
                    states: List[Tuple[List[int], List[int]]]) -> ParetoFrontier:
    """Skyline of the recorded search path. The last input point is the
    final (Eq. 4-trimmed) result: it is made the canonical representative of
    its throughput level (using the DSE's own 1e-9 bottleneck tolerance) so
    near-duplicate as-searched states never shadow it under ``best_under``."""
    f_res, f_thr = res_pts[-1], thr_pts[-1]
    lo, hi = f_thr * (1 - 1e-9), f_thr * (1 + 1e-9)
    idx = [i for i in range(len(res_pts) - 1)
           if not (lo <= thr_pts[i] <= hi)
           and not (res_pts[i] >= f_res and thr_pts[i] <= hi)]
    idx.append(len(res_pts) - 1)
    idx.sort(key=lambda i: (res_pts[i], -thr_pts[i]))
    keep: List[int] = []
    best = -math.inf
    for i in idx:
        if thr_pts[i] > best:
            keep.append(i)
            best = thr_pts[i]
    L = len(states[-1][0])
    return ParetoFrontier(
        res=np.array([res_pts[i] for i in keep], dtype=np.float64),
        thr=np.array([thr_pts[i] for i in keep], dtype=np.float64),
        spe=np.array([states[i][0] for i in keep],
                     dtype=np.int64).reshape(len(keep), L),
        n=np.array([states[i][1] for i in keep],
                   dtype=np.int64).reshape(len(keep), L))


@dataclass
class DSEResult:
    designs: List[DesignPoint]
    throughput: float             # samples/cycle (Eq. 3)
    resource: float               # total resource units (DSPs / tile-lanes)
    throughput_per_res: float
    trace: List[Tuple[float, float]]  # (resource, throughput) per increment
    frontier: Optional[ParetoFrontier] = None

    def images_per_s(self, hw: HardwareModel) -> float:
        return self.throughput * hw.freq


def _grow_options(l: LayerCost, d: DesignPoint, hw: HardwareModel):
    """Candidate increments for one layer: more MACs/SPE or more SPEs."""
    opts = []
    if d.macs_per_spe < hw.max_n(l):
        opts.append(replace(d, macs_per_spe=min(d.macs_per_spe * 2, hw.max_n(l))))
    if d.spe < hw.max_spe(l):
        opts.append(replace(d, spe=min(d.spe * 2, hw.max_spe(l))))
    return opts


def rate_balance_ref(layers: Sequence[LayerCost], designs: List[DesignPoint],
                     hw: HardwareModel, *, protect: Optional[set] = None,
                     strict: bool = False) -> List[DesignPoint]:
    """Reference (scalar, per-layer-loop) Eq. 4–5 implementation. Kept
    verbatim for equivalence testing against the vectorized ``rate_balance``;
    see that function for the semantics."""
    protect = protect or set()
    theta_r = pipeline_throughput(layers, designs, hw)
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    balanced: List[DesignPoint] = []
    for i, (l, d) in enumerate(zip(layers, designs)):
        if i in protect:
            balanced.append(d)
            continue
        best = d
        changed = True
        while changed:
            changed = False
            for cand in (replace(best, macs_per_spe=max(1, best.macs_per_spe // 2)),
                         replace(best, spe=max(1, best.spe // 2))):
                if (cand.spe, cand.macs_per_spe) == (best.spe, best.macs_per_spe):
                    continue
                if hw.layer_throughput(l, cand) >= lo:
                    best = cand
                    changed = True
                    break
        balanced.append(best)
    return balanced


# --------------------------------------------------------------------- #
# Vectorized engine (DESIGN.md §7): the design state is two small int
# vectors (spe, macs_per_spe) — designs only ever double/halve — operated
# on as flat arrays instead of per-layer dataclass lists.
# --------------------------------------------------------------------- #
def _design_arrays(designs: Sequence[DesignPoint]):
    spe = np.array([d.spe for d in designs], dtype=np.int64)
    n = np.array([d.macs_per_spe for d in designs], dtype=np.int64)
    return spe, n


def _designs_from(spe: np.ndarray, n: np.ndarray) -> List[DesignPoint]:
    return [DesignPoint(int(s), int(m)) for s, m in zip(spe, n)]


def _balance_arrays(hw: HardwareModel, lv: LayerVectors, spe: np.ndarray,
                    n: np.ndarray, protect: np.ndarray, strict: bool):
    """Vectorized Eq. 4–5 core. Each round, every unprotected layer takes its
    preferred feasible halving (macs_per_spe first, else spe — the reference
    candidate order) simultaneously; rounds repeat until no layer can shrink.
    Per-layer decisions are independent (theta_r is fixed at entry), so the
    simultaneous rounds replay each layer's reference shrink sequence exactly.
    """
    theta_r = float(hw.throughput_vec(lv, spe, n).min())
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    spe, n = spe.copy(), n.copy()
    free = ~protect
    while True:
        cand_n = np.maximum(1, n >> 1)
        ok_n = free & (cand_n != n) & \
            (hw.throughput_vec(lv, spe, cand_n) >= lo)
        cand_s = np.maximum(1, spe >> 1)
        ok_s = free & ~ok_n & (cand_s != spe) & \
            (hw.throughput_vec(lv, cand_s, n) >= lo)
        if not (ok_n.any() or ok_s.any()):
            return spe, n
        n = np.where(ok_n, cand_n, n)
        spe = np.where(ok_s, cand_s, spe)


def rate_balance(layers: Sequence[LayerCost], designs: List[DesignPoint],
                 hw: HardwareModel, *, protect: Optional[set] = None,
                 strict: bool = False) -> List[DesignPoint]:
    """Eq. 4–5: shrink every non-bottleneck layer to the smallest design whose
    modeled throughput still meets the pipeline's actual rate theta_r.

    ``strict=True`` is used *during* incrementing: a shrink must leave the
    layer's rate strictly above theta_r. With the literal (non-strict) Eq. 4
    rule, growing one of several bottleneck-tied layers gets undone by the
    next balancing pass (rate lands exactly on theta_r and is "still
    feasible"), deadlocking the greedy loop. Strict balancing keeps every
    layer within (theta_r, 2*theta_r] during growth; the final non-strict pass
    reclaims the leftover, which is the paper's Eq. 4 verbatim.
    ``protect`` exempts the just-grown layer.

    Vectorized; equivalent to ``rate_balance_ref`` design-for-design."""
    mask = np.zeros(len(designs), dtype=bool)
    for i in (protect or ()):
        mask[i] = True
    spe, n = _design_arrays(designs)
    spe, n = _balance_arrays(hw, hw.layer_vectors(layers), spe, n, mask,
                             strict)
    return _designs_from(spe, n)


def _run_incremental(lv: LayerVectors, hw: HardwareModel, budget: float,
                     max_iters: int):
    """Array-native §V-A.3 greedy loop; returns (spe, n, thr, res, trace).

    The state is two int vectors plus three maintained rate vectors: each
    layer's current rate (Eq. 2) and its rate after one macs_per_spe / one
    spe halving. Per iteration the engine does O(L) flat scans (argmin,
    shrink-feasibility) and re-derives rates only for the 1–2 layers that
    actually change, with the identical scalar expressions the reference
    evaluates — so results match ``incremental_dse_ref`` bit for bit while
    skipping its O(L * shrink-tries) dataclass churn and throughput
    recomputation.
    """
    L = len(lv)
    macs = lv.macs.tolist()
    m_dot = lv.m_dot.tolist()
    s_eff = lv.s_eff.tolist()
    max_n = lv.max_n.tolist()
    max_spe = lv.max_spe.tolist()
    unit = lv.res_unit.tolist()
    spe = [1] * L
    n = [1] * L
    # maintained per-layer rates: current (Eq. 2) and after one halving of
    # each coordinate — flat float lists; O(L) scans at Python-scalar cost
    # beat numpy-reduction dispatch for every realistic pipeline depth
    thr = [0.0] * L
    thr_nh = [0.0] * L
    thr_sh = [0.0] * L

    def thr_of(i: int, s: int, nn: int) -> float:
        if not macs[i]:
            return float("inf")
        t = t_cycles(s_eff[i], m_dot[i], nn)
        return s * m_dot[i] / (macs[i] * t)

    def sync(i: int) -> None:
        thr[i] = thr_of(i, spe[i], n[i])
        thr_nh[i] = thr_of(i, spe[i], max(1, n[i] // 2))
        thr_sh[i] = thr_of(i, max(1, spe[i] // 2), n[i])

    for i in range(L):
        sync(i)
    # resource totals are exact (integer DSPs / dyadic tile-lane fractions),
    # so incremental updates equal the reference's full re-summation
    res_total = float(sum(unit))

    def balance(lo: float, skip) -> List[Tuple[int, int, int]]:
        """One Eq. 4–5 pass against fixed ``lo``. ``skip`` is a protected
        layer index or per-layer bool list. Returns [(i, old_spe, old_n)] of
        changed layers. A layer shrinks at all iff its first halving is
        feasible, and each shrink chain is n-halvings then spe-halvings (rate
        is monotone in both coordinates, so the reference's retry-n-first
        loop reduces to exactly this), in scalar exact arithmetic."""
        nonlocal res_total
        changed = []
        skip_is_idx = isinstance(skip, int)
        for i in range(L):
            if (skip[i] if not skip_is_idx else i == skip):
                continue
            if not ((n[i] > 1 and thr_nh[i] >= lo) or
                    (spe[i] > 1 and thr_sh[i] >= lo)):
                continue
            s_i, n_i = spe[i], n[i]
            changed.append((i, s_i, n_i))
            while True:
                if n_i > 1 and thr_of(i, s_i, n_i // 2) >= lo:
                    n_i //= 2
                    continue
                if s_i > 1 and thr_of(i, s_i // 2, n_i) >= lo:
                    s_i //= 2
                    continue
                break
            res_total += (s_i * n_i - spe[i] * n[i]) * unit[i]
            spe[i], n[i] = s_i, n_i
            sync(i)
        return changed

    trace: List[Tuple[float, float]] = []
    # design-state snapshot per trace row: any frontier point can later be
    # materialized into concrete DesignPoints without re-running the search
    states: List[Tuple[List[int], List[int]]] = []
    for _ in range(max_iters):
        cur_thr = min(thr)
        slow = thr.index(cur_thr)
        trace.append((res_total, cur_thr))
        states.append((spe.copy(), n.copy()))
        # candidate increments for the slowest layer (macs_per_spe doubling
        # first — the reference option order, which wins Δthr/Δres ties)
        cur_res = spe[slow] * n[slow] * unit[slow]
        best = None
        best_score = None
        if n[slow] < max_n[slow]:
            n2 = min(n[slow] * 2, max_n[slow])
            dres = spe[slow] * n2 * unit[slow] - cur_res
            best = (spe[slow], n2)
            best_score = (thr_of(slow, spe[slow], n2) - cur_thr) / \
                max(dres, 1e-9)
        if spe[slow] < max_spe[slow]:
            s2 = min(spe[slow] * 2, max_spe[slow])
            dres = s2 * n[slow] * unit[slow] - cur_res
            score = (thr_of(slow, s2, n[slow]) - cur_thr) / max(dres, 1e-9)
            if best is None or score > best_score:
                best, best_score = (s2, n[slow]), score
        if best is None:
            break
        # apply the growth, strict-balance everyone else, keep if affordable
        res_before = res_total
        old_slow = (slow, spe[slow], n[slow])
        res_total += (best[0] * best[1] - spe[slow] * n[slow]) * unit[slow]
        spe[slow], n[slow] = best
        sync(slow)
        changed = balance(min(thr) * (1 + 1e-9), skip=slow)
        if res_total > budget:
            for i, s_i, n_i in [old_slow] + changed:
                spe[i], n[i] = s_i, n_i
                sync(i)
            res_total = res_before
            break

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    theta_r = min(thr)
    hi = theta_r * (1 + 1e-9)
    balance(theta_r * (1 - 1e-12), skip=[r <= hi for r in thr])
    f_thr = min(thr)
    states.append((spe.copy(), n.copy()))
    frontier = _build_frontier([r for r, _ in trace] + [res_total],
                               [t for _, t in trace] + [f_thr], states)
    return (np.array(spe, dtype=np.int64), np.array(n, dtype=np.int64),
            f_thr, res_total, trace, frontier)


def incremental_dse(layers: Sequence[LayerCost], hw: HardwareModel,
                    budget: float, *, max_iters: int = 10000) -> DSEResult:
    """§V-A.3: start resource-minimal, grow the slowest layer, re-balance.

    Vectorized greedy loop — identical designs/throughput/resource/trace to
    ``incremental_dse_ref`` (property-tested), ~10–100x faster. The returned
    ``DSEResult.frontier`` holds the full non-dominated (resource,
    throughput) set of the search path with per-point design state, so
    consumers (Eq. 6 scoring, DP partitioning) trade points without
    re-running the search (``incremental_dse_ref`` leaves it None)."""
    lv = hw.layer_vectors(layers)
    spe, n, thr, res, trace, frontier = _run_incremental(lv, hw, budget,
                                                         max_iters)
    return DSEResult(designs=_designs_from(spe, n), throughput=thr,
                     resource=res, throughput_per_res=thr / max(res, 1e-9),
                     trace=trace, frontier=frontier)


def incremental_dse_ref(layers: Sequence[LayerCost], hw: HardwareModel,
                        budget: float, *, max_iters: int = 10000) -> DSEResult:
    """Reference scalar implementation of ``incremental_dse`` (pre-vectorized
    code, kept verbatim as the equivalence oracle and for ``dse_bench``)."""
    designs = [DesignPoint(1, 1) for _ in layers]
    trace: List[Tuple[float, float]] = []

    def total_res(ds):
        return sum(hw.layer_resource(l, d) for l, d in zip(layers, ds))

    for _ in range(max_iters):
        thr = pipeline_throughput(layers, designs, hw)
        res = total_res(designs)
        trace.append((res, thr))
        # slowest layer
        rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
        slow = int(np.argmin(rates))
        opts = _grow_options(layers[slow], designs[slow], hw)
        if not opts:
            break
        # pick the increment with best Δthroughput per Δresource
        def score(opt):
            dthr = hw.layer_throughput(layers[slow], opt) - rates[slow]
            dres = hw.layer_resource(layers[slow], opt) - \
                hw.layer_resource(layers[slow], designs[slow])
            return dthr / max(dres, 1e-9)
        opt = max(opts, key=score)
        cand = list(designs)
        cand[slow] = opt
        cand = rate_balance_ref(layers, cand, hw, protect={slow}, strict=True)
        if total_res(cand) > budget:
            break
        designs = cand

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
    bottleneck = {i for i, r in enumerate(rates) if r <= min(rates) * (1 + 1e-9)}
    designs = rate_balance_ref(layers, designs, hw, protect=bottleneck)
    thr = pipeline_throughput(layers, designs, hw)
    res = total_res(designs)
    return DSEResult(designs=designs, throughput=thr, resource=res,
                     throughput_per_res=thr / max(res, 1e-9), trace=trace)


# --------------------------------------------------------------------- #
# Partitioning & reconfiguration (§V-A.4): segment-table DP
# --------------------------------------------------------------------- #
@dataclass
class PartitionResult:
    """One partitioning of a layer pipeline, with both schedule metrics.

    ``throughput`` is the *amortized temporal* rate: ``batch /
    time_per_batch`` where ``time_per_batch`` runs the partitions back to
    back on ONE executor and charges every switch between them — the FPGA
    reconfiguration schedule of §V-A.4. ``steady_throughput`` is the
    *spatial steady-state* rate: all partitions resident at once (one per
    chip), every batch flowing through the full chain, so the pipeline runs
    at the rate of its slowest stage — ``min`` over partition rates and,
    multi-chip, the per-sample ICI hop rates at the cuts. The two coincide
    only for a single partition; see DESIGN.md §10/§11 for when the
    objectives that optimize them pick different cuts.
    """
    cuts: List[int]               # split indices (exclusive prefix ends)
    batch: int
    time_per_batch: float         # cycles, incl. switch/transfer overhead
    throughput: float             # samples/cycle amortized (temporal)
    part_throughput: List[float] = field(default_factory=list)
    part_designs: List[List[DesignPoint]] = field(default_factory=list)
    steady_throughput: float = 0.0  # spatial-pipeline rate: min over
    #                                 partition rates and ICI hop rates
    dse_calls: int = 0            # segment DSE invocations (memoized table)
    objective: str = "sum"        # DP objective that picked the cuts


def boundary_activations(layers: Sequence[LayerCost], cut: int) -> float:
    """Activation elements per sample crossing a partition cut.

    A sequential pipeline hands ``layers[cut-1].act_out ==
    layers[cut].act_in`` across the boundary. When the two disagree the
    smaller side is the stream that actually crosses: LM ``act_in``/
    ``act_out`` carry per-layer ``n_apply`` multipliers (a MoE down-proj
    "emits" d_model x active_experts, but the block reduces back to one
    residual stream of width d_model = the next block's ``act_in``), and a
    shared-attention block consumes a concat of the d_model stream. Taking
    ``min`` prices the residual stream, not the intra-block fan-out
    (DESIGN.md §11)."""
    return float(min(layers[cut - 1].act_out, layers[cut].act_in))


class SegmentTable:
    """Memoized per-contiguous-segment DSE frontiers for partitioning.

    Each contiguous segment ``layers[i:j]`` is searched at most ONCE; the
    DP below then reads amortized batch times off the cached frontiers. The
    total segment-DSE count is therefore bounded by L(L+1)/2 regardless of
    how many cut configurations the optimizer considers — unlike SA, whose
    DSE count scales with annealing steps x partitions and which still only
    samples the cut space (DESIGN.md §10).
    """

    def __init__(self, layers: Sequence[LayerCost], hw: HardwareModel,
                 budget: float, batch: int, dse_iters: int):
        self.layers = list(layers)
        self.hw, self.budget = hw, budget
        self.batch, self.dse_iters = batch, dse_iters
        self._cache: Dict[Tuple[int, int], ParetoFrontier] = {}
        self.dse_calls = 0

    def frontier(self, i: int, j: int) -> ParetoFrontier:
        key = (i, j)
        if key not in self._cache:
            self.dse_calls += 1
            r = incremental_dse(self.layers[i:j], self.hw, self.budget,
                                max_iters=self.dse_iters)
            self._cache[key] = r.frontier
        return self._cache[key]

    def _best(self, i: int, j: int) -> int:
        f = self.frontier(i, j)
        k = f.best_under(self.budget)
        # infeasible budget: the resource-minimal design still runs (the
        # greedy's own behavior when it cannot afford any growth)
        return 0 if k is None else k

    def throughput(self, i: int, j: int) -> float:
        f = self.frontier(i, j)
        return float(f.thr[self._best(i, j)])

    def time(self, i: int, j: int) -> float:
        thr = self.throughput(i, j)
        return self.batch / thr if thr > 0 else float("inf")

    def designs(self, i: int, j: int) -> List[DesignPoint]:
        f = self.frontier(i, j)
        return f.materialize(self._best(i, j))


def partition_pipeline(layers: Sequence[LayerCost], hw: HardwareModel,
                       budget: float, *, n_parts: int, batch: int = 256,
                       reconfig_cycles: float = 5e7, seed: int = 0,
                       dse_iters: int = 300,
                       cut_points: Optional[Sequence[int]] = None,
                       objective: str = "auto") -> PartitionResult:
    """Fold the pipeline into at most ``n_parts`` sequential partitions, each
    run with the full per-partition ``budget``. Exact DP over cut positions
    on a memoized per-segment frontier table (one DSE per contiguous
    segment) — replaces the SA loop, which re-ran the full segment DSE on
    every annealing step (kept as ``partition_pipeline_sa``).

    Switch accounting (temporal schedule, ``time_per_batch``): a schedule
    with P resident partitions charges exactly P - 1 *switches* per
    processed batch — the mid-batch program transitions. A single resident
    partition (P = 1) charges none: it is never reconfigured, and reloading
    the first partition for the next batch overlaps with host-side batch
    staging, so neither end of the loop is charged. On a single-chip target
    a switch costs ``reconfig_cycles`` (FPGA full reconfiguration / TPU mesh
    program swap); on a multi-chip ``TPUModel`` (``hw.chips > 1``) each
    partition is resident on its own chip and a switch is instead the ICI
    transfer of the whole batch's boundary activations
    (``TPUModel.ici_transfer_cycles``), and ``n_parts`` is capped at
    ``hw.chips``.

    Metrics: ``throughput`` is the amortized *temporal* rate ``batch /
    time_per_batch`` (partitions time-multiplexed on one executor);
    ``steady_throughput`` is the *spatial* steady-state rate with every
    partition resident simultaneously — ``min`` over partition rates and,
    multi-chip, the per-sample ICI hop rates at the cuts. See the
    ``PartitionResult`` docstring and DESIGN.md §10/§11.

    ``objective`` selects what the DP optimizes:
      * ``"sum"``    — minimize ``time_per_batch`` (the sum-form temporal
        objective; the §V-A.4 reconfiguration schedule).
      * ``"maxmin"`` — maximize ``steady_throughput`` directly (max-min
        over stage and ICI-hop rates; multi-chip only, where the spatial
        schedule is the one actually run). Never worse on
        ``steady_throughput`` than the sum-form pick over the same cut
        space, because it exactly maximizes that metric; ties prefer the
        partition with the smaller ``time_per_batch``.
      * ``"auto"``   — ``"maxmin"`` for a multi-chip ``TPUModel``,
        ``"sum"`` otherwise (DESIGN.md §11).

    ``cut_points`` restricts the DP to a candidate set of cut indices
    (sorted, in ``1..L-1``); ``None`` allows every position. Deep LM stacks
    pass block boundaries (``perf_model.lm_block_bounds``, optionally
    thinned by ``thin_cut_points``) — the segment table then holds
    O(K^2) DSEs for K candidates instead of O(L^2).

    The DP may use fewer than ``n_parts`` partitions when a switch costs
    more than it saves (or, max-min, when an ICI hop would bottleneck the
    pipeline). ``seed`` is accepted for API compatibility with the SA
    reference and is unused — the DP is deterministic.
    """
    L = len(layers)
    multi_chip = isinstance(hw, TPUModel) and hw.chips > 1
    if objective == "auto":
        objective = "maxmin" if multi_chip else "sum"
    if objective not in ("sum", "maxmin"):
        raise ValueError(f"unknown objective {objective!r}")
    if objective == "maxmin" and not multi_chip:
        raise ValueError("objective='maxmin' optimizes the spatial "
                         "steady-state rate, which only exists for a "
                         "multi-chip TPUModel (chips > 1)")
    if cut_points is None:
        cands = list(range(L + 1))
    else:
        cp = sorted(set(int(c) for c in cut_points))
        if cp and not (1 <= cp[0] and cp[-1] <= L - 1):
            raise ValueError(f"cut_points must lie in 1..{L - 1}")
        cands = [0] + cp + [L]
    m = len(cands)                # candidate boundaries incl. 0 and L
    n_parts = min(n_parts, m - 1, hw.chips) if multi_chip \
        else min(n_parts, m - 1)
    n_parts = max(n_parts, 1)
    seg = SegmentTable(layers, hw, budget, batch, dse_iters)

    def switch_cost(cut: int) -> float:
        """Cycles charged for the transition at cut position ``cut``."""
        if multi_chip:
            n_bytes = batch * boundary_activations(layers, cut) * ACT_BYTES
            return hw.ici_transfer_cycles(n_bytes)
        return reconfig_cycles

    def hop_rate(cut: int) -> float:
        """Samples/cycle one ICI hop sustains at cut position ``cut``."""
        cyc = hw.ici_transfer_cycles(boundary_activations(layers, cut)
                                     * ACT_BYTES)
        return 1.0 / cyc if cyc > 0 else float("inf")

    INF = float("inf")
    if objective == "sum":
        # T[p][b]: min cycles for layers[:cands[b]] as exactly p partitions
        # (+ their switches); the DP walks candidate boundaries only.
        T = [[INF] * m for _ in range(n_parts + 1)]
        T[0][0] = 0.0
        back = [[-1] * m for _ in range(n_parts + 1)]
        for p in range(1, n_parts + 1):
            # prefixes b < m-1 only feed deeper recursions; the last p level
            # needs the full-pipeline entry alone
            bs = range(p, m) if p < n_parts else (m - 1,)
            for b in bs:
                j = cands[b]
                for a in range(p - 1, b):
                    if T[p - 1][a] == INF:
                        continue
                    i = cands[a]
                    t = T[p - 1][a] + seg.time(i, j) + \
                        (switch_cost(i) if i else 0.0)
                    if t < T[p][b]:
                        T[p][b], back[p][b] = t, a
        best_p = min(range(1, n_parts + 1), key=lambda p: T[p][m - 1])
        score = [T[p][m - 1] for p in range(n_parts + 1)]
    else:
        # R[p][b]: max achievable min-rate (stage rates and internal ICI
        # hops) for layers[:cands[b]] as exactly p partitions. min() is
        # associative, so the prefix decomposition is exact; +inf seeds the
        # empty prefix. First maximizer wins -> deterministic cuts.
        R = [[-INF] * m for _ in range(n_parts + 1)]
        R[0][0] = INF
        back = [[-1] * m for _ in range(n_parts + 1)]
        for p in range(1, n_parts + 1):
            bs = range(p, m) if p < n_parts else (m - 1,)
            for b in bs:
                j = cands[b]
                for a in range(p - 1, b):
                    if R[p - 1][a] == -INF:
                        continue
                    i = cands[a]
                    r = min(R[p - 1][a], seg.throughput(i, j))
                    if i:
                        r = min(r, hop_rate(i))
                    if r > R[p][b]:
                        R[p][b], back[p][b] = r, a
        # ties on the steady rate prefer the smaller amortized batch time
        best_rate = max(R[p][m - 1] for p in range(1, n_parts + 1))
        tied = [p for p in range(1, n_parts + 1)
                if R[p][m - 1] >= best_rate * (1 - 1e-12)]

        def _amortized(p: int) -> float:
            total, b = 0.0, m - 1
            for q in range(p, 0, -1):
                a = back[q][b]
                total += seg.time(cands[a], cands[b]) + \
                    (switch_cost(cands[a]) if cands[a] else 0.0)
                b = a
            return total
        best_p = min(tied, key=_amortized)
        score = None

    cuts: List[int] = []
    b = m - 1
    for p in range(best_p, 0, -1):
        a = back[p][b]
        if a > 0:
            cuts.append(cands[a])
        b = a
    cuts.reverse()
    bounds = [0] + cuts + [L]
    part_thr = [seg.throughput(a, b) for a, b in zip(bounds, bounds[1:])]
    part_designs = [seg.designs(a, b) for a, b in zip(bounds, bounds[1:])]
    steady = min(part_thr) if part_thr else 0.0
    if multi_chip:
        for c in cuts:
            steady = min(steady, hop_rate(c))
    total = sum(seg.time(a, b) for a, b in zip(bounds, bounds[1:])) + \
        sum(switch_cost(c) for c in cuts)
    if objective == "sum":
        assert abs(total - score[best_p]) <= 1e-9 * max(total, 1.0)
    return PartitionResult(cuts=cuts, batch=batch, time_per_batch=total,
                           throughput=batch / total if total > 0 else 0.0,
                           part_throughput=part_thr,
                           part_designs=part_designs,
                           steady_throughput=steady,
                           dse_calls=seg.dse_calls,
                           objective=objective)


def partition_pipeline_sa(layers: Sequence[LayerCost], hw: HardwareModel,
                          budget: float, *, n_parts: int, batch: int = 256,
                          reconfig_cycles: float = 5e7, seed: int = 0,
                          dse_iters: int = 300) -> PartitionResult:
    """Pre-DP SA-over-cuts implementation, retained as the comparison
    baseline (benchmarks/dse_bench.py, tests/test_partition_dp.py). Re-runs
    the segment DSE inside every annealing energy evaluation — the cost the
    memoized segment table removes. Uses the same switch accounting as
    ``partition_pipeline`` (P - 1 switches per processed batch) so the two
    optimize an identical objective over exactly ``n_parts`` partitions."""
    L = len(layers)
    n_parts = min(n_parts, L)

    def eval_cuts(cuts):
        total = 0.0
        prev = 0
        for c in list(cuts) + [L]:
            part = layers[prev:c]
            if not part:
                return float("inf")
            r = incremental_dse(part, hw, budget, max_iters=dse_iters)
            if r.throughput <= 0:
                return float("inf")
            total += batch / r.throughput
            prev = c
        total += reconfig_cycles * len(list(cuts))
        return total

    if n_parts <= 1:
        t = eval_cuts([])
        return PartitionResult([], batch, t, batch / t)

    init = [round(L * (i + 1) / n_parts) for i in range(n_parts - 1)]

    def neighbor(cuts, rng):
        c = list(cuts)
        i = rng.integers(len(c))
        lo = c[i - 1] + 1 if i else 1
        hi = c[i + 1] - 1 if i + 1 < len(c) else L - 1
        if hi <= lo:
            return c
        c[i] = int(np.clip(c[i] + rng.integers(-2, 3), lo, hi))
        return c

    best, best_e, _ = simulated_annealing(init, eval_cuts, neighbor,
                                          steps=60, seed=seed)
    return PartitionResult(list(best), batch, best_e, batch / best_e)
