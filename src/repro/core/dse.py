"""Accelerator Design-Space Exploration (§V-A of the paper).

Implements, verbatim in structure:
  1. performance modeling (Eq. 1–3, in ``core.perf_model``),
  2. resource-constrained rate balancing (Eq. 4–5),
  3. resource-constrained incrementing (start minimal; repeatedly grow the
     slowest layer, then re-balance, until the budget R is exhausted),
  4. partitioning & reconfiguration (exact DP over pipeline split points on
     a memoized per-segment Pareto-frontier table; on TPU "full
     reconfiguration" = switching the mesh program between partitions —
     or, multi-chip, the ICI boundary transfer — amortized by batch size;
     the paper's SA loop is retained as ``partition_pipeline_sa``).

Every search also returns its full (resource, throughput) ``ParetoFrontier``
with materializable per-point design state (DESIGN.md §10).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.annealing import simulated_annealing
from repro.core.perf_model import (ACT_BYTES, DesignPoint, HardwareModel,
                                   LayerCost, LayerVectors, TPUModel,
                                   pipeline_throughput, t_cycles)


@dataclass
class ParetoFrontier:
    """The non-dominated (resource, throughput) set traced by one DSE run.

    Both arrays are sorted strictly increasing, so the frontier *is* the
    budget -> throughput function of the search: ``best_under(b)`` is a
    binary search, and ``materialize(k)`` rebuilds the concrete per-layer
    ``DesignPoint`` list of point ``k`` from the captured design state —
    no re-run of the greedy loop. Interior points are as-searched states
    on the growth path (strict-balanced); the last point is the final
    Eq. 4-trimmed search result, so ``best_under(search_budget)`` equals
    the ``DSEResult`` exactly (DESIGN.md §10).
    """
    res: np.ndarray               # (K,) float64, strictly increasing
    thr: np.ndarray               # (K,) float64, strictly increasing
    spe: np.ndarray               # (K, L) int64 design-state snapshots
    n: np.ndarray                 # (K, L) int64

    def __len__(self) -> int:
        return len(self.res)

    def point(self, k: int) -> Tuple[float, float]:
        return float(self.res[k]), float(self.thr[k])

    def best_under(self, budget: float) -> Optional[int]:
        """Index of the max-throughput point with resource <= budget, or
        None when even the cheapest point exceeds the budget."""
        k = int(np.searchsorted(self.res, budget, side="right")) - 1
        return k if k >= 0 else None

    def select(self, score: Callable[[np.ndarray, np.ndarray], np.ndarray]) -> int:
        """Argmax of a vectorized ``score(res, thr)`` over frontier points —
        how Eq. 6 consumers pick a trade-off point without re-searching."""
        return int(np.argmax(score(self.res, self.thr)))

    def materialize(self, k: int) -> List[DesignPoint]:
        return _designs_from(self.spe[k], self.n[k])


def _frontier_keep(res_pts: List[float], thr_pts: List[float]) -> List[int]:
    """Skyline indices of the recorded search path. The last input point is
    the final (Eq. 4-trimmed) result: it is made the canonical representative
    of its throughput level (using the DSE's own 1e-9 bottleneck tolerance)
    so near-duplicate as-searched states never shadow it under
    ``best_under``."""
    f_res, f_thr = res_pts[-1], thr_pts[-1]
    lo, hi = f_thr * (1 - 1e-9), f_thr * (1 + 1e-9)
    idx = [i for i in range(len(res_pts) - 1)
           if not (lo <= thr_pts[i] <= hi)
           and not (res_pts[i] >= f_res and thr_pts[i] <= hi)]
    idx.append(len(res_pts) - 1)
    idx.sort(key=lambda i: (res_pts[i], -thr_pts[i]))
    keep: List[int] = []
    best = -math.inf
    for i in idx:
        if thr_pts[i] > best:
            keep.append(i)
            best = thr_pts[i]
    return keep


def _build_frontier(res_pts: List[float], thr_pts: List[float],
                    states: List[Tuple[List[int], List[int]]]) -> ParetoFrontier:
    keep = _frontier_keep(res_pts, thr_pts)
    L = len(states[-1][0])
    return ParetoFrontier(
        res=np.array([res_pts[i] for i in keep], dtype=np.float64),
        thr=np.array([thr_pts[i] for i in keep], dtype=np.float64),
        spe=np.array([states[i][0] for i in keep],
                     dtype=np.int64).reshape(len(keep), L),
        n=np.array([states[i][1] for i in keep],
                   dtype=np.int64).reshape(len(keep), L))


@dataclass
class DSEResult:
    designs: List[DesignPoint]
    throughput: float             # samples/cycle (Eq. 3)
    resource: float               # total resource units (DSPs / tile-lanes)
    throughput_per_res: float
    trace: List[Tuple[float, float]]  # (resource, throughput) per increment
    frontier: Optional[ParetoFrontier] = None
    theta_r: float = 0.0          # peak bottleneck rate before the final
    #                               Eq. 4 trim — the DSECache warm-start
    #                               certificate bound (DESIGN.md §12)

    def images_per_s(self, hw: HardwareModel) -> float:
        return self.throughput * hw.freq


def _grow_options(l: LayerCost, d: DesignPoint, hw: HardwareModel):
    """Candidate increments for one layer: more MACs/SPE or more SPEs."""
    opts = []
    if d.macs_per_spe < hw.max_n(l):
        opts.append(replace(d, macs_per_spe=min(d.macs_per_spe * 2, hw.max_n(l))))
    if d.spe < hw.max_spe(l):
        opts.append(replace(d, spe=min(d.spe * 2, hw.max_spe(l))))
    return opts


def rate_balance_ref(layers: Sequence[LayerCost], designs: List[DesignPoint],
                     hw: HardwareModel, *, protect: Optional[set] = None,
                     strict: bool = False) -> List[DesignPoint]:
    """Reference (scalar, per-layer-loop) Eq. 4–5 implementation. Kept
    verbatim for equivalence testing against the vectorized ``rate_balance``;
    see that function for the semantics."""
    protect = protect or set()
    theta_r = pipeline_throughput(layers, designs, hw)
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    balanced: List[DesignPoint] = []
    for i, (l, d) in enumerate(zip(layers, designs)):
        if i in protect:
            balanced.append(d)
            continue
        best = d
        changed = True
        while changed:
            changed = False
            for cand in (replace(best, macs_per_spe=max(1, best.macs_per_spe // 2)),
                         replace(best, spe=max(1, best.spe // 2))):
                if (cand.spe, cand.macs_per_spe) == (best.spe, best.macs_per_spe):
                    continue
                if hw.layer_throughput(l, cand) >= lo:
                    best = cand
                    changed = True
                    break
        balanced.append(best)
    return balanced


# --------------------------------------------------------------------- #
# Vectorized engine (DESIGN.md §7): the design state is two small int
# vectors (spe, macs_per_spe) — designs only ever double/halve — operated
# on as flat arrays instead of per-layer dataclass lists.
# --------------------------------------------------------------------- #
def _design_arrays(designs: Sequence[DesignPoint]):
    spe = np.array([d.spe for d in designs], dtype=np.int64)
    n = np.array([d.macs_per_spe for d in designs], dtype=np.int64)
    return spe, n


def _designs_from(spe: np.ndarray, n: np.ndarray) -> List[DesignPoint]:
    return [DesignPoint(int(s), int(m)) for s, m in zip(spe, n)]


def _balance_arrays(hw: HardwareModel, lv: LayerVectors, spe: np.ndarray,
                    n: np.ndarray, protect: np.ndarray, strict: bool):
    """Vectorized Eq. 4–5 core. Each round, every unprotected layer takes its
    preferred feasible halving (macs_per_spe first, else spe — the reference
    candidate order) simultaneously; rounds repeat until no layer can shrink.
    Per-layer decisions are independent (theta_r is fixed at entry), so the
    simultaneous rounds replay each layer's reference shrink sequence exactly.
    """
    theta_r = float(hw.throughput_vec(lv, spe, n).min())
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    spe, n = spe.copy(), n.copy()
    free = ~protect
    while True:
        cand_n = np.maximum(1, n >> 1)
        ok_n = free & (cand_n != n) & \
            (hw.throughput_vec(lv, spe, cand_n) >= lo)
        cand_s = np.maximum(1, spe >> 1)
        ok_s = free & ~ok_n & (cand_s != spe) & \
            (hw.throughput_vec(lv, cand_s, n) >= lo)
        if not (ok_n.any() or ok_s.any()):
            return spe, n
        n = np.where(ok_n, cand_n, n)
        spe = np.where(ok_s, cand_s, spe)


def rate_balance(layers: Sequence[LayerCost], designs: List[DesignPoint],
                 hw: HardwareModel, *, protect: Optional[set] = None,
                 strict: bool = False) -> List[DesignPoint]:
    """Eq. 4–5: shrink every non-bottleneck layer to the smallest design whose
    modeled throughput still meets the pipeline's actual rate theta_r.

    ``strict=True`` is used *during* incrementing: a shrink must leave the
    layer's rate strictly above theta_r. With the literal (non-strict) Eq. 4
    rule, growing one of several bottleneck-tied layers gets undone by the
    next balancing pass (rate lands exactly on theta_r and is "still
    feasible"), deadlocking the greedy loop. Strict balancing keeps every
    layer within (theta_r, 2*theta_r] during growth; the final non-strict pass
    reclaims the leftover, which is the paper's Eq. 4 verbatim.
    ``protect`` exempts the just-grown layer.

    Vectorized; equivalent to ``rate_balance_ref`` design-for-design."""
    mask = np.zeros(len(designs), dtype=bool)
    for i in (protect or ()):
        mask[i] = True
    spe, n = _design_arrays(designs)
    spe, n = _balance_arrays(hw, hw.layer_vectors(layers), spe, n, mask,
                             strict)
    return _designs_from(spe, n)


def _run_incremental(lv: LayerVectors, hw: HardwareModel, budget: float,
                     max_iters: int):
    """Array-native §V-A.3 greedy loop; returns (spe, n, thr, res, trace).

    The state is two int vectors plus three maintained rate vectors: each
    layer's current rate (Eq. 2) and its rate after one macs_per_spe / one
    spe halving. Per iteration the engine does O(L) flat scans (argmin,
    shrink-feasibility) and re-derives rates only for the 1–2 layers that
    actually change, with the identical scalar expressions the reference
    evaluates — so results match ``incremental_dse_ref`` bit for bit while
    skipping its O(L * shrink-tries) dataclass churn and throughput
    recomputation.
    """
    L = len(lv)
    macs = lv.macs.tolist()
    m_dot = lv.m_dot.tolist()
    s_eff = lv.s_eff.tolist()
    max_n = lv.max_n.tolist()
    max_spe = lv.max_spe.tolist()
    unit = lv.res_unit.tolist()
    spe = [1] * L
    n = [1] * L
    # maintained per-layer rates: current (Eq. 2) and after one halving of
    # each coordinate — flat float lists; O(L) scans at Python-scalar cost
    # beat numpy-reduction dispatch for every realistic pipeline depth
    thr = [0.0] * L
    thr_nh = [0.0] * L
    thr_sh = [0.0] * L

    def thr_of(i: int, s: int, nn: int) -> float:
        if not macs[i]:
            return float("inf")
        t = t_cycles(s_eff[i], m_dot[i], nn)
        return s * m_dot[i] / (macs[i] * t)

    def sync(i: int) -> None:
        thr[i] = thr_of(i, spe[i], n[i])
        thr_nh[i] = thr_of(i, spe[i], max(1, n[i] // 2))
        thr_sh[i] = thr_of(i, max(1, spe[i] // 2), n[i])

    for i in range(L):
        sync(i)
    # resource totals are exact (integer DSPs / dyadic tile-lane fractions),
    # so incremental updates equal the reference's full re-summation
    res_total = float(sum(unit))

    def balance(lo: float, skip) -> List[Tuple[int, int, int]]:
        """One Eq. 4–5 pass against fixed ``lo``. ``skip`` is a protected
        layer index or per-layer bool list. Returns [(i, old_spe, old_n)] of
        changed layers. A layer shrinks at all iff its first halving is
        feasible, and each shrink chain is n-halvings then spe-halvings (rate
        is monotone in both coordinates, so the reference's retry-n-first
        loop reduces to exactly this), in scalar exact arithmetic."""
        nonlocal res_total
        changed = []
        skip_is_idx = isinstance(skip, int)
        for i in range(L):
            if (skip[i] if not skip_is_idx else i == skip):
                continue
            if not ((n[i] > 1 and thr_nh[i] >= lo) or
                    (spe[i] > 1 and thr_sh[i] >= lo)):
                continue
            s_i, n_i = spe[i], n[i]
            changed.append((i, s_i, n_i))
            while True:
                if n_i > 1 and thr_of(i, s_i, n_i // 2) >= lo:
                    n_i //= 2
                    continue
                if s_i > 1 and thr_of(i, s_i // 2, n_i) >= lo:
                    s_i //= 2
                    continue
                break
            res_total += (s_i * n_i - spe[i] * n[i]) * unit[i]
            spe[i], n[i] = s_i, n_i
            sync(i)
        return changed

    trace: List[Tuple[float, float]] = []
    # design-state snapshot per trace row: any frontier point can later be
    # materialized into concrete DesignPoints without re-running the search
    states: List[Tuple[List[int], List[int]]] = []
    for _ in range(max_iters):
        cur_thr = min(thr)
        slow = thr.index(cur_thr)
        trace.append((res_total, cur_thr))
        states.append((spe.copy(), n.copy()))
        # candidate increments for the slowest layer (macs_per_spe doubling
        # first — the reference option order, which wins Δthr/Δres ties)
        cur_res = spe[slow] * n[slow] * unit[slow]
        best = None
        best_score = None
        if n[slow] < max_n[slow]:
            n2 = min(n[slow] * 2, max_n[slow])
            dres = spe[slow] * n2 * unit[slow] - cur_res
            best = (spe[slow], n2)
            best_score = (thr_of(slow, spe[slow], n2) - cur_thr) / \
                max(dres, 1e-9)
        if spe[slow] < max_spe[slow]:
            s2 = min(spe[slow] * 2, max_spe[slow])
            dres = s2 * n[slow] * unit[slow] - cur_res
            score = (thr_of(slow, s2, n[slow]) - cur_thr) / max(dres, 1e-9)
            if best is None or score > best_score:
                best, best_score = (s2, n[slow]), score
        if best is None:
            break
        # apply the growth, strict-balance everyone else, keep if affordable
        res_before = res_total
        old_slow = (slow, spe[slow], n[slow])
        res_total += (best[0] * best[1] - spe[slow] * n[slow]) * unit[slow]
        spe[slow], n[slow] = best
        sync(slow)
        changed = balance(min(thr) * (1 + 1e-9), skip=slow)
        if res_total > budget:
            for i, s_i, n_i in [old_slow] + changed:
                spe[i], n[i] = s_i, n_i
                sync(i)
            res_total = res_before
            break

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    theta_r = min(thr)
    hi = theta_r * (1 + 1e-9)
    balance(theta_r * (1 - 1e-12), skip=[r <= hi for r in thr])
    f_thr = min(thr)
    states.append((spe.copy(), n.copy()))
    frontier = _build_frontier([r for r, _ in trace] + [res_total],
                               [t for _, t in trace] + [f_thr], states)
    return (np.array(spe, dtype=np.int64), np.array(n, dtype=np.int64),
            f_thr, res_total, trace, frontier, theta_r)


def _layer_classes(lv: LayerVectors):
    """Partition layers into dynamics classes: two layers behave bit-
    identically inside the greedy iff their (macs, m_dot, s_eff, max_n,
    max_spe, res_unit) tuples are equal — the rate function and resource
    accounting read nothing else. Returns (C, pos) with ``pos[c]`` the
    ascending member positions of class ``c`` (first-appearance order).
    One ``tolist`` per column then a flat dict loop — per-element numpy
    indexing is the thing to avoid here, not the Python loop."""
    cols = zip(lv.macs.tolist(), lv.m_dot.tolist(), lv.s_eff.tolist(),
               lv.max_n.tolist(), lv.max_spe.tolist(), lv.res_unit.tolist())
    seen: Dict[tuple, int] = {}
    pos: List[List[int]] = []
    for i, key in enumerate(cols):
        c = seen.setdefault(key, len(pos))
        if c == len(pos):
            pos.append([])
        pos[c].append(i)
    return len(pos), pos


def _run_incremental_grouped(lv: LayerVectors, hw: HardwareModel,
                             budget: float, max_iters: int,
                             classes=None):
    """Class-grouped §V-A.3 greedy: bit-identical to ``_run_incremental``
    but O(G) per iteration instead of O(L), where G is the number of live
    (class, design-state) groups — deep LM stacks repeat the same ~10 matmul
    shapes across blocks, so G stays near the class count while L is in the
    hundreds (DESIGN.md §12).

    Exactness argument: the greedy reads a layer only through its class
    constants and design state, ties on the rate argmin break by lowest
    layer position (``thr.index``), and within a class the min-rate group's
    copies share one state so the winner is the group's first position.
    Copies therefore split off a group one position at a time in ascending
    order, keeping every group a contiguous position run; balance shrinks
    map whole groups identically, and ``res_total`` is accumulated over
    changed copies in ascending position order — the flat engine's float
    summation order, term for term."""
    L = len(lv)
    C, pos = classes if classes is not None else _layer_classes(lv)
    macs = [int(lv.macs[pos[c][0]]) for c in range(C)]
    m_dot = [int(lv.m_dot[pos[c][0]]) for c in range(C)]
    s_eff = [float(lv.s_eff[pos[c][0]]) for c in range(C)]
    max_n = [int(lv.max_n[pos[c][0]]) for c in range(C)]
    max_spe = [int(lv.max_spe[pos[c][0]]) for c in range(C)]
    unit = [float(lv.res_unit[pos[c][0]]) for c in range(C)]

    ceil = math.ceil

    def thr_of(c: int, s: int, nn: int) -> float:
        if not macs[c]:
            return float("inf")
        t = max(1, ceil((1.0 - s_eff[c]) * m_dot[c] / max(nn, 1)))
        return s * m_dot[c] / (macs[c] * t)

    # groups: per class, ascending-start list of
    # [start, cnt, s, n, rate, rate_nh, rate_sh]; positions of a group are
    # pos[c][start:start+cnt]. rate_nh/rate_sh are the rates after one
    # n-/spe-halving — maintained so balance entry checks are list reads,
    # the flat engine's thr_nh/thr_sh trick at group granularity.
    def _group(c: int, start: int, cnt: int, s: int, nn: int) -> List:
        return [start, cnt, s, nn, thr_of(c, s, nn),
                thr_of(c, s, max(1, nn // 2)), thr_of(c, max(1, s // 2), nn)]

    cgroups: List[List[List]] = [[_group(c, 0, len(pos[c]), 1, 1)]
                                 for c in range(C)]
    # flat per-layer design mirror, kept in sync with the groups; state
    # history is a per-row mutation log (``muts``), so a trace row costs
    # O(changes) instead of O(L) — wave rows change exactly one layer
    spe_l = [1] * L
    n_l = [1] * L
    # exact flat-engine float: sum(res_unit) in ascending position order
    res_total = float(sum(lv.res_unit.tolist()))

    def scan_min():
        """(min rate, argmin class, argmin group, strict second) in one
        pass; rate ties break by lowest member position — exactly the flat
        engine's ``thr.index(min(thr))``. ``second`` is the min over groups
        other than the argmin group (== cur on a tie)."""
        cur = second = math.inf
        best_c = best_g = None
        best_pos = L
        for c in range(C):
            for g in cgroups[c]:
                r = g[4]
                if r < cur:
                    second = cur
                    cur, best_c, best_g = r, c, g
                    best_pos = pos[c][g[0]]
                elif r == cur:
                    second = cur
                    p = pos[c][g[0]]
                    if p < best_pos:
                        best_c, best_g, best_pos = c, g, p
                elif r < second:
                    second = r
        return cur, best_c, best_g, second

    def compact(c: int) -> None:
        gs = cgroups[c]
        out = [gs[0]]
        for g in gs[1:]:
            p = out[-1]
            if p[2] == g[2] and p[3] == g[3]:
                p[1] += g[1]
            else:
                out.append(g)
        cgroups[c] = out

    # lazy per-row undo log: class -> its group list at row start; a budget
    # revert restores exactly the touched classes
    iter_log: Dict[int, List[List]] = {}

    def touch(c: int) -> None:
        if c not in iter_log:
            iter_log[c] = [list(g) for g in cgroups[c]]

    trace: List[Tuple[float, float]] = []
    muts: List[List[Tuple[int, int, int]]] = []   # per trace row: (p, s, n)
    undo: List[Tuple[int, int, int]] = []         # current row (p, s, n) old

    def balance(lo: float, skip) -> None:
        """One Eq. 4–5 pass against fixed ``lo``. ``skip`` is a group object
        or a set of id(group)s. Shrink chains are per-group (all copies of a
        group share the decision); the res_total deltas are then applied in
        ascending copy-position order, replaying the flat engine's float
        accumulation exactly."""
        nonlocal res_total
        updates: List[Tuple[int, float]] = []
        touched = []
        skip_set = skip if isinstance(skip, set) else None
        row = muts[-1]
        for c in range(C):
            for g in cgroups[c]:
                if g is skip or (skip_set and id(g) in skip_set):
                    continue
                s, nn = g[2], g[3]
                if not ((nn > 1 and g[5] >= lo) or (s > 1 and g[6] >= lo)):
                    continue
                touch(c)
                s_i, n_i = s, nn
                while True:
                    if n_i > 1 and thr_of(c, s_i, n_i // 2) >= lo:
                        n_i //= 2
                        continue
                    if s_i > 1 and thr_of(c, s_i // 2, n_i) >= lo:
                        s_i //= 2
                        continue
                    break
                delta = (s_i * n_i - s * nn) * unit[c]
                for p in pos[c][g[0]:g[0] + g[1]]:
                    updates.append((p, delta))
                    undo.append((p, spe_l[p], n_l[p]))
                    row.append((p, s_i, n_i))
                    spe_l[p] = s_i
                    n_l[p] = n_i
                g[2:] = _group(c, g[0], g[1], s_i, n_i)[2:]
                touched.append(c)
        updates.sort()
        for _, d in updates:
            res_total += d
        for c in set(touched):
            compact(c)

    it = 0
    broke = False
    while it < max_iters and not broke:
        cur_thr, slow_c, slow_g, second = scan_min()
        s, nn = slow_g[2], slow_g[3]
        cur_res = s * nn * unit[slow_c]
        best = None
        best_score = None
        if nn < max_n[slow_c]:
            n2 = min(nn * 2, max_n[slow_c])
            dres = s * n2 * unit[slow_c] - cur_res
            best = (s, n2)
            best_score = (thr_of(slow_c, s, n2) - cur_thr) / max(dres, 1e-9)
        if s < max_spe[slow_c]:
            s2 = min(s * 2, max_spe[slow_c])
            dres = s2 * nn * unit[slow_c] - cur_res
            score = (thr_of(slow_c, s2, nn) - cur_thr) / max(dres, 1e-9)
            if best is None or score > best_score:
                best = (s2, nn)
        if best is None:
            trace.append((res_total, cur_thr))
            muts.append([])
            break
        grown_rate = thr_of(slow_c, best[0], best[1])
        dgrow = (best[0] * best[1] - s * nn) * unit[slow_c]
        # wave width: while >1 copies lag at the strict minimum and the
        # grown design strictly improves, every next flat iteration grows
        # the next lagging copy with the identical decision, the pipeline
        # minimum stays cur_thr, and the balance pass is a no-op after the
        # first (same lo, feasibility unchanged) — batch those iterations.
        # The no-op argument needs the grown design itself to be
        # unshrinkable at that lo (a ceil-plateau spe-doubling can leave
        # its n free to halve, which the flat engine's next pass takes)
        wave = 0
        if slow_g[1] > 1 and grown_rate > cur_thr and cur_thr < second:
            lo_wave = cur_thr * (1 + 1e-9)
            g_nh = thr_of(slow_c, best[0], max(1, best[1] // 2))
            g_sh = thr_of(slow_c, max(1, best[0] // 2), best[1])
            if not ((best[1] > 1 and g_nh >= lo_wave) or
                    (best[0] > 1 and g_sh >= lo_wave)):
                # batch up to cnt-2 follow-up copies: growing the LAST
                # lagging copy moves the pipeline minimum, so its balance
                # pass runs at a different lo — leave it to a normal step
                wave = min(slow_g[1] - 2, max_iters - it - 1)
        iter_log.clear()
        undo.clear()
        res_before = res_total
        touch(slow_c)
        trace.append((res_total, cur_thr))
        muts.append([])
        # split the first (lowest-position) copy off the argmin group and
        # grow it — the flat engine grows exactly that layer index
        if slow_g[1] == 1:
            grown = slow_g
        else:
            grown = list(slow_g)
            grown[1] = 1
            slow_g[0] += 1
            slow_g[1] -= 1
            gi = cgroups[slow_c].index(slow_g)
            cgroups[slow_c].insert(gi, grown)
        res_total += dgrow
        grown[2:] = _group(slow_c, grown[0], 1, best[0], best[1])[2:]
        p_grown = pos[slow_c][grown[0]]
        undo.append((p_grown, spe_l[p_grown], n_l[p_grown]))
        muts[-1].append((p_grown, best[0], best[1]))
        spe_l[p_grown], n_l[p_grown] = best
        # min(thr) after the growth, without a rescan: growth only raised
        # the grown copy's rate; the lagging remainder (if any) still sits
        # at cur_thr, everything else at >= second (exact same floats the
        # flat engine's fresh min() sees)
        if grown is slow_g:
            m_after = second if second < grown_rate else grown_rate
        else:
            m_after = cur_thr
        balance(m_after * (1 + 1e-9), skip=grown)
        compact(slow_c)
        it += 1
        if res_total > budget:
            for c, gs in iter_log.items():
                cgroups[c] = gs
            for p, s_o, n_o in reversed(undo):
                spe_l[p], n_l[p] = s_o, n_o
            muts[-1] = []
            res_total = res_before
            break
        if not wave:
            continue
        # batched wave steps (flat iterations 2..wave+1 of this run).
        # compact() may have merged the grown singleton into an adjacent
        # same-state group (a previous interrupted wave's accumulator), so
        # re-locate the LIVE group holding the grown copy before mutating
        start0 = grown[0]
        acc = None
        for g in cgroups[slow_c]:
            if g[0] <= start0 < g[0] + g[1]:
                acc = g
                break
        for _ in range(wave):
            trace.append((res_total, cur_thr))
            muts.append([])
            res_wave = res_total
            p = pos[slow_c][slow_g[0]]
            slow_g[0] += 1
            slow_g[1] -= 1
            acc[1] += 1
            res_total += dgrow
            muts[-1].append((p, best[0], best[1]))
            spe_l[p], n_l[p] = best
            it += 1
            if res_total > budget:
                slow_g[0] -= 1
                slow_g[1] += 1
                acc[1] -= 1
                spe_l[p], n_l[p] = s, nn
                muts[-1] = []
                res_total = res_wave
                broke = True
                break

    theta_r = scan_min()[0]
    hi = theta_r * (1 + 1e-9)
    protected = {id(g) for gs in cgroups for g in gs if g[4] <= hi}
    muts.append([])           # final-pass mutations, applied after row T-1
    undo.clear()
    balance(theta_r * (1 - 1e-12), skip=protected)
    f_thr = scan_min()[0]

    # frontier assembly: replay the mutation log once, materializing the
    # kept rows (row j's state = initial + muts[0..j-1]); the final entry
    # is the post-trim state, one replay step past the last row
    res_pts = [r for r, _ in trace] + [res_total]
    thr_pts = [t for _, t in trace] + [f_thr]
    keep = _frontier_keep(res_pts, thr_pts)
    keep_set = set(keep)
    spe_r = [1] * L
    n_r = [1] * L
    kept: Dict[int, Tuple[List[int], List[int]]] = {}
    last = len(res_pts) - 1
    for j in range(len(trace)):         # trace rows: state BEFORE muts[j]
        if j in keep_set:
            kept[j] = (spe_r.copy(), n_r.copy())
        for p, s_m, n_m in muts[j]:
            spe_r[p] = s_m
            n_r[p] = n_m
    for p, s_m, n_m in muts[-1]:        # final Eq. 4 pass
        spe_r[p] = s_m
        n_r[p] = n_m
    kept[last] = (spe_r.copy(), n_r.copy())
    frontier = ParetoFrontier(
        res=np.array([res_pts[i] for i in keep], dtype=np.float64),
        thr=np.array([thr_pts[i] for i in keep], dtype=np.float64),
        spe=np.array([kept[i][0] for i in keep],
                     dtype=np.int64).reshape(len(keep), L),
        n=np.array([kept[i][1] for i in keep],
                   dtype=np.int64).reshape(len(keep), L))
    return (np.array(spe_l, dtype=np.int64), np.array(n_l, dtype=np.int64),
            f_thr, res_total, trace, frontier, theta_r)


def _run_dse(lv: LayerVectors, hw: HardwareModel, budget: float,
             max_iters: int, engine: str = "auto"):
    """Engine dispatch: ``grouped`` when enough layers share a dynamics
    class to pay for the group bookkeeping, ``flat`` otherwise. Both are
    bit-exact (property-tested), so ``auto`` is a pure perf choice."""
    classes = None
    if engine == "auto":
        classes = _layer_classes(lv)
        engine = "grouped" if len(lv) >= 16 and 2 * classes[0] <= len(lv) \
            else "flat"
    if engine == "grouped":
        return _run_incremental_grouped(lv, hw, budget, max_iters,
                                        classes=classes)
    if engine != "flat":
        raise ValueError(f"unknown engine {engine!r}")
    return _run_incremental(lv, hw, budget, max_iters)


def incremental_dse(layers: Sequence[LayerCost], hw: HardwareModel,
                    budget: float, *, max_iters: int = 10000,
                    engine: str = "auto") -> DSEResult:
    """§V-A.3: start resource-minimal, grow the slowest layer, re-balance.

    Vectorized greedy loop — identical designs/throughput/resource/trace to
    ``incremental_dse_ref`` (property-tested), ~10–100x faster. The returned
    ``DSEResult.frontier`` holds the full non-dominated (resource,
    throughput) set of the search path with per-point design state, so
    consumers (Eq. 6 scoring, DP partitioning) trade points without
    re-running the search (``incremental_dse_ref`` leaves it None).

    ``engine`` picks the loop implementation: ``"flat"`` is the per-layer
    engine; ``"grouped"`` collapses layers with identical dynamics into
    class groups (bit-exact, much faster on deep LM stacks whose blocks
    repeat the same matmul shapes); ``"auto"`` chooses by class count."""
    lv = hw.layer_vectors(layers)
    spe, n, thr, res, trace, frontier, theta_r = _run_dse(lv, hw, budget,
                                                          max_iters, engine)
    return DSEResult(designs=_designs_from(spe, n), throughput=thr,
                     resource=res, throughput_per_res=thr / max(res, 1e-9),
                     trace=trace, frontier=frontier, theta_r=theta_r)


def incremental_dse_ref(layers: Sequence[LayerCost], hw: HardwareModel,
                        budget: float, *, max_iters: int = 10000) -> DSEResult:
    """Reference scalar implementation of ``incremental_dse`` (pre-vectorized
    code, kept verbatim as the equivalence oracle and for ``dse_bench``)."""
    designs = [DesignPoint(1, 1) for _ in layers]
    trace: List[Tuple[float, float]] = []

    def total_res(ds):
        return sum(hw.layer_resource(l, d) for l, d in zip(layers, ds))

    for _ in range(max_iters):
        thr = pipeline_throughput(layers, designs, hw)
        res = total_res(designs)
        trace.append((res, thr))
        # slowest layer
        rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
        slow = int(np.argmin(rates))
        opts = _grow_options(layers[slow], designs[slow], hw)
        if not opts:
            break
        # pick the increment with best Δthroughput per Δresource
        def score(opt):
            dthr = hw.layer_throughput(layers[slow], opt) - rates[slow]
            dres = hw.layer_resource(layers[slow], opt) - \
                hw.layer_resource(layers[slow], designs[slow])
            return dthr / max(dres, 1e-9)
        opt = max(opts, key=score)
        cand = list(designs)
        cand[slow] = opt
        cand = rate_balance_ref(layers, cand, hw, protect={slow}, strict=True)
        if total_res(cand) > budget:
            break
        designs = cand

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
    bottleneck = {i for i, r in enumerate(rates) if r <= min(rates) * (1 + 1e-9)}
    designs = rate_balance_ref(layers, designs, hw, protect=bottleneck)
    thr = pipeline_throughput(layers, designs, hw)
    res = total_res(designs)
    return DSEResult(designs=designs, throughput=thr, resource=res,
                     throughput_per_res=thr / max(res, 1e-9), trace=trace)


# --------------------------------------------------------------------- #
# DSECache: memoized warm-start reuse across DSE calls (DESIGN.md §12)
# --------------------------------------------------------------------- #
class DSECache:
    """Exact result reuse for ``incremental_dse`` across a search session.

    Two reuse levels, both bit-exact (property-tested in
    ``tests/test_dse_cache.py``):

      * **exact** — results are memoized on the full dynamics key: the
        ``s_eff`` float vector plus a fingerprint of the workload constants
        (macs, m_dot, caps, res_unit), budget and max_iters. Equal keys
        replay the identical greedy trajectory by determinism.
      * **warm** — the floor-stability theorem: a layer whose design the
        greedy never grows stays at the resource floor (1, 1) for the whole
        run (shrinking from the floor is impossible), and it is never grown
        iff its floor rate strictly exceeds ``theta_r``, the run's peak
        bottleneck rate. Such a layer contributes a constant to every
        decision the greedy takes — argmin selection, balance feasibility,
        budget accounting — so two stacks that differ ONLY in layers that
        are floor-stable on both sides (rate at (1,1) strictly above the
        cached run's theta_r under both the cached and the query sparsity)
        have bit-identical DSE results. The certificate is O(L) per cached
        anchor, vectorized over all anchors; when it cannot be proven the
        query falls back to a cold run.

    A cold run is the normal engine (grouped/flat dispatch), so a cache
    MISS costs one array compare more than no cache at all. Results handed
    out are shared objects — treat them as immutable.
    """

    def __init__(self, max_entries: int = 256,
                 materialize_designs: bool = True):
        """``materialize_designs=False`` leaves ``DSEResult.designs`` empty
        on cache-produced results (consumers that only read the frontier —
        the analytic evaluators — skip building L DesignPoint objects per
        cold run; ``ParetoFrontier.materialize`` still rebuilds any point)."""
        self.max_entries = max_entries
        self.materialize_designs = materialize_designs
        self.hits = 0
        self.warm_hits = 0
        self.cold_runs = 0
        # fingerprint -> {s_eff bytes -> DSEResult}
        self._exact: Dict[int, Dict[bytes, DSEResult]] = {}
        # fingerprint -> [s_eff rows], [rate11 rows], [theta_r], [result]
        self._anchors: Dict[int, list] = {}

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "warm_hits": self.warm_hits,
                "cold_runs": self.cold_runs}

    @staticmethod
    def _fingerprint(lv: LayerVectors, budget: float, max_iters: int) -> int:
        return hash((lv.macs.tobytes(), lv.m_dot.tobytes(),
                     lv.max_n.tobytes(), lv.max_spe.tobytes(),
                     lv.res_unit.tobytes(), float(budget), int(max_iters)))

    @staticmethod
    def _rate11(lv: LayerVectors) -> np.ndarray:
        """Per-layer rate at the (1, 1) floor design — the same floats the
        engines' ``thr_of(i, 1, 1)`` computes."""
        t = np.maximum(1.0, np.ceil((1.0 - lv.s_eff) * lv.m_dot))
        with np.errstate(divide="ignore"):
            r = lv.m_dot / (lv.macs * t)
        return np.where(lv.macs > 0, r, np.inf)

    def dse_vec(self, lv: LayerVectors, hw: HardwareModel, budget: float,
                *, max_iters: int = 10000, engine: str = "auto") -> DSEResult:
        fp = self._fingerprint(lv, budget, max_iters)
        s_eff = np.ascontiguousarray(lv.s_eff, dtype=np.float64)
        key = s_eff.tobytes()
        exact = self._exact.setdefault(fp, {})
        r = exact.get(key)
        if r is not None:
            self.hits += 1
            return r
        anchors = self._anchors.setdefault(fp, [[], [], [], []])
        a_s, a_r11, a_th, a_res = anchors
        if a_s:
            q_r11 = self._rate11(lv)
            S = np.stack(a_s)
            R = np.stack(a_r11)
            th = np.asarray(a_th)[:, None]
            ok = (~(S != s_eff[None]) |
                  ((R > th) & (q_r11[None] > th))).all(axis=1)
            idx = np.nonzero(ok)[0]
            if len(idx):
                self.warm_hits += 1
                r = a_res[int(idx[0])]
                self._insert(fp, s_eff, key, q_r11, r)
                return r
        self.cold_runs += 1
        spe, n, thr, res, trace, frontier, theta_r = _run_dse(
            lv, hw, budget, max_iters, engine)
        designs = _designs_from(spe, n) if self.materialize_designs else []
        r = DSEResult(designs=designs, throughput=thr,
                      resource=res, throughput_per_res=thr / max(res, 1e-9),
                      trace=trace, frontier=frontier, theta_r=theta_r)
        self._insert(fp, s_eff, key, self._rate11(lv), r)
        return r

    def dse(self, layers: Sequence[LayerCost], hw: HardwareModel,
            budget: float, *, max_iters: int = 10000,
            engine: str = "auto") -> DSEResult:
        """Drop-in cached ``incremental_dse``."""
        return self.dse_vec(hw.layer_vectors(layers), hw, budget,
                            max_iters=max_iters, engine=engine)

    def _insert(self, fp: int, s_eff: np.ndarray, key: bytes,
                rate11: np.ndarray, r: DSEResult) -> None:
        exact = self._exact[fp]
        if len(exact) >= self.max_entries:
            exact.clear()                    # epoch reset: searches are
            self._anchors[fp] = [[], [], [], []]  # phase-local, old anchors
        exact[key] = r                       # rarely pay off past the cap
        a_s, a_r11, a_th, a_res = self._anchors[fp]
        a_s.append(s_eff)
        a_r11.append(rate11)
        a_th.append(r.theta_r)
        a_res.append(r)


# --------------------------------------------------------------------- #
# Partitioning & reconfiguration (§V-A.4): segment-table DP
# --------------------------------------------------------------------- #
@dataclass
class PartitionResult:
    """One partitioning of a layer pipeline, with both schedule metrics.

    ``throughput`` is the *amortized temporal* rate: ``batch /
    time_per_batch`` where ``time_per_batch`` runs the partitions back to
    back on ONE executor and charges every switch between them — the FPGA
    reconfiguration schedule of §V-A.4. ``steady_throughput`` is the
    *spatial steady-state* rate: all partitions resident at once (one per
    chip), every batch flowing through the full chain, so the pipeline runs
    at the rate of its slowest stage — ``min`` over partition rates and,
    multi-chip, the per-sample ICI hop rates at the cuts. The two coincide
    only for a single partition; see DESIGN.md §10/§11 for when the
    objectives that optimize them pick different cuts.
    """
    cuts: List[int]               # split indices (exclusive prefix ends)
    batch: int
    time_per_batch: float         # cycles, incl. switch/transfer overhead
    throughput: float             # samples/cycle amortized (temporal)
    part_throughput: List[float] = field(default_factory=list)
    part_designs: List[List[DesignPoint]] = field(default_factory=list)
    steady_throughput: float = 0.0  # spatial-pipeline rate: min over
    #                                 partition rates and ICI hop rates
    dse_calls: int = 0            # segment DSE invocations (memoized table)
    objective: str = "sum"        # DP objective that picked the cuts
    chip_budgets: Optional[List[float]] = None   # per-stage DSE budgets
    #                                 (heterogeneous slices; DESIGN.md §13)
    sim_report: Optional[object] = None   # SimReport of the winning
    #                                 candidate when objective="slo"


def boundary_activations(layers: Sequence[LayerCost], cut: int) -> float:
    """Activation elements per sample crossing a partition cut.

    A sequential pipeline hands ``layers[cut-1].act_out ==
    layers[cut].act_in`` across the boundary. When the two disagree the
    smaller side is the stream that actually crosses: LM ``act_in``/
    ``act_out`` carry per-layer ``n_apply`` multipliers (a MoE down-proj
    "emits" d_model x active_experts, but the block reduces back to one
    residual stream of width d_model = the next block's ``act_in``), and a
    shared-attention block consumes a concat of the d_model stream. Taking
    ``min`` prices the residual stream, not the intra-block fan-out
    (DESIGN.md §11)."""
    return float(min(layers[cut - 1].act_out, layers[cut].act_in))


class SegmentTable:
    """Memoized per-contiguous-segment DSE frontiers for partitioning.

    Each contiguous segment ``layers[i:j]`` is searched at most ONCE; the
    DP below then reads amortized batch times off the cached frontiers. The
    total segment-DSE count is therefore bounded by L(L+1)/2 regardless of
    how many cut configurations the optimizer considers — unlike SA, whose
    DSE count scales with annealing steps x partitions and which still only
    samples the cut space (DESIGN.md §10).

    A shared ``DSECache`` extends the reuse across *tables*: every
    ``partition_pipeline`` call in one search session (per chip count, per
    objective, per proposal) keys its segment DSEs in the same cache, so a
    segment whose layers' sparsity did not change is never re-searched
    (DESIGN.md §12).
    """

    def __init__(self, layers: Sequence[LayerCost], hw: HardwareModel,
                 budget: float, batch: int, dse_iters: int,
                 cache: Optional[DSECache] = None):
        self.layers = list(layers)
        self.hw, self.budget = hw, budget
        self.batch, self.dse_iters = batch, dse_iters
        self._cache: Dict[Tuple[int, int, float], ParetoFrontier] = {}
        self.dse_calls = 0
        self.shared = cache

    def frontier(self, i: int, j: int,
                 budget: Optional[float] = None) -> ParetoFrontier:
        """Per-segment frontier at ``budget`` (the table's own budget when
        None). Heterogeneous slices query the same segment at several
        per-chip budgets — each (i, j, budget) is searched at most once, and
        a shared ``DSECache`` dedupes across tables by the same key."""
        b = self.budget if budget is None else float(budget)
        key = (i, j, b)
        if key not in self._cache:
            self.dse_calls += 1
            if self.shared is not None:
                r = self.shared.dse(self.layers[i:j], self.hw, b,
                                    max_iters=self.dse_iters)
            else:
                r = incremental_dse(self.layers[i:j], self.hw, b,
                                    max_iters=self.dse_iters)
            self._cache[key] = r.frontier
        return self._cache[key]

    def _best(self, i: int, j: int, budget: Optional[float] = None) -> int:
        b = self.budget if budget is None else float(budget)
        f = self.frontier(i, j, b)
        k = f.best_under(b)
        # infeasible budget: the resource-minimal design still runs (the
        # greedy's own behavior when it cannot afford any growth)
        return 0 if k is None else k

    def throughput(self, i: int, j: int,
                   budget: Optional[float] = None) -> float:
        f = self.frontier(i, j, budget)
        return float(f.thr[self._best(i, j, budget)])

    def time(self, i: int, j: int, budget: Optional[float] = None) -> float:
        thr = self.throughput(i, j, budget)
        return self.batch / thr if thr > 0 else float("inf")

    def designs(self, i: int, j: int,
                budget: Optional[float] = None) -> List[DesignPoint]:
        f = self.frontier(i, j, budget)
        return f.materialize(self._best(i, j, budget))


def _keep_largest(budgets: Sequence[float], p: int) -> List[float]:
    """The ``p`` largest budgets, physical order preserved (ties keep the
    earlier chip) — the chips a ``p``-partition deployment holds on to."""
    idx = sorted(sorted(range(len(budgets)), key=lambda i: -budgets[i])[:p])
    return [budgets[i] for i in idx]


def _better_partition(a: PartitionResult, b: PartitionResult,
                      objective: str) -> bool:
    """Strictly-better comparison across the heterogeneous per-P runs,
    mirroring the DP's own tie rules (maxmin ties prefer the smaller
    amortized batch time; ascending-P iteration keeps remaining ties on
    the fewest chips)."""
    if objective == "maxmin":
        if a.steady_throughput > b.steady_throughput * (1 + 1e-12):
            return True
        if a.steady_throughput < b.steady_throughput * (1 - 1e-12):
            return False
    return a.time_per_batch < b.time_per_batch * (1 - 1e-12)


def partition_pipeline(layers: Sequence[LayerCost], hw: HardwareModel,
                       budget: float, *, n_parts: int, batch: int = 256,
                       reconfig_cycles: float = 5e7, seed: int = 0,
                       dse_iters: int = 300,
                       cut_points: Optional[Sequence[int]] = None,
                       objective: str = "auto",
                       cache: Optional[DSECache] = None,
                       chip_budgets: Optional[Sequence[float]] = None,
                       slo: Optional[object] = None,
                       trace: Optional[object] = None,
                       sim_kw: Optional[dict] = None,
                       _positional: bool = False) -> PartitionResult:
    """Fold the pipeline into at most ``n_parts`` sequential partitions, each
    run with the full per-partition ``budget``. Exact DP over cut positions
    on a memoized per-segment frontier table (one DSE per contiguous
    segment) — replaces the SA loop, which re-ran the full segment DSE on
    every annealing step (kept as ``partition_pipeline_sa``).

    Switch accounting (temporal schedule, ``time_per_batch``): a schedule
    with P resident partitions charges exactly P - 1 *switches* per
    processed batch — the mid-batch program transitions. A single resident
    partition (P = 1) charges none: it is never reconfigured, and reloading
    the first partition for the next batch overlaps with host-side batch
    staging, so neither end of the loop is charged. On a single-chip target
    a switch costs ``reconfig_cycles`` (FPGA full reconfiguration / TPU mesh
    program swap); on a multi-chip ``TPUModel`` (``hw.chips > 1``) each
    partition is resident on its own chip and a switch is instead the ICI
    transfer of the whole batch's boundary activations
    (``TPUModel.ici_transfer_cycles``), and ``n_parts`` is capped at
    ``hw.chips``.

    Metrics: ``throughput`` is the amortized *temporal* rate ``batch /
    time_per_batch`` (partitions time-multiplexed on one executor);
    ``steady_throughput`` is the *spatial* steady-state rate with every
    partition resident simultaneously — ``min`` over partition rates and,
    multi-chip, the per-sample ICI hop rates at the cuts. See the
    ``PartitionResult`` docstring and DESIGN.md §10/§11.

    ``objective`` selects what the DP optimizes:
      * ``"sum"``    — minimize ``time_per_batch`` (the sum-form temporal
        objective; the §V-A.4 reconfiguration schedule).
      * ``"maxmin"`` — maximize ``steady_throughput`` directly (max-min
        over stage and ICI-hop rates; multi-chip only, where the spatial
        schedule is the one actually run). Never worse on
        ``steady_throughput`` than the sum-form pick over the same cut
        space, because it exactly maximizes that metric; ties prefer the
        partition with the smaller ``time_per_batch``.
      * ``"auto"``   — ``"maxmin"`` for a multi-chip ``TPUModel``,
        ``"sum"`` otherwise (DESIGN.md §11).
      * ``"slo"``    — simulation-in-the-loop: build the per-P sum/max-min
        candidate partitions, simulate each against ``trace`` with the
        discrete-event deployment simulator, and pick the best candidate
        that meets the latency SLO (``slo``, a ``repro.sim.slo.SLO`` or a
        p99 target in cycles); extra simulator knobs go through ``sim_kw``.
        Delegates to ``repro.sim.slo.slo_partition_search`` (DESIGN.md §13);
        the returned result carries its winning ``sim_report``.

    ``chip_budgets`` gives each *stage* its own DSE budget on a
    heterogeneous (mixed-generation) slice. Multi-chip only, one entry per
    chip; defaults to ``hw.chip_budgets`` when the ``TPUModel`` declares
    ``chip_lanes``. A deployment with P partitions keeps the P *largest*
    chips (physical order preserved, ties to the earlier chip — a single
    resident partition lands on the largest chip, matching
    ``TPUModel.chip_budget``), and stage ``p`` is searched at the budget
    of the ``p``-th kept chip. Each P is priced by its own exact
    positional DP and the objective-best P wins (DESIGN.md §13;
    property-tested against brute force in ``tests/test_partition_dp.py``).
    ``_positional`` is internal: it marks one of those per-P runs, where
    ``chip_budgets`` lists exactly the kept stage budgets.

    ``cut_points`` restricts the DP to a candidate set of cut indices
    (sorted, in ``1..L-1``); ``None`` allows every position. Deep LM stacks
    pass block boundaries (``perf_model.lm_block_bounds``, optionally
    thinned by ``thin_cut_points``) — the segment table then holds
    O(K^2) DSEs for K candidates instead of O(L^2).

    The DP may use fewer than ``n_parts`` partitions when a switch costs
    more than it saves (or, max-min, when an ICI hop would bottleneck the
    pipeline). ``seed`` is accepted for API compatibility with the SA
    reference and is unused — the DP is deterministic.

    ``cache`` plugs a shared ``DSECache`` into the segment table, so
    repeated partition calls in one session (chip-count sweeps, sum vs
    max-min objectives, per-proposal re-partitioning) reuse every segment
    frontier whose layers did not change (DESIGN.md §12).
    """
    L = len(layers)
    multi_chip = isinstance(hw, TPUModel) and hw.chips > 1
    if objective == "slo":
        from repro.sim.slo import slo_partition_search
        return slo_partition_search(
            layers, hw, budget, slo=slo, trace=trace, n_parts=n_parts,
            batch=batch, reconfig_cycles=reconfig_cycles,
            dse_iters=dse_iters, cut_points=cut_points, cache=cache,
            chip_budgets=chip_budgets, **(sim_kw or {}))
    if slo is not None or trace is not None:
        raise ValueError("slo=/trace= are only read by objective='slo'")
    if objective == "auto":
        objective = "maxmin" if multi_chip else "sum"
    if objective not in ("sum", "maxmin"):
        raise ValueError(f"unknown objective {objective!r}")
    if chip_budgets is None and multi_chip and hw.chip_lanes is not None:
        chip_budgets = hw.chip_budgets
    if chip_budgets is not None:
        if not multi_chip:
            raise ValueError("chip_budgets models per-chip DSE budgets, "
                             "which only exist for a multi-chip TPUModel")
        chip_budgets = [float(b) for b in chip_budgets]
        if not _positional:
            if len(chip_budgets) != hw.chips:
                raise ValueError(f"chip_budgets has {len(chip_budgets)} "
                                 f"entries for {hw.chips} chips")
            if len(set(chip_budgets)) > 1:
                # heterogeneous: a P-partition deployment keeps the P
                # largest chips, so each P gets its own positional DP run
                # pinned to EXACTLY P partitions (a smaller partition count
                # is its own loop iteration with its own kept set — letting
                # an inner run fall back to fewer stages would price them
                # at a prefix of the wrong kept set). One shared cache —
                # the segment frontiers are reused across runs. The loop
                # stops at the cut space's capacity so no run is silently
                # capped below its kept-set size.
                shared = DSECache() if cache is None else cache
                kw = dict(batch=batch, reconfig_cycles=reconfig_cycles,
                          dse_iters=dse_iters, cut_points=cut_points,
                          objective=objective, cache=shared)
                cp_n = len(set(int(c) for c in cut_points)) \
                    if cut_points is not None else max(L - 1, 0)
                p_max = max(1, min(n_parts, hw.chips, cp_n + 1))
                best = None
                for p in range(1, p_max + 1):
                    r = partition_pipeline(
                        layers, hw, budget, n_parts=p,
                        chip_budgets=_keep_largest(chip_budgets, p),
                        _positional=True, **kw)
                    if best is None or _better_partition(r, best, objective):
                        best = r
                return best
    if objective == "maxmin" and not multi_chip:
        raise ValueError("objective='maxmin' optimizes the spatial "
                         "steady-state rate, which only exists for a "
                         "multi-chip TPUModel (chips > 1)")
    if cut_points is None:
        cands = list(range(L + 1))
    else:
        cp = sorted(set(int(c) for c in cut_points))
        if cp and not (1 <= cp[0] and cp[-1] <= L - 1):
            raise ValueError(f"cut_points must lie in 1..{L - 1}")
        cands = [0] + cp + [L]
    m = len(cands)                # candidate boundaries incl. 0 and L
    n_parts = min(n_parts, m - 1, hw.chips) if multi_chip \
        else min(n_parts, m - 1)
    if chip_budgets is not None:
        n_parts = min(n_parts, len(chip_budgets))
    n_parts = max(n_parts, 1)
    seg = SegmentTable(layers, hw, budget, batch, dse_iters, cache=cache)

    def stage_budget(p: int) -> float:
        """DSE budget of stage ``p`` (1-indexed): the uniform ``budget``, or
        the stage's resident chip on a heterogeneous slice."""
        return chip_budgets[p - 1] if chip_budgets is not None else budget

    def switch_cost(cut: int) -> float:
        """Cycles charged for the transition at cut position ``cut``."""
        if multi_chip:
            n_bytes = batch * boundary_activations(layers, cut) * ACT_BYTES
            return hw.ici_transfer_cycles(n_bytes)
        return reconfig_cycles

    def hop_rate(cut: int) -> float:
        """Samples/cycle one ICI hop sustains at cut position ``cut``."""
        cyc = hw.ici_transfer_cycles(boundary_activations(layers, cut)
                                     * ACT_BYTES)
        return 1.0 / cyc if cyc > 0 else float("inf")

    INF = float("inf")
    if objective == "sum":
        # T[p][b]: min cycles for layers[:cands[b]] as exactly p partitions
        # (+ their switches); the DP walks candidate boundaries only.
        T = [[INF] * m for _ in range(n_parts + 1)]
        T[0][0] = 0.0
        back = [[-1] * m for _ in range(n_parts + 1)]
        for p in range(1, n_parts + 1):
            # prefixes b < m-1 only feed deeper recursions; the last p level
            # needs the full-pipeline entry alone
            bs = range(p, m) if p < n_parts else (m - 1,)
            for b in bs:
                j = cands[b]
                for a in range(p - 1, b):
                    if T[p - 1][a] == INF:
                        continue
                    i = cands[a]
                    t = T[p - 1][a] + seg.time(i, j, stage_budget(p)) + \
                        (switch_cost(i) if i else 0.0)
                    if t < T[p][b]:
                        T[p][b], back[p][b] = t, a
        # positional hetero runs are pinned to exactly n_parts stages: the
        # kept-chip set is sized for that count, and smaller counts belong
        # to their own outer-loop iteration
        p_opts = (n_parts,) if _positional else range(1, n_parts + 1)
        best_p = min(p_opts, key=lambda p: T[p][m - 1])
        score = [T[p][m - 1] for p in range(n_parts + 1)]
    else:
        # R[p][b]: max achievable min-rate (stage rates and internal ICI
        # hops) for layers[:cands[b]] as exactly p partitions. min() is
        # associative, so the prefix decomposition is exact; +inf seeds the
        # empty prefix. First maximizer wins -> deterministic cuts.
        R = [[-INF] * m for _ in range(n_parts + 1)]
        R[0][0] = INF
        back = [[-1] * m for _ in range(n_parts + 1)]
        for p in range(1, n_parts + 1):
            bs = range(p, m) if p < n_parts else (m - 1,)
            for b in bs:
                j = cands[b]
                for a in range(p - 1, b):
                    if R[p - 1][a] == -INF:
                        continue
                    i = cands[a]
                    r = min(R[p - 1][a],
                            seg.throughput(i, j, stage_budget(p)))
                    if i:
                        r = min(r, hop_rate(i))
                    if r > R[p][b]:
                        R[p][b], back[p][b] = r, a
        # ties on the steady rate prefer the smaller amortized batch time;
        # positional hetero runs are pinned to exactly n_parts stages (see
        # the sum branch)
        p_opts = (n_parts,) if _positional else range(1, n_parts + 1)
        best_rate = max(R[p][m - 1] for p in p_opts)
        tied = [p for p in p_opts
                if R[p][m - 1] >= best_rate * (1 - 1e-12)]

        def _amortized(p: int) -> float:
            total, b = 0.0, m - 1
            for q in range(p, 0, -1):
                a = back[q][b]
                total += seg.time(cands[a], cands[b], stage_budget(q)) + \
                    (switch_cost(cands[a]) if cands[a] else 0.0)
                b = a
            return total
        best_p = min(tied, key=_amortized)
        score = None

    cuts: List[int] = []
    b = m - 1
    for p in range(best_p, 0, -1):
        a = back[p][b]
        if a > 0:
            cuts.append(cands[a])
        b = a
    cuts.reverse()
    bounds = [0] + cuts + [L]
    part_thr = [seg.throughput(a, b, stage_budget(s + 1))
                for s, (a, b) in enumerate(zip(bounds, bounds[1:]))]
    part_designs = [seg.designs(a, b, stage_budget(s + 1))
                    for s, (a, b) in enumerate(zip(bounds, bounds[1:]))]
    steady = min(part_thr) if part_thr else 0.0
    if multi_chip:
        for c in cuts:
            steady = min(steady, hop_rate(c))
    total = sum(seg.time(a, b, stage_budget(s + 1))
                for s, (a, b) in enumerate(zip(bounds, bounds[1:]))) + \
        sum(switch_cost(c) for c in cuts)
    if objective == "sum":
        assert abs(total - score[best_p]) <= 1e-9 * max(total, 1.0)
    return PartitionResult(cuts=cuts, batch=batch, time_per_batch=total,
                           throughput=batch / total if total > 0 else 0.0,
                           part_throughput=part_thr,
                           part_designs=part_designs,
                           steady_throughput=steady,
                           dse_calls=seg.dse_calls,
                           objective=objective,
                           chip_budgets=None if chip_budgets is None
                           else [stage_budget(s + 1)
                                 for s in range(len(bounds) - 1)])


def partition_pipeline_sa(layers: Sequence[LayerCost], hw: HardwareModel,
                          budget: float, *, n_parts: int, batch: int = 256,
                          reconfig_cycles: float = 5e7, seed: int = 0,
                          dse_iters: int = 300) -> PartitionResult:
    """Pre-DP SA-over-cuts implementation, retained as the comparison
    baseline (benchmarks/dse_bench.py, tests/test_partition_dp.py). Re-runs
    the segment DSE inside every annealing energy evaluation — the cost the
    memoized segment table removes. Uses the same switch accounting as
    ``partition_pipeline`` (P - 1 switches per processed batch) so the two
    optimize an identical objective over exactly ``n_parts`` partitions."""
    L = len(layers)
    n_parts = min(n_parts, L)

    def eval_cuts(cuts):
        total = 0.0
        prev = 0
        for c in list(cuts) + [L]:
            part = layers[prev:c]
            if not part:
                return float("inf")
            r = incremental_dse(part, hw, budget, max_iters=dse_iters)
            if r.throughput <= 0:
                return float("inf")
            total += batch / r.throughput
            prev = c
        total += reconfig_cycles * len(list(cuts))
        return total

    if n_parts <= 1:
        t = eval_cuts([])
        return PartitionResult([], batch, t, batch / t)

    init = [round(L * (i + 1) / n_parts) for i in range(n_parts - 1)]

    def neighbor(cuts, rng):
        c = list(cuts)
        i = rng.integers(len(c))
        lo = c[i - 1] + 1 if i else 1
        hi = c[i + 1] - 1 if i + 1 < len(c) else L - 1
        if hi <= lo:
            return c
        c[i] = int(np.clip(c[i] + rng.integers(-2, 3), lo, hi))
        return c

    best, best_e, _ = simulated_annealing(init, eval_cuts, neighbor,
                                          steps=60, seed=seed)
    return PartitionResult(list(best), batch, best_e, batch / best_e)
