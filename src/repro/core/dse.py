"""Accelerator Design-Space Exploration (§V-A of the paper).

Implements, verbatim in structure:
  1. performance modeling (Eq. 1–3, in ``core.perf_model``),
  2. resource-constrained rate balancing (Eq. 4–5),
  3. resource-constrained incrementing (start minimal; repeatedly grow the
     slowest layer, then re-balance, until the budget R is exhausted),
  4. partitioning & reconfiguration (SA over pipeline split points; on TPU
     "full reconfiguration" = switching the mesh program between partitions,
     amortized by batch size).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.annealing import simulated_annealing
from repro.core.perf_model import (DesignPoint, HardwareModel, LayerCost,
                                   LayerVectors, pipeline_throughput,
                                   t_cycles)


@dataclass
class DSEResult:
    designs: List[DesignPoint]
    throughput: float             # samples/cycle (Eq. 3)
    resource: float               # total resource units (DSPs / tile-lanes)
    throughput_per_res: float
    trace: List[Tuple[float, float]]  # (resource, throughput) per increment

    def images_per_s(self, hw: HardwareModel) -> float:
        return self.throughput * hw.freq


def _grow_options(l: LayerCost, d: DesignPoint, hw: HardwareModel):
    """Candidate increments for one layer: more MACs/SPE or more SPEs."""
    opts = []
    if d.macs_per_spe < hw.max_n(l):
        opts.append(replace(d, macs_per_spe=min(d.macs_per_spe * 2, hw.max_n(l))))
    if d.spe < hw.max_spe(l):
        opts.append(replace(d, spe=min(d.spe * 2, hw.max_spe(l))))
    return opts


def rate_balance_ref(layers: Sequence[LayerCost], designs: List[DesignPoint],
                     hw: HardwareModel, *, protect: Optional[set] = None,
                     strict: bool = False) -> List[DesignPoint]:
    """Reference (scalar, per-layer-loop) Eq. 4–5 implementation. Kept
    verbatim for equivalence testing against the vectorized ``rate_balance``;
    see that function for the semantics."""
    protect = protect or set()
    theta_r = pipeline_throughput(layers, designs, hw)
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    balanced: List[DesignPoint] = []
    for i, (l, d) in enumerate(zip(layers, designs)):
        if i in protect:
            balanced.append(d)
            continue
        best = d
        changed = True
        while changed:
            changed = False
            for cand in (replace(best, macs_per_spe=max(1, best.macs_per_spe // 2)),
                         replace(best, spe=max(1, best.spe // 2))):
                if (cand.spe, cand.macs_per_spe) == (best.spe, best.macs_per_spe):
                    continue
                if hw.layer_throughput(l, cand) >= lo:
                    best = cand
                    changed = True
                    break
        balanced.append(best)
    return balanced


# --------------------------------------------------------------------- #
# Vectorized engine (DESIGN.md §7): the design state is two small int
# vectors (spe, macs_per_spe) — designs only ever double/halve — operated
# on as flat arrays instead of per-layer dataclass lists.
# --------------------------------------------------------------------- #
def _design_arrays(designs: Sequence[DesignPoint]):
    spe = np.array([d.spe for d in designs], dtype=np.int64)
    n = np.array([d.macs_per_spe for d in designs], dtype=np.int64)
    return spe, n


def _designs_from(spe: np.ndarray, n: np.ndarray) -> List[DesignPoint]:
    return [DesignPoint(int(s), int(m)) for s, m in zip(spe, n)]


def _balance_arrays(hw: HardwareModel, lv: LayerVectors, spe: np.ndarray,
                    n: np.ndarray, protect: np.ndarray, strict: bool):
    """Vectorized Eq. 4–5 core. Each round, every unprotected layer takes its
    preferred feasible halving (macs_per_spe first, else spe — the reference
    candidate order) simultaneously; rounds repeat until no layer can shrink.
    Per-layer decisions are independent (theta_r is fixed at entry), so the
    simultaneous rounds replay each layer's reference shrink sequence exactly.
    """
    theta_r = float(hw.throughput_vec(lv, spe, n).min())
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    spe, n = spe.copy(), n.copy()
    free = ~protect
    while True:
        cand_n = np.maximum(1, n >> 1)
        ok_n = free & (cand_n != n) & \
            (hw.throughput_vec(lv, spe, cand_n) >= lo)
        cand_s = np.maximum(1, spe >> 1)
        ok_s = free & ~ok_n & (cand_s != spe) & \
            (hw.throughput_vec(lv, cand_s, n) >= lo)
        if not (ok_n.any() or ok_s.any()):
            return spe, n
        n = np.where(ok_n, cand_n, n)
        spe = np.where(ok_s, cand_s, spe)


def rate_balance(layers: Sequence[LayerCost], designs: List[DesignPoint],
                 hw: HardwareModel, *, protect: Optional[set] = None,
                 strict: bool = False) -> List[DesignPoint]:
    """Eq. 4–5: shrink every non-bottleneck layer to the smallest design whose
    modeled throughput still meets the pipeline's actual rate theta_r.

    ``strict=True`` is used *during* incrementing: a shrink must leave the
    layer's rate strictly above theta_r. With the literal (non-strict) Eq. 4
    rule, growing one of several bottleneck-tied layers gets undone by the
    next balancing pass (rate lands exactly on theta_r and is "still
    feasible"), deadlocking the greedy loop. Strict balancing keeps every
    layer within (theta_r, 2*theta_r] during growth; the final non-strict pass
    reclaims the leftover, which is the paper's Eq. 4 verbatim.
    ``protect`` exempts the just-grown layer.

    Vectorized; equivalent to ``rate_balance_ref`` design-for-design."""
    mask = np.zeros(len(designs), dtype=bool)
    for i in (protect or ()):
        mask[i] = True
    spe, n = _design_arrays(designs)
    spe, n = _balance_arrays(hw, hw.layer_vectors(layers), spe, n, mask,
                             strict)
    return _designs_from(spe, n)


def _run_incremental(lv: LayerVectors, hw: HardwareModel, budget: float,
                     max_iters: int):
    """Array-native §V-A.3 greedy loop; returns (spe, n, thr, res, trace).

    The state is two int vectors plus three maintained rate vectors: each
    layer's current rate (Eq. 2) and its rate after one macs_per_spe / one
    spe halving. Per iteration the engine does O(L) flat scans (argmin,
    shrink-feasibility) and re-derives rates only for the 1–2 layers that
    actually change, with the identical scalar expressions the reference
    evaluates — so results match ``incremental_dse_ref`` bit for bit while
    skipping its O(L * shrink-tries) dataclass churn and throughput
    recomputation.
    """
    L = len(lv)
    macs = lv.macs.tolist()
    m_dot = lv.m_dot.tolist()
    s_eff = lv.s_eff.tolist()
    max_n = lv.max_n.tolist()
    max_spe = lv.max_spe.tolist()
    unit = lv.res_unit.tolist()
    spe = [1] * L
    n = [1] * L
    # maintained per-layer rates: current (Eq. 2) and after one halving of
    # each coordinate — flat float lists; O(L) scans at Python-scalar cost
    # beat numpy-reduction dispatch for every realistic pipeline depth
    thr = [0.0] * L
    thr_nh = [0.0] * L
    thr_sh = [0.0] * L

    def thr_of(i: int, s: int, nn: int) -> float:
        if not macs[i]:
            return float("inf")
        t = t_cycles(s_eff[i], m_dot[i], nn)
        return s * m_dot[i] / (macs[i] * t)

    def sync(i: int) -> None:
        thr[i] = thr_of(i, spe[i], n[i])
        thr_nh[i] = thr_of(i, spe[i], max(1, n[i] // 2))
        thr_sh[i] = thr_of(i, max(1, spe[i] // 2), n[i])

    for i in range(L):
        sync(i)
    # resource totals are exact (integer DSPs / dyadic tile-lane fractions),
    # so incremental updates equal the reference's full re-summation
    res_total = float(sum(unit))

    def balance(lo: float, skip) -> List[Tuple[int, int, int]]:
        """One Eq. 4–5 pass against fixed ``lo``. ``skip`` is a protected
        layer index or per-layer bool list. Returns [(i, old_spe, old_n)] of
        changed layers. A layer shrinks at all iff its first halving is
        feasible, and each shrink chain is n-halvings then spe-halvings (rate
        is monotone in both coordinates, so the reference's retry-n-first
        loop reduces to exactly this), in scalar exact arithmetic."""
        nonlocal res_total
        changed = []
        skip_is_idx = isinstance(skip, int)
        for i in range(L):
            if (skip[i] if not skip_is_idx else i == skip):
                continue
            if not ((n[i] > 1 and thr_nh[i] >= lo) or
                    (spe[i] > 1 and thr_sh[i] >= lo)):
                continue
            s_i, n_i = spe[i], n[i]
            changed.append((i, s_i, n_i))
            while True:
                if n_i > 1 and thr_of(i, s_i, n_i // 2) >= lo:
                    n_i //= 2
                    continue
                if s_i > 1 and thr_of(i, s_i // 2, n_i) >= lo:
                    s_i //= 2
                    continue
                break
            res_total += (s_i * n_i - spe[i] * n[i]) * unit[i]
            spe[i], n[i] = s_i, n_i
            sync(i)
        return changed

    trace: List[Tuple[float, float]] = []
    for _ in range(max_iters):
        cur_thr = min(thr)
        slow = thr.index(cur_thr)
        trace.append((res_total, cur_thr))
        # candidate increments for the slowest layer (macs_per_spe doubling
        # first — the reference option order, which wins Δthr/Δres ties)
        cur_res = spe[slow] * n[slow] * unit[slow]
        best = None
        best_score = None
        if n[slow] < max_n[slow]:
            n2 = min(n[slow] * 2, max_n[slow])
            dres = spe[slow] * n2 * unit[slow] - cur_res
            best = (spe[slow], n2)
            best_score = (thr_of(slow, spe[slow], n2) - cur_thr) / \
                max(dres, 1e-9)
        if spe[slow] < max_spe[slow]:
            s2 = min(spe[slow] * 2, max_spe[slow])
            dres = s2 * n[slow] * unit[slow] - cur_res
            score = (thr_of(slow, s2, n[slow]) - cur_thr) / max(dres, 1e-9)
            if best is None or score > best_score:
                best, best_score = (s2, n[slow]), score
        if best is None:
            break
        # apply the growth, strict-balance everyone else, keep if affordable
        res_before = res_total
        old_slow = (slow, spe[slow], n[slow])
        res_total += (best[0] * best[1] - spe[slow] * n[slow]) * unit[slow]
        spe[slow], n[slow] = best
        sync(slow)
        changed = balance(min(thr) * (1 + 1e-9), skip=slow)
        if res_total > budget:
            for i, s_i, n_i in [old_slow] + changed:
                spe[i], n[i] = s_i, n_i
                sync(i)
            res_total = res_before
            break

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    theta_r = min(thr)
    hi = theta_r * (1 + 1e-9)
    balance(theta_r * (1 - 1e-12), skip=[r <= hi for r in thr])
    return (np.array(spe, dtype=np.int64), np.array(n, dtype=np.int64),
            min(thr), res_total, trace)


def incremental_dse(layers: Sequence[LayerCost], hw: HardwareModel,
                    budget: float, *, max_iters: int = 10000) -> DSEResult:
    """§V-A.3: start resource-minimal, grow the slowest layer, re-balance.

    Vectorized greedy loop — identical designs/throughput/resource/trace to
    ``incremental_dse_ref`` (property-tested), ~10–100x faster."""
    lv = hw.layer_vectors(layers)
    spe, n, thr, res, trace = _run_incremental(lv, hw, budget, max_iters)
    return DSEResult(designs=_designs_from(spe, n), throughput=thr,
                     resource=res, throughput_per_res=thr / max(res, 1e-9),
                     trace=trace)


def incremental_dse_ref(layers: Sequence[LayerCost], hw: HardwareModel,
                        budget: float, *, max_iters: int = 10000) -> DSEResult:
    """Reference scalar implementation of ``incremental_dse`` (pre-vectorized
    code, kept verbatim as the equivalence oracle and for ``dse_bench``)."""
    designs = [DesignPoint(1, 1) for _ in layers]
    trace: List[Tuple[float, float]] = []

    def total_res(ds):
        return sum(hw.layer_resource(l, d) for l, d in zip(layers, ds))

    for _ in range(max_iters):
        thr = pipeline_throughput(layers, designs, hw)
        res = total_res(designs)
        trace.append((res, thr))
        # slowest layer
        rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
        slow = int(np.argmin(rates))
        opts = _grow_options(layers[slow], designs[slow], hw)
        if not opts:
            break
        # pick the increment with best Δthroughput per Δresource
        def score(opt):
            dthr = hw.layer_throughput(layers[slow], opt) - rates[slow]
            dres = hw.layer_resource(layers[slow], opt) - \
                hw.layer_resource(layers[slow], designs[slow])
            return dthr / max(dres, 1e-9)
        opt = max(opts, key=score)
        cand = list(designs)
        cand[slow] = opt
        cand = rate_balance_ref(layers, cand, hw, protect={slow}, strict=True)
        if total_res(cand) > budget:
            break
        designs = cand

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
    bottleneck = {i for i, r in enumerate(rates) if r <= min(rates) * (1 + 1e-9)}
    designs = rate_balance_ref(layers, designs, hw, protect=bottleneck)
    thr = pipeline_throughput(layers, designs, hw)
    res = total_res(designs)
    return DSEResult(designs=designs, throughput=thr, resource=res,
                     throughput_per_res=thr / max(res, 1e-9), trace=trace)


# --------------------------------------------------------------------- #
# Partitioning & reconfiguration (§V-A.4)
# --------------------------------------------------------------------- #
@dataclass
class PartitionResult:
    cuts: List[int]               # split indices (exclusive prefix ends)
    batch: int
    time_per_batch: float         # cycles, incl. reconfiguration
    throughput: float             # samples/cycle amortized


def partition_pipeline(layers: Sequence[LayerCost], hw: HardwareModel,
                       budget: float, *, n_parts: int, batch: int = 256,
                       reconfig_cycles: float = 5e7, seed: int = 0,
                       dse_iters: int = 300) -> PartitionResult:
    """Fold the pipeline into ``n_parts`` sequential partitions, each run with
    the full budget (FPGA full reconfiguration / TPU program switch). SA over
    cut positions trades reconfiguration time vs per-partition throughput."""
    L = len(layers)
    n_parts = min(n_parts, L)

    def eval_cuts(cuts):
        total = 0.0
        prev = 0
        for c in list(cuts) + [L]:
            part = layers[prev:c]
            if not part:
                return float("inf")
            r = incremental_dse(part, hw, budget, max_iters=dse_iters)
            if r.throughput <= 0:
                return float("inf")
            total += batch / r.throughput
            prev = c
        total += reconfig_cycles * n_parts
        return total

    if n_parts <= 1:
        t = eval_cuts([])
        return PartitionResult([], batch, t, batch / t)

    init = [round(L * (i + 1) / n_parts) for i in range(n_parts - 1)]

    def neighbor(cuts, rng):
        c = list(cuts)
        i = rng.integers(len(c))
        lo = c[i - 1] + 1 if i else 1
        hi = c[i + 1] - 1 if i + 1 < len(c) else L - 1
        if hi <= lo:
            return c
        c[i] = int(np.clip(c[i] + rng.integers(-2, 3), lo, hi))
        return c

    best, best_e, _ = simulated_annealing(init, eval_cuts, neighbor,
                                          steps=60, seed=seed)
    return PartitionResult(list(best), batch, best_e, batch / best_e)
