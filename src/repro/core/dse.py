"""Accelerator Design-Space Exploration (§V-A of the paper).

Implements, verbatim in structure:
  1. performance modeling (Eq. 1–3, in ``core.perf_model``),
  2. resource-constrained rate balancing (Eq. 4–5),
  3. resource-constrained incrementing (start minimal; repeatedly grow the
     slowest layer, then re-balance, until the budget R is exhausted),
  4. partitioning & reconfiguration (SA over pipeline split points; on TPU
     "full reconfiguration" = switching the mesh program between partitions,
     amortized by batch size).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.annealing import simulated_annealing
from repro.core.perf_model import (DesignPoint, HardwareModel, LayerCost,
                                   pipeline_throughput, t_cycles)


@dataclass
class DSEResult:
    designs: List[DesignPoint]
    throughput: float             # samples/cycle (Eq. 3)
    resource: float               # total resource units (DSPs / tile-lanes)
    throughput_per_res: float
    trace: List[Tuple[float, float]]  # (resource, throughput) per increment

    def images_per_s(self, hw: HardwareModel) -> float:
        return self.throughput * hw.freq


def _grow_options(l: LayerCost, d: DesignPoint, hw: HardwareModel):
    """Candidate increments for one layer: more MACs/SPE or more SPEs."""
    opts = []
    if d.macs_per_spe < hw.max_n(l):
        opts.append(replace(d, macs_per_spe=min(d.macs_per_spe * 2, hw.max_n(l))))
    if d.spe < hw.max_spe(l):
        opts.append(replace(d, spe=min(d.spe * 2, hw.max_spe(l))))
    return opts


def rate_balance(layers: Sequence[LayerCost], designs: List[DesignPoint],
                 hw: HardwareModel, *, protect: Optional[set] = None,
                 strict: bool = False) -> List[DesignPoint]:
    """Eq. 4–5: shrink every non-bottleneck layer to the smallest design whose
    modeled throughput still meets the pipeline's actual rate theta_r.

    ``strict=True`` is used *during* incrementing: a shrink must leave the
    layer's rate strictly above theta_r. With the literal (non-strict) Eq. 4
    rule, growing one of several bottleneck-tied layers gets undone by the
    next balancing pass (rate lands exactly on theta_r and is "still
    feasible"), deadlocking the greedy loop. Strict balancing keeps every
    layer within (theta_r, 2*theta_r] during growth; the final non-strict pass
    reclaims the leftover, which is the paper's Eq. 4 verbatim.
    ``protect`` exempts the just-grown layer."""
    protect = protect or set()
    theta_r = pipeline_throughput(layers, designs, hw)
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    balanced: List[DesignPoint] = []
    for i, (l, d) in enumerate(zip(layers, designs)):
        if i in protect:
            balanced.append(d)
            continue
        best = d
        changed = True
        while changed:
            changed = False
            for cand in (replace(best, macs_per_spe=max(1, best.macs_per_spe // 2)),
                         replace(best, spe=max(1, best.spe // 2))):
                if (cand.spe, cand.macs_per_spe) == (best.spe, best.macs_per_spe):
                    continue
                if hw.layer_throughput(l, cand) >= lo:
                    best = cand
                    changed = True
                    break
        balanced.append(best)
    return balanced


def incremental_dse(layers: Sequence[LayerCost], hw: HardwareModel,
                    budget: float, *, max_iters: int = 10000) -> DSEResult:
    """§V-A.3: start resource-minimal, grow the slowest layer, re-balance."""
    designs = [DesignPoint(1, 1) for _ in layers]
    trace: List[Tuple[float, float]] = []

    def total_res(ds):
        return sum(hw.layer_resource(l, d) for l, d in zip(layers, ds))

    for _ in range(max_iters):
        thr = pipeline_throughput(layers, designs, hw)
        res = total_res(designs)
        trace.append((res, thr))
        # slowest layer
        rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
        slow = int(np.argmin(rates))
        opts = _grow_options(layers[slow], designs[slow], hw)
        if not opts:
            break
        # pick the increment with best Δthroughput per Δresource
        def score(opt):
            dthr = hw.layer_throughput(layers[slow], opt) - rates[slow]
            dres = hw.layer_resource(layers[slow], opt) - \
                hw.layer_resource(layers[slow], designs[slow])
            return dthr / max(dres, 1e-9)
        opt = max(opts, key=score)
        cand = list(designs)
        cand[slow] = opt
        cand = rate_balance(layers, cand, hw, protect={slow}, strict=True)
        if total_res(cand) > budget:
            break
        designs = cand

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
    bottleneck = {i for i, r in enumerate(rates) if r <= min(rates) * (1 + 1e-9)}
    designs = rate_balance(layers, designs, hw, protect=bottleneck)
    thr = pipeline_throughput(layers, designs, hw)
    res = total_res(designs)
    return DSEResult(designs=designs, throughput=thr, resource=res,
                     throughput_per_res=thr / max(res, 1e-9), trace=trace)


# --------------------------------------------------------------------- #
# Partitioning & reconfiguration (§V-A.4)
# --------------------------------------------------------------------- #
@dataclass
class PartitionResult:
    cuts: List[int]               # split indices (exclusive prefix ends)
    batch: int
    time_per_batch: float         # cycles, incl. reconfiguration
    throughput: float             # samples/cycle amortized


def partition_pipeline(layers: Sequence[LayerCost], hw: HardwareModel,
                       budget: float, *, n_parts: int, batch: int = 256,
                       reconfig_cycles: float = 5e7, seed: int = 0,
                       dse_iters: int = 300) -> PartitionResult:
    """Fold the pipeline into ``n_parts`` sequential partitions, each run with
    the full budget (FPGA full reconfiguration / TPU program switch). SA over
    cut positions trades reconfiguration time vs per-partition throughput."""
    L = len(layers)
    n_parts = min(n_parts, L)

    def eval_cuts(cuts):
        total = 0.0
        prev = 0
        for c in list(cuts) + [L]:
            part = layers[prev:c]
            if not part:
                return float("inf")
            r = incremental_dse(part, hw, budget, max_iters=dse_iters)
            if r.throughput <= 0:
                return float("inf")
            total += batch / r.throughput
            prev = c
        total += reconfig_cycles * n_parts
        return total

    if n_parts <= 1:
        t = eval_cuts([])
        return PartitionResult([], batch, t, batch / t)

    init = [round(L * (i + 1) / n_parts) for i in range(n_parts - 1)]

    def neighbor(cuts, rng):
        c = list(cuts)
        i = rng.integers(len(c))
        lo = c[i - 1] + 1 if i else 1
        hi = c[i + 1] - 1 if i + 1 < len(c) else L - 1
        if hi <= lo:
            return c
        c[i] = int(np.clip(c[i] + rng.integers(-2, 3), lo, hi))
        return c

    best, best_e, _ = simulated_annealing(init, eval_cuts, neighbor,
                                          steps=60, seed=seed)
    return PartitionResult(list(best), batch, best_e, batch / best_e)
