"""Accelerator Design-Space Exploration (§V-A of the paper).

Implements, verbatim in structure:
  1. performance modeling (Eq. 1–3, in ``core.perf_model``),
  2. resource-constrained rate balancing (Eq. 4–5),
  3. resource-constrained incrementing (start minimal; repeatedly grow the
     slowest layer, then re-balance, until the budget R is exhausted),
  4. partitioning & reconfiguration (exact DP over pipeline split points on
     a memoized per-segment Pareto-frontier table; on TPU "full
     reconfiguration" = switching the mesh program between partitions —
     or, multi-chip, the ICI boundary transfer — amortized by batch size;
     the paper's SA loop is retained as ``partition_pipeline_sa``).

Every search also returns its full (resource, throughput) ``ParetoFrontier``
with materializable per-point design state (DESIGN.md §10).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from itertools import repeat
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import _dse_ckernel
from repro.core.annealing import simulated_annealing
from repro.core.perf_model import (ACT_BYTES, DesignPoint, HardwareModel,
                                   LayerCost, LayerVectors, TPUModel,
                                   pipeline_throughput, t_cycles)
from repro.obs.trace import Counters

# engine-dispatch telemetry (DESIGN.md §18): which backend each DSE
# invocation actually ran — ``flat``/``grouped`` for the serial greedy,
# ``compiled``/``lockstep`` for the batched engines. Always-on plain dict
# increments (one per whole engine run, nothing per iteration); the search
# flight recorder snapshots deltas per trial.
ENGINE_DISPATCH = Counters("flat", "grouped", "compiled", "lockstep")


def engine_dispatch_stats() -> Dict[str, int]:
    """Cumulative engine-dispatch counts for this process."""
    return ENGINE_DISPATCH.as_dict()


def reset_engine_dispatch() -> None:
    for k in ENGINE_DISPATCH.as_dict():
        ENGINE_DISPATCH.set(k, 0)


@dataclass
class ParetoFrontier:
    """The non-dominated (resource, throughput) set traced by one DSE run.

    Both arrays are sorted strictly increasing, so the frontier *is* the
    budget -> throughput function of the search: ``best_under(b)`` is a
    binary search, and ``materialize(k)`` rebuilds the concrete per-layer
    ``DesignPoint`` list of point ``k`` from the captured design state —
    no re-run of the greedy loop. Interior points are as-searched states
    on the growth path (strict-balanced); the last point is the final
    Eq. 4-trimmed search result, so ``best_under(search_budget)`` equals
    the ``DSEResult`` exactly (DESIGN.md §10).
    """
    res: np.ndarray               # (K,) float64, strictly increasing
    thr: np.ndarray               # (K,) float64, strictly increasing
    spe: np.ndarray               # (K, L) int64 design-state snapshots
    n: np.ndarray                 # (K, L) int64

    def __len__(self) -> int:
        return len(self.res)

    def point(self, k: int) -> Tuple[float, float]:
        return float(self.res[k]), float(self.thr[k])

    def best_under(self, budget: float) -> Optional[int]:
        """Index of the max-throughput point with resource <= budget, or
        None when even the cheapest point exceeds the budget."""
        k = int(np.searchsorted(self.res, budget, side="right")) - 1
        return k if k >= 0 else None

    def select(self, score: Callable[[np.ndarray, np.ndarray], np.ndarray]) -> int:
        """Argmax of a vectorized ``score(res, thr)`` over frontier points —
        how Eq. 6 consumers pick a trade-off point without re-searching."""
        return int(np.argmax(score(self.res, self.thr)))

    def materialize(self, k: int) -> List[DesignPoint]:
        return _designs_from(self.spe[k], self.n[k])


def _frontier_keep(res_pts: List[float], thr_pts: List[float]) -> List[int]:
    """Skyline indices of the recorded search path. The last input point is
    the final (Eq. 4-trimmed) result: it is made the canonical representative
    of its throughput level (using the DSE's own 1e-9 bottleneck tolerance)
    so near-duplicate as-searched states never shadow it under
    ``best_under``. Vectorized; (res, -thr) ordering ties resolve to the
    earliest row both here (stable lexsort) and in the scalar original
    (stable list sort), so the kept set is unchanged."""
    r = np.asarray(res_pts, dtype=np.float64)
    t = np.asarray(thr_pts, dtype=np.float64)
    f_res, f_thr = r[-1], t[-1]
    lo, hi = f_thr * (1 - 1e-9), f_thr * (1 + 1e-9)
    m = ~(((t >= lo) & (t <= hi)) | ((r >= f_res) & (t <= hi)))
    m[-1] = True
    idx = np.nonzero(m)[0]
    idx = idx[np.lexsort((-t[idx], r[idx]))]
    tt = t[idx]
    run_max = np.maximum.accumulate(
        np.concatenate(([-np.inf], tt[:-1])))
    return idx[tt > run_max].tolist()


def _build_frontier(res_pts: List[float], thr_pts: List[float],
                    states: List[Tuple[List[int], List[int]]]) -> ParetoFrontier:
    keep = _frontier_keep(res_pts, thr_pts)
    L = len(states[-1][0])
    return ParetoFrontier(
        res=np.array([res_pts[i] for i in keep], dtype=np.float64),
        thr=np.array([thr_pts[i] for i in keep], dtype=np.float64),
        spe=np.array([states[i][0] for i in keep],
                     dtype=np.int64).reshape(len(keep), L),
        n=np.array([states[i][1] for i in keep],
                   dtype=np.int64).reshape(len(keep), L))


@dataclass
class DSEResult:
    designs: List[DesignPoint]
    throughput: float             # samples/cycle (Eq. 3)
    resource: float               # total resource units (DSPs / tile-lanes)
    throughput_per_res: float
    trace: List[Tuple[float, float]]  # (resource, throughput) per increment
    frontier: Optional[ParetoFrontier] = None
    theta_r: float = 0.0          # peak bottleneck rate before the final
    #                               Eq. 4 trim — the DSECache warm-start
    #                               certificate bound (DESIGN.md §12)

    def images_per_s(self, hw: HardwareModel) -> float:
        return self.throughput * hw.freq


def _grow_options(l: LayerCost, d: DesignPoint, hw: HardwareModel):
    """Candidate increments for one layer: more MACs/SPE or more SPEs."""
    opts = []
    if d.macs_per_spe < hw.max_n(l):
        opts.append(replace(d, macs_per_spe=min(d.macs_per_spe * 2, hw.max_n(l))))
    if d.spe < hw.max_spe(l):
        opts.append(replace(d, spe=min(d.spe * 2, hw.max_spe(l))))
    return opts


def rate_balance_ref(layers: Sequence[LayerCost], designs: List[DesignPoint],
                     hw: HardwareModel, *, protect: Optional[set] = None,
                     strict: bool = False) -> List[DesignPoint]:
    """Reference (scalar, per-layer-loop) Eq. 4–5 implementation. Kept
    verbatim for equivalence testing against the vectorized ``rate_balance``;
    see that function for the semantics."""
    protect = protect or set()
    theta_r = pipeline_throughput(layers, designs, hw)
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    balanced: List[DesignPoint] = []
    for i, (l, d) in enumerate(zip(layers, designs)):
        if i in protect:
            balanced.append(d)
            continue
        best = d
        changed = True
        while changed:
            changed = False
            for cand in (replace(best, macs_per_spe=max(1, best.macs_per_spe // 2)),
                         replace(best, spe=max(1, best.spe // 2))):
                if (cand.spe, cand.macs_per_spe) == (best.spe, best.macs_per_spe):
                    continue
                if hw.layer_throughput(l, cand) >= lo:
                    best = cand
                    changed = True
                    break
        balanced.append(best)
    return balanced


# --------------------------------------------------------------------- #
# Vectorized engine (DESIGN.md §7): the design state is two small int
# vectors (spe, macs_per_spe) — designs only ever double/halve — operated
# on as flat arrays instead of per-layer dataclass lists.
# --------------------------------------------------------------------- #
def _design_arrays(designs: Sequence[DesignPoint]):
    spe = np.array([d.spe for d in designs], dtype=np.int64)
    n = np.array([d.macs_per_spe for d in designs], dtype=np.int64)
    return spe, n


def _designs_from(spe: np.ndarray, n: np.ndarray) -> List[DesignPoint]:
    return [DesignPoint(int(s), int(m)) for s, m in zip(spe, n)]


def _balance_arrays(hw: HardwareModel, lv: LayerVectors, spe: np.ndarray,
                    n: np.ndarray, protect: np.ndarray, strict: bool):
    """Vectorized Eq. 4–5 core. Each round, every unprotected layer takes its
    preferred feasible halving (macs_per_spe first, else spe — the reference
    candidate order) simultaneously; rounds repeat until no layer can shrink.
    Per-layer decisions are independent (theta_r is fixed at entry), so the
    simultaneous rounds replay each layer's reference shrink sequence exactly.
    """
    theta_r = float(hw.throughput_vec(lv, spe, n).min())
    lo = theta_r * (1 + 1e-9) if strict else theta_r * (1 - 1e-12)
    spe, n = spe.copy(), n.copy()
    free = ~protect
    while True:
        cand_n = np.maximum(1, n >> 1)
        ok_n = free & (cand_n != n) & \
            (hw.throughput_vec(lv, spe, cand_n) >= lo)
        cand_s = np.maximum(1, spe >> 1)
        ok_s = free & ~ok_n & (cand_s != spe) & \
            (hw.throughput_vec(lv, cand_s, n) >= lo)
        if not (ok_n.any() or ok_s.any()):
            return spe, n
        n = np.where(ok_n, cand_n, n)
        spe = np.where(ok_s, cand_s, spe)


def rate_balance(layers: Sequence[LayerCost], designs: List[DesignPoint],
                 hw: HardwareModel, *, protect: Optional[set] = None,
                 strict: bool = False) -> List[DesignPoint]:
    """Eq. 4–5: shrink every non-bottleneck layer to the smallest design whose
    modeled throughput still meets the pipeline's actual rate theta_r.

    ``strict=True`` is used *during* incrementing: a shrink must leave the
    layer's rate strictly above theta_r. With the literal (non-strict) Eq. 4
    rule, growing one of several bottleneck-tied layers gets undone by the
    next balancing pass (rate lands exactly on theta_r and is "still
    feasible"), deadlocking the greedy loop. Strict balancing keeps every
    layer within (theta_r, 2*theta_r] during growth; the final non-strict pass
    reclaims the leftover, which is the paper's Eq. 4 verbatim.
    ``protect`` exempts the just-grown layer.

    Vectorized; equivalent to ``rate_balance_ref`` design-for-design."""
    mask = np.zeros(len(designs), dtype=bool)
    for i in (protect or ()):
        mask[i] = True
    spe, n = _design_arrays(designs)
    spe, n = _balance_arrays(hw, hw.layer_vectors(layers), spe, n, mask,
                             strict)
    return _designs_from(spe, n)


def _run_incremental(lv: LayerVectors, hw: HardwareModel, budget: float,
                     max_iters: int):
    """Array-native §V-A.3 greedy loop; returns (spe, n, thr, res, trace).

    The state is two int vectors plus three maintained rate vectors: each
    layer's current rate (Eq. 2) and its rate after one macs_per_spe / one
    spe halving. Per iteration the engine does O(L) flat scans (argmin,
    shrink-feasibility) and re-derives rates only for the 1–2 layers that
    actually change, with the identical scalar expressions the reference
    evaluates — so results match ``incremental_dse_ref`` bit for bit while
    skipping its O(L * shrink-tries) dataclass churn and throughput
    recomputation.
    """
    L = len(lv)
    macs = lv.macs.tolist()
    m_dot = lv.m_dot.tolist()
    s_eff = lv.s_eff.tolist()
    max_n = lv.max_n.tolist()
    max_spe = lv.max_spe.tolist()
    unit = lv.res_unit.tolist()
    # t_cycles numerator per layer: (1 - s_eff) * m_dot, times the pattern
    # decode-cost multiplier when one is set (DESIGN.md §16). With t_scale
    # None this is the exact sub-expression t_cycles evaluated before, so
    # the default path is bit-identical.
    if lv.t_scale is None:
        om = [(1.0 - s_eff[i]) * m_dot[i] for i in range(L)]
    else:
        tsc = lv.t_scale.tolist()
        om = [(1.0 - s_eff[i]) * m_dot[i] * tsc[i] for i in range(L)]
    spe = [1] * L
    n = [1] * L
    # maintained per-layer rates: current (Eq. 2) and after one halving of
    # each coordinate — flat float lists; O(L) scans at Python-scalar cost
    # beat numpy-reduction dispatch for every realistic pipeline depth
    thr = [0.0] * L
    thr_nh = [0.0] * L
    thr_sh = [0.0] * L

    ceil = math.ceil

    def thr_of(i: int, s: int, nn: int) -> float:
        if not macs[i]:
            return float("inf")
        t = max(1, ceil(om[i] / max(nn, 1)))
        return s * m_dot[i] / (macs[i] * t)

    def sync(i: int) -> None:
        thr[i] = thr_of(i, spe[i], n[i])
        thr_nh[i] = thr_of(i, spe[i], max(1, n[i] // 2))
        thr_sh[i] = thr_of(i, max(1, spe[i] // 2), n[i])

    for i in range(L):
        sync(i)
    # resource totals are exact (integer DSPs / dyadic tile-lane fractions),
    # so incremental updates equal the reference's full re-summation
    res_total = float(sum(unit))

    def balance(lo: float, skip) -> List[Tuple[int, int, int]]:
        """One Eq. 4–5 pass against fixed ``lo``. ``skip`` is a protected
        layer index or per-layer bool list. Returns [(i, old_spe, old_n)] of
        changed layers. A layer shrinks at all iff its first halving is
        feasible, and each shrink chain is n-halvings then spe-halvings (rate
        is monotone in both coordinates, so the reference's retry-n-first
        loop reduces to exactly this), in scalar exact arithmetic."""
        nonlocal res_total
        changed = []
        skip_is_idx = isinstance(skip, int)
        for i in range(L):
            if (skip[i] if not skip_is_idx else i == skip):
                continue
            if not ((n[i] > 1 and thr_nh[i] >= lo) or
                    (spe[i] > 1 and thr_sh[i] >= lo)):
                continue
            s_i, n_i = spe[i], n[i]
            changed.append((i, s_i, n_i))
            while True:
                if n_i > 1 and thr_of(i, s_i, n_i // 2) >= lo:
                    n_i //= 2
                    continue
                if s_i > 1 and thr_of(i, s_i // 2, n_i) >= lo:
                    s_i //= 2
                    continue
                break
            res_total += (s_i * n_i - spe[i] * n[i]) * unit[i]
            spe[i], n[i] = s_i, n_i
            sync(i)
        return changed

    trace: List[Tuple[float, float]] = []
    # design-state snapshot per trace row: any frontier point can later be
    # materialized into concrete DesignPoints without re-running the search
    states: List[Tuple[List[int], List[int]]] = []
    for _ in range(max_iters):
        cur_thr = min(thr)
        slow = thr.index(cur_thr)
        trace.append((res_total, cur_thr))
        states.append((spe.copy(), n.copy()))
        # candidate increments for the slowest layer (macs_per_spe doubling
        # first — the reference option order, which wins Δthr/Δres ties)
        cur_res = spe[slow] * n[slow] * unit[slow]
        best = None
        best_score = None
        if n[slow] < max_n[slow]:
            n2 = min(n[slow] * 2, max_n[slow])
            dres = spe[slow] * n2 * unit[slow] - cur_res
            best = (spe[slow], n2)
            best_score = (thr_of(slow, spe[slow], n2) - cur_thr) / \
                max(dres, 1e-9)
        if spe[slow] < max_spe[slow]:
            s2 = min(spe[slow] * 2, max_spe[slow])
            dres = s2 * n[slow] * unit[slow] - cur_res
            score = (thr_of(slow, s2, n[slow]) - cur_thr) / max(dres, 1e-9)
            if best is None or score > best_score:
                best, best_score = (s2, n[slow]), score
        if best is None:
            break
        # apply the growth, strict-balance everyone else, keep if affordable
        res_before = res_total
        old_slow = (slow, spe[slow], n[slow])
        res_total += (best[0] * best[1] - spe[slow] * n[slow]) * unit[slow]
        spe[slow], n[slow] = best
        sync(slow)
        changed = balance(min(thr) * (1 + 1e-9), skip=slow)
        if res_total > budget:
            for i, s_i, n_i in [old_slow] + changed:
                spe[i], n[i] = s_i, n_i
                sync(i)
            res_total = res_before
            break

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    theta_r = min(thr)
    hi = theta_r * (1 + 1e-9)
    balance(theta_r * (1 - 1e-12), skip=[r <= hi for r in thr])
    f_thr = min(thr)
    states.append((spe.copy(), n.copy()))
    frontier = _build_frontier([r for r, _ in trace] + [res_total],
                               [t for _, t in trace] + [f_thr], states)
    return (np.array(spe, dtype=np.int64), np.array(n, dtype=np.int64),
            f_thr, res_total, trace, frontier, theta_r)


def _layer_classes(lv: LayerVectors):
    """Partition layers into dynamics classes: two layers behave bit-
    identically inside the greedy iff their (macs, m_dot, s_eff, max_n,
    max_spe, res_unit, t_scale) tuples are equal — the rate function and
    resource accounting read nothing else. Returns (C, pos) with ``pos[c]`` the
    ascending member positions of class ``c`` (first-appearance order).
    One ``tolist`` per column then a flat dict loop — per-element numpy
    indexing is the thing to avoid here, not the Python loop."""
    tsc = [1.0] * len(lv) if lv.t_scale is None else lv.t_scale.tolist()
    cols = zip(lv.macs.tolist(), lv.m_dot.tolist(), lv.s_eff.tolist(),
               lv.max_n.tolist(), lv.max_spe.tolist(), lv.res_unit.tolist(),
               tsc)
    seen: Dict[tuple, int] = {}
    pos: List[List[int]] = []
    for i, key in enumerate(cols):
        c = seen.setdefault(key, len(pos))
        if c == len(pos):
            pos.append([])
        pos[c].append(i)
    return len(pos), pos


def _run_incremental_grouped(lv: LayerVectors, hw: HardwareModel,
                             budget: float, max_iters: int,
                             classes=None):
    """Class-grouped §V-A.3 greedy: bit-identical to ``_run_incremental``
    but O(G) per iteration instead of O(L), where G is the number of live
    (class, design-state) groups — deep LM stacks repeat the same ~10 matmul
    shapes across blocks, so G stays near the class count while L is in the
    hundreds (DESIGN.md §12).

    Exactness argument: the greedy reads a layer only through its class
    constants and design state, ties on the rate argmin break by lowest
    layer position (``thr.index``), and within a class the min-rate group's
    copies share one state so the winner is the group's first position.
    Copies therefore split off a group one position at a time in ascending
    order, keeping every group a contiguous position run; balance shrinks
    map whole groups identically, and ``res_total`` is accumulated over
    changed copies in ascending position order — the flat engine's float
    summation order, term for term."""
    L = len(lv)
    C, pos = classes if classes is not None else _layer_classes(lv)
    macs = [int(lv.macs[pos[c][0]]) for c in range(C)]
    m_dot = [int(lv.m_dot[pos[c][0]]) for c in range(C)]
    s_eff = [float(lv.s_eff[pos[c][0]]) for c in range(C)]
    max_n = [int(lv.max_n[pos[c][0]]) for c in range(C)]
    max_spe = [int(lv.max_spe[pos[c][0]]) for c in range(C)]
    unit = [float(lv.res_unit[pos[c][0]]) for c in range(C)]
    # per-class t_cycles numerator, pattern-scaled exactly like the flat
    # engine (same float op order, so grouped == flat stays bit-exact)
    if lv.t_scale is None:
        om = [(1.0 - s_eff[c]) * m_dot[c] for c in range(C)]
    else:
        om = [(1.0 - s_eff[c]) * m_dot[c] * float(lv.t_scale[pos[c][0]])
              for c in range(C)]

    ceil = math.ceil

    def thr_of(c: int, s: int, nn: int) -> float:
        if not macs[c]:
            return float("inf")
        t = max(1, ceil(om[c] / max(nn, 1)))
        return s * m_dot[c] / (macs[c] * t)

    # groups: per class, ascending-start list of
    # [start, cnt, s, n, rate, rate_nh, rate_sh]; positions of a group are
    # pos[c][start:start+cnt]. rate_nh/rate_sh are the rates after one
    # n-/spe-halving — maintained so balance entry checks are list reads,
    # the flat engine's thr_nh/thr_sh trick at group granularity.
    def _group(c: int, start: int, cnt: int, s: int, nn: int) -> List:
        return [start, cnt, s, nn, thr_of(c, s, nn),
                thr_of(c, s, max(1, nn // 2)), thr_of(c, max(1, s // 2), nn)]

    cgroups: List[List[List]] = [[_group(c, 0, len(pos[c]), 1, 1)]
                                 for c in range(C)]
    # flat per-layer design mirror, kept in sync with the groups; state
    # history is a per-row mutation log (``muts``), so a trace row costs
    # O(changes) instead of O(L) — wave rows change exactly one layer
    spe_l = [1] * L
    n_l = [1] * L
    # exact flat-engine float: sum(res_unit) in ascending position order
    res_total = float(sum(lv.res_unit.tolist()))

    def scan_min():
        """(min rate, argmin class, argmin group, strict second) in one
        pass; rate ties break by lowest member position — exactly the flat
        engine's ``thr.index(min(thr))``. ``second`` is the min over groups
        other than the argmin group (== cur on a tie)."""
        cur = second = math.inf
        best_c = best_g = None
        best_pos = L
        for c in range(C):
            for g in cgroups[c]:
                r = g[4]
                if r < cur:
                    second = cur
                    cur, best_c, best_g = r, c, g
                    best_pos = pos[c][g[0]]
                elif r == cur:
                    second = cur
                    p = pos[c][g[0]]
                    if p < best_pos:
                        best_c, best_g, best_pos = c, g, p
                elif r < second:
                    second = r
        return cur, best_c, best_g, second

    def compact(c: int) -> None:
        gs = cgroups[c]
        out = [gs[0]]
        for g in gs[1:]:
            p = out[-1]
            if p[2] == g[2] and p[3] == g[3]:
                p[1] += g[1]
            else:
                out.append(g)
        cgroups[c] = out

    # lazy per-row undo log: class -> its group list at row start; a budget
    # revert restores exactly the touched classes
    iter_log: Dict[int, List[List]] = {}

    def touch(c: int) -> None:
        if c not in iter_log:
            iter_log[c] = [list(g) for g in cgroups[c]]

    trace: List[Tuple[float, float]] = []
    muts: List[List[Tuple[int, int, int]]] = []   # per trace row: (p, s, n)
    undo: List[Tuple[int, int, int]] = []         # current row (p, s, n) old

    def balance(lo: float, skip) -> None:
        """One Eq. 4–5 pass against fixed ``lo``. ``skip`` is a group object
        or a set of id(group)s. Shrink chains are per-group (all copies of a
        group share the decision); the res_total deltas are then applied in
        ascending copy-position order, replaying the flat engine's float
        accumulation exactly."""
        nonlocal res_total
        updates: List[Tuple[int, float]] = []
        touched = []
        skip_set = skip if isinstance(skip, set) else None
        row = muts[-1]
        for c in range(C):
            for g in cgroups[c]:
                if g is skip or (skip_set and id(g) in skip_set):
                    continue
                s, nn = g[2], g[3]
                if not ((nn > 1 and g[5] >= lo) or (s > 1 and g[6] >= lo)):
                    continue
                touch(c)
                s_i, n_i = s, nn
                while True:
                    if n_i > 1 and thr_of(c, s_i, n_i // 2) >= lo:
                        n_i //= 2
                        continue
                    if s_i > 1 and thr_of(c, s_i // 2, n_i) >= lo:
                        s_i //= 2
                        continue
                    break
                delta = (s_i * n_i - s * nn) * unit[c]
                for p in pos[c][g[0]:g[0] + g[1]]:
                    updates.append((p, delta))
                    undo.append((p, spe_l[p], n_l[p]))
                    row.append((p, s_i, n_i))
                    spe_l[p] = s_i
                    n_l[p] = n_i
                g[2:] = _group(c, g[0], g[1], s_i, n_i)[2:]
                touched.append(c)
        updates.sort()
        for _, d in updates:
            res_total += d
        for c in set(touched):
            compact(c)

    it = 0
    broke = False
    while it < max_iters and not broke:
        cur_thr, slow_c, slow_g, second = scan_min()
        s, nn = slow_g[2], slow_g[3]
        cur_res = s * nn * unit[slow_c]
        best = None
        best_score = None
        if nn < max_n[slow_c]:
            n2 = min(nn * 2, max_n[slow_c])
            dres = s * n2 * unit[slow_c] - cur_res
            best = (s, n2)
            best_score = (thr_of(slow_c, s, n2) - cur_thr) / max(dres, 1e-9)
        if s < max_spe[slow_c]:
            s2 = min(s * 2, max_spe[slow_c])
            dres = s2 * nn * unit[slow_c] - cur_res
            score = (thr_of(slow_c, s2, nn) - cur_thr) / max(dres, 1e-9)
            if best is None or score > best_score:
                best = (s2, nn)
        if best is None:
            trace.append((res_total, cur_thr))
            muts.append([])
            break
        grown_rate = thr_of(slow_c, best[0], best[1])
        dgrow = (best[0] * best[1] - s * nn) * unit[slow_c]
        # wave width: while >1 copies lag at the strict minimum and the
        # grown design strictly improves, every next flat iteration grows
        # the next lagging copy with the identical decision, the pipeline
        # minimum stays cur_thr, and the balance pass is a no-op after the
        # first (same lo, feasibility unchanged) — batch those iterations.
        # The no-op argument needs the grown design itself to be
        # unshrinkable at that lo (a ceil-plateau spe-doubling can leave
        # its n free to halve, which the flat engine's next pass takes)
        wave = 0
        if slow_g[1] > 1 and grown_rate > cur_thr and cur_thr < second:
            lo_wave = cur_thr * (1 + 1e-9)
            g_nh = thr_of(slow_c, best[0], max(1, best[1] // 2))
            g_sh = thr_of(slow_c, max(1, best[0] // 2), best[1])
            if not ((best[1] > 1 and g_nh >= lo_wave) or
                    (best[0] > 1 and g_sh >= lo_wave)):
                # batch up to cnt-2 follow-up copies: growing the LAST
                # lagging copy moves the pipeline minimum, so its balance
                # pass runs at a different lo — leave it to a normal step
                wave = min(slow_g[1] - 2, max_iters - it - 1)
        iter_log.clear()
        undo.clear()
        res_before = res_total
        touch(slow_c)
        trace.append((res_total, cur_thr))
        muts.append([])
        # split the first (lowest-position) copy off the argmin group and
        # grow it — the flat engine grows exactly that layer index
        if slow_g[1] == 1:
            grown = slow_g
        else:
            grown = list(slow_g)
            grown[1] = 1
            slow_g[0] += 1
            slow_g[1] -= 1
            gi = cgroups[slow_c].index(slow_g)
            cgroups[slow_c].insert(gi, grown)
        res_total += dgrow
        grown[2:] = _group(slow_c, grown[0], 1, best[0], best[1])[2:]
        p_grown = pos[slow_c][grown[0]]
        undo.append((p_grown, spe_l[p_grown], n_l[p_grown]))
        muts[-1].append((p_grown, best[0], best[1]))
        spe_l[p_grown], n_l[p_grown] = best
        # min(thr) after the growth, without a rescan: growth only raised
        # the grown copy's rate; the lagging remainder (if any) still sits
        # at cur_thr, everything else at >= second (exact same floats the
        # flat engine's fresh min() sees)
        if grown is slow_g:
            m_after = second if second < grown_rate else grown_rate
        else:
            m_after = cur_thr
        balance(m_after * (1 + 1e-9), skip=grown)
        compact(slow_c)
        it += 1
        if res_total > budget:
            for c, gs in iter_log.items():
                cgroups[c] = gs
            for p, s_o, n_o in reversed(undo):
                spe_l[p], n_l[p] = s_o, n_o
            muts[-1] = []
            res_total = res_before
            break
        if not wave:
            continue
        # batched wave steps (flat iterations 2..wave+1 of this run).
        # compact() may have merged the grown singleton into an adjacent
        # same-state group (a previous interrupted wave's accumulator), so
        # re-locate the LIVE group holding the grown copy before mutating
        start0 = grown[0]
        acc = None
        for g in cgroups[slow_c]:
            if g[0] <= start0 < g[0] + g[1]:
                acc = g
                break
        for _ in range(wave):
            trace.append((res_total, cur_thr))
            muts.append([])
            res_wave = res_total
            p = pos[slow_c][slow_g[0]]
            slow_g[0] += 1
            slow_g[1] -= 1
            acc[1] += 1
            res_total += dgrow
            muts[-1].append((p, best[0], best[1]))
            spe_l[p], n_l[p] = best
            it += 1
            if res_total > budget:
                slow_g[0] -= 1
                slow_g[1] += 1
                acc[1] -= 1
                spe_l[p], n_l[p] = s, nn
                muts[-1] = []
                res_total = res_wave
                broke = True
                break

    theta_r = scan_min()[0]
    hi = theta_r * (1 + 1e-9)
    protected = {id(g) for gs in cgroups for g in gs if g[4] <= hi}
    muts.append([])           # final-pass mutations, applied after row T-1
    undo.clear()
    balance(theta_r * (1 - 1e-12), skip=protected)
    f_thr = scan_min()[0]

    res_pts = [r for r, _ in trace] + [res_total]
    thr_pts = [t for _, t in trace] + [f_thr]
    frontier = _frontier_from_muts(res_pts, thr_pts, muts, L)
    return (np.array(spe_l, dtype=np.int64), np.array(n_l, dtype=np.int64),
            f_thr, res_total, trace, frontier, theta_r)


def _frontier_from_muts(res_pts: List[float], thr_pts: List[float],
                        muts: List[List[Tuple[int, int, int]]],
                        L: int) -> ParetoFrontier:
    """Frontier assembly from a per-row mutation log: replay the log once,
    materializing the kept rows (row j's state = initial + muts[0..j-1]);
    the final point is the post-trim state, one replay step past the last
    row (``muts[-1]`` is the final Eq. 4 pass). Shared by the grouped and
    proposal-batched engines, which keep O(changes) mutation rows instead
    of the flat engine's O(L) per-row snapshots. A row is either a list of
    (p, s, n) mutations or — the batched engine's wave rows, which mutate
    exactly one layer — a bare (p, s, n) tuple."""
    keep = _frontier_keep(res_pts, thr_pts)
    keep_set = set(keep)
    spe_r = np.ones(L, dtype=np.int64)
    n_r = np.ones(L, dtype=np.int64)
    kept: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    last = len(res_pts) - 1
    for j in range(last):               # trace rows: state BEFORE muts[j]
        if j in keep_set:
            kept[j] = (spe_r.copy(), n_r.copy())
        row = muts[j]
        if type(row) is tuple:
            spe_r[row[0]] = row[1]
            n_r[row[0]] = row[2]
        else:
            for p, s_m, n_m in row:
                spe_r[p] = s_m
                n_r[p] = n_m
    row = muts[-1]                      # final Eq. 4 pass
    if type(row) is tuple:
        spe_r[row[0]] = row[1]
        n_r[row[0]] = row[2]
    else:
        for p, s_m, n_m in row:
            spe_r[p] = s_m
            n_r[p] = n_m
    kept[last] = (spe_r, n_r)
    return ParetoFrontier(
        res=np.array([res_pts[i] for i in keep], dtype=np.float64),
        thr=np.array([thr_pts[i] for i in keep], dtype=np.float64),
        spe=np.stack([kept[i][0] for i in keep]),
        n=np.stack([kept[i][1] for i in keep]))


def _run_dse(lv: LayerVectors, hw: HardwareModel, budget: float,
             max_iters: int, engine: str = "auto"):
    """Engine dispatch: ``grouped`` when enough layers share a dynamics
    class to pay for the group bookkeeping, ``flat`` otherwise. Both are
    bit-exact (property-tested), so ``auto`` is a pure perf choice."""
    classes = None
    if engine == "auto":
        classes = _layer_classes(lv)
        engine = "grouped" if len(lv) >= 16 and 2 * classes[0] <= len(lv) \
            else "flat"
    if engine == "grouped":
        ENGINE_DISPATCH.inc("grouped")
        return _run_incremental_grouped(lv, hw, budget, max_iters,
                                        classes=classes)
    if engine != "flat":
        raise ValueError(f"unknown engine {engine!r}")
    ENGINE_DISPATCH.inc("flat")
    return _run_incremental(lv, hw, budget, max_iters)


# --------------------------------------------------------------------- #
# Proposal-batched engine (DESIGN.md §15): one array program advances all
# k proposals of a TPE wave at once, bit-exact per proposal.
# --------------------------------------------------------------------- #
def _run_incremental_batch(lv: LayerVectors, hw: HardwareModel,
                           budget: float, s_eff_batch: np.ndarray,
                           max_iters: int):
    """Proposal-batched §V-A.3 greedy: B independent flat-engine runs over
    one shared workload template, advanced in lockstep on (B, L) arrays —
    per round, every still-active proposal takes its next real growth step
    in one array program (argmin, option scoring, strict balance), and
    proposals whose run has converged (no growth option / budget break /
    max_iters) are masked out. Wave runs — the grouped engine's batching of
    identical lagging-copy growths — collapse per proposal into O(wave)
    Python bookkeeping between rounds, so a kind-tied LM stack costs
    ~#distinct-growth-decisions rounds, not ~max_iters (DESIGN.md §15).

    Bit-exactness per proposal vs ``_run_incremental`` rests on three
    facts. (1) Proposals never interact: every array op is elementwise per
    proposal row, with float semantics identical to the flat engine's
    scalar expressions (same operation order; products < 2**53, the
    ``throughput_vec`` invariant). (2) ``res_total`` float accumulation
    replays the flat engine's ascending-layer order: balance deltas are
    applied column-by-column in ascending layer order and adding the 0.0
    of an untouched (proposal, layer) cell is an exact identity. (3) Wave
    runs generalize the grouped engine's argument to the whole tied set:
    the flat engine's next argmins are exactly the ascending tied
    positions, each growth applies that copy's own class decision, and
    while every grown copy strictly improves and is unshrinkable at
    ``lo = cur*(1+1e-9)`` the interleaved balance passes are no-ops — so
    the prefix of the tied set satisfying the per-copy conditions (minus a
    last copy, whose growth moves the pipeline minimum) advances in one
    bookkeeping sweep instead of one round each (DESIGN.md §15).

    Returns a list of B (spe, n, f_thr, res, trace, frontier, theta_r)
    tuples, each bit-identical to the serial engines' output.
    """
    S = np.ascontiguousarray(s_eff_batch, dtype=np.float64)
    B, L = S.shape
    macs = lv.macs
    m_dot = lv.m_dot
    max_n = lv.max_n
    max_spe = lv.max_spe
    unit = lv.res_unit
    nz = macs > 0
    has_zero = not bool(nz.all())
    # (1 - s_eff) * m_dot, the t_cycles numerator — scalar op order kept;
    # pattern decode costs multiply afterwards exactly like the serial
    # engines' per-layer ``* t_scale`` (DESIGN.md §16)
    omsm = (1.0 - S) * m_dot
    if lv.t_scale is not None:
        omsm = omsm * lv.t_scale

    # design-state n is always >= 1 (floors at 1, candidates are clipped),
    # so the scalar engine's max(nn, 1) divisor guard is an identity here
    def rates_pre(om, md, mc, nzm, s_a, n_a):
        """Eq. 1-2 on pre-gathered constants — float-for-float the flat
        engine's ``thr_of`` (``throughput_vec`` invariant)."""
        t = np.maximum(1.0, np.ceil(om / n_a))
        r = (s_a * md) / (mc * t)
        return np.where(nzm, r, np.inf) if has_zero else r

    def rates(spe_a, n_a):
        """Eq. 1-2 on full (B, L) state arrays."""
        return rates_pre(omsm, m_dot, macs, nz, spe_a, n_a)

    spe = np.ones((B, L), dtype=np.int64)
    n = np.ones((B, L), dtype=np.int64)
    # exact flat-engine float: sum(res_unit) in ascending position order
    res0 = 0.0
    for u in unit.tolist():
        res0 += u
    res = np.full(B, res0, dtype=np.float64)
    it = np.zeros(B, dtype=np.int64)
    active = np.ones(B, dtype=bool)
    trace: List[List[Tuple[float, float]]] = [[] for _ in range(B)]
    muts: List[list] = [[] for _ in range(B)]
    ar = np.arange(B)

    # maintained rate views of the design state — thr == rates(spe, n) and
    # r_nh/r_sh are the one-halving rates (the flat engine's thr_nh/thr_sh
    # trick at (B, L)); refreshed at exactly the cells whose (spe, n)
    # changed, so steady-state rounds do O(changed) rate math, not O(B*L)
    thr = rates(spe, n)
    r_nh = thr.copy()               # n == 1: halving is the identity
    r_sh = thr.copy()               # spe == 1

    def refresh(bi, li):
        """Recompute the maintained rates at the given gathered cells."""
        s_g = spe[bi, li]
        n_g = n[bi, li]
        om = omsm[bi, li]
        md = m_dot[li]
        mc = macs[li]
        nzm = nz[li]
        thr[bi, li] = rates_pre(om, md, mc, nzm, s_g, n_g)
        r_nh[bi, li] = rates_pre(om, md, mc, nzm, s_g,
                                 np.maximum(1, n_g >> 1))
        r_sh[bi, li] = rates_pre(om, md, mc, nzm,
                                 np.maximum(1, s_g >> 1), n_g)

    def balance(lo, mask, skip_rows=None, skip_cols=None, protect=None):
        """One vectorized Eq. 4-5 pass at per-proposal fixed ``lo`` over
        the proposals in ``mask``. ``skip_rows``/``skip_cols`` protect one
        (proposal, layer) cell each (the just-grown layer); ``protect`` is
        a (B, L) bool mask (the final pass's bottleneck set). Entry reads
        the maintained halving rates; the shrink chains then run on the
        gathered entered cells only, each cell taking the flat engine's
        preferred feasible halving (n first) per step. Mutation rows are
        appended and res deltas accumulated per proposal in ascending
        layer order — the flat engine's float summation, term for term.
        Returns the changed cells' (bi, li, prev_spe, prev_n) so a budget
        revert can restore and re-``refresh`` exactly those cells."""
        lo2 = lo[:, None]
        ent = mask[:, None] & (((n > 1) & (r_nh >= lo2)) |
                               ((spe > 1) & (r_sh >= lo2)))
        if protect is not None:
            ent &= ~protect
        if skip_rows is not None:
            ent[skip_rows, skip_cols] = False
        if not ent.any():
            return None
        bi, li = np.nonzero(ent)        # row-major: ascending li per row
        s_g = spe[bi, li]
        n_g = n[bi, li]
        ps = s_g.copy()
        pn = n_g.copy()
        om = omsm[bi, li]
        md = m_dot[li]
        mc = macs[li]
        nzm = nz[li]
        lo_g = lo[bi]
        while True:
            cn = np.maximum(1, n_g >> 1)
            ok_n = (cn != n_g) & \
                (rates_pre(om, md, mc, nzm, s_g, cn) >= lo_g)
            cs = np.maximum(1, s_g >> 1)
            ok_s = ~ok_n & (cs != s_g) & \
                (rates_pre(om, md, mc, nzm, cs, n_g) >= lo_g)
            if not (ok_n.any() or ok_s.any()):
                break
            n_g[ok_n] = cn[ok_n]
            s_g[ok_s] = cs[ok_s]
        spe[bi, li] = s_g
        n[bi, li] = n_g
        refresh(bi, li)
        delta = ((s_g * n_g - ps * pn) * unit[li]).tolist()
        li_l = li.tolist()
        s_l = s_g.tolist()
        n_l = n_g.tolist()
        starts = np.searchsorted(bi, ar)
        ends = np.searchsorted(bi, ar, side="right")
        for b in np.unique(bi).tolist():
            r = float(res[b])
            row = muts[b][-1]
            for j in range(int(starts[b]), int(ends[b])):
                r += delta[j]
                row.append((li_l[j], s_l[j], n_l[j]))
            res[b] = r
        return bi, li, ps, pn

    while active.any():
        cur = thr.min(axis=1)
        slow = thr.argmin(axis=1)       # first minimum — thr.index(min)
        sl_s = spe[ar, slow]
        sl_n = n[ar, slow]
        sl_unit = unit[slow]
        sl_maxn = max_n[slow]
        sl_maxs = max_spe[slow]
        om_s = omsm[ar, slow]
        md_s = m_dot[slow]
        mc_s = macs[slow]
        nz_s = nz[slow]
        cur_res = sl_s * sl_n * sl_unit
        # candidate increments (macs_per_spe doubling first — wins ties)
        have_n = sl_n < sl_maxn
        n2 = np.minimum(sl_n * 2, sl_maxn)
        dres_n = sl_s * n2 * sl_unit - cur_res
        score_n = (rates_pre(om_s, md_s, mc_s, nz_s, sl_s, n2) - cur) / \
            np.maximum(dres_n, 1e-9)
        have_s = sl_s < sl_maxs
        s2 = np.minimum(sl_s * 2, sl_maxs)
        dres_s = s2 * sl_n * sl_unit - cur_res
        score_s = (rates_pre(om_s, md_s, mc_s, nz_s, s2, sl_n) - cur) / \
            np.maximum(dres_s, 1e-9)
        use_s = have_s & (~have_n | (score_s > score_n))
        b_s = np.where(use_s, s2, sl_s)
        b_n = np.where(use_s, sl_n, n2)
        none = ~(have_n | have_s)
        grown_rate = rates_pre(om_s, md_s, mc_s, nz_s, b_s, b_n)
        dgrow = (b_s * b_n - sl_s * sl_n) * sl_unit
        grow = active & ~none
        # wave pre-check (round-start state, before any mutation): the flat
        # engine's next argmins are exactly the ascending positions tied at
        # ``cur``, so compute each tied copy's own growth decision and take
        # the prefix whose grown designs all strictly improve and are
        # unshrinkable at lo = cur*(1+1e-9) — those flat iterations have
        # no-op balance passes and collapse into bookkeeping (DESIGN.md §15)
        wave: Dict[int, Tuple[np.ndarray, ...]] = {}
        tied_m = grow[:, None] & (thr == cur[:, None])
        t_cnt = tied_m.sum(axis=1)
        rows_w = (t_cnt >= 2) & (it < max_iters - 1)
        if rows_w.any():
            tied_m &= rows_w[:, None]
            bi, li = np.nonzero(tied_m)   # row-major: ascending positions
            t_s = spe[bi, li]
            t_n = n[bi, li]
            t_u = unit[li]
            t_mn = max_n[li]
            t_ms = max_spe[li]
            t_cur = cur[bi]
            om_t = omsm[bi, li]
            md_t = m_dot[li]
            mc_t = macs[li]
            nz_t = nz[li]
            t_res = t_s * t_n * t_u
            t_hn = t_n < t_mn
            t_n2 = np.minimum(t_n * 2, t_mn)
            t_scn = (rates_pre(om_t, md_t, mc_t, nz_t, t_s, t_n2) -
                     t_cur) / np.maximum(t_s * t_n2 * t_u - t_res, 1e-9)
            t_hs = t_s < t_ms
            t_s2 = np.minimum(t_s * 2, t_ms)
            t_scs = (rates_pre(om_t, md_t, mc_t, nz_t, t_s2, t_n) -
                     t_cur) / np.maximum(t_s2 * t_n * t_u - t_res, 1e-9)
            t_us = t_hs & (~t_hn | (t_scs > t_scn))
            w_s = np.where(t_us, t_s2, t_s)
            w_n = np.where(t_us, t_n, t_n2)
            w_gr = rates_pre(om_t, md_t, mc_t, nz_t, w_s, w_n)
            w_dg = (w_s * w_n - t_s * t_n) * t_u
            w_lo = t_cur * (1 + 1e-9)
            w_nh = rates_pre(om_t, md_t, mc_t, nz_t, w_s,
                             np.maximum(1, w_n >> 1))
            w_sh = rates_pre(om_t, md_t, mc_t, nz_t,
                             np.maximum(1, w_s >> 1), w_n)
            w_shr = ((w_n > 1) & (w_nh >= w_lo)) | \
                    ((w_s > 1) & (w_sh >= w_lo))
            ok = (t_hn | t_hs) & (w_gr > t_cur) & ~w_shr
            starts = np.searchsorted(bi, ar)
            ends = np.searchsorted(bi, ar, side="right")
            for b in np.nonzero(rows_w)[0].tolist():
                lo_i, hi_i = int(starts[b]), int(ends[b])
                okb = ok[lo_i:hi_i]
                m = hi_i - lo_i
                k = int(np.argmin(okb)) if not okb.all() else m
                # leave the last tied copy for a real round (its growth
                # moves the pipeline minimum, so its balance lo differs)
                w = min(min(k, m - 1) - 1, int(max_iters - it[b] - 1))
                if w > 0:
                    sl = slice(lo_i + 1, lo_i + 1 + w)
                    wave[b] = (li[sl], w_s[sl], w_n[sl], w_dg[sl],
                               w_gr[sl], w_nh[sl], w_sh[sl])
        # record the round's trace rows; option-less proposals stop here
        res_l = res.tolist()
        cur_l = cur.tolist()
        for b in np.nonzero(active)[0].tolist():
            trace[b].append((res_l[b], cur_l[b]))
            muts[b].append([])
        active &= ~none
        if not grow.any():
            break
        old_res = res.copy()
        # apply the growth, strict-balance everyone else, keep if affordable
        res[grow] += dgrow[grow]
        bi_g = ar[grow]
        li_g = slow[grow]
        spe[bi_g, li_g] = b_s[grow]
        n[bi_g, li_g] = b_n[grow]
        refresh(bi_g, li_g)
        slow_l = slow.tolist()
        bs_l = b_s.tolist()
        bn_l = b_n.tolist()
        for b in np.nonzero(grow)[0].tolist():
            muts[b][-1].append((slow_l[b], bs_l[b], bn_l[b]))
        m_after = thr.min(axis=1)       # fresh min, the flat engine's floats
        bal = balance(m_after * (1 + 1e-9), grow, skip_rows=bi_g,
                      skip_cols=li_g)
        it[grow] += 1
        over = grow & (res > budget)
        if over.any():
            ob = np.nonzero(over)[0]
            spe[ob, slow[ob]] = sl_s[ob]
            n[ob, slow[ob]] = sl_n[ob]
            refresh(ob, slow[ob])
            if bal is not None:
                bbi, bli, bps, bpn = bal
                bm = over[bbi]
                if bm.any():
                    spe[bbi[bm], bli[bm]] = bps[bm]
                    n[bbi[bm], bli[bm]] = bpn[bm]
                    refresh(bbi[bm], bli[bm])
            res[over] = old_res[over]
            for b in ob.tolist():
                muts[b][-1] = []
            active &= ~over
        # batched wave steps (flat iterations 2..wave+1 of each run):
        # np.cumsum is strictly sequential addition, so it replays the flat
        # engine's per-copy ``res += dgrow`` float chain term for term
        for b in np.nonzero(grow & ~over)[0].tolist():
            got = wave.get(b)
            if got is None:
                continue
            wpos, ws, wn, wdg, wgr, wnh, wsh = got
            c_b = cur_l[b]
            r_seq = np.cumsum(np.concatenate(([res[b]], wdg)))
            w = len(wpos)
            over_j = np.nonzero(r_seq[1:] > budget)[0]
            steps = w if over_j.size == 0 else int(over_j[0]) + 1
            done = steps if over_j.size == 0 else steps - 1
            trace[b].extend(zip(r_seq[:steps].tolist(), repeat(c_b, steps)))
            muts[b].extend(zip(wpos[:done].tolist(), ws[:done].tolist(),
                               wn[:done].tolist()))
            if over_j.size:
                muts[b].append([])
                active[b] = False
            res[b] = r_seq[done]
            cp = wpos[:done]
            spe[b, cp] = ws[:done]
            n[b, cp] = wn[:done]
            thr[b, cp] = wgr[:done]
            r_nh[b, cp] = wnh[:done]
            r_sh[b, cp] = wsh[:done]
            it[b] += steps
        active &= it < max_iters

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    theta = thr.min(axis=1)
    protect = thr <= (theta * (1 + 1e-9))[:, None]
    for b in range(B):
        muts[b].append([])
    balance(theta * (1 - 1e-12), np.ones(B, dtype=bool), protect=protect)
    f_thr = thr.min(axis=1)

    out = []
    for b in range(B):
        res_pts = [r for r, _ in trace[b]] + [float(res[b])]
        thr_pts = [t for _, t in trace[b]] + [float(f_thr[b])]
        frontier = _frontier_from_muts(res_pts, thr_pts, muts[b], L)
        out.append((spe[b].copy(), n[b].copy(), float(f_thr[b]),
                    float(res[b]), trace[b], frontier, float(theta[b])))
    return out


def _run_incremental_batch_c(lv: LayerVectors, hw: HardwareModel,
                             budget: float, s_eff_batch: np.ndarray,
                             max_iters: int, lib):
    """Compiled-backend batched greedy: B independent flat-engine runs in
    one C call (``_dse_ckernel``), plus numpy/C post-processing that
    rebuilds each proposal's trace, frontier and final state. Bit-exact vs
    ``_run_incremental`` by construction — the kernel is a scalar-for-
    scalar port (see the float contract in ``_dse_ckernel``) and the
    frontier path reuses ``_frontier_keep`` on the kernel's own (res, thr)
    points with design snapshots replayed from the kernel's mutation log
    (``dse_replay``), the grouped engine's ``_frontier_from_muts`` scheme
    with the replay loop in C."""
    S = np.ascontiguousarray(s_eff_batch, dtype=np.float64)
    B, L = S.shape
    omsm = np.ascontiguousarray((1.0 - S) * lv.m_dot)
    m_dot = np.ascontiguousarray(lv.m_dot, dtype=np.float64)
    macs = np.ascontiguousarray(lv.macs, dtype=np.float64)
    unit = np.ascontiguousarray(lv.res_unit, dtype=np.float64)
    max_n = np.ascontiguousarray(lv.max_n, dtype=np.int64)
    max_spe = np.ascontiguousarray(lv.max_spe, dtype=np.int64)
    # mutation-stream bound: every growth row logs 1 mut and <= its own
    # halvings; total halvings <= total doublings <= max_iters, and the
    # final trim adds <= L — so 2*max_iters + L covers it (slack for the
    # clipped-growth edge)
    M = 2 * max_iters + L + 16
    spe = np.empty((B, L), dtype=np.int64)
    n = np.empty((B, L), dtype=np.int64)
    res = np.empty(B, dtype=np.float64)
    fthr = np.empty(B, dtype=np.float64)
    theta = np.empty(B, dtype=np.float64)
    tr_res = np.empty((B, max_iters), dtype=np.float64)
    tr_cur = np.empty((B, max_iters), dtype=np.float64)
    tr_len = np.empty(B, dtype=np.int64)
    mut_pos = np.empty((B, M), dtype=np.int64)
    mut_s = np.empty((B, M), dtype=np.int64)
    mut_n = np.empty((B, M), dtype=np.int64)
    mut_cnt = np.zeros((B, max_iters + 1), dtype=np.int64)
    # pointer args are raw addresses (see _dse_ckernel's argtype note):
    # every array above is freshly allocated here, correct dtype, C order
    p = (lambda a: a.ctypes.data)
    rc = lib.dse_run_batch(
        B, L, max_iters, float(budget), p(omsm), p(S), p(m_dot), p(macs),
        p(unit), p(max_n), p(max_spe), p(spe), p(n), p(res), p(fthr),
        p(theta), p(tr_res), p(tr_cur), p(tr_len), p(mut_pos), p(mut_s),
        p(mut_n), p(mut_cnt), M)
    if rc:
        raise RuntimeError("DSE kernel internal error "
                           f"(code {rc}: mutation overflow or OOM)")
    w_spe = np.empty(L, dtype=np.int64)
    w_n = np.empty(L, dtype=np.int64)
    out = []
    for b in range(B):
        T = int(tr_len[b])
        trace = list(zip(tr_res[b, :T].tolist(), tr_cur[b, :T].tolist()))
        res_pts = np.append(tr_res[b, :T], res[b])
        thr_pts = np.append(tr_cur[b, :T], fthr[b])
        keep = np.asarray(_frontier_keep(res_pts, thr_pts), dtype=np.int64)
        K = len(keep)
        order = np.argsort(keep, kind="stable")
        f_spe = np.empty((K, L), dtype=np.int64)
        f_n = np.empty((K, L), dtype=np.int64)
        krows = np.ascontiguousarray(keep[order])   # named: p() takes the
        mp, ms, mn, mc = (mut_pos[b], mut_s[b], mut_n[b], mut_cnt[b])
        lib.dse_replay(L, T + 1, p(mp), p(ms), p(mn), p(mc), K, p(krows),
                       p(f_spe), p(f_n), p(w_spe), p(w_n))   # address only
        inv = np.empty(K, dtype=np.int64)
        inv[order] = np.arange(K)
        frontier = ParetoFrontier(res=res_pts[keep], thr=thr_pts[keep],
                                  spe=f_spe[inv], n=f_n[inv])
        out.append((spe[b], n[b], float(fthr[b]), float(res[b]),
                    trace, frontier, float(theta[b])))
    return out


def _run_batch_dispatch(lv: LayerVectors, hw: HardwareModel, budget: float,
                        s_eff_batch: np.ndarray, max_iters: int,
                        engine: str = "auto"):
    """Batched-engine dispatch: ``compiled`` is the C kernel (DESIGN.md
    §15), ``lockstep`` the pure-numpy array program; ``auto`` prefers the
    kernel and falls back when the environment can't build it. Both are
    bit-exact vs the serial engines (property-tested), so ``auto`` is a
    pure perf choice — like ``_run_dse``'s."""
    if lv.t_scale is not None and engine in ("auto", "compiled"):
        # explicit lockstep-only fallback for patterned rows (DESIGN.md
        # §16): the C kernel's dynamics-class key compares the six
        # pre-pattern per-layer constants and doesn't know t_scale, so two
        # layers with equal s_eff but different decode costs would be
        # mis-grouped there. The numpy lockstep engine consumes the
        # already-scaled omsm and stays bit-exact vs the serial engines.
        if engine == "compiled":
            raise RuntimeError("compiled batch engine does not support "
                               "pattern t_scale rows; use lockstep/auto")
        engine = "lockstep"
    if engine == "auto":
        engine = "compiled" if _dse_ckernel.get_lib() is not None \
            else "lockstep"
    if engine == "compiled":
        lib = _dse_ckernel.get_lib()
        if lib is None:
            raise RuntimeError("compiled DSE kernel unavailable "
                               "(no C compiler or REPRO_DSE_CKERNEL=0)")
        ENGINE_DISPATCH.inc("compiled")
        return _run_incremental_batch_c(lv, hw, budget, s_eff_batch,
                                        max_iters, lib)
    if engine != "lockstep":
        raise ValueError(f"unknown batch engine {engine!r}")
    ENGINE_DISPATCH.inc("lockstep")
    return _run_incremental_batch(lv, hw, budget,
                                  np.asarray(s_eff_batch, dtype=np.float64),
                                  max_iters)


def incremental_dse_batch(lv: LayerVectors, hw: HardwareModel,
                          budget: float, s_eff_batch: np.ndarray,
                          *, max_iters: int = 10000,
                          materialize_designs: bool = True,
                          engine: str = "auto") -> List[DSEResult]:
    """Batched ``incremental_dse`` over one workload template: row ``b`` of
    ``s_eff_batch`` (shape (B, L)) is one proposal's effective-sparsity
    vector; all other workload constants come from ``lv``. Returns B
    ``DSEResult``s, each bit-identical — designs, throughput, resource,
    trace, frontier, theta_r — to ``incremental_dse`` on the corresponding
    single stack (property-tested), at a fraction of B serial runs'
    wall-clock on kind-tied stacks (DESIGN.md §15). ``engine`` selects the
    backend (``compiled``/``lockstep``/``auto``). This is the engine under
    ``DSECache.dse_vec_batch`` / ``hass_search(batch_size=k)``."""
    rows = _run_batch_dispatch(lv, hw, budget,
                               np.asarray(s_eff_batch, dtype=np.float64),
                               max_iters, engine)
    out = []
    for spe, n, f_thr, res, trace, frontier, theta_r in rows:
        designs = _designs_from(spe, n) if materialize_designs else []
        out.append(DSEResult(designs=designs, throughput=f_thr, resource=res,
                             throughput_per_res=f_thr / max(res, 1e-9),
                             trace=trace, frontier=frontier,
                             theta_r=theta_r))
    return out


@dataclass
class DegradationRung:
    """One step of a graceful-degradation ladder: serve at extra sparsity
    ``s_extra`` on top of the searched masks, trading accuracy for the
    throughput of the correspondingly re-searched accelerator. ``step_scale``
    is the decode step-cycle multiplier relative to rung 0 (``thr_base /
    thr_rung``, so faster rungs have smaller scales) — the value
    ``serve.fleet.DegradationPolicy`` consumes."""
    s_extra: float       # extra sparsity fraction composed onto s_eff
    throughput: float    # DSE pipeline throughput at this rung (samples/cyc)
    step_scale: float    # step-cycle multiplier vs rung 0 (<= 1.0)


def degradation_ladder(layers: Sequence[LayerCost], hw: HardwareModel,
                       budget: float,
                       *, s_extra: Sequence[float] = (0.0, 0.15, 0.3),
                       max_iters: int = 10000,
                       engine: str = "auto") -> List[DegradationRung]:
    """Price a graceful-degradation ladder off the sparsity frontier.

    Rung ``k`` composes ``s_extra[k]`` of additional sparsity onto every
    layer's hardware-effective density — ``s' = 1 - (1 - s_eff) * (1 -
    e)`` — and re-runs the batched DSE on the stepped-up stacks in ONE
    ``incremental_dse_batch`` call (rows share the workload template, so
    the lockstep engines amortize the sweep). The returned rungs map each
    accuracy step-down to its measured throughput gain as a step-cycle
    multiplier; feed ``tuple(r.step_scale for r in rungs)`` to
    ``DegradationPolicy(ladder=...)``. ``s_extra`` must start at 0.0
    (rung 0 is the undegraded operating point; its scale is exactly 1.0)
    and increase strictly; scales are clamped monotone nonincreasing so a
    non-monotone greedy-DSE wobble can never produce a ladder the policy
    validator rejects."""
    grid = [float(e) for e in s_extra]
    if not grid or grid[0] != 0.0:
        raise ValueError("degradation_ladder: s_extra must start at 0.0")
    if any(b <= a for a, b in zip(grid, grid[1:])):
        raise ValueError("degradation_ladder: s_extra must increase strictly")
    if any(e < 0.0 or e >= 1.0 for e in grid):
        raise ValueError("degradation_ladder: s_extra must lie in [0, 1)")
    lv = hw.layer_vectors(layers)
    batch = np.stack([1.0 - (1.0 - lv.s_eff) * (1.0 - e) for e in grid])
    results = incremental_dse_batch(lv, hw, budget, batch,
                                    max_iters=max_iters,
                                    materialize_designs=False, engine=engine)
    thr0 = results[0].throughput
    rungs: List[DegradationRung] = []
    floor = 1.0
    for e, r in zip(grid, results):
        scale = 1.0 if e == 0.0 else (
            thr0 / r.throughput if r.throughput > 0.0 else 1.0)
        floor = min(floor, scale)
        rungs.append(DegradationRung(s_extra=e, throughput=r.throughput,
                                     step_scale=floor))
    return rungs


def incremental_dse(layers: Sequence[LayerCost], hw: HardwareModel,
                    budget: float, *, max_iters: int = 10000,
                    engine: str = "auto") -> DSEResult:
    """§V-A.3: start resource-minimal, grow the slowest layer, re-balance.

    Vectorized greedy loop — identical designs/throughput/resource/trace to
    ``incremental_dse_ref`` (property-tested), ~10–100x faster. The returned
    ``DSEResult.frontier`` holds the full non-dominated (resource,
    throughput) set of the search path with per-point design state, so
    consumers (Eq. 6 scoring, DP partitioning) trade points without
    re-running the search (``incremental_dse_ref`` leaves it None).

    ``engine`` picks the loop implementation: ``"flat"`` is the per-layer
    engine; ``"grouped"`` collapses layers with identical dynamics into
    class groups (bit-exact, much faster on deep LM stacks whose blocks
    repeat the same matmul shapes); ``"auto"`` chooses by class count."""
    lv = hw.layer_vectors(layers)
    spe, n, thr, res, trace, frontier, theta_r = _run_dse(lv, hw, budget,
                                                          max_iters, engine)
    return DSEResult(designs=_designs_from(spe, n), throughput=thr,
                     resource=res, throughput_per_res=thr / max(res, 1e-9),
                     trace=trace, frontier=frontier, theta_r=theta_r)


def incremental_dse_ref(layers: Sequence[LayerCost], hw: HardwareModel,
                        budget: float, *, max_iters: int = 10000) -> DSEResult:
    """Reference scalar implementation of ``incremental_dse`` (pre-vectorized
    code, kept verbatim as the equivalence oracle and for ``dse_bench``)."""
    designs = [DesignPoint(1, 1) for _ in layers]
    trace: List[Tuple[float, float]] = []

    def total_res(ds):
        return sum(hw.layer_resource(l, d) for l, d in zip(layers, ds))

    for _ in range(max_iters):
        thr = pipeline_throughput(layers, designs, hw)
        res = total_res(designs)
        trace.append((res, thr))
        # slowest layer
        rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
        slow = int(np.argmin(rates))
        opts = _grow_options(layers[slow], designs[slow], hw)
        if not opts:
            break
        # pick the increment with best Δthroughput per Δresource
        def score(opt):
            dthr = hw.layer_throughput(layers[slow], opt) - rates[slow]
            dres = hw.layer_resource(layers[slow], opt) - \
                hw.layer_resource(layers[slow], designs[slow])
            return dthr / max(dres, 1e-9)
        opt = max(opts, key=score)
        cand = list(designs)
        cand[slow] = opt
        cand = rate_balance_ref(layers, cand, hw, protect={slow}, strict=True)
        if total_res(cand) > budget:
            break
        designs = cand

    # final literal Eq. 4 pass: trim over-provision, keep the bottleneck set
    rates = [hw.layer_throughput(l, d) for l, d in zip(layers, designs)]
    bottleneck = {i for i, r in enumerate(rates) if r <= min(rates) * (1 + 1e-9)}
    designs = rate_balance_ref(layers, designs, hw, protect=bottleneck)
    thr = pipeline_throughput(layers, designs, hw)
    res = total_res(designs)
    return DSEResult(designs=designs, throughput=thr, resource=res,
                     throughput_per_res=thr / max(res, 1e-9), trace=trace)


# --------------------------------------------------------------------- #
# DSECache: memoized warm-start reuse across DSE calls (DESIGN.md §12, §15)
# --------------------------------------------------------------------- #
def _reachable_n(max_n: int) -> Tuple[int, ...]:
    """Closure of {1} under the two N moves either engine ever makes —
    grow ``n -> min(2n, max_n)`` and shrink ``n -> max(1, n >> 1)``. Every
    N value a layer can hold at any point of any run is in this set
    (O(log^2 max_n) values), which is what makes the level-2 certificate's
    t-vector finite (DESIGN.md §15)."""
    seen = {1}
    stack = [1]
    while stack:
        v = stack.pop()
        for w in (min(2 * v, max_n), max(1, v >> 1)):
            if w not in seen:
                seen.add(w)
                stack.append(w)
    return tuple(sorted(seen))


_REACHABLE_N_MEMO: Dict[int, Tuple[int, ...]] = {}


class DSECache:
    """Exact result reuse for ``incremental_dse`` across a search session.

    Three reuse levels, all bit-exact (property-tested in
    ``tests/test_dse_cache.py``):

      * **exact** — results are memoized on the full dynamics key: the
        ``s_eff`` float vector plus a fingerprint of the workload constants
        (macs, m_dot, caps, res_unit), budget and max_iters. Equal keys
        replay the identical greedy trajectory by determinism.
      * **warm level 1** — the floor-stability theorem: a layer whose
        design the greedy never grows stays at the resource floor (1, 1)
        for the whole run (shrinking from the floor is impossible), and it
        is never grown iff its floor rate strictly exceeds ``theta_r``, the
        run's peak bottleneck rate. Such a layer contributes a constant to
        every decision the greedy takes — argmin selection, balance
        feasibility, budget accounting — so two stacks that differ ONLY in
        layers that are floor-stable on both sides (rate at (1,1) strictly
        above the cached run's theta_r under both the cached and the query
        sparsity) have bit-identical DSE results.
      * **warm level 2** — the dynamics-equivalence certificate for
        floor-adjacent layers (layers the anchor run DID grow, where level
        1 can't apply): sparsity reaches the engines only through the
        cycle count ``t(n) = max(1, ceil((1 - s_eff) * m_dot / n))``, and
        ``n`` only ever takes values in the layer's reachable-N closure
        (``_reachable_n``). If a differing layer's float t-vector over
        that whole closure is equal under the cached and the query
        sparsity, every rate the engine can ever compute for it is equal
        float-for-float, so the full decision log replays identically —
        the anchor's growth events for that layer are re-validated against
        the query sparsity in one vector compare (DESIGN.md §15 has the
        proof sketch). When neither certificate can be proven the query
        falls back to a cold run.

    A cold run is the normal engine (grouped/flat dispatch), so a cache
    MISS costs one array compare (plus at most ``_L2_CANDIDATES`` t-vector
    compares) more than no cache at all. Results handed out are shared
    objects — treat them as immutable.
    """

    #: miss-path bound: level-2 certificates are attempted on at most this
    #: many anchors (the ones with the fewest unproven layers), keeping the
    #: worst-case miss overhead flat as anchors accumulate
    _L2_CANDIDATES = 8

    def __init__(self, max_entries: int = 256,
                 materialize_designs: bool = True):
        """``materialize_designs=False`` leaves ``DSEResult.designs`` empty
        on cache-produced results (consumers that only read the frontier —
        the analytic evaluators — skip building L DesignPoint objects per
        cold run; ``ParetoFrontier.materialize`` still rebuilds any point)."""
        self.max_entries = max_entries
        self.materialize_designs = materialize_designs
        # decision counters re-backed by the obs Counters bag (DESIGN.md
        # §18); ``hits``/``warm_l1``/``warm_l2``/``cold_runs`` stay plain
        # read/write attributes via the properties below, so every
        # ``self.hits += 1`` site and the ``stats()`` dict are unchanged
        self._counters = Counters("hits", "warm_l1", "warm_l2", "cold_runs")
        # fingerprint -> {s_eff bytes -> DSEResult}
        self._exact: Dict[int, Dict[bytes, DSEResult]] = {}
        # fingerprint -> [s_eff rows], [rate11 rows], [theta_r], [t-vecs],
        #                [result]
        self._anchors: Dict[int, list] = {}
        # fingerprint -> (flat reachable-N, per-layer segment starts)
        self._nlayout: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    def _counter(name: str):                       # noqa: N805
        def _get(self) -> int:
            return self._counters.get(name)

        def _set(self, v: int) -> None:
            self._counters.set(name, int(v))

        return property(_get, _set)

    hits = _counter("hits")
    warm_l1 = _counter("warm_l1")
    warm_l2 = _counter("warm_l2")
    cold_runs = _counter("cold_runs")
    del _counter

    @property
    def warm_hits(self) -> int:
        """Back-compat aggregate: warm reuses at either certificate level."""
        return self.warm_l1 + self.warm_l2

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "warm_hits": self.warm_hits,
                "warm_l1": self.warm_l1, "warm_l2": self.warm_l2,
                "cold_runs": self.cold_runs}

    @staticmethod
    def _fingerprint(lv: LayerVectors, budget: float, max_iters: int) -> int:
        # t_scale joins the workload constants (a pattern changes the
        # dynamics, so anchors must never mix across decode-cost vectors);
        # None keeps a distinct sentinel so the default path's keyspace is
        # untouched within a session
        return hash((lv.macs.tobytes(), lv.m_dot.tobytes(),
                     lv.max_n.tobytes(), lv.max_spe.tobytes(),
                     lv.res_unit.tobytes(),
                     None if lv.t_scale is None else lv.t_scale.tobytes(),
                     float(budget), int(max_iters)))

    @staticmethod
    def _om(lv: LayerVectors, s_eff: np.ndarray) -> np.ndarray:
        """The engines' t_cycles numerator ``(1 - s_eff) * m_dot``
        (pattern-scaled when t_scale is set) — the single expression both
        certificates must share with the engines float-for-float."""
        om = (1.0 - s_eff) * lv.m_dot
        if lv.t_scale is not None:
            om = om * lv.t_scale
        return om

    @staticmethod
    def _rate11(lv: LayerVectors) -> np.ndarray:
        """Per-layer rate at the (1, 1) floor design — the same floats the
        engines' ``thr_of(i, 1, 1)`` computes."""
        t = np.maximum(1.0, np.ceil(DSECache._om(lv, lv.s_eff)))
        with np.errstate(divide="ignore"):
            r = lv.m_dot / (lv.macs * t)
        return np.where(lv.macs > 0, r, np.inf)

    def _layout(self, fp: int, lv: LayerVectors):
        """(flat_N, starts) for this workload: per-layer reachable-N sets
        concatenated, plus ``reduceat`` segment starts."""
        lay = self._nlayout.get(fp)
        if lay is None:
            sets = []
            for mn in lv.max_n.tolist():
                ns = _REACHABLE_N_MEMO.get(mn)
                if ns is None:
                    ns = _REACHABLE_N_MEMO[mn] = _reachable_n(mn)
                sets.append(ns)
            counts = np.array([len(s) for s in sets], dtype=np.int64)
            flat_n = np.array([v for s in sets for v in s], dtype=np.float64)
            starts = np.zeros(len(sets), dtype=np.int64)
            np.cumsum(counts[:-1], out=starts[1:])
            lay = self._nlayout[fp] = (flat_n, starts, counts)
        return lay

    def _tvec(self, lv: LayerVectors, s_eff: np.ndarray, flat_n: np.ndarray,
              counts: np.ndarray) -> np.ndarray:
        """Float t over every (layer, reachable N) pair — the same
        ``(1 - s) * m_dot`` product (pattern-scaled) then division the
        engines compute, so equality here is equality of every t either
        engine can produce."""
        om = np.repeat(self._om(lv, s_eff), counts)
        return np.maximum(1.0, np.ceil(om / flat_n))

    def _lookup(self, fp: int, lv: LayerVectors, s_eff: np.ndarray,
                key: bytes) -> Optional[DSEResult]:
        """Exact/warm lookup for one query row; bumps counters and promotes
        warm hits to exact entries. ``None`` means the caller runs cold."""
        exact = self._exact.setdefault(fp, {})
        r = exact.get(key)
        if r is not None:
            self.hits += 1
            return r
        anchors = self._anchors.setdefault(fp, [[], [], [], [], []])
        a_s, a_r11, a_th, a_tv, a_res = anchors
        if not a_s:
            return None
        q_r11 = self._rate11(lv)
        S = np.stack(a_s)
        R = np.stack(a_r11)
        th = np.asarray(a_th)[:, None]
        diff = S != s_eff[None]
        l1 = (R > th) & (q_r11[None] > th)
        need = diff & ~l1               # layers level 1 leaves unproven
        n_need = need.sum(axis=1)
        idx = np.nonzero(n_need == 0)[0]
        if len(idx):
            self.warm_l1 += 1
            r = a_res[int(idx[0])]
            self._insert(fp, lv, s_eff, key, r)
            return r
        # level 2: re-validate the unproven layers' dynamics by t-vector
        # equality, cheapest anchors first, bounded candidate count
        flat_n, starts, counts = self._layout(fp, lv)
        q_tv = self._tvec(lv, s_eff, flat_n, counts)
        for a in np.argsort(n_need, kind="stable")[:self._L2_CANDIDATES]:
            a = int(a)
            layer_ok = np.logical_and.reduceat(a_tv[a] == q_tv, starts)
            if layer_ok[need[a]].all():
                self.warm_l2 += 1
                r = a_res[a]
                self._insert(fp, lv, s_eff, key, r)
                return r
        return None

    def dse_vec(self, lv: LayerVectors, hw: HardwareModel, budget: float,
                *, max_iters: int = 10000, engine: str = "auto") -> DSEResult:
        fp = self._fingerprint(lv, budget, max_iters)
        s_eff = np.ascontiguousarray(lv.s_eff, dtype=np.float64)
        key = s_eff.tobytes()
        r = self._lookup(fp, lv, s_eff, key)
        if r is not None:
            return r
        self.cold_runs += 1
        spe, n, thr, res, trace, frontier, theta_r = _run_dse(
            lv, hw, budget, max_iters, engine)
        designs = _designs_from(spe, n) if self.materialize_designs else []
        r = DSEResult(designs=designs, throughput=thr,
                      resource=res, throughput_per_res=thr / max(res, 1e-9),
                      trace=trace, frontier=frontier, theta_r=theta_r)
        self._insert(fp, lv, s_eff, key, r)
        return r

    def dse_vec_batch(self, lv: LayerVectors, hw: HardwareModel,
                      budget: float, s_eff_batch: np.ndarray,
                      *, max_iters: int = 10000,
                      engine: str = "auto") -> List[DSEResult]:
        """Batched ``dse_vec``: row ``b`` of ``s_eff_batch`` is looked up
        in row order (so within-batch duplicates alias the first
        occurrence, as a serial loop would), and ALL cold rows then run
        through ``incremental_dse_batch`` in one engine invocation — the
        whole point of the proposal-batched path (DESIGN.md §15). Returns
        per-row results bit-identical to ``[dse_vec(row b) for b]``
        (certificate soundness + batch-engine exactness, property-tested).
        ``engine`` here selects the BATCH backend
        (``auto``/``compiled``/``lockstep``)."""
        S = np.ascontiguousarray(np.asarray(s_eff_batch, dtype=np.float64))
        B = S.shape[0]
        out: List[Optional[DSEResult]] = [None] * B
        if B == 0:
            return []
        fp = self._fingerprint(lv, budget, max_iters)
        exact = self._exact.setdefault(fp, {})
        anchors = self._anchors.setdefault(fp, [[], [], [], [], []])
        a_s, a_r11, a_th, a_tv, a_res = anchors
        # warm certificates for the WHOLE batch in one array program
        # against the at-entry anchor snapshot (anchors promoted mid-batch
        # aren't re-scanned; a row that would have certified against one
        # just runs cold — same bits either way, by soundness)
        A = len(a_s)
        if A:
            om11 = (1.0 - S) * lv.m_dot
            if lv.t_scale is not None:
                om11 = om11 * lv.t_scale
            with np.errstate(divide="ignore"):
                t11 = np.maximum(1.0, np.ceil(om11))
                R11 = lv.m_dot / (lv.macs * t11)
            R11 = np.where(lv.macs > 0, R11, np.inf)      # (B, L)
            th = np.asarray(a_th)[None, :, None]
            diff = S[:, None, :] != np.stack(a_s)[None]   # (B, A, L)
            l1 = (np.stack(a_r11)[None] > th) & (R11[:, None, :] > th)
            n_need = (diff & ~l1).sum(axis=2)             # (B, A)
        cold: List[int] = []            # row index of first cold occurrence
        pending: Dict[bytes, int] = {}  # key -> index into ``cold``
        dups: List[Tuple[int, int]] = []
        for b in range(B):
            key = S[b].tobytes()
            r = exact.get(key)
            if r is not None:
                self.hits += 1
                out[b] = r
                continue
            if A:
                idx = np.nonzero(n_need[b] == 0)[0]
                if len(idx):
                    self.warm_l1 += 1
                    r = a_res[int(idx[0])]
                    self._insert(fp, lv, S[b], key, r, rate11=R11[b])
                    out[b] = r
                    continue
                flat_n, starts, counts = self._layout(fp, lv)
                q_tv = self._tvec(lv, S[b], flat_n, counts)
                for a in np.argsort(n_need[b],
                                    kind="stable")[:self._L2_CANDIDATES]:
                    a = int(a)
                    ok = np.logical_and.reduceat(a_tv[a] == q_tv, starts)
                    if ok[diff[b, a] & ~l1[b, a]].all():
                        self.warm_l2 += 1
                        r = a_res[a]
                        self._insert(fp, lv, S[b], key, r,
                                     rate11=R11[b], tvec=q_tv)
                        out[b] = r
                        break
                if out[b] is not None:
                    continue
            ci = pending.get(key)
            if ci is not None:
                self.hits += 1          # a serial loop would exact-hit here
                dups.append((b, ci))
                continue
            pending[key] = len(cold)
            cold.append(b)
        if cold:
            results = incremental_dse_batch(
                lv, hw, budget, S[cold], max_iters=max_iters,
                materialize_designs=self.materialize_designs, engine=engine)
            for b, r in zip(cold, results):
                self.cold_runs += 1
                self._insert(fp, lv, S[b], S[b].tobytes(), r)
            for b, r in zip(cold, results):
                out[b] = r
        for b, ci in dups:
            out[b] = out[cold[ci]]
        return out

    def dse(self, layers: Sequence[LayerCost], hw: HardwareModel,
            budget: float, *, max_iters: int = 10000,
            engine: str = "auto") -> DSEResult:
        """Drop-in cached ``incremental_dse``."""
        return self.dse_vec(hw.layer_vectors(layers), hw, budget,
                            max_iters=max_iters, engine=engine)

    def _insert(self, fp: int, lv: LayerVectors, s_eff: np.ndarray,
                key: bytes, r: DSEResult,
                rate11: Optional[np.ndarray] = None,
                tvec: Optional[np.ndarray] = None) -> None:
        """``rate11``/``tvec`` are computed from ``s_eff`` (NOT from
        ``lv.s_eff`` — batch callers pass a template ``lv``) when a caller
        hasn't already paid for them."""
        exact = self._exact[fp]
        if len(exact) >= self.max_entries:
            exact.clear()                    # epoch reset: searches are
            self._anchors[fp] = [[], [], [], [], []]  # phase-local, old
        exact[key] = r                       # anchors rarely pay off past
        a_s, a_r11, a_th, a_tv, a_res = self._anchors[fp]    # the cap
        flat_n, starts, counts = self._layout(fp, lv)
        if rate11 is None:
            rate11 = self._rate11(replace(lv, s_eff=s_eff))
        if tvec is None:
            tvec = self._tvec(lv, s_eff, flat_n, counts)
        a_s.append(s_eff)
        a_r11.append(rate11)
        a_th.append(r.theta_r)
        a_tv.append(tvec)
        a_res.append(r)


# --------------------------------------------------------------------- #
# Partitioning & reconfiguration (§V-A.4): segment-table DP
# --------------------------------------------------------------------- #
@dataclass
class PartitionResult:
    """One partitioning of a layer pipeline, with both schedule metrics.

    ``throughput`` is the *amortized temporal* rate: ``batch /
    time_per_batch`` where ``time_per_batch`` runs the partitions back to
    back on ONE executor and charges every switch between them — the FPGA
    reconfiguration schedule of §V-A.4. ``steady_throughput`` is the
    *spatial steady-state* rate: all partitions resident at once (one per
    chip), every batch flowing through the full chain, so the pipeline runs
    at the rate of its slowest stage — ``min`` over partition rates and,
    multi-chip, the per-sample ICI hop rates at the cuts. The two coincide
    only for a single partition; see DESIGN.md §10/§11 for when the
    objectives that optimize them pick different cuts.
    """
    cuts: List[int]               # split indices (exclusive prefix ends)
    batch: int
    time_per_batch: float         # cycles, incl. switch/transfer overhead
    throughput: float             # samples/cycle amortized (temporal)
    part_throughput: List[float] = field(default_factory=list)
    part_designs: List[List[DesignPoint]] = field(default_factory=list)
    steady_throughput: float = 0.0  # spatial-pipeline rate: min over
    #                                 partition rates and ICI hop rates
    dse_calls: int = 0            # segment DSE invocations (memoized table)
    objective: str = "sum"        # DP objective that picked the cuts
    chip_budgets: Optional[List[float]] = None   # per-stage DSE budgets
    #                                 (heterogeneous slices; DESIGN.md §13)
    sim_report: Optional[object] = None   # SimReport of the winning
    #                                 candidate when objective="slo"
    fault_reports: Optional[List[object]] = None  # per-fault-scenario
    #                                 SimReports of the winner when the SLO
    #                                 search ran with a fault set


def boundary_activations(layers: Sequence[LayerCost], cut: int) -> float:
    """Activation elements per sample crossing a partition cut.

    A sequential pipeline hands ``layers[cut-1].act_out ==
    layers[cut].act_in`` across the boundary. When the two disagree the
    smaller side is the stream that actually crosses: LM ``act_in``/
    ``act_out`` carry per-layer ``n_apply`` multipliers (a MoE down-proj
    "emits" d_model x active_experts, but the block reduces back to one
    residual stream of width d_model = the next block's ``act_in``), and a
    shared-attention block consumes a concat of the d_model stream. Taking
    ``min`` prices the residual stream, not the intra-block fan-out
    (DESIGN.md §11)."""
    return float(min(layers[cut - 1].act_out, layers[cut].act_in))


class SegmentTable:
    """Memoized per-contiguous-segment DSE frontiers for partitioning.

    Each contiguous segment ``layers[i:j]`` is searched at most ONCE; the
    DP below then reads amortized batch times off the cached frontiers. The
    total segment-DSE count is therefore bounded by L(L+1)/2 regardless of
    how many cut configurations the optimizer considers — unlike SA, whose
    DSE count scales with annealing steps x partitions and which still only
    samples the cut space (DESIGN.md §10).

    A shared ``DSECache`` extends the reuse across *tables*: every
    ``partition_pipeline`` call in one search session (per chip count, per
    objective, per proposal) keys its segment DSEs in the same cache, so a
    segment whose layers' sparsity did not change is never re-searched
    (DESIGN.md §12).
    """

    def __init__(self, layers: Sequence[LayerCost], hw: HardwareModel,
                 budget: float, batch: int, dse_iters: int,
                 cache: Optional[DSECache] = None):
        self.layers = list(layers)
        self.hw, self.budget = hw, budget
        self.batch, self.dse_iters = batch, dse_iters
        self._cache: Dict[Tuple[int, int, float], ParetoFrontier] = {}
        self.dse_calls = 0
        self.shared = cache

    def frontier(self, i: int, j: int,
                 budget: Optional[float] = None) -> ParetoFrontier:
        """Per-segment frontier at ``budget`` (the table's own budget when
        None). Heterogeneous slices query the same segment at several
        per-chip budgets — each (i, j, budget) is searched at most once, and
        a shared ``DSECache`` dedupes across tables by the same key."""
        b = self.budget if budget is None else float(budget)
        key = (i, j, b)
        if key not in self._cache:
            self.dse_calls += 1
            if self.shared is not None:
                r = self.shared.dse(self.layers[i:j], self.hw, b,
                                    max_iters=self.dse_iters)
            else:
                r = incremental_dse(self.layers[i:j], self.hw, b,
                                    max_iters=self.dse_iters)
            self._cache[key] = r.frontier
        return self._cache[key]

    def _best(self, i: int, j: int, budget: Optional[float] = None) -> int:
        b = self.budget if budget is None else float(budget)
        f = self.frontier(i, j, b)
        k = f.best_under(b)
        # infeasible budget: the resource-minimal design still runs (the
        # greedy's own behavior when it cannot afford any growth)
        return 0 if k is None else k

    def throughput(self, i: int, j: int,
                   budget: Optional[float] = None) -> float:
        f = self.frontier(i, j, budget)
        return float(f.thr[self._best(i, j, budget)])

    def time(self, i: int, j: int, budget: Optional[float] = None) -> float:
        thr = self.throughput(i, j, budget)
        return self.batch / thr if thr > 0 else float("inf")

    def designs(self, i: int, j: int,
                budget: Optional[float] = None) -> List[DesignPoint]:
        f = self.frontier(i, j, budget)
        return f.materialize(self._best(i, j, budget))


def _keep_largest(budgets: Sequence[float], p: int) -> List[float]:
    """The ``p`` largest budgets, physical order preserved (ties keep the
    earlier chip) — the chips a ``p``-partition deployment holds on to."""
    idx = sorted(sorted(range(len(budgets)), key=lambda i: -budgets[i])[:p])
    return [budgets[i] for i in idx]


def _better_partition(a: PartitionResult, b: PartitionResult,
                      objective: str) -> bool:
    """Strictly-better comparison across the heterogeneous per-P runs,
    mirroring the DP's own tie rules (maxmin ties prefer the smaller
    amortized batch time; ascending-P iteration keeps remaining ties on
    the fewest chips)."""
    if objective == "maxmin":
        if a.steady_throughput > b.steady_throughput * (1 + 1e-12):
            return True
        if a.steady_throughput < b.steady_throughput * (1 - 1e-12):
            return False
    return a.time_per_batch < b.time_per_batch * (1 - 1e-12)


def partition_pipeline(layers: Sequence[LayerCost], hw: HardwareModel,
                       budget: float, *, n_parts: int, batch: int = 256,
                       reconfig_cycles: float = 5e7, seed: int = 0,
                       dse_iters: int = 300,
                       cut_points: Optional[Sequence[int]] = None,
                       objective: str = "auto",
                       cache: Optional[DSECache] = None,
                       chip_budgets: Optional[Sequence[float]] = None,
                       slo: Optional[object] = None,
                       trace: Optional[object] = None,
                       sim_kw: Optional[dict] = None,
                       _positional: bool = False) -> PartitionResult:
    """Fold the pipeline into at most ``n_parts`` sequential partitions, each
    run with the full per-partition ``budget``. Exact DP over cut positions
    on a memoized per-segment frontier table (one DSE per contiguous
    segment) — replaces the SA loop, which re-ran the full segment DSE on
    every annealing step (kept as ``partition_pipeline_sa``).

    Switch accounting (temporal schedule, ``time_per_batch``): a schedule
    with P resident partitions charges exactly P - 1 *switches* per
    processed batch — the mid-batch program transitions. A single resident
    partition (P = 1) charges none: it is never reconfigured, and reloading
    the first partition for the next batch overlaps with host-side batch
    staging, so neither end of the loop is charged. On a single-chip target
    a switch costs ``reconfig_cycles`` (FPGA full reconfiguration / TPU mesh
    program swap); on a multi-chip ``TPUModel`` (``hw.chips > 1``) each
    partition is resident on its own chip and a switch is instead the ICI
    transfer of the whole batch's boundary activations
    (``TPUModel.ici_transfer_cycles``), and ``n_parts`` is capped at
    ``hw.chips``.

    Metrics: ``throughput`` is the amortized *temporal* rate ``batch /
    time_per_batch`` (partitions time-multiplexed on one executor);
    ``steady_throughput`` is the *spatial* steady-state rate with every
    partition resident simultaneously — ``min`` over partition rates and,
    multi-chip, the per-sample ICI hop rates at the cuts. See the
    ``PartitionResult`` docstring and DESIGN.md §10/§11.

    ``objective`` selects what the DP optimizes:
      * ``"sum"``    — minimize ``time_per_batch`` (the sum-form temporal
        objective; the §V-A.4 reconfiguration schedule).
      * ``"maxmin"`` — maximize ``steady_throughput`` directly (max-min
        over stage and ICI-hop rates; multi-chip only, where the spatial
        schedule is the one actually run). Never worse on
        ``steady_throughput`` than the sum-form pick over the same cut
        space, because it exactly maximizes that metric; ties prefer the
        partition with the smaller ``time_per_batch``.
      * ``"auto"``   — ``"maxmin"`` for a multi-chip ``TPUModel``,
        ``"sum"`` otherwise (DESIGN.md §11).
      * ``"slo"``    — simulation-in-the-loop: build the per-P sum/max-min
        candidate partitions, simulate each against ``trace`` with the
        discrete-event deployment simulator, and pick the best candidate
        that meets the latency SLO (``slo``, a ``repro.sim.slo.SLO`` or a
        p99 target in cycles); extra simulator knobs go through ``sim_kw``.
        Delegates to ``repro.sim.slo.slo_partition_search`` (DESIGN.md §13);
        the returned result carries its winning ``sim_report``.

    ``chip_budgets`` gives each *stage* its own DSE budget on a
    heterogeneous (mixed-generation) slice. Multi-chip only, one entry per
    chip; defaults to ``hw.chip_budgets`` when the ``TPUModel`` declares
    ``chip_lanes``. A deployment with P partitions keeps the P *largest*
    chips (physical order preserved, ties to the earlier chip — a single
    resident partition lands on the largest chip, matching
    ``TPUModel.chip_budget``), and stage ``p`` is searched at the budget
    of the ``p``-th kept chip. Each P is priced by its own exact
    positional DP and the objective-best P wins (DESIGN.md §13;
    property-tested against brute force in ``tests/test_partition_dp.py``).
    ``_positional`` is internal: it marks one of those per-P runs, where
    ``chip_budgets`` lists exactly the kept stage budgets.

    ``cut_points`` restricts the DP to a candidate set of cut indices
    (sorted, in ``1..L-1``); ``None`` allows every position. Deep LM stacks
    pass block boundaries (``perf_model.lm_block_bounds``, optionally
    thinned by ``thin_cut_points``) — the segment table then holds
    O(K^2) DSEs for K candidates instead of O(L^2).

    The DP may use fewer than ``n_parts`` partitions when a switch costs
    more than it saves (or, max-min, when an ICI hop would bottleneck the
    pipeline). ``seed`` is accepted for API compatibility with the SA
    reference and is unused — the DP is deterministic.

    ``cache`` plugs a shared ``DSECache`` into the segment table, so
    repeated partition calls in one session (chip-count sweeps, sum vs
    max-min objectives, per-proposal re-partitioning) reuse every segment
    frontier whose layers did not change (DESIGN.md §12).
    """
    L = len(layers)
    multi_chip = isinstance(hw, TPUModel) and hw.chips > 1
    if objective == "slo":
        from repro.sim.slo import slo_partition_search
        return slo_partition_search(
            layers, hw, budget, slo=slo, trace=trace, n_parts=n_parts,
            batch=batch, reconfig_cycles=reconfig_cycles,
            dse_iters=dse_iters, cut_points=cut_points, cache=cache,
            chip_budgets=chip_budgets, **(sim_kw or {}))
    if slo is not None or trace is not None:
        raise ValueError("slo=/trace= are only read by objective='slo'")
    if objective == "auto":
        objective = "maxmin" if multi_chip else "sum"
    if objective not in ("sum", "maxmin"):
        raise ValueError(f"unknown objective {objective!r}")
    if chip_budgets is None and multi_chip and hw.chip_lanes is not None:
        chip_budgets = hw.chip_budgets
    if chip_budgets is not None:
        if not multi_chip:
            raise ValueError("chip_budgets models per-chip DSE budgets, "
                             "which only exist for a multi-chip TPUModel")
        chip_budgets = [float(b) for b in chip_budgets]
        if not _positional:
            if len(chip_budgets) != hw.chips:
                raise ValueError(f"chip_budgets has {len(chip_budgets)} "
                                 f"entries for {hw.chips} chips")
            if len(set(chip_budgets)) > 1:
                # heterogeneous: a P-partition deployment keeps the P
                # largest chips, so each P gets its own positional DP run
                # pinned to EXACTLY P partitions (a smaller partition count
                # is its own loop iteration with its own kept set — letting
                # an inner run fall back to fewer stages would price them
                # at a prefix of the wrong kept set). One shared cache —
                # the segment frontiers are reused across runs. The loop
                # stops at the cut space's capacity so no run is silently
                # capped below its kept-set size.
                shared = DSECache() if cache is None else cache
                kw = dict(batch=batch, reconfig_cycles=reconfig_cycles,
                          dse_iters=dse_iters, cut_points=cut_points,
                          objective=objective, cache=shared)
                cp_n = len(set(int(c) for c in cut_points)) \
                    if cut_points is not None else max(L - 1, 0)
                p_max = max(1, min(n_parts, hw.chips, cp_n + 1))
                best = None
                for p in range(1, p_max + 1):
                    r = partition_pipeline(
                        layers, hw, budget, n_parts=p,
                        chip_budgets=_keep_largest(chip_budgets, p),
                        _positional=True, **kw)
                    if best is None or _better_partition(r, best, objective):
                        best = r
                return best
    if objective == "maxmin" and not multi_chip:
        raise ValueError("objective='maxmin' optimizes the spatial "
                         "steady-state rate, which only exists for a "
                         "multi-chip TPUModel (chips > 1)")
    if cut_points is None:
        cands = list(range(L + 1))
    else:
        cp = sorted(set(int(c) for c in cut_points))
        if cp and not (1 <= cp[0] and cp[-1] <= L - 1):
            raise ValueError(f"cut_points must lie in 1..{L - 1}")
        cands = [0] + cp + [L]
    m = len(cands)                # candidate boundaries incl. 0 and L
    n_parts = min(n_parts, m - 1, hw.chips) if multi_chip \
        else min(n_parts, m - 1)
    if chip_budgets is not None:
        n_parts = min(n_parts, len(chip_budgets))
    n_parts = max(n_parts, 1)
    seg = SegmentTable(layers, hw, budget, batch, dse_iters, cache=cache)

    def stage_budget(p: int) -> float:
        """DSE budget of stage ``p`` (1-indexed): the uniform ``budget``, or
        the stage's resident chip on a heterogeneous slice."""
        return chip_budgets[p - 1] if chip_budgets is not None else budget

    def switch_cost(cut: int) -> float:
        """Cycles charged for the transition at cut position ``cut``."""
        if multi_chip:
            n_bytes = batch * boundary_activations(layers, cut) * ACT_BYTES
            return hw.ici_transfer_cycles(n_bytes)
        return reconfig_cycles

    def hop_rate(cut: int) -> float:
        """Samples/cycle one ICI hop sustains at cut position ``cut``."""
        cyc = hw.ici_transfer_cycles(boundary_activations(layers, cut)
                                     * ACT_BYTES)
        return 1.0 / cyc if cyc > 0 else float("inf")

    INF = float("inf")
    if objective == "sum":
        # T[p][b]: min cycles for layers[:cands[b]] as exactly p partitions
        # (+ their switches); the DP walks candidate boundaries only.
        T = [[INF] * m for _ in range(n_parts + 1)]
        T[0][0] = 0.0
        back = [[-1] * m for _ in range(n_parts + 1)]
        for p in range(1, n_parts + 1):
            # prefixes b < m-1 only feed deeper recursions; the last p level
            # needs the full-pipeline entry alone
            bs = range(p, m) if p < n_parts else (m - 1,)
            for b in bs:
                j = cands[b]
                for a in range(p - 1, b):
                    if T[p - 1][a] == INF:
                        continue
                    i = cands[a]
                    t = T[p - 1][a] + seg.time(i, j, stage_budget(p)) + \
                        (switch_cost(i) if i else 0.0)
                    if t < T[p][b]:
                        T[p][b], back[p][b] = t, a
        # positional hetero runs are pinned to exactly n_parts stages: the
        # kept-chip set is sized for that count, and smaller counts belong
        # to their own outer-loop iteration
        p_opts = (n_parts,) if _positional else range(1, n_parts + 1)
        best_p = min(p_opts, key=lambda p: T[p][m - 1])
        score = [T[p][m - 1] for p in range(n_parts + 1)]
    else:
        # R[p][b]: max achievable min-rate (stage rates and internal ICI
        # hops) for layers[:cands[b]] as exactly p partitions. min() is
        # associative, so the prefix decomposition is exact; +inf seeds the
        # empty prefix. First maximizer wins -> deterministic cuts.
        R = [[-INF] * m for _ in range(n_parts + 1)]
        R[0][0] = INF
        back = [[-1] * m for _ in range(n_parts + 1)]
        for p in range(1, n_parts + 1):
            bs = range(p, m) if p < n_parts else (m - 1,)
            for b in bs:
                j = cands[b]
                for a in range(p - 1, b):
                    if R[p - 1][a] == -INF:
                        continue
                    i = cands[a]
                    r = min(R[p - 1][a],
                            seg.throughput(i, j, stage_budget(p)))
                    if i:
                        r = min(r, hop_rate(i))
                    if r > R[p][b]:
                        R[p][b], back[p][b] = r, a
        # ties on the steady rate prefer the smaller amortized batch time;
        # positional hetero runs are pinned to exactly n_parts stages (see
        # the sum branch)
        p_opts = (n_parts,) if _positional else range(1, n_parts + 1)
        best_rate = max(R[p][m - 1] for p in p_opts)
        tied = [p for p in p_opts
                if R[p][m - 1] >= best_rate * (1 - 1e-12)]

        def _amortized(p: int) -> float:
            total, b = 0.0, m - 1
            for q in range(p, 0, -1):
                a = back[q][b]
                total += seg.time(cands[a], cands[b], stage_budget(q)) + \
                    (switch_cost(cands[a]) if cands[a] else 0.0)
                b = a
            return total
        best_p = min(tied, key=_amortized)
        score = None

    cuts: List[int] = []
    b = m - 1
    for p in range(best_p, 0, -1):
        a = back[p][b]
        if a > 0:
            cuts.append(cands[a])
        b = a
    cuts.reverse()
    bounds = [0] + cuts + [L]
    part_thr = [seg.throughput(a, b, stage_budget(s + 1))
                for s, (a, b) in enumerate(zip(bounds, bounds[1:]))]
    part_designs = [seg.designs(a, b, stage_budget(s + 1))
                    for s, (a, b) in enumerate(zip(bounds, bounds[1:]))]
    steady = min(part_thr) if part_thr else 0.0
    if multi_chip:
        for c in cuts:
            steady = min(steady, hop_rate(c))
    total = sum(seg.time(a, b, stage_budget(s + 1))
                for s, (a, b) in enumerate(zip(bounds, bounds[1:]))) + \
        sum(switch_cost(c) for c in cuts)
    if objective == "sum":
        assert abs(total - score[best_p]) <= 1e-9 * max(total, 1.0)
    return PartitionResult(cuts=cuts, batch=batch, time_per_batch=total,
                           throughput=batch / total if total > 0 else 0.0,
                           part_throughput=part_thr,
                           part_designs=part_designs,
                           steady_throughput=steady,
                           dse_calls=seg.dse_calls,
                           objective=objective,
                           chip_budgets=None if chip_budgets is None
                           else [stage_budget(s + 1)
                                 for s in range(len(bounds) - 1)])


def partition_pipeline_sa(layers: Sequence[LayerCost], hw: HardwareModel,
                          budget: float, *, n_parts: int, batch: int = 256,
                          reconfig_cycles: float = 5e7, seed: int = 0,
                          dse_iters: int = 300) -> PartitionResult:
    """Pre-DP SA-over-cuts implementation, retained as the comparison
    baseline (benchmarks/dse_bench.py, tests/test_partition_dp.py). Re-runs
    the segment DSE inside every annealing energy evaluation — the cost the
    memoized segment table removes. Uses the same switch accounting as
    ``partition_pipeline`` (P - 1 switches per processed batch) so the two
    optimize an identical objective over exactly ``n_parts`` partitions."""
    L = len(layers)
    n_parts = min(n_parts, L)

    def eval_cuts(cuts):
        total = 0.0
        prev = 0
        for c in list(cuts) + [L]:
            part = layers[prev:c]
            if not part:
                return float("inf")
            r = incremental_dse(part, hw, budget, max_iters=dse_iters)
            if r.throughput <= 0:
                return float("inf")
            total += batch / r.throughput
            prev = c
        total += reconfig_cycles * len(list(cuts))
        return total

    if n_parts <= 1:
        t = eval_cuts([])
        return PartitionResult([], batch, t, batch / t)

    init = [round(L * (i + 1) / n_parts) for i in range(n_parts - 1)]

    def neighbor(cuts, rng):
        c = list(cuts)
        i = rng.integers(len(c))
        lo = c[i - 1] + 1 if i else 1
        hi = c[i + 1] - 1 if i + 1 < len(c) else L - 1
        if hi <= lo:
            return c
        c[i] = int(np.clip(c[i] + rng.integers(-2, 3), lo, hi))
        return c

    best, best_e, _ = simulated_annealing(init, eval_cuts, neighbor,
                                          steps=60, seed=seed)
    return PartitionResult(list(best), batch, best_e, batch / best_e)
