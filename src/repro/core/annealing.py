"""Simulated annealing — used by the DSE for (a) the intra-layer balancing
strategy (assigning input-channel/output-filter groups to SPEs so their
processing rates match, §IV) and (b) pipeline partitioning (§V-A.4)."""
from __future__ import annotations

import math
from typing import Callable, List, Sequence

import numpy as np


def simulated_annealing(init_state, energy: Callable, neighbor: Callable,
                        *, steps: int = 2000, t0: float = 1.0,
                        t1: float = 1e-3, seed: int = 0):
    """Generic SA minimizer. Returns (best_state, best_energy, trace)."""
    rng = np.random.default_rng(seed)
    state = init_state
    e = energy(state)
    best, best_e = state, e
    trace = [e]
    for i in range(steps):
        t = t0 * (t1 / t0) ** (i / max(steps - 1, 1))
        cand = neighbor(state, rng)
        ce = energy(cand)
        if ce <= e or rng.random() < math.exp(-(ce - e) / max(t, 1e-12)):
            state, e = cand, ce
            if ce < best_e:
                best, best_e = cand, ce
        trace.append(e)
    return best, best_e, trace


def balance_assignment(rates: Sequence[float], n_engines: int,
                       *, steps: int = 2000, seed: int = 0) -> List[int]:
    """Assign work items with processing ``rates`` to ``n_engines`` engines,
    minimizing the max-engine load (the paper's Balancing Strategy: channels
    x filters onto i x o SPEs). Returns engine index per item."""
    rates = np.asarray(rates, dtype=float)
    n = len(rates)

    def energy(assign):
        loads = np.zeros(n_engines)
        np.add.at(loads, assign, rates)
        return loads.max() - loads.mean()

    def neighbor(assign, rng):
        a = assign.copy()
        a[rng.integers(n)] = rng.integers(n_engines)
        return a

    # greedy LPT init: largest rate -> least-loaded engine
    order = np.argsort(-rates)
    init = np.zeros(n, dtype=int)
    loads = np.zeros(n_engines)
    for idx in order:
        e = int(loads.argmin())
        init[idx] = e
        loads[e] += rates[idx]
    best, _, _ = simulated_annealing(init, energy, neighbor, steps=steps,
                                     seed=seed)
    return list(map(int, best))


def buffer_depths(rates: Sequence[float], window: int = 32,
                  slack: float = 1.5) -> List[int]:
    """The paper's Buffering Strategy heuristic (after [4]): size FIFOs to the
    moving-window variance of inter-engine rate mismatch."""
    rates = np.asarray(rates, dtype=float)
    mu = rates.mean() if len(rates) else 1.0
    # tokens a faster engine can run ahead within one window
    depth = np.ceil(slack * window * np.maximum(rates - mu, 0.0) / max(mu, 1e-9))
    return [int(max(2, d)) for d in depth]
