"""HASS performance model — Eq. 1–3 of the paper, plus the TPU mapping.

The paper models a layer-pipelined sparse dataflow accelerator:

    t(S̄)        = ceil((1 - S̄) * M / N)                     (Eq. 1)
    θ(l, d, S̄)  = (i*o) * M / (C_l * t(S̄))   [outputs/cycle] (Eq. 2)
    θ(network)  = min_l θ(l, d_l, S̄_l)                       (Eq. 3)

where M = weight/activation pairs per dot product, N = MACs per SPE,
i*o = parallel SPEs, C_l = dense MAC count of the layer, and S̄ = probability
that a (weight, activation) pair has at least one zero:
S̄ = 1 - (1 - S_w)(1 - S_a) under the paper's calibration-based estimate.

Two hardware backends implement the same interface:
  * ``FPGAModel``  — the paper's own units (DSPs, 250 MHz, images/s) used by
    the paper-faithful benchmarks (Table II, Fig. 4/5/6).
  * ``TPUModel``   — the TPU-v5e adaptation: SPEs -> MXU tile lanes, DSPs ->
    chip-MXU-seconds, with *tile-granular* compute skipping (a systolic array
    cannot skip single MACs; DESIGN.md §6). Used by the LM-side DSE and the
    §Roofline accounting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------- #
# TPU v5e hardware constants (per chip)
# ---------------------------------------------------------------------- #
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # 2D torus
HBM_BYTES = 16 * 2 ** 30
MXU_TILE = 128               # systolic dim: tiles are 128-aligned
ACT_BYTES = 2                # bf16 activations (ICI boundary transfers)


@dataclass
class LayerCost:
    """One pipeline-stage workload (the paper's (l, C_l, M) triple + memory)."""
    name: str
    macs: int                     # dense MACs per sample (C_l)
    m_dot: int                    # M: pairs per dot product (fan-in)
    weight_count: int
    act_in: int                   # input activations per sample
    act_out: int
    kind: str = "linear"          # conv | linear | attn | other
    prunable: bool = True
    s_w: float = 0.0              # weight sparsity (compile-time)
    s_a: float = 0.0              # activation sparsity (calibrated)
    s_w_tile: float = 0.0         # fraction of all-zero weight tiles (TPU skip)
    pattern: str = "unstructured"  # sparsity pattern (pruning.PATTERNS §16)
    t_scale: float = 1.0          # per-pattern decode-cost multiplier on the
    #                               t_cycles numerator (1.0 = free skipping)

    @property
    def s_pair(self) -> float:
        """S̄: P(weight==0 or activation==0)."""
        return 1.0 - (1.0 - self.s_w) * (1.0 - self.s_a)

    @property
    def s_pair_tile(self) -> float:
        """Tile-granular S̄ for the MXU backend (weight tiles only are
        skippable at compile time; activation sparsity does not skip MXU
        compute — DESIGN.md §6)."""
        return self.s_w_tile


def pair_sparsity(s_w: float, s_a: float) -> float:
    return 1.0 - (1.0 - s_w) * (1.0 - s_a)


def t_cycles(s_bar: float, M: int, N: int, scale: float = 1.0) -> int:
    """Eq. 1: initiation interval of one SPE. ``scale`` is the per-pattern
    decode-cost multiplier on the non-zero work (DESIGN.md §16) — the
    default 1.0 takes the original expression path, so pre-pattern callers
    are bit-identical."""
    om = (1.0 - s_bar) * M
    if scale != 1.0:
        om = om * scale
    return max(1, math.ceil(om / max(N, 1)))


@dataclass
class DesignPoint:
    """d in the paper: per-layer hardware allocation."""
    spe: int = 1                  # i*o parallel engines (FPGA) / tile lanes (TPU)
    macs_per_spe: int = 1         # N


@dataclass
class LayerVectors:
    """Per-layer workload constants as flat arrays — the vectorized DSE's
    view of a pipeline. Design state lives outside this struct as two int
    arrays (spe, macs_per_spe); designs only ever double/halve, so the whole
    search state is those two small vectors (DESIGN.md §7).
    """
    macs: np.ndarray        # (L,) int64 — C_l
    m_dot: np.ndarray       # (L,) int64 — M
    s_eff: np.ndarray       # (L,) float64 — hardware-effective S̄
    max_n: np.ndarray       # (L,) int64
    max_spe: np.ndarray     # (L,) int64
    res_unit: np.ndarray    # (L,) float64 — resource per (spe * macs_per_spe)
    t_scale: "Optional[np.ndarray]" = None   # (L,) float64 per-pattern
    #   decode-cost multiplier on the t_cycles numerator, or None (== all
    #   ones, the pre-pattern path: every engine keeps the original float
    #   expressions bit-for-bit; DESIGN.md §16)

    def __len__(self) -> int:
        return len(self.macs)


@dataclass
class HardwareModel:
    freq: float = 250e6

    def layer_throughput(self, l: LayerCost, d: DesignPoint) -> float:
        """Eq. 2, in samples/cycle."""
        t = t_cycles(self.effective_sparsity(l), l.m_dot, d.macs_per_spe,
                     l.t_scale)
        return d.spe * l.m_dot / (l.macs * t) if l.macs else float("inf")

    def effective_sparsity(self, l: LayerCost) -> float:
        raise NotImplementedError

    def layer_resource(self, l: LayerCost, d: DesignPoint) -> float:
        raise NotImplementedError

    def max_n(self, l: LayerCost) -> int:
        return max(1, l.m_dot)

    def max_spe(self, l: LayerCost) -> int:
        return max(1, l.macs // max(l.m_dot, 1))

    # ------------------------------------------------------------------ #
    # Vectorized API (the DSE hot path operates on these; DESIGN.md §7)
    # ------------------------------------------------------------------ #
    def layer_vectors(self, layers: Sequence[LayerCost]) -> LayerVectors:
        """Freeze a pipeline's workload constants into arrays. ``res_unit``
        is derived from ``layer_resource`` at the unit design, so any model
        whose resource is proportional to spe*macs_per_spe (both backends
        here) stays consistent with the scalar API by construction."""
        unit = DesignPoint(1, 1)
        return LayerVectors(
            macs=np.array([l.macs for l in layers], dtype=np.int64),
            m_dot=np.array([l.m_dot for l in layers], dtype=np.int64),
            s_eff=np.array([self.effective_sparsity(l) for l in layers],
                           dtype=np.float64),
            max_n=np.array([self.max_n(l) for l in layers], dtype=np.int64),
            max_spe=np.array([self.max_spe(l) for l in layers],
                             dtype=np.int64),
            res_unit=np.array([self.layer_resource(l, unit) for l in layers],
                              dtype=np.float64),
            t_scale=self._t_scale_vec(layers))

    @staticmethod
    def _t_scale_vec(layers: Sequence[LayerCost]) -> Optional[np.ndarray]:
        """Per-layer decode-cost multipliers, or None when every layer is
        at the free-skipping default — the None sentinel keeps the engines,
        the cache fingerprint, and the compiled-C dispatch on their exact
        pre-pattern paths (DESIGN.md §16)."""
        ts = [l.t_scale for l in layers]
        if all(v == 1.0 for v in ts):
            return None
        return np.array(ts, dtype=np.float64)

    def throughput_vec(self, lv: LayerVectors, spe: np.ndarray,
                       n: np.ndarray) -> np.ndarray:
        """Eq. 1–2 over all layers at once; float-for-float identical to
        ``layer_throughput`` (same operation order, products < 2**53)."""
        om = (1.0 - lv.s_eff) * lv.m_dot
        if lv.t_scale is not None:
            om = om * lv.t_scale
        t = np.maximum(1.0, np.ceil(om / np.maximum(n, 1)))
        with np.errstate(divide="ignore"):
            thr = (spe * lv.m_dot) / (lv.macs * t)
        return np.where(lv.macs > 0, thr, np.inf)

    def resource_vec(self, lv: LayerVectors, spe: np.ndarray,
                     n: np.ndarray) -> np.ndarray:
        return spe * n * lv.res_unit


@dataclass
class FPGAModel(HardwareModel):
    """The paper's backend: resource = DSPs (1 DSP per MAC), 250 MHz."""
    dsp_budget: float = 12288     # Alveo U250

    def effective_sparsity(self, l: LayerCost) -> float:
        return l.s_pair if l.prunable else 0.0

    def layer_resource(self, l: LayerCost, d: DesignPoint) -> float:
        return d.spe * d.macs_per_spe


@dataclass
class TPUModel(HardwareModel):
    """TPU adaptation: an SPE lane is one 128x128 MXU tile-row pass; N maps to
    tiles processed per pass; resource = chip-MXU occupancy (in tile-lanes).
    Compute skipping is tile-granular (s_w_tile).

    ``chips > 1`` models a multi-chip slice: a pipeline partition is resident
    on one chip (a mesh program does not span chips), so per-partition DSE
    runs against ``chip_budget`` and the partition handoff is an ICI transfer
    of the boundary activations (``ici_transfer_cycles``) instead of an FPGA
    full reconfiguration — DESIGN.md §10.

    ``chip_lanes`` models a *heterogeneous* (mixed-generation) slice: per-chip
    tile-lane budgets, one entry per chip. ``chip_budgets`` expands either
    spelling to the per-chip tuple the max-min DP's budget lookup reads
    (``partition_pipeline(chip_budgets=...)`` — DESIGN.md §13)."""
    freq: float = 940e6           # v5e MXU clock
    chips: int = 1
    lanes_per_chip: int = 4 * 128  # 4 MXUs x 128 rows
    chip_lanes: Optional[Sequence[float]] = None   # per-chip lane budgets

    def effective_sparsity(self, l: LayerCost) -> float:
        """Per-pattern hardware-effective S̄ (DESIGN.md §16): the MXU skips
        whole all-zero tiles for unstructured pruning (``s_w_tile``), but a
        compile-time N:M / hierarchical structure is decodable at group
        granularity — the structured decode path (cf. 2:4 sparse cores)
        skips every structured zero, so those patterns spend the full
        element sparsity ``s_w`` (paying their decode cost through
        ``t_scale``). Activation sparsity never skips MXU compute."""
        if not l.prunable:
            return 0.0
        if l.pattern in ("nm", "hierarchical"):
            return l.s_w
        return l.s_pair_tile

    def layer_resource(self, l: LayerCost, d: DesignPoint) -> float:
        return d.spe * d.macs_per_spe / MXU_TILE   # tile-lane occupancy

    @property
    def budget(self) -> float:
        return float(sum(self.chip_budgets))

    @property
    def chip_budgets(self) -> Tuple[float, ...]:
        """Per-chip tile-lane budgets. Uniform ``lanes_per_chip`` unless the
        slice is heterogeneous (``chip_lanes``); pipeline stage ``p`` is
        resident on chip ``p``, so the DP prices segment DSEs against the
        stage's own chip."""
        if self.chip_lanes is not None:
            if len(self.chip_lanes) != self.chips:
                raise ValueError(
                    f"chip_lanes has {len(self.chip_lanes)} entries for "
                    f"{self.chips} chips")
            return tuple(float(b) for b in self.chip_lanes)
        return (float(self.lanes_per_chip),) * self.chips

    @property
    def chip_budget(self) -> float:
        """Tile-lane budget of a single chip (one resident partition). On a
        heterogeneous slice this is the largest chip — the one a single
        resident partition would land on; per-stage budgets go through
        ``chip_budgets``."""
        return max(self.chip_budgets)

    def ici_transfer_cycles(self, n_bytes: float) -> float:
        """MXU cycles to move ``n_bytes`` across one chip-to-chip hop, all
        torus links aggregated (the roofline collective constants)."""
        return n_bytes / (ICI_BW * ICI_LINKS) * self.freq


def pipeline_throughput(layers: Sequence[LayerCost],
                        designs: Sequence[DesignPoint],
                        hw: HardwareModel) -> float:
    """Eq. 3, samples/cycle."""
    return min(hw.layer_throughput(l, d) for l, d in zip(layers, designs))


# ---------------------------------------------------------------------- #
# Workload extraction: CNNs (paper models) and LMs (assigned archs)
# ---------------------------------------------------------------------- #
def cnn_layer_costs(cfg: ModelConfig) -> List[LayerCost]:
    from repro.models.cnn import build_specs
    out: List[LayerCost] = []
    for s in build_specs(cfg):
        if s.kind == "conv":
            m = s.cin * s.k * s.k
        elif s.kind == "dwconv":
            m = s.k * s.k
        elif s.kind == "linear":
            m = s.cin
        elif s.kind == "se":
            m = s.cin
        else:
            continue
        out.append(LayerCost(
            name=s.name, macs=s.macs, m_dot=m, weight_count=s.weights,
            act_in=s.cin * s.in_hw ** 2 if s.in_hw else s.cin,
            act_out=s.cout * s.out_hw ** 2 if s.out_hw else s.cout,
            kind="conv" if s.kind in ("conv", "dwconv") else "linear",
            prunable=s.prunable))
    return out


def lm_layer_costs(cfg: ModelConfig, seq_len: int = 1,
                   per_layer: bool = True) -> List[LayerCost]:
    """Per-transformer-layer matmul workloads, per token (sample = token)."""
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    out: List[LayerCost] = []

    def add(name, cin, cout, n_apply=1, kind="linear", prunable=True):
        out.append(LayerCost(name=name, macs=cin * cout * n_apply, m_dot=cin,
                             weight_count=cin * cout, act_in=cin * n_apply,
                             act_out=cout * n_apply, kind=kind,
                             prunable=prunable))

    L = cfg.num_layers
    for i in range(L if per_layer else 1):
        tag = f"l{i}"
        if cfg.mla is not None:
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            add(f"{tag}.wq_a", d, m.q_lora_rank)
            add(f"{tag}.wq_b", m.q_lora_rank, H * qk)
            add(f"{tag}.wkv_a", d, m.kv_lora_rank + m.qk_rope_head_dim)
            add(f"{tag}.wkv_b", m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim))
            add(f"{tag}.wo", H * m.v_head_dim, d)
            attn_macs = H * (qk + m.v_head_dim) * seq_len
        elif cfg.rwkv is not None:
            for nm in ("wr", "wk", "wv", "wg", "wo"):
                add(f"{tag}.{nm}", d, d)
            add(f"{tag}.cm_wk", d, cfg.d_ff)
            add(f"{tag}.cm_wv", cfg.d_ff, d)
            add(f"{tag}.cm_wr", d, d)
            attn_macs = d * cfg.rwkv.head_dim      # state update per token
        elif cfg.ssm is not None:
            s = cfg.ssm
            d_in = s.expand * d
            add(f"{tag}.in_proj", d, 2 * d_in + 2 * s.state_dim + d_in // s.head_dim)
            add(f"{tag}.out_proj", d_in, d)
            attn_macs = d_in * s.state_dim * 2     # SSD state update per token
        else:
            add(f"{tag}.wq", d, H * hd)
            add(f"{tag}.wk", d, KV * hd)
            add(f"{tag}.wv", d, KV * hd)
            add(f"{tag}.wo", H * hd, d)
            win = cfg.attn_window or seq_len
            attn_macs = H * hd * min(seq_len, win)
        # attention score/value (not weight-prunable: data-data product)
        out.append(LayerCost(name=f"{tag}.attn", macs=2 * attn_macs,
                             m_dot=hd, weight_count=0, act_in=d, act_out=d,
                             kind="attn", prunable=False))
        if cfg.moe is not None:
            fe = cfg.moe.expert_d_ff or cfg.d_ff
            active = cfg.moe.top_k + cfg.moe.num_shared_experts
            add(f"{tag}.moe_gate", d, fe, n_apply=active)
            add(f"{tag}.moe_up", d, fe, n_apply=active)
            add(f"{tag}.moe_down", fe, d, n_apply=active)
        elif cfg.ssm is None and cfg.rwkv is None:
            add(f"{tag}.w_gate", d, cfg.d_ff)
            add(f"{tag}.w_up", d, cfg.d_ff)
            add(f"{tag}.w_down", cfg.d_ff, d)
        if cfg.hybrid_attn_every and i % cfg.hybrid_attn_every == 0:
            add(f"{tag}.shared_qkvo", 2 * d, 4 * d)   # concat-proj + attn blk
            add(f"{tag}.shared_ffn", d, 2 * cfg.d_ff)
    add("unembed", d, cfg.vocab_size)
    return out


def lm_block_bounds(layers: Sequence[LayerCost]) -> List[int]:
    """Block boundaries of an ``lm_layer_costs`` stack: the indices ``k``
    where ``layers[k]`` starts a new transformer block (the ``l{i}.`` name
    prefix changes; ``unembed`` is its own block). These are the natural cut
    positions for partitioning an LM pipeline across chips — a cut inside a
    block would split a residual stream mid-layer (DESIGN.md §11)."""
    bounds: List[int] = []
    prev = None
    for k, l in enumerate(layers):
        tag = l.name.split(".", 1)[0]
        if tag != prev:
            if k:
                bounds.append(k)
            prev = tag
    return bounds


def thin_cut_points(bounds: Sequence[int], max_cuts: int) -> List[int]:
    """Evenly subsample candidate cut positions down to ``max_cuts`` (keeps
    the DP's segment table at O(max_cuts^2) DSEs on deep LM stacks)."""
    bounds = list(bounds)
    if max_cuts <= 0 or len(bounds) <= max_cuts:
        return bounds
    idx = np.linspace(0, len(bounds) - 1, max_cuts).round().astype(int)
    return [bounds[i] for i in sorted(set(int(i) for i in idx))]


def tile_quantize_sparsity(s_w: float, m_dot: int, weight_count: int) -> float:
    """Largest achievable tile-granular sparsity <= ``s_w`` for a weight
    matrix of shape (m_dot, weight_count/m_dot) pruned in whole 128x128
    tiles. The MXU can only skip all-zero 128-aligned tiles (DESIGN.md §6),
    so a tile-structured pruner realizes sparsity in steps of 1/n_tiles."""
    if weight_count <= 0 or m_dot <= 0:
        return 0.0
    cout = max(1, weight_count // m_dot)
    n_tiles = math.ceil(m_dot / MXU_TILE) * math.ceil(cout / MXU_TILE)
    return math.floor(min(max(s_w, 0.0), 1.0) * n_tiles) / n_tiles


def param_count(cfg: ModelConfig) -> int:
    total = sum(l.weight_count for l in lm_layer_costs(cfg)) \
        if cfg.family != "cnn" else sum(l.weight_count for l in cnn_layer_costs(cfg))
    if cfg.family != "cnn":
        total += cfg.vocab_size * cfg.d_model        # embed
        if cfg.moe is not None:                      # all experts (not just active)
            fe = cfg.moe.expert_d_ff or cfg.d_ff
            inactive = cfg.moe.num_experts - cfg.moe.top_k
            total += cfg.num_layers * inactive * 3 * cfg.d_model * fe
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params."""
    active = sum(l.weight_count for l in lm_layer_costs(cfg)) \
        if cfg.family != "cnn" else sum(l.weight_count for l in cnn_layer_costs(cfg))
    if cfg.family != "cnn":
        active += cfg.vocab_size * cfg.d_model
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


# ---------------------------------------------------------------------- #
# Roofline terms (used by analysis/roofline.py on dry-run artifacts)
# ---------------------------------------------------------------------- #
@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int) -> RooflineTerms:
    return RooflineTerms(
        compute_s=hlo_flops / (chips * PEAK_FLOPS),
        memory_s=hlo_bytes / (chips * HBM_BW),
        collective_s=collective_bytes / (chips * ICI_BW))
