"""One-shot magnitude pruning (§III of the paper).

Per-layer thresholds tau_w zero out weights with |w| < tau_w at compile time
(weight sparsity S_w, static); per-layer tau_a are applied at run time by the
clip units (``models.common.act_clip`` / the ``act_clip`` Pallas kernel),
giving dynamic activation sparsity S_a. No fine-tuning (one-shot,
post-training), exactly as in the paper.

Thresholds are parameterized by *target sparsity* (quantile of |w|): the TPE
search proposes sparsity levels in [0, s_max] and we derive tau from the
weight distribution — numerically better-conditioned than raw thresholds and
identical in expressive power (monotone bijection).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- #
# Weight pruning
# --------------------------------------------------------------------- #
def threshold_for_sparsity(w: jnp.ndarray, sparsity) -> jnp.ndarray:
    """tau such that P(|w| < tau) ~= sparsity. Jit-safe (sparsity may trace)."""
    a = jnp.abs(w).reshape(-1)
    q = jnp.quantile(a, jnp.clip(sparsity, 0.0, 1.0))
    return jnp.where(jnp.asarray(sparsity) <= 0.0, 0.0, q)


def prune_tensor(w: jnp.ndarray, tau) -> jnp.ndarray:
    return jnp.where(jnp.abs(w) >= tau, w, jnp.zeros_like(w))


def prune_by_sparsity(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    return prune_tensor(w, threshold_for_sparsity(w, sparsity))


def sparsity_of(w: jnp.ndarray) -> float:
    return float(jnp.mean(w == 0.0))


def tile_sparsity(w: jnp.ndarray, bk: int = 128, bn: int = 128) -> float:
    """Fraction of (bk, bn) weight tiles that are entirely zero — the compute
    the MXU backend can actually skip (static tile schedule)."""
    if w.ndim != 2:
        w = w.reshape(-1, w.shape[-1])
    K, N = w.shape
    pk, pn = (-K) % bk, (-N) % bn
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    t = wp.reshape((K + pk) // bk, bk, (N + pn) // bn, bn)
    nonzero = jnp.any(t != 0, axis=(1, 3))
    return float(1.0 - jnp.mean(nonzero))


def prune_params(params: Dict[str, Any],
                 sparsities: Dict[str, float],
                 match: Optional[Callable[[str], bool]] = None
                 ) -> Tuple[Dict[str, Any], Dict[str, float]]:
    """One-shot prune a params pytree.

    sparsities: maps flat path ("blocks/attn/wq") to target sparsity. For
    stacked-layer params a 1-leaf path prunes each layer slice with its own
    quantile threshold when the value is a (L,)-vector, or uniformly when
    scalar. Returns (pruned_params, achieved element sparsity per path).
    """
    flat = _flatten(params)
    achieved: Dict[str, float] = {}
    new_flat = {}
    for path, w in flat.items():
        s = sparsities.get(path)
        if s is None or (match and not match(path)):
            new_flat[path] = w
            continue
        if np.ndim(s) == 1 and w.ndim >= 2 and w.shape[0] == len(s):
            taus = jax.vmap(threshold_for_sparsity)(
                w.reshape(w.shape[0], -1), jnp.asarray(s))
            w2 = prune_tensor(w, taus.reshape((-1,) + (1,) * (w.ndim - 1)))
        else:
            w2 = prune_by_sparsity(w, float(np.mean(s)))
        new_flat[path] = w2
        achieved[path] = sparsity_of(w2)
    return _unflatten(new_flat), achieved


def _flatten(tree, prefix="") -> Dict[str, jnp.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, jnp.ndarray]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


PRUNABLE_TOKENS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "wq_a", "wq_b", "wkv_a", "wkv_b", "router", "shared_w",
                   "cm_w", "wr", "wg", "in_proj", "out_proj", "lm_head", "w")


def default_prunable(path: str) -> bool:
    leaf = path.rsplit("/", 1)[-1]
    return any(leaf == t or leaf.startswith(t) for t in PRUNABLE_TOKENS) and \
        "norm" not in path and "ln" not in leaf and "embed" not in path


# --------------------------------------------------------------------- #
# Activation sparsity (dynamic): calibration + analytic model
# --------------------------------------------------------------------- #
def act_sparsity_gaussian(tau: float, sigma: float = 1.0) -> float:
    """P(|x| < tau) for x ~ N(0, sigma^2) — the analytic estimate used to
    extrapolate calibration results to full-size LMs (pre-matmul activations
    sit behind RMSNorm, so sigma ~= 1; validated in tests vs smoke models)."""
    return math.erf(tau / (sigma * math.sqrt(2.0)))


def tau_for_act_sparsity(s: float, sigma: float = 1.0) -> float:
    """Inverse of ``act_sparsity_gaussian`` via bisection."""
    if s <= 0:
        return 0.0
    lo, hi = 0.0, 8.0 * sigma
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if act_sparsity_gaussian(mid, sigma) < s:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def calibrate_activation_sparsity(forward_stats: Callable[[], Dict[str, jnp.ndarray]]
                                  ) -> Dict[str, float]:
    """Run a stats-collecting forward (e.g. cnn.forward(collect_stats=True))
    and return measured per-layer input zero fractions."""
    stats = forward_stats()
    return {k: float(v) for k, v in stats.items()}
