"""One-shot magnitude pruning (§III of the paper).

Per-layer thresholds tau_w zero out weights with |w| < tau_w at compile time
(weight sparsity S_w, static); per-layer tau_a are applied at run time by the
clip units (``models.common.act_clip`` / the ``act_clip`` Pallas kernel),
giving dynamic activation sparsity S_a. No fine-tuning (one-shot,
post-training), exactly as in the paper.

Thresholds are parameterized by *target sparsity* (quantile of |w|): the TPE
search proposes sparsity levels in [0, s_max] and we derive tau from the
weight distribution — numerically better-conditioned than raw thresholds and
identical in expressive power (monotone bijection).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- #
# Weight pruning
# --------------------------------------------------------------------- #
def threshold_for_sparsity(w: jnp.ndarray, sparsity) -> jnp.ndarray:
    """tau such that P(|w| < tau) ~= sparsity. Jit-safe (sparsity may trace)."""
    a = jnp.abs(w).reshape(-1)
    q = jnp.quantile(a, jnp.clip(sparsity, 0.0, 1.0))
    return jnp.where(jnp.asarray(sparsity) <= 0.0, 0.0, q)


def prune_tensor(w: jnp.ndarray, tau) -> jnp.ndarray:
    return jnp.where(jnp.abs(w) >= tau, w, jnp.zeros_like(w))


def sorted_abs(w: jnp.ndarray) -> jnp.ndarray:
    """Sorted |w| vector — the precomputable half of a quantile threshold.
    Weights are constant across a whole sparsity search, so sorting once
    and gathering per proposal replaces the O(n log n) sort that
    ``jnp.quantile`` re-runs inside every evaluation (DESIGN.md §12)."""
    return jnp.sort(jnp.abs(w).reshape(-1))


def sorted_quantile(asort: jnp.ndarray, q) -> jnp.ndarray:
    """``jnp.quantile(a, q)`` (method='linear') on a pre-sorted 1-D array.

    Replicates jax's ``_quantile`` lax-op structure operation for operation
    (scale, floor/ceil, clamp, two gathers, lerp as low*lw + high*hw) so the
    result is bit-identical to calling ``jnp.quantile`` on the unsorted
    data — property-tested in ``tests/test_pruning_tpe.py``. Jit-safe
    (``q`` may trace)."""
    from jax import lax
    q = jnp.asarray(q, asort.dtype)
    n = lax.convert_element_type(asort.shape[0], q.dtype)
    q = lax.mul(q, n - 1)
    low = lax.floor(q)
    high = lax.ceil(q)
    high_weight = lax.sub(q, low)
    low_weight = lax.sub(jnp.asarray(1, high_weight.dtype), high_weight)
    low = lax.clamp(jnp.asarray(0, low.dtype), low, n - 1)
    high = lax.clamp(jnp.asarray(0, high.dtype), high, n - 1)
    low_value = asort[lax.convert_element_type(low, jnp.int32)]
    high_value = asort[lax.convert_element_type(high, jnp.int32)]
    return lax.add(lax.mul(low_value.astype(q.dtype), low_weight),
                   lax.mul(high_value.astype(q.dtype), high_weight))


def threshold_for_sparsity_sorted(asort: jnp.ndarray, sparsity) -> jnp.ndarray:
    """``threshold_for_sparsity`` reading a ``sorted_abs`` table instead of
    sorting — bit-identical tau (same clip/zero-floor semantics)."""
    q = sorted_quantile(asort, jnp.clip(sparsity, 0.0, 1.0))
    return jnp.where(jnp.asarray(sparsity) <= 0.0, 0.0, q)


def prune_by_sparsity(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    return prune_tensor(w, threshold_for_sparsity(w, sparsity))


def sparsity_of(w: jnp.ndarray) -> float:
    return float(jnp.mean(w == 0.0))


def tile_sparsity(w: jnp.ndarray, bk: int = 128, bn: int = 128) -> float:
    """Fraction of (bk, bn) weight tiles that are entirely zero — the compute
    the MXU backend can actually skip (static tile schedule)."""
    if w.ndim != 2:
        w = w.reshape(-1, w.shape[-1])
    K, N = w.shape
    pk, pn = (-K) % bk, (-N) % bn
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    t = wp.reshape((K + pk) // bk, bk, (N + pn) // bn, bn)
    nonzero = jnp.any(t != 0, axis=(1, 3))
    return float(1.0 - jnp.mean(nonzero))


def tile_prune(w: jnp.ndarray, sparsity, bk: int = 128, bn: int = 128):
    """Tile-structured one-shot pruning: zero out whole 128-aligned
    (bk, bn) tiles, lowest mean-|w| first, targeting a ``sparsity``
    fraction of all-zero tiles — the only sparsity pattern the MXU backend
    can actually skip (``LayerCost.s_w_tile``, DESIGN.md §6/§12).

    Non-2D weights flatten leading dims (a conv's (k, k, cin, cout) prunes
    as the (k*k*cin, cout) matmul the lowering runs); ragged edges are
    zero-padded for tile scoring, so boundary tiles rank slightly lower.
    Jit-safe (``sparsity`` may trace). Returns ``(pruned w, realized
    fraction of all-zero tiles)`` — realized is *measured* on the pruned
    tensor (quantile ties can under-shoot the target; pre-existing zero
    tiles count)."""
    orig_shape = w.shape
    w2 = w if w.ndim == 2 else w.reshape(-1, w.shape[-1])
    K, N = w2.shape
    pk, pn = (-K) % bk, (-N) % bn
    wp = jnp.pad(w2, ((0, pk), (0, pn)))
    Kt, Nt = (K + pk) // bk, (N + pn) // bn
    tiles = wp.reshape(Kt, bk, Nt, bn)
    norms = jnp.mean(jnp.abs(tiles), axis=(1, 3))
    tau = jnp.quantile(norms.reshape(-1), jnp.clip(sparsity, 0.0, 1.0))
    keep = norms >= tau
    keep = jnp.where(jnp.asarray(sparsity) <= 0.0,
                     jnp.ones_like(keep), keep)
    pruned_tiles = tiles * keep[:, None, :, None]
    zero_frac = 1.0 - jnp.mean(jnp.any(pruned_tiles != 0, axis=(1, 3)))
    out = pruned_tiles.reshape(K + pk, N + pn)[:K, :N].reshape(orig_shape)
    return out, zero_frac


# --------------------------------------------------------------------- #
# Sparsity patterns (DESIGN.md §16): the pattern axis the search picks per
# matrix kind. "unstructured" is the paper's element/tile pruner;
# "nm" keeps N of every M consecutive weights along the reduction dim;
# "hierarchical" composes tile-level pruning with intra-tile N:M
# (HighLight-style); "activation" realizes the budget as runtime
# activation clipping instead of weight zeros (SparseNN-style).
# --------------------------------------------------------------------- #
PATTERNS = ("unstructured", "nm", "hierarchical", "activation")

#: group size M of the N:M patterns — 8 matches the sublane granularity a
#: structured decoder indexes (achievable sparsity grid is k/8, k=0..7)
NM_M = 8


def nm_keep_for_sparsity(s, m: int = NM_M):
    """Keep-count n of the largest achievable N:M grid point 1 - n/m <= s.
    Jit-safe (``s`` may trace); never returns < 1 (a group always keeps at
    least one weight, so the grid tops out at 1 - 1/m)."""
    z = jnp.floor(jnp.clip(jnp.asarray(s), 0.0, 1.0) * m)
    return jnp.clip(m - z, 1, m)


def nm_sparsity_grid(s, m: int = NM_M):
    """Realized sparsity 1 - n/m of ``nm_keep_for_sparsity`` — numpy-safe
    (the analytic LM evaluator snaps targets with this)."""
    s = np.clip(np.asarray(s, dtype=np.float64), 0.0, 1.0)
    n = np.clip(m - np.floor(s * m), 1, m)
    return 1.0 - n / m


def nm_prune(w: jnp.ndarray, n, m: int = NM_M) -> jnp.ndarray:
    """N:M structured pruning: within every group of ``m`` consecutive
    weights along the reduction dim (rows of the (m_dot, cout) matmul view;
    non-2D weights flatten leading dims like ``tile_prune``), keep the ``n``
    largest-|w| and zero the rest. Exactly ``n`` survivors per group —
    ties break to the lower row index (stable argsort), so ``sparsity_of``
    on a dense input is exactly ``1 - n/m`` when the reduction dim divides
    ``m``. Jit-safe (``n`` may trace: the keep test is a rank compare)."""
    orig_shape = w.shape
    w2 = w if w.ndim == 2 else w.reshape(-1, w.shape[-1])
    K, N = w2.shape
    pad = (-K) % m
    wp = jnp.pad(w2, ((0, pad), (0, 0)))
    g = wp.reshape(-1, m, N)                        # (groups, m, N)
    a = jnp.abs(g)
    order = jnp.argsort(-a, axis=1)                 # descending, stable
    ranks = jnp.argsort(order, axis=1)              # rank of each element
    keep = ranks < jnp.asarray(n)
    out = (g * keep).reshape(K + pad, N)[:K]
    return out.reshape(orig_shape)


def hierarchical_prune(w: jnp.ndarray, tile_frac, n, m: int = NM_M,
                       bk: int = 128, bn: int = 128):
    """Hierarchical structured pruning (HighLight): tile-level pruning then
    intra-tile N:M — literally the composition
    ``nm_prune(tile_prune(w, tile_frac)[0], n, m)`` (the property-test
    oracle). Zeroed tiles keep all-zero groups under N:M (zeros rank last),
    so both levels survive in the output. Returns ``(pruned w, realized
    all-zero-tile fraction)`` like ``tile_prune``."""
    wt, ztile = tile_prune(w, tile_frac, bk=bk, bn=bn)
    return nm_prune(wt, n, m), ztile


def act_realize_pattern(s_w, s_a):
    """Activation-pattern realization hook: the searched weight-axis budget
    is spent as EXTRA runtime activation clipping (the weights stay dense).
    Independent clip events compose like pair sparsity: the combined
    activation target is 1 - (1-s_a)(1-s_w). numpy/jnp generic."""
    return 1.0 - (1.0 - s_a) * (1.0 - s_w)


def prune_params(params: Dict[str, Any],
                 sparsities: Dict[str, float],
                 match: Optional[Callable[[str], bool]] = None
                 ) -> Tuple[Dict[str, Any], Dict[str, float]]:
    """One-shot prune a params pytree.

    sparsities: maps flat path ("blocks/attn/wq") to target sparsity. For
    stacked-layer params a 1-leaf path prunes each layer slice with its own
    quantile threshold when the value is a (L,)-vector, or uniformly when
    scalar. Returns (pruned_params, achieved element sparsity per path).
    """
    flat = _flatten(params)
    achieved: Dict[str, float] = {}
    new_flat = {}
    for path, w in flat.items():
        s = sparsities.get(path)
        if s is None or (match and not match(path)):
            new_flat[path] = w
            continue
        if np.ndim(s) == 1 and w.ndim >= 2 and w.shape[0] == len(s):
            taus = jax.vmap(threshold_for_sparsity)(
                w.reshape(w.shape[0], -1), jnp.asarray(s))
            w2 = prune_tensor(w, taus.reshape((-1,) + (1,) * (w.ndim - 1)))
        else:
            w2 = prune_by_sparsity(w, float(np.mean(s)))
        new_flat[path] = w2
        achieved[path] = sparsity_of(w2)
    return _unflatten(new_flat), achieved


def _flatten(tree, prefix="") -> Dict[str, jnp.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, jnp.ndarray]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = v
    return root


PRUNABLE_TOKENS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "wq_a", "wq_b", "wkv_a", "wkv_b", "router", "shared_w",
                   "cm_w", "wr", "wg", "in_proj", "out_proj", "lm_head", "w")


def default_prunable(path: str) -> bool:
    leaf = path.rsplit("/", 1)[-1]
    return any(leaf == t or leaf.startswith(t) for t in PRUNABLE_TOKENS) and \
        "norm" not in path and "ln" not in leaf and "embed" not in path


# --------------------------------------------------------------------- #
# Activation sparsity (dynamic): calibration + analytic model
# --------------------------------------------------------------------- #
def act_sparsity_gaussian(tau: float, sigma: float = 1.0) -> float:
    """P(|x| < tau) for x ~ N(0, sigma^2) — the analytic estimate used to
    extrapolate calibration results to full-size LMs (pre-matmul activations
    sit behind RMSNorm, so sigma ~= 1; validated in tests vs smoke models)."""
    return math.erf(tau / (sigma * math.sqrt(2.0)))


def tau_for_act_sparsity(s: float, sigma: float = 1.0) -> float:
    """Inverse of ``act_sparsity_gaussian`` via bisection."""
    if s <= 0:
        return 0.0
    lo, hi = 0.0, 8.0 * sigma
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if act_sparsity_gaussian(mid, sigma) < s:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def calibrate_activation_sparsity(forward_stats: Callable[[], Dict[str, jnp.ndarray]]
                                  ) -> Dict[str, float]:
    """Run a stats-collecting forward (e.g. cnn.forward(collect_stats=True))
    and return measured per-layer input zero fractions."""
    stats = forward_stats()
    return {k: float(v) for k, v in stats.items()}
