"""Tree-structured Parzen Estimator (Bergstra et al., NeurIPS'11) — the
multi-objective search engine of §V-B. Self-contained numpy implementation.

Maximizes f(x) over a box [lo, hi]^D: after ``n_startup`` random trials,
split observations at the γ-quantile into good/bad sets, fit diagonal Parzen
(KDE) densities l(x), g(x), and pick the candidate maximizing l(x)/g(x)
among ``n_ei`` samples drawn from l.

Categorical dims (``cats``; DESIGN.md §16): a dim with cardinality k > 0
lives on [lo, lo+k) and every proposal is snapped to a bin center
``lo + floor(x - lo) + 0.5`` AFTER the continuous machinery runs — the
quantization consumes no RNG, so a search with ``cats=None`` (the default)
replays the pre-categorical stream bit-for-bit, and mixed spaces (some
continuous, some categorical dims) need no special-case sampling: the KDE
simply sees clustered bin centers and reproduces the classic
one-Parzen-per-category TPE behavior in the limit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class TPE:
    lo: np.ndarray
    hi: np.ndarray
    gamma: float = 0.25
    n_startup: int = 10
    n_ei: int = 48
    seed: int = 0
    cats: Optional[np.ndarray] = None   # per-dim cardinality (0=continuous)
    xs: List[np.ndarray] = field(default_factory=list)
    ys: List[float] = field(default_factory=list)

    def __post_init__(self):
        self.lo = np.asarray(self.lo, float)
        self.hi = np.asarray(self.hi, float)
        if self.cats is not None:
            self.cats = np.asarray(self.cats, np.int64)
            if len(self.cats) != len(self.lo):
                raise ValueError(f"cats has {len(self.cats)} dims, "
                                 f"box has {len(self.lo)}")
            k = self.cats > 0
            if not np.allclose(self.hi[k] - self.lo[k], self.cats[k]):
                raise ValueError("categorical dims need hi - lo == "
                                 "cardinality")
            self._cat_mask = k
        self._rng = np.random.default_rng(self.seed)

    def _snap(self, x: np.ndarray) -> np.ndarray:
        """Quantize categorical dims to bin centers. Deterministic, no RNG
        — the continuous path (``cats=None``) returns ``x`` untouched, so
        the pre-categorical stream is bit-identical."""
        if self.cats is None:
            return x
        k = self._cat_mask
        x = np.array(x, float)
        off = np.clip(x[k] - self.lo[k], 0.0, self.cats[k] - 1e-9)
        x[k] = self.lo[k] + np.floor(off) + 0.5
        return x

    @property
    def dim(self) -> int:
        return len(self.lo)

    # -------------------------------------------------------------- #
    def _fit(self):
        """Split observations at the γ-quantile and fit both Parzen densities
        (points + bandwidths). Pure function of (xs, ys) — no RNG use — so a
        batch of asks can share one fit."""
        X = np.stack(self.xs)
        y = np.asarray(self.ys)
        n_good = max(1, int(np.ceil(self.gamma * len(y))))
        order = np.argsort(-y)                        # maximize
        good, bad = X[order[:n_good]], X[order[n_good:]]
        if len(bad) == 0:
            bad = X
        return good, self._bw(good), bad, self._bw(bad)

    def _propose(self, fit) -> np.ndarray:
        good, bw_good, bad, bw_bad = fit
        cand = self._sample_parzen(good, bw_good, self.n_ei)
        score = self._log_kde(cand, good, bw_good) - \
            self._log_kde(cand, bad, bw_bad)
        return cand[int(np.argmax(score))]

    def ask(self) -> np.ndarray:
        if len(self.xs) < self.n_startup:
            return self._snap(self._rng.uniform(self.lo, self.hi))
        return self._snap(self._propose(self._fit()))

    def ask_batch(self, k: int,
                  liar: Optional[str] = None) -> List[np.ndarray]:
        """k proposals without intermediate tells.

        ``liar=None`` (the legacy mode): candidates are independent draws
        from the current l(x)/g(x) model (random-restart parallel TPE)
        sharing ONE model fit (the fit consumes no RNG and xs/ys don't change
        inside a batch): each draw advances the RNG, so the batch is diverse,
        and ask_batch(1) is bit-identical to a single ask() — the serial
        search is the batch_size=1 special case (DESIGN.md §8).

        ``liar in ("min", "mean", "max")`` enables the constant-liar
        protocol (Ginsbourger et al.; DESIGN.md §12): after each batch
        member is proposed, it is *provisionally told* to a scratch copy of
        the observations with a constant lie — the worst (min), mean, or
        best (max) score seen so far — and the Parzen model is refit before
        the next member. The pessimistic ``"min"`` lie marks the region
        just proposed as bad, pushing later members away from it: the batch
        spreads over distinct basins instead of resampling one mode.
        Nothing persists: ``tell_batch`` later records the REAL scores, and
        the lies never touch ``self.xs``/``self.ys``. Model refits consume
        no RNG and each member still draws ``n_ei`` candidates, so the RNG
        stream position after ``ask_batch(k, liar=...)`` is identical to
        the legacy mode — downstream draws replay bit-for-bit at a fixed
        seed, whichever protocol ran. ``ask_batch(1, liar=...)`` is a
        single ``ask()`` (there is no one to lie to).
        """
        if liar not in (None, "min", "mean", "max"):
            raise ValueError(f"unknown liar mode {liar!r}")
        if liar is None or k <= 1 or not self.ys:
            if len(self.xs) < self.n_startup:
                return [self._snap(self._rng.uniform(self.lo, self.hi))
                        for _ in range(k)]
            # one array program per wave (DESIGN.md §15): candidates are
            # drawn member by member (identical RNG stream to k serial
            # ``_propose`` calls) but all k * n_ei are SCORED in one KDE
            # evaluation — ``_log_kde`` reduces strictly per row, so each
            # member's winner is bit-identical to its serial pick, and a
            # ragged tail round (k < batch_size, n_trials not a multiple of
            # batch_size) truncates to exactly k members with the RNG
            # position k serial asks would leave
            good, bw_good, bad, bw_bad = self._fit()
            cands = [self._sample_parzen(good, bw_good, self.n_ei)
                     for _ in range(k)]
            allc = np.concatenate(cands)
            score = (self._log_kde(allc, good, bw_good) -
                     self._log_kde(allc, bad, bw_bad)).reshape(k, self.n_ei)
            return [self._snap(cands[i][int(np.argmax(score[i]))])
                    for i in range(k)]
        lie = {"min": min(self.ys), "mean": float(np.mean(self.ys)),
               "max": max(self.ys)}[liar]
        real_xs, real_ys = self.xs, self.ys
        n_real = len(real_xs)
        out: List[np.ndarray] = []
        try:
            self.xs, self.ys = list(real_xs), list(real_ys)
            for i in range(k):
                # startup is judged on REAL observations at batch entry so
                # a pre-startup batch stays all-uniform exactly like the
                # legacy mode (same RNG consumption per member)
                if n_real < self.n_startup:
                    x = self._snap(self._rng.uniform(self.lo, self.hi))
                else:
                    x = self._snap(self._propose(self._fit()))
                out.append(x)
                if i + 1 < k:
                    self.xs.append(np.asarray(x, float))
                    self.ys.append(lie)
        finally:
            self.xs, self.ys = real_xs, real_ys
        return out

    def tell(self, x: np.ndarray, y: float) -> None:
        self.xs.append(np.asarray(x, float))
        self.ys.append(float(y))

    def tell_batch(self, xs: Sequence[np.ndarray],
                   ys: Sequence[float]) -> None:
        """Record a batch of observations in proposal order (so a fixed-seed
        batched run replays the serial trial sequence)."""
        if len(xs) != len(ys):
            raise ValueError(f"got {len(xs)} proposals but {len(ys)} scores")
        for x, y in zip(xs, ys):
            self.tell(x, y)

    @property
    def best(self) -> Tuple[np.ndarray, float]:
        i = int(np.argmax(self.ys))
        return self.xs[i], self.ys[i]

    # -------------------------------------------------------------- #
    def _bw(self, pts: np.ndarray) -> np.ndarray:
        """Per-point, per-dim bandwidths = distance to the neighbouring
        observation in that dim (hyperopt's adaptive Parzen): wide while the
        good set is spread out (exploration), tight once it clusters
        (refinement). A pure Scott bandwidth collapses onto the incumbent and
        the search stalls at random-search quality. All dims are sorted in
        one argsort call — this sits on the per-ask hot path."""
        span = self.hi - self.lo
        m = len(pts)
        order = np.argsort(pts, axis=0, kind="stable")        # (m, D)
        v = np.empty((m + 2, self.dim))
        v[0] = self.lo
        v[-1] = self.hi
        v[1:-1] = np.take_along_axis(pts, order, axis=0)
        bw_sorted = np.maximum(v[1:-1] - v[:-2], v[2:] - v[1:-1])
        bws = np.empty((m, self.dim))
        np.put_along_axis(bws, order, bw_sorted, axis=0)
        return np.clip(bws, 0.02 * span, 0.7 * span)

    def _sample_parzen(self, pts: np.ndarray, bw: np.ndarray,
                       n: int) -> np.ndarray:
        idx = self._rng.integers(len(pts), size=n)
        samp = pts[idx] + self._rng.normal(size=(n, self.dim)) * bw[idx]
        # uniform-prior component: 20% of candidates explore globally
        n_prior = max(1, n // 5)
        samp[:n_prior] = self._rng.uniform(self.lo, self.hi,
                                           size=(n_prior, self.dim))
        return np.clip(samp, self.lo, self.hi)

    def _log_kde(self, x: np.ndarray, pts: np.ndarray,
                 bw: np.ndarray) -> np.ndarray:
        d = (x[:, None, :] - pts[None, :, :]) / bw[None]      # (n, m, D)
        log_comp = -0.5 * np.sum(d * d, axis=-1) - \
            np.sum(np.log(bw), axis=-1)[None]
        m = log_comp.max(axis=1, keepdims=True)
        return (m[:, 0] + np.log(np.exp(log_comp - m).sum(axis=1))) - \
            np.log(len(pts))
