"""The paper's main experiment: hardware-aware sparsity search on ResNet-18,
hardware-aware vs software-metrics-only (Fig. 5).

    PYTHONPATH=src python examples/hass_search.py --iters 24
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=24)
    ap.add_argument("--img-res", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="TPE proposals per vmapped evaluation round "
                         "(0 = serial ask/tell loop)")
    ap.add_argument("--chips", type=int, default=1,
                    help="TPU chips for the partitioned multi-chip DSE on "
                         "the best proposal (1 = skip)")
    args = ap.parse_args()

    from benchmarks.fig5_search_compare import run
    payload = run(iters=args.iters, img_res=args.img_res,
                  batch_size=args.batch_size, chips=args.chips)
    hw, sw = payload["hw_best"], payload["sw_best"]
    print(f"\nsearch throughput: {payload['trials_per_s']:.2f} trials/s "
          f"(batch={args.batch_size})")
    print(f"\nhardware-aware: eff={hw['eff']:.1f} acc={hw['acc']:.3f} "
          f"thr={hw['thr']:.0f} img/s dsp={hw['dsp']:.2f}")
    print(f"software-only : eff={sw['eff']:.1f} acc={sw['acc']:.3f} "
          f"thr={sw['thr']:.0f} img/s dsp={sw['dsp']:.2f}")
    print(f"efficiency gain from hardware awareness: "
          f"{hw['eff'] / max(sw['eff'], 1e-9):.2f}x  (paper Fig. 5: higher)")
    mc = payload.get("multi_chip")
    if mc:
        print(f"\npartitioned multi-chip TPU DSE ({mc['chips']} chips): "
              f"{mc['parts']} partitions, cuts={mc['cuts']}")
        print(f"  amortized {mc['imgs_per_s']:.0f} img/s "
              f"(steady pipeline {mc['steady_imgs_per_s']:.0f} img/s, "
              f"{mc['dse_calls']} segment DSEs)")


if __name__ == "__main__":
    main()
