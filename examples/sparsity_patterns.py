"""Pattern-aware sparsity search (DESIGN.md §16): the TPE picks a sparsity
PATTERN (unstructured / N:M / hierarchical / activation) per matrix kind,
jointly with its level, priced by measured per-pattern decode factors from
the seeded Pallas/XLA microbench (kernels.kernel_costs).

    PYTHONPATH=src python examples/sparsity_patterns.py --iters 24
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=24)
    ap.add_argument("--config", default="qwen3-0.6b")
    ap.add_argument("--meas", type=float, default=0.05,
                    help="Eq. 6 weight of the measured decode-cost term")
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_config
    from repro.core import pruning
    from repro.core.hass import Lambdas, LMEvaluator, hass_search
    from repro.core.perf_model import TPUModel
    from repro.kernels import kernel_costs

    costs_path = os.path.join(os.path.dirname(__file__), "..",
                              "experiments", "kernel_costs.json")
    table = kernel_costs.load_or_measure(costs_path)
    factors = table["decode_factors"]
    print("measured decode factors (cycles per unit of skippable work):")
    for p in pruning.PATTERNS:
        print(f"  {p:13s} {factors[p]:.4f}")

    cfg = get_config(args.config)
    tpu = TPUModel(chips=1)
    lam = Lambdas(meas=args.meas)
    kw = dict(iters=args.iters, seed=0, include_act=False, lambdas=lam)

    # both arms carry a dense x0 anchor so the trial sets always contain
    # the don't-prune point (DESIGN.md §16)
    ev_u = LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=150)
    r_u = hass_search(ev_u, ev_u.n_search, **kw,
                      x0=np.zeros(ev_u.n_search))

    ev_p = LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=150,
                       patterns=pruning.PATTERNS, pattern_costs=factors)
    r_p = hass_search(ev_p, ev_p.n_search, **kw,
                      x0=np.zeros(2 * ev_p.n_search))

    n = ev_p.n_search
    codes = np.clip(r_p.best_x[-n:].astype(np.int64), 0,
                    len(ev_p.patterns) - 1)
    s_w = np.clip(r_p.best_x[:n], 0.0, 1.0)
    print(f"\nbest pattern assignment ({args.config}, {args.iters} trials):")
    for k, name in enumerate(ev_p.group_names):
        print(f"  {name:14s} {ev_p.patterns[codes[k]]:13s} s={s_w[k]:.2f}")

    mu, mp = r_u.best_metrics, r_p.best_metrics
    print(f"\nunstructured-only: acc={mu['acc']:.3f} thr={mu['thr']:.0f} "
          f"tok/s dsp={mu['dsp']:.3f} score={mu['score']:.4f}")
    print(f"pattern-aware    : acc={mp['acc']:.3f} thr={mp['thr']:.0f} "
          f"tok/s dsp={mp['dsp']:.3f} meas={mp.get('meas', 0.0):.3f} "
          f"score={mp['score']:.4f}")


if __name__ == "__main__":
    main()
