"""Fleet serving demo (DESIGN.md §14): open-loop continuous batching,
autoscale policy search, and a real serve-path replay.

Three acts on one seeded bursty trace:

  1. **open loop, one replica** — the trace's arrival timestamps drive
     ``ServeSession.serve_open_loop``: requests wait for batch slots, join
     the running decode batch at bucket boundaries, and the ``ServeReport``
     carries per-request queueing/latency like the simulator's.
  2. **policy search** — ``autoscale_policy_search`` runs a TPE over the
     fleet controller's knobs (replica schedule bounds, backlog
     thresholds, admission depth, boundary slack), scoring each candidate
     with ``simulate_fleet`` against the scaled trace, and prints the
     searched policy next to every static replica count.
  3. **replay** — the searched fleet's busiest replica stream goes back
     through the *real* open-loop serve path on a tiny CPU transformer;
     the timing twin (``fleet.open_loop_schedule``) and the real session
     report identical admission/completion clocks.

    PYTHONPATH=src python examples/fleet_serve.py
    PYTHONPATH=src python examples/fleet_serve.py --trace diurnal
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.serve.fleet import AutoscalePolicy, simulate_fleet
from repro.serve.serve_loop import ServeSession, requests_from_trace
from repro.sim import autoscale_policy_search, diurnal_trace, mmpp_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--trace", choices=["mmpp", "diurnal"], default="mmpp")
    ap.add_argument("--requests", type=int, default=4000,
                    help="trace length for the policy search")
    ap.add_argument("--replay-requests", type=int, default=24,
                    help="requests replayed through the real serve path")
    ap.add_argument("--batch-slots", type=int, default=8)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--step-cycles", type=float, default=100.0)
    ap.add_argument("--prefill-cycles", type=float, default=300.0)
    ap.add_argument("--trials", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.trace == "mmpp":
        tr = mmpp_trace(args.requests, 2e-4, 1.5e-2, dwell_base=3e5,
                        dwell_burst=8e4, sizes=[8, 16], seed=args.seed)
    else:
        tr = diurnal_trace(args.requests, 2e-5, 1.2e-2, 4e5,
                           sizes=[8, 16], seed=args.seed)
    kw = dict(batch_slots=args.batch_slots, step_cycles=args.step_cycles,
              prefill_cycles=args.prefill_cycles)
    print(f"trace: {tr.kind}, {len(tr)} requests over {tr.span:.3g} cycles "
          f"(offered {tr.offered_load:.3g} tok/cycle)")

    t0 = time.perf_counter()
    pol, rep, base = autoscale_policy_search(
        tr, max_replicas=args.max_replicas, n_trials=args.trials,
        seed=args.seed, **kw)
    dt = time.perf_counter() - t0
    for r in range(1, args.max_replicas + 1):
        p99, cost = base[r]
        tag = " <- best static" if r == base["static_best"] else ""
        print(f"  static R={r}: p99={p99:10.0f}  "
              f"replica-cycles={cost:.3e}{tag}")
    print(f"  searched  : p99={rep.p99:10.0f}  "
          f"replica-cycles={rep.replica_cycles:.3e}  "
          f"(min={pol.min_replicas}, up@{pol.scale_up_backlog:.2g}, "
          f"down@{pol.scale_down_backlog:.2g}, "
          f"boundary={pol.boundary_cycles:.3g} cyc)  [{dt:.1f}s search]")
    p99_s, cost_s = base[base["static_best"]]
    print(f"  win: p99 {rep.p99 / p99_s:.2f}x static at "
          f"{rep.replica_cycles / cost_s:.0%} of the replica-cycles")

    # --- replay the busiest replica's stream through the real serve path
    counts = np.bincount(rep.assignment, minlength=args.max_replicas)
    busiest = int(np.argmax(counts))
    idx = np.flatnonzero(rep.assignment == busiest)[:args.replay_requests]
    sub = tr.__class__(rep.routed_at[idx] - rep.routed_at[idx].min(),
                       tr.sizes[idx], kind=tr.kind)
    cfg = reduce_config(get_config(args.arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sess = ServeSession(api, params, batch_slots=args.batch_slots,
                        S_max=int(8 + max(tr.sizes) + 8))
    reqs = requests_from_trace(sub, vocab_size=cfg.vocab_size,
                               prompt_len=8, seed=args.seed)
    t0 = time.time()
    srep = sess.serve_open_loop(reqs, step_cycles=args.step_cycles,
                                prefill_cycles=args.prefill_cycles)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in srep.outputs)
    print(f"replayed replica {busiest}'s first {len(idx)} requests through "
          f"the real open-loop serve path ({cfg.name}): {n_tok} tokens, "
          f"{srep.prefills} prefills, {srep.decode_steps} decode steps "
          f"in {dt:.1f}s")
    print(f"  virtual clock: p50={srep.p50:.0f} p99={srep.p99:.0f} cycles, "
          f"mean queue wait {srep.queue_wait.mean():.0f} cycles")


if __name__ == "__main__":
    main()
