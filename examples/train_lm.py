"""End-to-end training driver: ~100M-parameter LM, few hundred steps, with
gradient accumulation, remat, checkpointing and fault-tolerant resume.

Full run (the EXPERIMENTS.md §Examples record):
    PYTHONPATH=src python examples/train_lm.py --steps 200
Smoke:
    PYTHONPATH=src python examples/train_lm.py --smoke
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import DataPipeline
from repro.models import build_model
from repro.core.perf_model import param_count
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import StepWatchdog, run_resilient
from repro.train.optimizer import OptConfig
from repro.train.train_loop import TrainConfig, init_train_state, make_train_step

LM100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=768, num_heads=12,
    num_kv_heads=4, d_ff=2048, vocab_size=32000, tied_embeddings=True,
    qk_norm=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = LM100M
    if args.smoke:
        from repro.configs import reduce_config
        cfg = reduce_config(cfg)
        args.steps = min(args.steps, 8)

    api = build_model(cfg)
    print(f"model {cfg.name}: ~{param_count(cfg) / 1e6:.0f}M params")
    tcfg = TrainConfig(
        opt=OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps,
                      weight_decay=0.1),
        accum=args.accum, remat="full")
    state = init_train_state(api.init, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(api.loss, tcfg), donate_argnums=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
    if args.resume:
        restored = mgr.restore_or_none()
        if restored is not None:
            state, step0, _ = (restored[0], restored[1], restored[2])
            print(f"resumed from step {step0}")

    shape = ShapeConfig("train", args.seq, args.batch * args.accum, "train")
    pipe = DataPipeline(cfg, shape, seed=0, prefetch=2)

    t0 = time.time()
    hist = []

    def next_batch(i):
        return pipe.batch_at(i)

    rep = run_resilient(step_fn, state, next_batch, steps=args.steps,
                        ckpt=mgr, ckpt_every=max(args.steps // 5, 5),
                        watchdog=StepWatchdog())
    dt = time.time() - t0
    toks = args.steps * args.batch * args.accum * args.seq
    print(f"loss {rep.history[0]:.3f} -> {rep.final_loss:.3f} over "
          f"{rep.steps_run} steps | {toks / dt:.0f} tok/s | "
          f"{dt:.0f}s total | restarts={rep.restarts}")
    assert rep.final_loss < rep.history[0], "training must reduce loss"
    pipe.close()


if __name__ == "__main__":
    main()
