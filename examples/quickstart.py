"""Quickstart: the HASS flow end-to-end on a laptop-scale model.

1. build a reduced LM, 2. one-shot magnitude-prune it (§III),
3. run the hardware-aware search (Eq. 6) on a reduced ResNet-18,
4. execute a pruned matmul through the block-sparse Pallas kernel (§IV).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.configs.paper_cnns import RESNET18
from repro.core import pruning
from repro.core.hass import CNNEvaluator, hass_search
from repro.core.perf_model import FPGAModel
from repro.data.synthetic import lm_batch
from repro.kernels import ops
from repro.models import build_model, cnn

rng = jax.random.PRNGKey(0)

# ---------------------------------------------------------------- 1+2
print("== 1/4: build + prune a reduced qwen3 ==")
cfg = reduce_config(get_config("qwen3-0.6b"))
api = build_model(cfg)
params = api.init(rng)
batch = lm_batch(cfg, 4, 32)
loss_dense, _ = api.loss(params, batch)
pruned, achieved = pruning.prune_params(
    params, {"blocks/ffn/w_gate": 0.6, "blocks/ffn/w_up": 0.6})
loss_sparse, _ = api.loss(pruned, batch)
print(f"   dense loss {float(loss_dense):.3f} -> 60%-pruned FFN loss "
      f"{float(loss_sparse):.3f}; achieved S_w={list(achieved.values())}")

# ---------------------------------------------------------------- 3
print("== 2/4: hardware-aware sparsity search (8 TPE iters, Eq. 6) ==")
ccfg = reduce_config(RESNET18)
cparams = cnn.init_params(ccfg, rng)
images = jax.random.normal(rng, (8, ccfg.img_res, ccfg.img_res, 3))
ev = CNNEvaluator(ccfg, cparams, images, FPGAModel(), budget=4096,
                  dse_iters=300)
res = hass_search(ev, len(ev.prunable), iters=8, hardware_aware=True)
m = res.best_metrics
print(f"   best: acc={m['acc']:.3f} S̄={m['spa']:.2f} "
      f"thr={m['thr']:.0f} img/s eff={m['eff']:.1f}")

# ---------------------------------------------------------------- 4
print("== 3/4: block-sparse Pallas kernel on the pruned weight ==")
w = np.asarray(pruned["blocks"]["ffn"]["w_gate"][0])
sw = ops.SparseWeight(jnp.asarray(w))
x = jax.random.normal(rng, (16, w.shape[0]))
y = sw.matmul(x)
err = float(jnp.abs(y - x @ jnp.asarray(w)).max())
print(f"   tile density {sw.tile_density:.2f}, kernel max err {err:.2e}")

print("== 4/4: activation clipping kernel (dynamic S_a) ==")
a = jax.random.normal(rng, (64, 256))
y2, zeros = ops.act_clip(a, 0.7)
print(f"   tau=0.7 zeroed {int(zeros)}/{a.size} "
      f"({int(zeros) / a.size:.0%}) — model predicts "
      f"{pruning.act_sparsity_gaussian(0.7):.0%}")
print("quickstart OK")
