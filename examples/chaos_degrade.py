"""Chaos & graceful-degradation demo (DESIGN.md §17): fault-injected
fleet serving, frontier-priced degradation, and failure-aware search.

Four acts on one seeded bursty trace with one replica lost at the peak:

  1. **crash** — ``simulate_fleet`` replays the trace under a
     ``replica_loss`` fault: in-flight requests on the crashed replica
     re-enqueue with retry backoff, deadline-bound stragglers shed, and
     the report accounts every request (completed or shed, never lost).
  2. **degrade** — a ``DegradationPolicy`` ladder priced off the DSE
     frontier (``core.dse.degradation_ladder``: extra sparsity -> faster
     decode steps) lets the fleet trade accuracy for throughput during
     the outage; the degraded run sheds strictly fewer requests at no
     extra replica cost.
  3. **search** — ``autoscale_policy_search`` run fault-blind vs
     failure-aware (trials simulated under the fault set): the aware
     winner survives the crash with a lower tail.
  4. **replay** — the degraded rung schedule goes through the *real*
     open-loop serve path on a tiny CPU transformer; the timing twin and
     the real session report identical clocks.

    PYTHONPATH=src python examples/chaos_degrade.py
    PYTHONPATH=src python examples/chaos_degrade.py --deadline 3e5
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=1500)
    ap.add_argument("--deadline", type=float, default=2e5,
                    help="per-request deadline in cycles past arrival")
    ap.add_argument("--trials", type=int, default=16)
    ap.add_argument("--replay-requests", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.paper_cnns import RESNET18
    from repro.core.dse import degradation_ladder
    from repro.core.perf_model import FPGAModel
    from repro.serve.fleet import (AutoscalePolicy, DegradationPolicy,
                                   open_loop_schedule, simulate_fleet)
    from repro.sim import (autoscale_policy_search, mmpp_trace,
                           replica_loss)

    kw = dict(batch_slots=8, step_cycles=100.0, prefill_cycles=300.0)
    tr = mmpp_trace(args.requests, 2e-4, 2e-2, dwell_base=2e5,
                    dwell_burst=1.5e5, sizes=[8, 16], seed=args.seed)
    peak = float(np.median(tr.arrivals))
    ft = replica_loss(1, peak, peak + 2e6)
    print(f"trace: {len(tr)} requests over {tr.span:.3g} cycles; replica 1 "
          f"lost at t={peak:.3g} for 2e6 cycles; deadline "
          f"{args.deadline:.3g} cycles")

    # --- 1: the crash, hard-shedding fleet
    plain = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft,
                           deadline_cycles=args.deadline, **kw)
    print(f"  crash:    shed={plain.shed:4d}  retries={plain.retries.sum()}"
          f"  p99={plain.p99:.3e}  cost={plain.replica_cycles:.3e}")

    # --- 2: the same crash with a frontier-priced degradation ladder
    rungs = degradation_ladder(
        _sparse_stack(RESNET18, args.seed), FPGAModel(), budget=4096.0,
        s_extra=(0.0, 0.2, 0.4))
    ladder = tuple(r.step_scale for r in rungs)
    deg = DegradationPolicy(ladder=ladder, degrade_backlog=3.0,
                            recover_backlog=0.5, dwell_cycles=1e5,
                            switch_cycles=1e4)
    soft = simulate_fleet(tr, AutoscalePolicy.static(2), faults=ft,
                          deadline_cycles=args.deadline, degradation=deg,
                          **kw)
    print(f"  degrade:  shed={soft.shed:4d}  "
          f"ladder={tuple(round(s, 3) for s in ladder)}  "
          f"rung moves={len(soft.rung_timeline) - 1}  "
          f"p99={soft.p99:.3e}  cost={soft.replica_cycles:.3e}")

    # --- 3: fault-blind vs failure-aware policy search
    t0 = time.perf_counter()
    pol_b, _, _ = autoscale_policy_search(tr, max_replicas=3,
                                          n_trials=args.trials,
                                          seed=args.seed, **kw)
    pol_a, rep_a, _ = autoscale_policy_search(
        tr, max_replicas=3, n_trials=args.trials, seed=args.seed,
        faults=ft, deadline_cycles=args.deadline, **kw)
    rep_b = simulate_fleet(tr, pol_b, faults=ft,
                           deadline_cycles=args.deadline, **kw)
    dt = time.perf_counter() - t0
    print(f"  search:   fault-blind winner under the crash: "
          f"p99={rep_b.p99:.3e} shed={rep_b.shed} | failure-aware: "
          f"p99={rep_a.p99:.3e} shed={rep_a.shed}  [{dt:.1f}s]")

    # --- 4: the degraded schedule through the real serve path
    import jax

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serve.serve_loop import Request, ServeSession
    rng = np.random.default_rng(args.seed)
    n = args.replay_requests
    arr = np.cumsum(rng.exponential(400.0, n)).astype(float)
    new = rng.integers(4, 20, n).astype(float)
    dls = arr + rng.uniform(2e3, 2e4, n)
    sched = [(0.0, ladder[0]), (float(arr[n // 3]), ladder[-1]),
             (float(arr[-3]), ladder[0])]
    cfg = reduce_config(get_config(args.arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sess = ServeSession(api, params, batch_slots=4, S_max=40)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=6),
                    max_new=int(new[i]), arrival=float(arr[i]),
                    deadline=float(dls[i])) for i in range(n)]
    rep = sess.serve_open_loop(reqs, step_cycles=60.0, prefill_cycles=180.0,
                               step_schedule=sched, switch_cycles=90.0)
    adm, comp = open_loop_schedule(arr, new, batch_slots=sess.B,
                                   step_cycles=60.0, prefill_cycles=180.0,
                                   deadlines=dls, step_schedule=sched,
                                   switch_cycles=90.0)
    twin = (np.array_equal(rep.admissions, adm)
            and np.array_equal(rep.completions, comp))
    print(f"  replay:   {n} requests through the real serve path "
          f"({cfg.name}): twin-identical={twin}, shed={rep.shed}, "
          f"rung stalls={rep.switch_stalls}")


def _sparse_stack(cfg, seed):
    from repro.core.perf_model import cnn_layer_costs
    rng = np.random.default_rng(seed)
    layers = cnn_layer_costs(cfg)
    for l in layers:
        l.s_w = float(rng.uniform(0.1, 0.8))
        l.s_a = float(rng.uniform(0.1, 0.6))
    return layers


if __name__ == "__main__":
    main()
