"""LM-workload hardware-aware sparsity search (DESIGN.md §11).

The deep-stack HASS pipeline: TPE proposes per-matrix-kind sparsity targets
for a hundreds-of-matmul LM stack (``lm_layer_costs``, sample = token), the
analytic ``LMEvaluator`` scores Eq. 6 on the TPU backend, and the best
proposal's sparse stack is partitioned across chips with the segment-table
DP — max-min steady-rate objective vs the sum-form temporal objective.

    PYTHONPATH=src python examples/lm_search.py --config deepseek_v3_671b --chips 4
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="deepseek_v3_671b",
                    help="arch id (underscore or hyphen spelling)")
    ap.add_argument("--chips", type=int, default=4,
                    help="TPU chips; >1 partitions the best stack")
    ap.add_argument("--iters", type=int, default=12,
                    help="TPE iterations")
    ap.add_argument("--batch-size", type=int, default=4,
                    help="TPE proposals per round (0 = serial)")
    ap.add_argument("--seq-len", type=int, default=2048,
                    help="context length for the attn-score workload")
    ap.add_argument("--max-cuts", type=int, default=12,
                    help="candidate cut positions for the partition DP "
                         "(block boundaries, evenly thinned)")
    ap.add_argument("--pipeline-batch", type=int, default=64,
                    help="tokens per pipelined batch (amortizes switches)")
    ap.add_argument("--dse-iters", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_config
    from repro.core.dse import DSECache, partition_pipeline
    from repro.core.hass import LMEvaluator, hass_search
    from repro.core.perf_model import (TPUModel, lm_block_bounds,
                                      param_count, thin_cut_points)

    cfg = get_config(args.config)
    tpu = TPUModel(chips=max(args.chips, 1))
    ev = LMEvaluator(cfg, tpu, tpu.chip_budget, seq_len=args.seq_len,
                     dse_iters=args.dse_iters)
    print(f"{cfg.name}: {len(ev.layers)} matmul workloads "
          f"({sum(1 for l in ev.layers if l.prunable)} prunable), "
          f"{param_count(cfg) / 1e9:.1f}B params, "
          f"{ev.n_search} search vars ({', '.join(ev.group_names)})")

    t0 = time.perf_counter()
    res = hass_search(ev, ev.n_search, iters=args.iters, seed=args.seed,
                      include_act=False,     # s_a never skips MXU compute
                      batch_size=args.batch_size or None)
    dt = time.perf_counter() - t0
    m = res.best_metrics
    print(f"\nsearch: {args.iters} trials in {dt:.1f}s "
          f"({args.iters / dt:.1f} trials/s)")
    print(f"best: acc={m['acc']:.3f} spa={m['spa']:.3f} "
          f"thr={m['thr']:.1f} tok/s dsp={m['dsp']:.3f} "
          f"score={m['score']:.3f}")
    targets = ", ".join(f"{n}={s:.2f}" for n, s in
                        zip(ev.group_names, res.best_x[:ev.n_search]))
    print(f"tile-sparsity targets: {targets}")
    st = ev.dse_cache.stats()
    print(f"search DSECache: {st['cold_runs']} cold engine runs, "
          f"{st['hits']} exact hits, warm starts "
          f"L1={st['warm_l1']} (floor-stability) "
          f"L2={st['warm_l2']} (t-vector certificate)")

    if args.chips <= 1:
        return
    layers = ev.sparse_layers(res.best_x)
    cut_points = thin_cut_points(lm_block_bounds(layers), args.max_cuts)
    # ONE DSECache across both objectives (and any further what-ifs): the
    # second DP re-reads every segment frontier instead of re-searching it
    # (DESIGN.md §12)
    cache = DSECache()
    kw = dict(n_parts=args.chips, batch=args.pipeline_batch,
              dse_iters=args.dse_iters, cut_points=cut_points, cache=cache)
    print(f"\npartitioning across {args.chips} chips "
          f"({len(cut_points)} candidate cuts at block boundaries):")
    for objective in ("sum", "maxmin"):
        t0 = time.perf_counter()
        p = partition_pipeline(layers, tpu, tpu.chip_budget,
                               objective=objective, **kw)
        print(f"  {objective:6s}: cuts={p.cuts} "
              f"steady={p.steady_throughput * tpu.freq:8.1f} tok/s "
              f"amortized={p.throughput * tpu.freq:8.1f} tok/s "
              f"({p.dse_calls} segment DSEs, "
              f"{time.perf_counter() - t0:.1f}s)")
    st = cache.stats()
    print(f"  shared DSECache: {st['cold_runs']} cold segment DSEs, "
          f"{st['hits']} exact + {st['warm_l1']} warm-L1 + "
          f"{st['warm_l2']} warm-L2 reuses "
          f"(maxmin re-reads the sum DP's frontiers; never worse on the "
          f"steady rate — DESIGN.md §11/§12)")


if __name__ == "__main__":
    main()
