"""Deployment simulation demo (DESIGN.md §13): search -> partition ->
simulate -> SLO-aware pick.

Runs a quick LM sparsity search, partitions the best stack across chips
with the analytic max-min DP, then replays a bursty (MMPP) request trace
through the discrete-event simulator and lets ``objective="slo"`` re-pick
the cuts against a p99 latency target. Optionally closes the loop inside
the search itself (``--lat-weight``): proposals are scored with a
simulated-latency Eq. 6 term via ``SimLatencyEvaluator``.

    PYTHONPATH=src python examples/deploy_sim.py --config qwen3_0_6b --chips 4
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="qwen3_0_6b")
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--iters", type=int, default=8, help="TPE iterations")
    ap.add_argument("--requests", type=int, default=600,
                    help="trace length (requests)")
    ap.add_argument("--util", type=float, default=0.45,
                    help="mean offered load as a fraction of the max-min "
                         "pick's steady rate")
    ap.add_argument("--req-tokens", type=int, default=32,
                    help="decode tokens per request")
    ap.add_argument("--slo-x", type=float, default=3.0,
                    help="p99 SLO as a multiple of the single-chip "
                         "service time per request")
    ap.add_argument("--max-cuts", type=int, default=10)
    ap.add_argument("--dse-iters", type=int, default=200)
    ap.add_argument("--lat-weight", type=float, default=0.0,
                    help="> 0 adds the simulated-latency Eq. 6 term to the "
                         "search itself (SimLatencyEvaluator)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_config
    from repro.core.dse import DSECache, partition_pipeline
    from repro.core.hass import Lambdas, LMEvaluator, hass_search
    from repro.core.perf_model import (TPUModel, lm_block_bounds,
                                       thin_cut_points)
    from repro.sim import (SLO, SimLatencyEvaluator, mmpp_trace,
                           request_rate, simulate_partition)

    cfg = get_config(args.config)
    tpu = TPUModel(chips=max(args.chips, 2))
    ev = LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=args.dse_iters)
    res = hass_search(ev, ev.n_search, iters=args.iters, seed=args.seed,
                      include_act=False, batch_size=4)
    layers = ev.sparse_layers(res.best_x)
    cut_points = thin_cut_points(lm_block_bounds(layers), args.max_cuts)
    print(f"{cfg.name}: best proposal acc={res.best_metrics['acc']:.3f} "
          f"thr={res.best_metrics['thr']:.1f} tok/s "
          f"({len(layers)} workloads, {len(cut_points)} candidate cuts)")

    cache = DSECache()
    kw = dict(n_parts=tpu.chips, batch=args.req_tokens,
              dse_iters=args.dse_iters, cut_points=cut_points, cache=cache)
    mm = partition_pipeline(layers, tpu, tpu.chip_budget,
                            objective="maxmin", **kw)

    # offered load: bursty MMPP at --util of the max-min steady rate
    rate = request_rate(mm.steady_throughput, args.util, args.req_tokens)
    trace = mmpp_trace(args.requests, 0.6 * rate, 3.0 * rate,
                       dwell_base=4.0 / rate, dwell_burst=1.0 / rate,
                       sizes=args.req_tokens, seed=args.seed)
    print(f"trace: {trace.kind}, {len(trace)} requests x "
          f"{args.req_tokens} tok, offered "
          f"{trace.offered_load * tpu.freq:.0f} tok/s "
          f"({trace.offered_load / mm.steady_throughput:.0%} of max-min "
          f"steady rate)")

    one = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=1,
                             batch=args.req_tokens, dse_iters=args.dse_iters,
                             cut_points=cut_points, cache=cache,
                             objective="sum")
    slo = SLO(target=args.slo_x * args.req_tokens / one.part_throughput[0],
              quantile=99.0)
    print(f"SLO: p99 <= {slo.target / tpu.freq * 1e3:.2f} ms")

    t0 = time.perf_counter()
    sl = partition_pipeline(layers, tpu, tpu.chip_budget, objective="slo",
                            slo=slo, trace=trace, **kw)
    dt = time.perf_counter() - t0
    for tag, p in (("maxmin", mm), ("slo", sl)):
        rep = p.sim_report if p.sim_report is not None else \
            simulate_partition(layers, tpu, p, trace)
        print(f"  {tag:6s}: cuts={p.cuts} "
              f"steady={p.steady_throughput * tpu.freq:8.1f} tok/s  "
              f"sim p50/p99={rep.p50 / tpu.freq * 1e3:6.2f}/"
              f"{rep.p99 / tpu.freq * 1e3:6.2f} ms  "
              f"util={np.round(rep.utilization, 2)}")
    st = cache.stats()
    print(f"  slo pick in {dt:.1f}s; shared DSECache: {st['cold_runs']} "
          f"cold, {st['hits']} exact + {st['warm_hits']} warm reuses")

    if args.lat_weight > 0:
        print(f"\nsearch with simulated-latency term "
              f"(lambda_lat={args.lat_weight}):")
        sev = SimLatencyEvaluator(
            LMEvaluator(cfg, tpu, tpu.chip_budget, dse_iters=args.dse_iters),
            tpu, tpu.chip_budget, trace=trace, slo=slo,
            n_parts=tpu.chips, batch=args.req_tokens,
            dse_iters=args.dse_iters, cut_points=cut_points)
        res2 = hass_search(sev, sev.n_search, iters=args.iters,
                           seed=args.seed, include_act=False,
                           lambdas=Lambdas(lat=args.lat_weight))
        m = res2.best_metrics
        print(f"  best: acc={m['acc']:.3f} thr={m['thr']:.1f} tok/s "
              f"sim p99={m['lat_cycles'] / tpu.freq * 1e3:.2f} ms "
              f"(lat={m['lat']:.2f}x SLO, score={m['score']:.3f})")


if __name__ == "__main__":
    main()
