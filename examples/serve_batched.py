"""Batched serving demo: prefill + decode with KV cache, continuous batching,
and the sparse-serving path (activation clipping live at decode).

``--trace poisson|mmpp`` replaces the fixed request list with the request
*mix* of a seeded simulator trace (``repro.sim.trace``) — the same
request counts and decode-length buckets the deployment simulator scores
analytically (DESIGN.md §13). The replay is closed-loop (back to back):
arrival-time burstiness only matters under open-loop admission, which is
the simulator's job, not this CPU demo's.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --trace mmpp
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.serve.serve_loop import ServeSession


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--trace", choices=["poisson", "mmpp"], default=None,
                    help="drive the session from a seeded simulator trace "
                         "instead of a fixed request list")
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    sess = ServeSession(api, params, batch_slots=args.batch_slots,
                        S_max=args.prompt_len + args.max_new + 8)
    if args.trace:
        from repro.sim.trace import mmpp_trace, poisson_trace
        sizes = ((8, args.max_new), (0.5, 0.5))   # two decode-length buckets
        tr = poisson_trace(args.requests, 1e-5, sizes=sizes, seed=0) \
            if args.trace == "poisson" else \
            mmpp_trace(args.requests, 1e-5, 5e-5, dwell_base=2e6,
                       dwell_burst=5e5, sizes=sizes, seed=0)
        print(f"replaying a {tr.kind} trace: {len(tr)} requests, "
              f"{tr.total_samples} decode tokens")
        t0 = time.time()
        outs = sess.replay_trace(tr, vocab_size=cfg.vocab_size,
                                 prompt_len=args.prompt_len)
        dt = time.time() - t0
    else:
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
                   for _ in range(args.requests)]
        t0 = time.time()
        outs = sess.generate(prompts, max_new=args.max_new)
        dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"arch={cfg.name} served {args.requests} requests "
          f"({n_tok} new tokens) in {dt:.2f}s -> {n_tok / dt:.1f} tok/s "
          f"on 1 CPU core")
    print(f"first completion: {outs[0][:10]}...")
    assert len(outs) == args.requests


if __name__ == "__main__":
    main()
