"""Batched serving demo: prefill + decode with KV cache, continuous batching,
and the sparse-serving path (activation clipping live at decode).

    PYTHONPATH=src python examples/serve_batched.py
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.models import build_model
from repro.serve.serve_loop import ServeSession


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               for _ in range(args.requests)]

    sess = ServeSession(api, params, batch_slots=args.batch_slots,
                        S_max=args.prompt_len + args.max_new + 8)
    t0 = time.time()
    outs = sess.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"arch={cfg.name} served {args.requests} requests "
          f"({n_tok} new tokens) in {dt:.2f}s -> {n_tok / dt:.1f} tok/s "
          f"on 1 CPU core")
    print(f"first completion: {outs[0][:10]}...")
    assert len(outs) == args.requests


if __name__ == "__main__":
    main()
