"""Intra-repo markdown link checker (CI gate for README.md / DESIGN.md).

Checks every inline markdown link ``[text](target)`` whose target is not an
external URL:

  * relative file targets must exist (resolved against the markdown file's
    directory);
  * ``#anchor`` fragments (same-file or on a relative target) must match a
    heading in the referenced file, using GitHub's slug rule (lowercase,
    punctuation stripped, spaces -> hyphens).

    python tools/check_links.py README.md DESIGN.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links; trailing ) of the construct excluded from the target
LINK_RE = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase; drop everything but alphanumerics,
    spaces and hyphens (markdown emphasis/code markers included); then
    spaces -> hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = "".join(ch for ch in h if ch.isalnum() or ch in " -")
    return h.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    return {github_slug(m.group(1))
            for m in HEADING_RE.finditer(md_path.read_text())}


def check_file(md_path: Path) -> list:
    errors = []
    for m in LINK_RE.finditer(md_path.read_text()):
        target = m.group(1)
        if target.startswith(EXTERNAL):
            continue
        path_part, _, frag = target.partition("#")
        dest = md_path if not path_part \
            else (md_path.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md_path}: broken link target {target!r} "
                          f"(no such file {path_part!r})")
            continue
        if frag:
            if dest.suffix.lower() not in (".md", ".markdown"):
                continue                      # anchors into code: not checked
            if github_slug(frag) not in anchors_of(dest):
                errors.append(f"{md_path}: broken anchor {target!r} "
                              f"(no heading slugs to {frag!r} in {dest.name})")
    return errors


def main(argv) -> int:
    files = [Path(a) for a in argv] or [Path("README.md"), Path("DESIGN.md")]
    errors = []
    n_links = 0
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file not found")
            continue
        n_links += sum(1 for m in LINK_RE.finditer(f.read_text())
                       if not m.group(1).startswith(EXTERNAL))
        errors.extend(check_file(f))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(files)} files, {n_links} intra-repo links, "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
