"""Flight-recorder report tool: summarize or diff search runs.

A flight-recorder run (``repro.obs.FlightRecorder``) is one JSONL file:
a ``header`` record (search name + config), one ``trial`` record per
evaluated proposal (index, x, score, metric terms, cache/engine counter
deltas, per-phase wall seconds), and a ``footer`` with run-level totals.

    python tools/trace_report.py summary run.jsonl [--top 5]
    python tools/trace_report.py diff a.jsonl b.jsonl

``summary`` prints the per-phase time breakdown, DSE-cache efficiency,
engine dispatch mix, and the top-k slowest trials. ``diff`` compares two
runs of the *same* search: per-phase timing deltas, trial-count and
score divergence (first trial where x or score differs — zero for two
same-seed runs, by the recorder's bit-identity contract).

Standalone on purpose: records are parsed inline (stdlib json only), so
the tool runs without PYTHONPATH or the repro package installed.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional


def load_run(path: str) -> dict:
    """Parse one JSONL run into ``{"header", "trials", "footer"}``.
    Tolerates a missing footer (crashed/killed run) — ``footer`` is then
    ``None`` and totals are rebuilt from the trial records."""
    header = footer = None
    trials: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            kind = rec.get("record")
            if kind == "header":
                header = rec
            elif kind == "trial":
                trials.append(rec)
            elif kind == "footer":
                footer = rec
    if header is None:
        raise SystemExit(f"{path}: no header record — not a recorder run")
    return {"header": header, "trials": trials, "footer": footer}


def _sum_field(trials: List[dict], field: str) -> dict:
    out: dict = {}
    for t in trials:
        for k, v in (t.get(field) or {}).items():
            out[k] = out.get(k, 0) + v
    return out


def totals_of(run: dict) -> dict:
    """Run-level totals: the footer's, or rebuilt from trials when the
    run died before writing one."""
    if run["footer"] is not None:
        return run["footer"].get("totals", {})
    return {"cache": _sum_field(run["trials"], "cache"),
            "engine": _sum_field(run["trials"], "engine"),
            "phases": _sum_field(run["trials"], "phases")}


def _fmt_s(s: float) -> str:
    return f"{s * 1e3:9.3f} ms" if s < 1.0 else f"{s:9.3f} s "


def summarize(run: dict, top: int = 5, out=sys.stdout) -> None:
    h, trials, footer = run["header"], run["trials"], run["footer"]
    tot = totals_of(run)
    w = out.write
    w(f"search   : {h.get('search', '?')}\n")
    cfg = h.get("config", {})
    if cfg:
        w("config   : " + ", ".join(f"{k}={v}" for k, v in
                                    sorted(cfg.items())) + "\n")
    n = footer["n_trials"] if footer else len(trials)
    w(f"trials   : {n}\n")
    if footer and footer.get("best_score") is not None:
        w(f"best     : {footer['best_score']:.6g}\n")
    if footer and footer.get("wall_s") is not None:
        w(f"wall     : {_fmt_s(footer['wall_s'])}\n")
    phases = tot.get("phases", {})
    ptot = sum(phases.values())
    if phases:
        w("phases   :\n")
        for k, v in sorted(phases.items(), key=lambda kv: -kv[1]):
            share = 100.0 * v / ptot if ptot > 0 else 0.0
            w(f"  {k:<12} {_fmt_s(v)}  {share:5.1f}%\n")
    cache = tot.get("cache", {})
    if cache:
        runs = cache.get("cold_runs", 0)
        reuse = (cache.get("hits", 0) + cache.get("warm_l1", 0)
                 + cache.get("warm_l2", 0))
        denom = runs + reuse
        eff = 100.0 * reuse / denom if denom > 0 else 0.0
        w("cache    : " + ", ".join(f"{k}={v}" for k, v in
                                    sorted(cache.items()))
          + f"  (reuse {eff:.1f}%)\n")
    engine = {k: v for k, v in tot.get("engine", {}).items() if v}
    if engine:
        w("engine   : " + ", ".join(f"{k}={v}" for k, v in
                                    sorted(engine.items())) + "\n")
    if trials and top > 0:
        slow = sorted(trials, key=lambda t: -sum((t.get("phases")
                                                  or {}).values()))[:top]
        w(f"slowest {min(top, len(trials))} trials:\n")
        for t in slow:
            dt = sum((t.get("phases") or {}).values())
            w(f"  #{t['i']:<4} {_fmt_s(dt)}  score={t.get('score'):.6g}\n")


def diff_runs(a: dict, b: dict, out=sys.stdout) -> int:
    """Print per-phase deltas and trial divergence between two runs of
    the same search. Returns the number of diverging trials (compared
    index-by-index on x and score; length mismatch counts the tail)."""
    w = out.write
    sa, sb = a["header"].get("search"), b["header"].get("search")
    if sa != sb:
        w(f"WARNING: different searches ({sa} vs {sb})\n")
    ta, tb = a["trials"], b["trials"]
    w(f"trials   : {len(ta)} vs {len(tb)}"
      + (f"  (count differs by {abs(len(ta) - len(tb))})\n"
         if len(ta) != len(tb) else "\n"))
    diverged = abs(len(ta) - len(tb))
    first: Optional[int] = None
    for i, (x, y) in enumerate(zip(ta, tb)):
        if x.get("x") != y.get("x") or x.get("score") != y.get("score"):
            diverged += 1
            if first is None:
                first = i
    if first is not None:
        w(f"diverge  : {diverged} trials differ, first at #{first} "
          f"(score {ta[first].get('score'):.6g} vs "
          f"{tb[first].get('score'):.6g})\n")
    elif diverged:
        w(f"diverge  : {diverged} trials differ (tail beyond the shorter "
          "run)\n")
    else:
        w("diverge  : 0 trials — identical proposals and scores\n")
    pa = totals_of(a).get("phases", {})
    pb = totals_of(b).get("phases", {})
    keys = sorted(set(pa) | set(pb))
    if keys:
        w("phase deltas (b - a):\n")
        for k in keys:
            va, vb = pa.get(k, 0.0), pb.get(k, 0.0)
            pct = 100.0 * (vb - va) / va if va > 0 else float("inf")
            w(f"  {k:<12} {_fmt_s(va)} -> {_fmt_s(vb)}  "
              f"({vb - va:+.6f} s, {pct:+.1f}%)\n")
    fa, fb = a["footer"], b["footer"]
    if fa and fb and fa.get("wall_s") is not None \
            and fb.get("wall_s") is not None:
        w(f"wall     : {_fmt_s(fa['wall_s'])} -> {_fmt_s(fb['wall_s'])}\n")
    return diverged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    s = sub.add_parser("summary", help="summarize one recorded run")
    s.add_argument("run")
    s.add_argument("--top", type=int, default=5,
                   help="how many slowest trials to list")
    d = sub.add_parser("diff", help="compare two recorded runs")
    d.add_argument("run_a")
    d.add_argument("run_b")
    args = ap.parse_args(argv)
    if args.cmd == "summary":
        summarize(load_run(args.run), top=args.top)
        return 0
    diff_runs(load_run(args.run_a), load_run(args.run_b))
    return 0


if __name__ == "__main__":
    sys.exit(main())
