"""Training substrate: optimizer, grad accumulation, remat, state dtypes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.synthetic import lm_batch
from repro.models import build_model
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.train_loop import (TrainConfig, init_train_state,
                                    make_train_step)

CFG = reduce_config(get_config("qwen3-0.6b"))
RNG = jax.random.PRNGKey(0)


def test_lr_schedule():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(lr_at(oc, 0)) == 0.0
    assert float(lr_at(oc, 10)) == pytest.approx(1.0, abs=1e-3)
    assert float(lr_at(oc, 110)) == pytest.approx(0.1, abs=1e-3)


def test_loss_decreases_over_steps():
    api = build_model(CFG)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                       accum=1, remat=None)
    state = init_train_state(api.init, tcfg, RNG)
    step = jax.jit(make_train_step(api.loss, tcfg))
    losses = []
    for i in range(10):
        state, m = step(state, lm_batch(CFG, 8, 32, seed=0, step=i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_grad_accum_matches_full_batch():
    """accum=2 over batch 8 must equal accum=1 over the same batch 8."""
    api = build_model(dataclasses.replace(CFG, dtype="float32"))
    batch = lm_batch(CFG, 8, 32, seed=1, step=0)
    t1 = TrainConfig(opt=OptConfig(lr=1e-3), accum=1, remat=None)
    t2 = TrainConfig(opt=OptConfig(lr=1e-3), accum=2, remat=None)
    s1 = init_train_state(api.init, t1, RNG)
    s2 = init_train_state(api.init, t2, RNG)
    s1, m1 = make_train_step(api.loss, t1)(s1, batch)
    s2, m2 = make_train_step(api.loss, t2)(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        s1["params"], s2["params"])
    assert max(jax.tree_util.tree_leaves(d)) < 1e-5


def test_remat_matches_no_remat():
    api = build_model(dataclasses.replace(CFG, dtype="float32"))
    batch = lm_batch(CFG, 4, 32, seed=2, step=0)
    outs = []
    for remat in (None, "full", "dots"):
        t = TrainConfig(opt=OptConfig(lr=1e-3), accum=1, remat=remat)
        s = init_train_state(api.init, t, RNG)
        s, m = make_train_step(api.loss, t)(s, batch)
        outs.append(float(m["loss"]))
    assert outs[0] == pytest.approx(outs[1], rel=1e-6)
    assert outs[0] == pytest.approx(outs[2], rel=1e-6)


@pytest.mark.parametrize("sdtype", ["float32", "bfloat16", "int8"])
def test_state_dtypes_train(sdtype):
    api = build_model(CFG)
    t = TrainConfig(opt=OptConfig(lr=1e-3, state_dtype=sdtype), accum=1,
                    remat=None)
    state = init_train_state(api.init, t, RNG)
    step = jax.jit(make_train_step(api.loss, t))
    l0 = None
    for i in range(6):
        state, m = step(state, lm_batch(CFG, 8, 32, seed=0, step=i))
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0          # still trains


def test_compressed_grads_numerics():
    api = build_model(CFG)
    t = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                    accum=1, remat=None, compress_grads=True)
    state = init_train_state(api.init, t, RNG)
    assert "ef" in state
    step = jax.jit(make_train_step(api.loss, t))
    losses = []
    for i in range(8):
        state, m = step(state, lm_batch(CFG, 8, 32, seed=0, step=i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2   # error feedback keeps training
    ef_norm = sum(float(jnp.sum(jnp.abs(e)))
                  for e in jax.tree_util.tree_leaves(state["ef"]))
    assert ef_norm > 0                    # feedback is actually carrying error


def test_weight_decay_mask_excludes_vectors():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    oc = OptConfig(lr=1.0, weight_decay=0.1, warmup_steps=0, total_steps=10)
    st = init_opt_state(params, oc)
    p2, _, _ = adamw_update(params, grads, st, oc)
    assert float(jnp.max(jnp.abs(p2["b"] - 1.0))) < 1e-6     # no decay on bias
    assert float(jnp.max(jnp.abs(p2["w"] - 1.0))) > 1e-3     # decay on matrix
