"""Prefill + decode must reproduce teacher-forced forward logits, per family.
(MoE uses an oversized capacity factor so no tokens drop — drops are the one
legitimate batch-size-dependent difference.)"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.models import build_model
import repro.models.transformer as tfm
import repro.models.rwkv as rwkv_m
import repro.models.ssm as ssm_m

RNG = jax.random.PRNGKey(1)
B, S, SPLIT = 2, 12, 8


def _f32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    return cfg


def _teacher(cfg, params, tokens, frames=None):
    if cfg.rwkv is not None:
        return rwkv_m.forward(cfg, params, tokens)[0]
    if cfg.ssm is not None:
        return ssm_m.forward(cfg, params, tokens)
    return tfm.lm_forward(cfg, params, tokens, frames=frames)[1]


slow = pytest.mark.slow       # heaviest prefill/decode compiles


@pytest.mark.parametrize("arch,tol", [
    ("qwen3-0.6b", 1e-4), ("qwen2.5-3b", 1e-4), ("stablelm-12b", 1e-4),
    ("chameleon-34b", 1e-4), ("deepseek-67b", 1e-4),
    pytest.param("deepseek-v3-671b", 1e-4, marks=slow),
    ("mixtral-8x7b", 1e-4), ("rwkv6-1.6b", 1e-4),
    pytest.param("zamba2-1.2b", 5e-4, marks=slow),
    pytest.param("whisper-base", 1e-4, marks=slow),
])
def test_decode_matches_teacher_forcing(arch, tol):
    cfg = _f32(reduce_config(get_config(arch)))
    api = build_model(cfg)
    params = api.init(RNG)
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    kw = {}
    frames = None
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(RNG, (B, cfg.num_frames, cfg.d_model))
        kw["frames"] = frames
    ref = _teacher(cfg, params, tokens, frames=frames)

    last, cache = api.prefill(params, tokens[:, :SPLIT], 16, **kw)
    scale = float(jnp.abs(ref).max())
    errs = [float(jnp.abs(last[:, 0] - ref[:, SPLIT - 1]).max())]
    for t in range(SPLIT, S):
        lg, cache = api.decode_step(params, cache, tokens[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0] - ref[:, t]).max()))
    assert max(errs) <= tol * max(scale, 1.0), f"{arch}: {errs}"


@pytest.mark.slow
def test_swa_ring_buffer_beyond_window():
    """Mixtral-style SWA: decode far past the window stays consistent."""
    cfg = _f32(reduce_config(get_config("mixtral-8x7b")))   # window 8
    api = build_model(cfg)
    params = api.init(RNG)
    S_long = 24
    tokens = jax.random.randint(RNG, (B, S_long), 0, cfg.vocab_size)
    ref = _teacher(cfg, params, tokens)
    last, cache = api.prefill(params, tokens[:, :8], 32)
    errs = []
    for t in range(8, S_long):
        lg, cache = api.decode_step(params, cache, tokens[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0] - ref[:, t]).max()))
    assert max(errs) < 1e-3, errs
