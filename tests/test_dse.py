"""DSE: rate balancing (Eq. 4–5), resource-constrained incrementing, SA
partitioning, and the Fig. 4 qualitative behaviours."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.paper_cnns import RESNET18
from repro.core.dse import (incremental_dse, partition_pipeline, rate_balance)
from repro.core.perf_model import (DesignPoint, FPGAModel, LayerCost,
                                   cnn_layer_costs, pipeline_throughput)


def _layers(sparsities=(0.0, 0.0, 0.0)):
    return [LayerCost(f"l{i}", macs=4096 * (i + 1), m_dot=64,
                      weight_count=4096, act_in=1, act_out=1, s_w=s)
            for i, s in enumerate(sparsities)]


def test_rate_balance_never_lowers_pipeline_throughput():
    hw = FPGAModel()
    layers = _layers()
    designs = [DesignPoint(4, 16), DesignPoint(8, 32), DesignPoint(8, 64)]
    before = pipeline_throughput(layers, designs, hw)
    balanced = rate_balance(layers, designs, hw)
    after = pipeline_throughput(layers, balanced, hw)
    assert after >= before * (1 - 1e-12)
    # and resource cannot grow
    res_b = sum(hw.layer_resource(l, d) for l, d in zip(layers, designs))
    res_a = sum(hw.layer_resource(l, d) for l, d in zip(layers, balanced))
    assert res_a <= res_b


def test_incremental_dse_respects_budget_and_improves():
    hw = FPGAModel()
    layers = _layers()
    small = incremental_dse(layers, hw, budget=64)
    big = incremental_dse(layers, hw, budget=1024)
    assert small.resource <= 64
    assert big.resource <= 1024
    assert big.throughput > small.throughput


def test_dse_gives_sparse_layer_fewer_macs():
    """Fig. 4: higher sparsity -> smaller MAC-per-SPE allocation for equal
    throughput (the arbiter keeps fewer MACs busy)."""
    hw = FPGAModel()
    layers = [
        LayerCost("dense", macs=65536, m_dot=256, weight_count=1, act_in=1,
                  act_out=1, s_w=0.0),
        LayerCost("sparse", macs=65536, m_dot=256, weight_count=1, act_in=1,
                  act_out=1, s_w=0.75),
    ]
    r = incremental_dse(layers, hw, budget=512)
    res = [hw.layer_resource(l, d) for l, d in zip(layers, r.designs)]
    assert res[1] < res[0]
    # rates stay balanced within 2x
    rates = [hw.layer_throughput(l, d) for l, d in zip(layers, r.designs)]
    assert max(rates) / min(rates) <= 4.0


def test_dse_trace_is_monotone_in_resource():
    hw = FPGAModel()
    r = incremental_dse(_layers((0.2, 0.5, 0.0)), hw, budget=2048)
    res = [t[0] for t in r.trace]
    assert all(b >= a for a, b in zip(res, res[1:]))


def test_resnet18_dse_end_to_end():
    hw = FPGAModel()
    layers = cnn_layer_costs(RESNET18)
    r = incremental_dse(layers, hw, budget=12288, max_iters=2500)
    assert 0 < r.resource <= 12288
    imgs = r.throughput * hw.freq
    assert imgs > 10          # sane scale for a dense U250-class budget


def test_partitioning_tradeoff():
    hw = FPGAModel()
    layers = cnn_layer_costs(RESNET18)[:8]
    one = partition_pipeline(layers, hw, budget=256, n_parts=1, batch=256,
                             reconfig_cycles=1e6, dse_iters=100)
    two = partition_pipeline(layers, hw, budget=256, n_parts=2, batch=256,
                             reconfig_cycles=1e6, dse_iters=100)
    assert one.time_per_batch > 0 and two.time_per_batch > 0
    # n_parts is an upper bound: the extra partition is used only when the
    # throughput gain repays the switch, so the DP can never be worse
    assert two.time_per_batch <= one.time_per_batch
    # with a huge reconfig cost the DP folds back to a single resident
    # partition (which is never reconfigured — no charge)
    expensive = partition_pipeline(layers, hw, budget=256, n_parts=2,
                                   batch=256, reconfig_cycles=1e12,
                                   dse_iters=100)
    assert expensive.cuts == []
    assert expensive.time_per_batch == one.time_per_batch
