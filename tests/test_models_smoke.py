"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward/train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduce_config
from repro.configs.paper_cnns import PAPER_CNNS
from repro.models import build_model
from repro.data.synthetic import batch_for, lm_batch, image_batch
from repro.configs.base import ShapeConfig

RNG = jax.random.PRNGKey(0)

# heaviest compiles (hybrid/MLA/enc-dec towers); slow-marked so the tier-1
# default run keeps one representative per family instead of every giant
_HEAVY_ARCHS = {"zamba2-1.2b", "deepseek-v3-671b", "whisper-base",
                "chameleon-34b", "stablelm-12b"}


def _arch_params():
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
            else a for a in sorted(ASSIGNED)]


@pytest.mark.parametrize("arch", _arch_params())
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduce_config(get_config(arch))
    api = build_model(cfg)
    params = api.init(RNG)

    B, S = 2, 16
    batch = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            RNG, (B, cfg.num_frames, cfg.d_model), jnp.bfloat16)

    loss, metrics = api.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0

    # one SGD-flavoured train step: loss must change and stay finite
    grads = jax.grad(lambda p: api.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"
    params2 = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = api.loss(params2, batch)
    assert not bool(jnp.isnan(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_arch_smoke_decode_shapes(arch):
    cfg = reduce_config(get_config(arch))
    api = build_model(cfg)
    if api.decode_step is None:
        pytest.skip("no decode for this family")
    params = api.init(RNG)
    B = 2
    cache = api.init_cache(B, 32)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = api.decode_step(params, cache, token)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert int(new_cache["pos"][0]) == 1


@pytest.mark.parametrize(
    "cfg", [c if c.name.startswith("resnet")
            else pytest.param(c, marks=pytest.mark.slow)
            for c in PAPER_CNNS], ids=lambda c: c.name)
def test_paper_cnn_smoke(cfg):
    rcfg = reduce_config(cfg)
    api = build_model(rcfg)
    params = api.init(RNG)
    batch = image_batch(rcfg, 2, seed=0)
    loss, _ = api.loss(params, batch)
    assert np.isfinite(float(loss))


def test_synthetic_lm_batches_deterministic():
    cfg = reduce_config(get_config("qwen3-0.6b"))
    b1 = lm_batch(cfg, 4, 32, seed=3, step=7)
    b2 = lm_batch(cfg, 4, 32, seed=3, step=7)
    b3 = lm_batch(cfg, 4, 32, seed=3, step=8)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
