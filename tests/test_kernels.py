"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.block_sparse_matmul import build_tile_schedule

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 384, 256),
                                   (100, 300, 200), (64, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_sparse_matmul_sweep(M, K, N, dtype):
    x = jnp.asarray(RNG.normal(size=(M, K)), dtype)
    w = RNG.normal(size=(K, N)).astype(np.float32)
    # zero out random tiles entirely so skipping has something to skip
    Kt, Nt = -(-K // 128), -(-N // 128)
    for i in range(Kt):
        for j in range(Nt):
            if RNG.random() < 0.4:
                w[i * 128:(i + 1) * 128, j * 128:(j + 1) * 128] = 0.0
    w = jnp.asarray(w, dtype)
    sw = ops.SparseWeight(w, bk=128, bn=128)
    out = sw.matmul(x)
    oracle = ref.block_sparse_matmul_ref(
        jnp.asarray(x, jnp.float32), jnp.asarray(w, jnp.float32),
        sw.mask, 128, 128)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               atol=tol, rtol=tol)


def test_schedule_skips_zero_tiles():
    """The static schedule is the paper's Eq.1 at tile granularity: grid steps
    per output column == nnz tiles, not K/bk."""
    mask = np.array([[1, 0], [0, 0], [1, 1]], dtype=bool)   # (Kt=3, Nt=2)
    counts, indices = build_tile_schedule(mask)
    assert counts.tolist() == [2, 1]
    assert indices[0, :2].tolist() == [0, 2]
    assert indices.shape[1] == 2                            # max_nnz, not Kt


def test_masked_tiles_contribute_zero_even_if_weight_nonzero():
    """Semantics: the kernel never loads masked tiles."""
    x = jnp.ones((128, 256), jnp.float32)
    w = np.ones((256, 128), np.float32)
    mask = np.array([[True], [False]])                      # second K-tile off
    counts, indices = build_tile_schedule(mask)
    from repro.kernels.block_sparse_matmul import block_sparse_matmul
    out = block_sparse_matmul(x, jnp.asarray(w), jnp.asarray(counts),
                              jnp.asarray(indices), interpret=True)
    np.testing.assert_allclose(np.asarray(out), 128.0)      # only 128 of 256


@pytest.mark.parametrize("shape", [(64, 64), (100, 333), (7, 1024), (1, 9)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tau", [0.0, 0.5, 2.0])
def test_act_clip_sweep(shape, dtype, tau):
    x = jnp.asarray(RNG.normal(size=shape), dtype)
    y, cnt = ops.act_clip(x, tau)
    y_ref, cnt_ref = ref.act_clip_count_ref(x, tau)
    assert y.shape == x.shape and y.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    assert int(cnt) == int(cnt_ref)


@settings(max_examples=15, deadline=None)
@given(kt=st.integers(1, 4), nt=st.integers(1, 3),
       density=st.floats(0.1, 1.0))
def test_property_schedule_counts_match_mask(kt, nt, density):
    mask = RNG.random((kt, nt)) < density
    counts, indices = build_tile_schedule(mask)
    assert (counts == mask.sum(0)).all()
    for j in range(nt):
        nz = np.nonzero(mask[:, j])[0]
        assert indices[j, :len(nz)].tolist() == nz.tolist()


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 130), k=st.integers(1, 300), tau=st.floats(0, 3))
def test_property_clip_idempotent_and_counts(m, k, tau):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    y, cnt = ops.act_clip(x, tau)
    y2, cnt2 = ops.act_clip(y, tau)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
    assert int(cnt) == int(cnt2) == int(np.sum(np.asarray(y) == 0.0))


# --------------------------------------------------------------------- #
# Vectorized + memoized schedule builder (DESIGN.md §12)
# --------------------------------------------------------------------- #
def test_build_tile_schedule_matches_reference_loop():
    from repro.kernels.block_sparse_matmul import _build_tile_schedule_ref
    rng = np.random.default_rng(0)
    for _ in range(60):
        kt = int(rng.integers(1, 40))
        nt = int(rng.integers(1, 40))
        mask = rng.random((kt, nt)) < rng.uniform(0.0, 1.0)
        c1, i1 = build_tile_schedule(mask)
        c2, i2 = _build_tile_schedule_ref(mask)
        assert np.array_equal(c1, c2)
        assert np.array_equal(i1, i2)


def test_build_tile_schedule_memoizes_per_mask_content():
    from repro.kernels.block_sparse_matmul import _SCHEDULE_CACHE
    rng = np.random.default_rng(1)
    mask = rng.random((12, 9)) < 0.4
    _SCHEDULE_CACHE.clear()
    a = build_tile_schedule(mask)
    b = build_tile_schedule(mask.copy())       # same content, new array
    assert a[0] is b[0] and a[1] is b[1]       # dict hit, shared arrays
    assert len(_SCHEDULE_CACHE) == 1
    # different content is a different entry
    mask2 = mask.copy()
    mask2[0, 0] = not mask2[0, 0]
    build_tile_schedule(mask2)
    assert len(_SCHEDULE_CACHE) == 2


def test_schedule_cache_is_bounded():
    from repro.kernels import block_sparse_matmul as bsm
    rng = np.random.default_rng(2)
    bsm._SCHEDULE_CACHE.clear()
    for _ in range(bsm._SCHEDULE_CACHE_MAX + 10):
        build_tile_schedule(rng.random((6, 6)) < 0.5)
    assert len(bsm._SCHEDULE_CACHE) <= bsm._SCHEDULE_CACHE_MAX


# --------------------------------------------------------------------- #
# Pattern-pruned weights through the schedule + kernel (DESIGN.md §16)
# --------------------------------------------------------------------- #
def _patterned_weight(kind, seed, K=384, N=256):
    from repro.core import pruning
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    if kind == "nm":
        # tile-level zeros first so N:M pruning leaves all-zero tiles for
        # the schedule to skip, then the N:M grid on what's left
        wt, _ = pruning.tile_prune(w, 0.4)
        return pruning.nm_prune(wt, 4)
    if kind == "hierarchical":
        return pruning.hierarchical_prune(w, 0.5, 3)[0]
    wt, _ = pruning.tile_prune(w, 0.5)
    return wt


@pytest.mark.parametrize("kind", ["unstructured", "nm", "hierarchical"])
@pytest.mark.parametrize("seed", [0, 1])
def test_patterned_mask_schedule_matches_reference(kind, seed):
    """build_tile_schedule on masks of N:M- and hierarchically-pruned
    weights == the per-column reference loop — the pattern pruners produce
    ordinary tile masks, nothing schedule-special."""
    from repro.kernels.block_sparse_matmul import (_build_tile_schedule_ref,
                                                   tile_mask)
    w = _patterned_weight(kind, seed)
    mask = tile_mask(np.asarray(w))
    c1, i1 = build_tile_schedule(mask)
    c2, i2 = _build_tile_schedule_ref(mask)
    assert np.array_equal(c1, c2) and np.array_equal(i1, i2)
    if kind != "nm":
        assert (c1 < mask.shape[0]).any()      # something actually skipped


@pytest.mark.parametrize("kind", ["nm", "hierarchical"])
def test_block_sparse_matmul_on_patterned_weights(kind):
    """The winning pattern's schedule EXECUTES: kernel output on a pruned
    weight == dense jnp reference on the same (element-sparse) weight."""
    from repro.kernels.block_sparse_matmul import (block_sparse_matmul,
                                                   build_tile_schedule,
                                                   tile_mask)
    w = _patterned_weight(kind, 3)
    x = jnp.asarray(RNG.normal(size=(128, w.shape[0])), jnp.float32)
    counts, indices = build_tile_schedule(tile_mask(np.asarray(w)))
    out = block_sparse_matmul(x, w, jnp.asarray(counts),
                              jnp.asarray(indices), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               atol=1e-4, rtol=1e-4)


def test_tile_mask_shape_and_content():
    from repro.kernels.block_sparse_matmul import tile_mask
    w = np.zeros((256, 256), np.float32)
    w[130, 5] = 1.0                            # one element in tile (1, 0)
    mask = tile_mask(w)
    assert mask.shape == (2, 2)
    assert mask.tolist() == [[False, False], [True, False]]
    with pytest.raises(AssertionError):
        tile_mask(np.zeros((100, 256), np.float32))
