"""LM-stack DSE pipeline: analytic ``LMEvaluator`` + max-min multi-chip DP
(DESIGN.md §11).

The load-bearing contracts:
  * the max-min DP's partition is never worse on ``steady_throughput`` than
    the sum-form DP's pick, across randomized LM stacks (the acceptance
    property of the LM-workload PR);
  * on small stacks the max-min DP equals brute-force enumeration of every
    cut subset (it is exact, not just better);
  * ``cut_points`` restricts the DP without changing its accounting;
  * the ``LMEvaluator`` produces valid Eq. 6 metric dicts, tile-quantized
    sparsity on TPU, and runs end-to-end through ``hass_search``.
"""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduce_config
from repro.core.dse import (boundary_activations, incremental_dse,
                            partition_pipeline)
from repro.core.hass import LMEvaluator, hass_search
from repro.core.perf_model import (ACT_BYTES, FPGAModel, TPUModel,
                                   lm_block_bounds, lm_layer_costs,
                                   thin_cut_points, tile_quantize_sparsity)

LM_ARCHS = ["qwen3-0.6b", "mixtral-8x7b", "deepseek-v3-671b", "zamba2-1.2b",
            "rwkv6-1.6b"]


def sparse_lm_stack(arch: str, seed: int, reduced: bool = True):
    cfg = get_config(arch)
    layers = lm_layer_costs(reduce_config(cfg) if reduced else cfg,
                            seq_len=128)
    rng = np.random.default_rng(seed)
    for l in layers:
        if l.prunable:
            l.s_w = l.s_w_tile = float(rng.uniform(0.0, 0.8))
    return layers


def steady_rate(layers, tpu, budget, cuts, dse_iters):
    """Spatial steady-state rate of one explicit partitioning: min over
    per-segment DSE rates and per-cut ICI hop rates."""
    bounds = [0] + list(cuts) + [len(layers)]
    rate = min(incremental_dse(layers[a:b], tpu, budget,
                               max_iters=dse_iters).throughput
               for a, b in zip(bounds, bounds[1:]))
    for c in cuts:
        hop = tpu.ici_transfer_cycles(boundary_activations(layers, c)
                                      * ACT_BYTES)
        rate = min(rate, 1.0 / hop)
    return rate


# --------------------------------------------------------------------- #
# max-min DP vs sum-form DP
# --------------------------------------------------------------------- #
@settings(max_examples=10, deadline=None)
@given(arch=st.sampled_from(LM_ARCHS), seed=st.integers(0, 10 ** 6),
       chips=st.integers(2, 5))
def test_property_maxmin_never_worse_than_sum_on_steady(arch, seed, chips):
    """The acceptance property: across randomized LM stacks the max-min
    DP's partition is never worse in ``steady_throughput`` than the
    sum-form DP's partition (same cut space, same segment table)."""
    layers = sparse_lm_stack(arch, seed)
    tpu = TPUModel(chips=chips)
    cuts = lm_block_bounds(layers)
    kw = dict(n_parts=chips, batch=32, dse_iters=80, cut_points=cuts)
    mm = partition_pipeline(layers, tpu, tpu.chip_budget,
                            objective="maxmin", **kw)
    sm = partition_pipeline(layers, tpu, tpu.chip_budget,
                            objective="sum", **kw)
    assert mm.steady_throughput >= sm.steady_throughput * (1 - 1e-12)
    assert mm.objective == "maxmin" and sm.objective == "sum"
    # and the sum-form pick still minimizes the amortized batch time
    assert sm.time_per_batch <= mm.time_per_batch * (1 + 1e-12)


def test_maxmin_equals_bruteforce_on_small_stack():
    """Exactness: on a small stack the DP's steady rate matches exhaustive
    enumeration of every cut subset within the candidate set."""
    layers = sparse_lm_stack("qwen3-0.6b", seed=3)[:18]
    tpu = TPUModel(chips=3)
    cands = list(range(1, len(layers)))
    dse_iters = 60
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=32, dse_iters=dse_iters, cut_points=cands,
                           objective="maxmin")
    best = max(
        steady_rate(layers, tpu, tpu.chip_budget, c, dse_iters)
        for k in range(3)
        for c in itertools.combinations(cands, k))
    assert r.steady_throughput == pytest.approx(best, rel=1e-12)


def test_maxmin_steady_matches_its_own_partition():
    layers = sparse_lm_stack("mixtral-8x7b", seed=0)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=32, dse_iters=80,
                           cut_points=lm_block_bounds(layers),
                           objective="maxmin")
    assert r.steady_throughput == pytest.approx(
        steady_rate(layers, tpu, tpu.chip_budget, r.cuts, 80), rel=1e-12)
    # switch accounting is unchanged: P-1 ICI transfers per batch, priced
    # at the residual stream that crosses each cut
    seg_time = sum(r.batch / t for t in r.part_throughput)
    ici = sum(tpu.ici_transfer_cycles(r.batch * boundary_activations(layers, c)
                                      * ACT_BYTES) for c in r.cuts)
    assert r.time_per_batch == pytest.approx(seg_time + ici, rel=1e-12)


def test_maxmin_requires_multi_chip():
    layers = sparse_lm_stack("qwen3-0.6b", seed=0)[:10]
    with pytest.raises(ValueError, match="maxmin"):
        partition_pipeline(layers, FPGAModel(), 512.0, n_parts=2,
                           objective="maxmin")
    with pytest.raises(ValueError, match="maxmin"):
        partition_pipeline(layers, TPUModel(chips=1), 512.0, n_parts=2,
                           objective="maxmin")
    with pytest.raises(ValueError, match="objective"):
        partition_pipeline(layers, TPUModel(chips=2), 512.0, n_parts=2,
                           objective="bogus")


def test_auto_objective_picks_maxmin_only_for_multichip():
    layers = sparse_lm_stack("qwen3-0.6b", seed=1)[:12]
    multi = partition_pipeline(layers, TPUModel(chips=2), 512.0, n_parts=2,
                               batch=32, dse_iters=60)
    single = partition_pipeline(layers, TPUModel(chips=1), 512.0, n_parts=2,
                                batch=32, dse_iters=60)
    fpga = partition_pipeline(layers, FPGAModel(), 512.0, n_parts=2,
                              batch=32, dse_iters=60)
    assert multi.objective == "maxmin"
    assert single.objective == "sum" and fpga.objective == "sum"


def test_cut_points_restrict_the_dp():
    layers = sparse_lm_stack("qwen3-0.6b", seed=2)
    cands = thin_cut_points(lm_block_bounds(layers), 6)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=32, dse_iters=80, cut_points=cands)
    assert set(r.cuts) <= set(cands)
    assert len(r.cuts) + 1 <= tpu.chips
    # K candidates -> at most K(K+1)/2 segment DSEs, far below L(L+1)/2
    K = len(cands) + 1
    assert r.dse_calls <= K * (K + 1) // 2
    with pytest.raises(ValueError, match="cut_points"):
        partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           cut_points=[0, 5])
    with pytest.raises(ValueError, match="cut_points"):
        partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           cut_points=[len(layers)])


def test_sum_dp_with_cut_points_matches_unrestricted_on_free_cut_space():
    """With every position allowed, the candidate-set DP reproduces the
    unrestricted DP exactly (the pre-LM behavior is unchanged)."""
    layers = sparse_lm_stack("qwen3-0.6b", seed=4)[:14]
    hw = FPGAModel()
    kw = dict(n_parts=3, batch=64, reconfig_cycles=1e5, dse_iters=60)
    free = partition_pipeline(layers, hw, 2048.0, **kw)
    full = partition_pipeline(layers, hw, 2048.0,
                              cut_points=list(range(1, len(layers))), **kw)
    assert free.cuts == full.cuts
    assert free.time_per_batch == full.time_per_batch


def test_boundary_activations_price_the_residual_stream():
    """A MoE block's last matmul 'emits' d_model x active_experts, but only
    one residual stream of width d_model crosses a block cut — the ICI cost
    must not inherit the intra-block n_apply fan-out."""
    cfg = get_config("deepseek-v3-671b")
    layers = lm_layer_costs(cfg)
    for c in lm_block_bounds(layers):
        assert boundary_activations(layers, c) == cfg.d_model
        assert layers[c - 1].act_out > cfg.d_model   # moe_down fan-out
    # sequential handoffs (the CNN case) are priced at the actual tensor
    assert boundary_activations(layers, 1) == \
        min(layers[0].act_out, layers[1].act_in)


# --------------------------------------------------------------------- #
# LMEvaluator
# --------------------------------------------------------------------- #
def _tpu_evaluator(arch="qwen3-0.6b", **kw):
    tpu = TPUModel()
    return LMEvaluator(get_config(arch), tpu, tpu.budget, dse_iters=120,
                       **kw)


def test_lm_evaluator_metric_dict_is_valid():
    ev = _tpu_evaluator()
    m = ev(np.full(ev.n_search, 0.4))
    assert set(m) >= {"acc", "spa", "thr", "thr_norm", "dsp", "eff"}
    assert 0.0 < m["acc"] <= 1.0
    assert 0.0 <= m["spa"] < 1.0
    assert m["thr"] > 0 and m["dsp"] > 0


def test_lm_evaluator_dense_proposal_is_lossless():
    ev = _tpu_evaluator()
    m = ev(np.zeros(ev.n_search))
    assert m["acc"] == 1.0 and m["spa"] == 0.0


def test_lm_evaluator_sparsity_tradeoff_is_monotone():
    """More sparsity: never more accuracy, never less modeled throughput."""
    ev = _tpu_evaluator()
    lo = ev(np.full(ev.n_search, 0.2))
    hi = ev(np.full(ev.n_search, 0.7))
    assert hi["acc"] <= lo["acc"]
    assert hi["thr"] >= lo["thr"]
    assert hi["spa"] > lo["spa"]


def test_lm_evaluator_tpu_sparsity_is_tile_quantized():
    ev = _tpu_evaluator()
    layers = ev.sparse_layers(np.full(ev.n_search, 0.37))
    assert any(l.prunable for l in layers)
    for l in layers:
        if l.prunable:
            assert l.s_w == l.s_w_tile
            assert l.s_w == tile_quantize_sparsity(0.37, l.m_dot,
                                                   l.weight_count)
        else:
            assert l.s_w_tile == 0.0


def test_lm_evaluator_fpga_keeps_element_sparsity():
    ev = LMEvaluator(get_config("qwen3-0.6b"), FPGAModel(), 4096.0,
                     dse_iters=120)
    layers = ev.sparse_layers(np.full(ev.n_search, 0.37))
    for l in layers:
        if l.prunable:
            assert l.s_w == 0.37 and l.s_w_tile == 0.0


def test_lm_evaluator_tie_modes():
    ev_kind = _tpu_evaluator(tie="kind")
    ev_none = _tpu_evaluator(tie="none")
    assert ev_kind.n_search == len(set(ev_kind.group_names))
    assert ev_kind.n_search < ev_none.n_search
    assert ev_none.n_search == len(ev_none.prunable)
    with pytest.raises(ValueError, match="tie"):
        _tpu_evaluator(tie="blocks")
    # tied expansion broadcasts one target to every block's same-kind matmul
    x = np.arange(ev_kind.n_search, dtype=float) / (2 * ev_kind.n_search)
    s_w, _ = ev_kind._split(x)
    for l, s in zip(ev_kind.prunable, s_w):
        kind = l.name.split(".", 1)[-1]
        assert s == x[ev_kind.group_names.index(kind)]


def test_hass_search_runs_end_to_end_on_lm_evaluator():
    ev = _tpu_evaluator("zamba2-1.2b")
    res = hass_search(ev, ev.n_search, iters=6, include_act=False,
                      batch_size=3, seed=0)
    assert len(res.trials) == 6
    assert np.isfinite(res.best_score)
    assert res.best_metrics["acc"] > 0
    # the best proposal's stack feeds the multi-chip DP directly
    layers = ev.sparse_layers(res.best_x)
    tpu = TPUModel(chips=2)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           batch=16, dse_iters=60,
                           cut_points=thin_cut_points(
                               lm_block_bounds(layers), 6))
    assert r.steady_throughput > 0


# --------------------------------------------------------------------- #
# Accelerated evaluator path == seed path, bit for bit (DESIGN.md §12)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x7b"])
def test_lm_evaluator_accel_matches_baseline_bitwise(arch):
    cfg = get_config(arch)
    tpu = TPUModel()
    kw = dict(dse_iters=150)
    ev_a = LMEvaluator(cfg, tpu, tpu.chip_budget, accel=True, **kw)
    ev_b = LMEvaluator(cfg, tpu, tpu.chip_budget, accel=False,
                       dse_engine="flat", **kw)
    rng = np.random.default_rng(0)
    for _ in range(4):
        x = rng.uniform(0.0, 0.9, ev_a.n_search)
        assert ev_a(x) == ev_b(x)
    assert ev_a.dse_cache.stats()["cold_runs"] >= 1


def test_lm_realize_matches_sparse_layers_s_eff():
    """The vectorized realization must produce the exact floats the
    LayerCost path hands to ``hw.effective_sparsity``."""
    for hw in (TPUModel(), FPGAModel()):
        ev = LMEvaluator(get_config("qwen3-0.6b"), hw, 512.0, dse_iters=50)
        rng = np.random.default_rng(1)
        x = rng.uniform(0.0, 0.9, 2 * ev.n_search)
        _, _, s_eff = ev._realize(x)
        via_layers = np.array([hw.effective_sparsity(l)
                               for l in ev.sparse_layers(x)])
        assert np.array_equal(s_eff, via_layers)


def test_lm_search_cache_reuses_across_repeated_proposals():
    ev = LMEvaluator(get_config("qwen3-0.6b"), TPUModel(), 512.0,
                     dse_iters=100)
    x = np.full(ev.n_search, 0.4)
    m1 = ev(x)
    m2 = ev(np.array(x))
    assert m1 == m2
    assert ev.dse_cache.stats()["hits"] >= 1
