"""Magnitude pruning (§III) + TPE search properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pruning
from repro.core.tpe import TPE

RNG = np.random.default_rng(3)


@settings(max_examples=25, deadline=None)
@given(s=st.floats(0.0, 0.95), n=st.integers(64, 2048))
def test_property_achieved_sparsity_close_to_target(s, n):
    w = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    w2 = pruning.prune_by_sparsity(w, s)
    achieved = pruning.sparsity_of(w2)
    assert abs(achieved - s) <= 2.0 / np.sqrt(n) + 0.02


@settings(max_examples=25, deadline=None)
@given(s=st.floats(0.0, 0.9))
def test_property_pruning_idempotent_and_monotone(s):
    w = jnp.asarray(RNG.normal(size=(512,)), jnp.float32)
    w1 = pruning.prune_by_sparsity(w, s)
    w2 = pruning.prune_by_sparsity(w1, s)
    assert pruning.sparsity_of(w2) >= pruning.sparsity_of(w1) - 1e-9
    # more aggressive threshold ⇒ superset of zeros
    w3 = pruning.prune_by_sparsity(w, min(0.95, s + 0.2))
    zeros1 = np.asarray(w1) == 0
    zeros3 = np.asarray(w3) == 0
    assert np.all(zeros3 | ~zeros1 | zeros1 & zeros3)
    assert zeros3.sum() >= zeros1.sum()


def test_prune_params_per_layer_thresholds():
    params = {"blocks": {"attn": {"wq": jnp.asarray(
        RNG.normal(size=(3, 32, 32)), jnp.float32)}}}
    # per-layer sparsity vector
    out, achieved = pruning.prune_params(
        params, {"blocks/attn/wq": np.array([0.0, 0.5, 0.9])})
    w = np.asarray(out["blocks"]["attn"]["wq"])
    per_layer = (w == 0).mean(axis=(1, 2))
    assert per_layer[0] <= 0.02
    assert abs(per_layer[1] - 0.5) < 0.1
    assert abs(per_layer[2] - 0.9) < 0.1


def test_tile_sparsity_counts_zero_tiles():
    w = np.ones((256, 256), np.float32)
    w[:128, :128] = 0.0
    assert pruning.tile_sparsity(jnp.asarray(w), 128, 128) == pytest.approx(0.25)


def test_default_prunable_paths():
    assert pruning.default_prunable("blocks/attn/wq")
    assert pruning.default_prunable("blocks/ffn/w_gate")
    assert not pruning.default_prunable("blocks/ln1")
    assert not pruning.default_prunable("embed")
    assert not pruning.default_prunable("blocks/attn/q_norm")


def test_gaussian_act_model_matches_empirical():
    x = RNG.normal(size=200_000)
    for tau in (0.1, 0.5, 1.0, 2.0):
        pred = pruning.act_sparsity_gaussian(tau)
        emp = float((np.abs(x) < tau).mean())
        assert abs(pred - emp) < 0.01
    # inverse
    for s in (0.1, 0.5, 0.9):
        tau = pruning.tau_for_act_sparsity(s)
        assert abs(pruning.act_sparsity_gaussian(tau) - s) < 1e-6


def test_tpe_beats_random_on_quadratic():
    """TPE must beat equal-budget random search on average over seeds."""
    def f(x):
        return -np.sum((x - 0.3) ** 2)

    lo, hi = np.zeros(4), np.ones(4)
    tpe_scores, rand_scores = [], []
    for seed in range(5):
        tpe = TPE(lo=lo, hi=hi, seed=seed, n_startup=8)
        for _ in range(60):
            x = tpe.ask()
            tpe.tell(x, f(x))
        tpe_scores.append(tpe.best[1])
        rng = np.random.default_rng(seed)
        rand_scores.append(max(f(rng.uniform(lo, hi)) for _ in range(60)))
    assert np.mean(tpe_scores) > np.mean(rand_scores)


# --------------------------------------------------------------------- #
# Presorted quantile tables + tile-structured pruning (DESIGN.md §12)
# --------------------------------------------------------------------- #
def test_sorted_quantile_bit_matches_jnp_quantile():
    """The accel path's whole claim: a gather from a presorted table is the
    SAME floats jnp.quantile computes — across sizes and traced q."""
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    f_ref = jax.jit(lambda a, q: jnp.quantile(jnp.abs(a), q))
    f_new = jax.jit(pruning.sorted_quantile)
    for n in (17, 1000, 65536):
        a = jnp.asarray(rng.normal(size=n).astype(np.float32))
        asort = pruning.sorted_abs(a)
        for q in rng.uniform(0, 1, 64).astype(np.float32):
            assert float(f_ref(a, jnp.float32(q))) == \
                float(f_new(asort, jnp.float32(q))), (n, q)


def test_threshold_for_sparsity_sorted_matches_unsorted():
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
    asort = pruning.sorted_abs(w)
    f_a = jax.jit(pruning.threshold_for_sparsity_sorted)
    f_b = jax.jit(pruning.threshold_for_sparsity)
    for s in (0.0, 0.2, 0.55, 0.95, 1.0):
        assert float(f_a(asort, jnp.float32(s))) == \
            float(f_b(w, jnp.float32(s)))
    # zero-target floor preserved
    assert float(f_a(asort, jnp.float32(-0.1))) == 0.0


def test_tile_prune_produces_aligned_all_zero_tiles():
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(256, 384)).astype(np.float32))
    for target in (0.25, 0.5, 0.75):
        w2, frac = pruning.tile_prune(w, target)
        frac = float(frac)
        # realized fraction is measured, tile-granular, near the target
        assert abs(frac - target) <= 1.0 / 6 + 1e-6
        assert frac == pytest.approx(pruning.tile_sparsity(w2, 128, 128))
        # zeroed tiles are fully zero and 128-aligned
        t = np.asarray(w2).reshape(2, 128, 3, 128)
        zero_tiles = ~np.any(t != 0, axis=(1, 3))
        assert zero_tiles.sum() == round(frac * 6)
        # surviving weights are untouched
        keep = np.repeat(np.repeat(~zero_tiles, 128, 0), 128, 1)
        assert np.array_equal(np.asarray(w2)[keep], np.asarray(w)[keep])


def test_tile_prune_zero_target_is_identity():
    import jax.numpy as jnp
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(130, 200)).astype(np.float32))
    w2, frac = pruning.tile_prune(w, 0.0)
    assert np.array_equal(np.asarray(w2), np.asarray(w))
    assert float(frac) == 0.0


# --------------------------------------------------------------------- #
# Sparsity-pattern axis: N:M / hierarchical pruners (DESIGN.md §16)
# --------------------------------------------------------------------- #
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 8), rows=st.integers(1, 40), cols=st.integers(1, 24),
       seed=st.integers(0, 1000))
def test_property_nm_prune_group_budget(n, rows, cols, seed):
    """Every M-group along the reduction dim keeps at most N nonzeros —
    for any shape, including ragged K (zero-padded groups)."""
    m = pruning.NM_M
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(rows, cols)),
                    jnp.float32)
    w2 = np.asarray(pruning.nm_prune(w, n))
    pad = (-rows) % m
    g = np.pad(w2, ((0, pad), (0, 0))).reshape(-1, m, cols)
    per_group = (g != 0).sum(axis=1)
    assert per_group.max() <= n
    # within each group, kept magnitudes dominate dropped ones
    a = np.abs(np.pad(np.asarray(w), ((0, pad), (0, 0))).reshape(-1, m, cols))
    kept = g != 0
    for j in range(cols):
        for gi in range(a.shape[0]):
            k, d = a[gi, kept[gi, :, j], j], a[gi, ~kept[gi, :, j], j]
            if len(k) and len(d):
                assert k.min() >= d.max() - 1e-7


def test_nm_prune_exact_sparsity_on_dense_input():
    """sparsity_of == exactly 1 - N/M for dense inputs with K % M == 0."""
    m = pruning.NM_M
    w = jnp.asarray(RNG.normal(size=(16 * m, 32)) + 10.0, jnp.float32)
    for n in range(1, m + 1):
        w2 = pruning.nm_prune(w, n)
        assert float(pruning.sparsity_of(w2)) == \
            pytest.approx(1.0 - n / m, abs=1e-7)


def test_nm_keep_and_grid_consistency():
    m = pruning.NM_M
    for s in np.linspace(0.0, 1.0, 33):
        n = int(pruning.nm_keep_for_sparsity(s))
        assert 1 <= n <= m
        grid = float(pruning.nm_sparsity_grid(s))
        assert grid == pytest.approx(1.0 - n / m)
        assert grid <= s + 1e-9   # snap never overshoots the target


def test_nm_prune_traced_n_matches_static():
    """The CNN pattern path traces n through jit — same zeros either way."""
    w = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
    f = jax.jit(pruning.nm_prune)
    for n in (1, 3, 8):
        assert np.array_equal(np.asarray(f(w, jnp.int32(n))),
                              np.asarray(pruning.nm_prune(w, n)))


@settings(max_examples=15, deadline=None)
@given(tile_frac=st.floats(0.0, 0.9), n=st.integers(1, 8),
       seed=st.integers(0, 100))
def test_property_hierarchical_equals_tile_then_nm(tile_frac, n, seed):
    """Composition oracle: hierarchical_prune == nm_prune ∘ tile_prune."""
    w = jnp.asarray(np.random.default_rng(seed).normal(size=(256, 256)),
                    jnp.float32)
    got, ztile = pruning.hierarchical_prune(w, tile_frac, n)
    wt, ztile_ref = pruning.tile_prune(w, tile_frac)
    ref = pruning.nm_prune(wt, n)
    assert float(ztile) == float(ztile_ref)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 7), seed=st.integers(0, 1000))
def test_property_nm_dominated_by_unstructured_magnitude(n, seed):
    """Anything N:M keeps that equal-budget unstructured pruning drops must
    sit at or below the smallest magnitude unstructured keeps — the
    structure tax only ever swaps in SMALLER weights, never larger."""
    w = np.random.default_rng(seed).normal(size=(8 * 16, 24))
    w2 = np.asarray(pruning.nm_prune(jnp.asarray(w, jnp.float32), n))
    kept_p = w2 != 0
    k = int(kept_p.sum())
    a = np.abs(w).ravel()
    order = np.argsort(-a, kind="stable")
    kept_u = np.zeros(a.size, bool)
    kept_u[order[:k]] = True            # global top-k at the same budget
    kept_u = kept_u.reshape(w.shape)
    swapped_in = kept_p & ~kept_u
    if swapped_in.any():
        assert np.abs(w)[swapped_in].max() <= np.abs(w)[kept_u].min() + 1e-7


def test_act_realize_pattern_combines_rates():
    assert pruning.act_realize_pattern(0.0, 0.3) == pytest.approx(0.3)
    assert pruning.act_realize_pattern(0.5, 0.5) == pytest.approx(0.75)
    assert pruning.act_realize_pattern(0.2, 0.0) == pytest.approx(0.2)


# --------------------------------------------------------------------- #
# TPE categorical dims (DESIGN.md §16)
# --------------------------------------------------------------------- #
def test_tpe_categorical_snaps_to_bin_centers():
    t = TPE(lo=np.array([0.0, 0.0]), hi=np.array([0.9, 4.0]),
            seed=5, cats=np.array([0, 4]))
    centers = {0.5, 1.5, 2.5, 3.5}
    seen = set()
    for _ in range(50):
        x = t.ask()
        assert x[1] in centers
        seen.add(x[1])
        t.tell(x, -abs(x[1] - 2.5) + x[0])
    for x in t.ask_batch(6, liar="min") + t.ask_batch(6):
        assert x[1] in centers
    assert len(seen) >= 3          # the axis is actually explored


def test_tpe_cats_none_replays_pre_categorical_stream():
    """cats=None must be bit-identical to a TPE without the feature — the
    snap consumes no RNG and never touches continuous dims."""
    a = TPE(lo=np.zeros(3), hi=np.ones(3), seed=11)
    b = TPE(lo=np.zeros(3), hi=np.ones(3), seed=11, cats=None)
    for _ in range(30):
        xa, xb = a.ask(), b.ask()
        assert np.array_equal(xa, xb)
        y = float(np.sum(xa))
        a.tell(xa, y)
        b.tell(xb, y)
    for xa, xb in zip(a.ask_batch(5, liar="min"), b.ask_batch(5, liar="min")):
        assert np.array_equal(xa, xb)


def test_tpe_cats_validation():
    with pytest.raises(ValueError):
        TPE(lo=np.zeros(2), hi=np.ones(2), cats=np.array([2, 0]))
    with pytest.raises(ValueError):
        TPE(lo=np.zeros(2), hi=np.ones(2), cats=np.array([0]))


def test_tile_prune_non_2d_weights_flatten_leading_dims():
    import jax.numpy as jnp
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.normal(size=(3, 3, 32, 128)).astype(np.float32))
    w2, frac = pruning.tile_prune(w, 0.5)
    assert w2.shape == w.shape
    assert 0.0 <= float(frac) <= 1.0
    # the ragged boundary tile (mostly zero padding) ranks lowest and is
    # pruned first, so the ELEMENT zero fraction can sit well under the
    # tile fraction — it just has to be non-trivial
    assert float(jnp.mean(w2 == 0.0)) > 0.05
