"""Magnitude pruning (§III) + TPE search properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pruning
from repro.core.tpe import TPE

RNG = np.random.default_rng(3)


@settings(max_examples=25, deadline=None)
@given(s=st.floats(0.0, 0.95), n=st.integers(64, 2048))
def test_property_achieved_sparsity_close_to_target(s, n):
    w = jnp.asarray(RNG.normal(size=(n,)), jnp.float32)
    w2 = pruning.prune_by_sparsity(w, s)
    achieved = pruning.sparsity_of(w2)
    assert abs(achieved - s) <= 2.0 / np.sqrt(n) + 0.02


@settings(max_examples=25, deadline=None)
@given(s=st.floats(0.0, 0.9))
def test_property_pruning_idempotent_and_monotone(s):
    w = jnp.asarray(RNG.normal(size=(512,)), jnp.float32)
    w1 = pruning.prune_by_sparsity(w, s)
    w2 = pruning.prune_by_sparsity(w1, s)
    assert pruning.sparsity_of(w2) >= pruning.sparsity_of(w1) - 1e-9
    # more aggressive threshold ⇒ superset of zeros
    w3 = pruning.prune_by_sparsity(w, min(0.95, s + 0.2))
    zeros1 = np.asarray(w1) == 0
    zeros3 = np.asarray(w3) == 0
    assert np.all(zeros3 | ~zeros1 | zeros1 & zeros3)
    assert zeros3.sum() >= zeros1.sum()


def test_prune_params_per_layer_thresholds():
    params = {"blocks": {"attn": {"wq": jnp.asarray(
        RNG.normal(size=(3, 32, 32)), jnp.float32)}}}
    # per-layer sparsity vector
    out, achieved = pruning.prune_params(
        params, {"blocks/attn/wq": np.array([0.0, 0.5, 0.9])})
    w = np.asarray(out["blocks"]["attn"]["wq"])
    per_layer = (w == 0).mean(axis=(1, 2))
    assert per_layer[0] <= 0.02
    assert abs(per_layer[1] - 0.5) < 0.1
    assert abs(per_layer[2] - 0.9) < 0.1


def test_tile_sparsity_counts_zero_tiles():
    w = np.ones((256, 256), np.float32)
    w[:128, :128] = 0.0
    assert pruning.tile_sparsity(jnp.asarray(w), 128, 128) == pytest.approx(0.25)


def test_default_prunable_paths():
    assert pruning.default_prunable("blocks/attn/wq")
    assert pruning.default_prunable("blocks/ffn/w_gate")
    assert not pruning.default_prunable("blocks/ln1")
    assert not pruning.default_prunable("embed")
    assert not pruning.default_prunable("blocks/attn/q_norm")


def test_gaussian_act_model_matches_empirical():
    x = RNG.normal(size=200_000)
    for tau in (0.1, 0.5, 1.0, 2.0):
        pred = pruning.act_sparsity_gaussian(tau)
        emp = float((np.abs(x) < tau).mean())
        assert abs(pred - emp) < 0.01
    # inverse
    for s in (0.1, 0.5, 0.9):
        tau = pruning.tau_for_act_sparsity(s)
        assert abs(pruning.act_sparsity_gaussian(tau) - s) < 1e-6


def test_tpe_beats_random_on_quadratic():
    """TPE must beat equal-budget random search on average over seeds."""
    def f(x):
        return -np.sum((x - 0.3) ** 2)

    lo, hi = np.zeros(4), np.ones(4)
    tpe_scores, rand_scores = [], []
    for seed in range(5):
        tpe = TPE(lo=lo, hi=hi, seed=seed, n_startup=8)
        for _ in range(60):
            x = tpe.ask()
            tpe.tell(x, f(x))
        tpe_scores.append(tpe.best[1])
        rng = np.random.default_rng(seed)
        rand_scores.append(max(f(rng.uniform(lo, hi)) for _ in range(60)))
    assert np.mean(tpe_scores) > np.mean(rand_scores)
