"""Multi-device behaviours (pipeline parallel, compressed collectives,
sharding rules, elastic re-mesh) — run in a subprocess with 8 virtual
devices so the main pytest process keeps the single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    # ---- pipeline parallelism matches sequential execution ----
    from repro.distributed.pipeline import make_pipelined_fn, bubble_fraction
    mesh = jax.make_mesh((4,), ("stage",))
    S, M, mb, d = 4, 8, 2, 16
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(M, mb, d)), jnp.float32)
    pp = make_pipelined_fn(lambda W, h: jnp.tanh(h @ W), mesh,
                           n_stages=S, n_microbatches=M)
    y = pp(Ws, x)
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ Ws[s])
    assert float(jnp.abs(y - ref).max()) < 1e-5, "pp mismatch"
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9

    # ---- compressed int8 + error-feedback all-reduce ----
    from repro.distributed.collectives import compressed_psum
    mesh2 = jax.make_mesh((8,), ("data",))
    g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    err = jnp.zeros_like(g)
    mean, err2 = compressed_psum(g, err, mesh2, axis="data")
    true_mean = jnp.mean(g, axis=0)
    assert float(jnp.abs(mean[0] - true_mean).max()) < 0.05, "psum mean"
    assert float(jnp.abs(err2).max()) > 0, "error feedback empty"
    # error feedback: quantized value + its error reconstructs the input
    # (per-device decomposition property)
    q_plus_e = (g + 0.0)  # y = x + e0; deq = y - e1 => deq + e1 == y
    # second round shrinks systematic bias: accumulate twice
    mean2, err3 = compressed_psum(g, err2, mesh2, axis="data")
    assert float(jnp.abs(mean2[0] - true_mean).max()) < 0.1

    # ---- sharding rules produce valid, divisible NamedShardings ----
    from repro.configs import get_config
    from repro.distributed.sharding import param_specs, batch_spec, cache_spec
    from repro.models import build_model, input_specs
    from repro.configs.base import SHAPE_BY_NAME
    mesh3 = jax.make_mesh((2, 4), ("data", "model"))
    for arch in ("qwen3-0.6b", "mixtral-8x7b", "rwkv6-1.6b", "zamba2-1.2b",
                 "whisper-base", "deepseek-v3-671b"):
        cfg = get_config(arch)
        api = build_model(cfg)
        pshape = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
        specs = param_specs(mesh3, pshape)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_p = jax.tree_util.tree_leaves(pshape)
        sizes = dict(zip(mesh3.axis_names, mesh3.devices.shape))
        for leaf, spec in zip(flat_p, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axs = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([sizes[a] for a in axs]))
                assert dim % n == 0, (arch, leaf.shape, spec)
    print("MULTIDEVICE-OK")
""")


@pytest.mark.slow
def test_multidevice_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env=env, timeout=540)
    assert "MULTIDEVICE-OK" in r.stdout, r.stdout + r.stderr
