"""End-to-end behaviour: the paper's full flow on a reduced model —
prune -> calibrate -> DSE -> deploy sparse weights through the Pallas kernel —
plus a short resilient training run with checkpoint/restart."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import pruning
from repro.core.dse import incremental_dse
from repro.core.perf_model import FPGAModel, LayerCost
from repro.data.synthetic import lm_batch
from repro.kernels import ops
from repro.models import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import run_resilient
from repro.train.optimizer import OptConfig
from repro.train.train_loop import TrainConfig, init_train_state, make_train_step

RNG = jax.random.PRNGKey(0)


def test_full_hass_flow_on_lm():
    """One-shot prune an LM, measure sparsity, run the DSE, and execute the
    pruned matmul through the block-sparse kernel."""
    cfg = reduce_config(get_config("qwen3-0.6b"))
    api = build_model(cfg)
    params = api.init(RNG)

    # 1) one-shot magnitude pruning (§III), per-layer thresholds
    target = {"blocks/ffn/w_gate": np.full(cfg.num_layers, 0.6),
              "blocks/ffn/w_up": np.full(cfg.num_layers, 0.6)}
    pruned, achieved = pruning.prune_params(params, target)
    assert all(0.5 < v < 0.7 for v in achieved.values())

    # 2) pruned model still runs and degrades gracefully
    batch = lm_batch(cfg, 4, 32, seed=0, step=0)
    l_dense, _ = api.loss(params, batch)
    l_sparse, _ = api.loss(pruned, batch)
    assert np.isfinite(float(l_sparse))

    # 3) DSE with the measured sparsity (Eq. 1-3)
    layers = [LayerCost(f"l{i}", macs=cfg.d_model * cfg.d_ff, m_dot=cfg.d_model,
                        weight_count=cfg.d_model * cfg.d_ff, act_in=1,
                        act_out=1, s_w=list(achieved.values())[0])
              for i in range(4)]
    r = incremental_dse(layers, FPGAModel(), budget=1024)
    assert r.throughput > 0

    # 4) the pruned weight runs through the Pallas block-sparse kernel
    w = np.asarray(pruned["blocks"]["ffn"]["w_gate"][0])
    # tile-align sparsity: zero whole 128-tiles where density is low
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, w.shape[0])),
                    jnp.float32)
    sw = ops.SparseWeight(jnp.asarray(w))
    y = sw.matmul(x)
    ref = x @ jnp.asarray(w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3,
                               rtol=1e-3)


def test_sparse_training_with_activation_clipping():
    """Train with the paper's activation clipping active (dynamic S_a)."""
    cfg = reduce_config(get_config("qwen3-0.6b"))
    api = build_model(cfg)
    taus = {"attn": jnp.full((cfg.num_layers,), 0.05),
            "ffn": jnp.full((cfg.num_layers,), 0.05)}
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3), accum=1, remat=None)
    state = init_train_state(api.init, tcfg, RNG)
    step = jax.jit(make_train_step(api.loss, tcfg, sparsity=taus))
    losses = []
    for i in range(6):
        state, m = step(state, lm_batch(cfg, 8, 32, seed=0, step=i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_end_to_end_resilient_training(tmp_path):
    cfg = reduce_config(get_config("rwkv6-1.6b"))
    api = build_model(cfg)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3), accum=2, remat="full")
    state = init_train_state(api.init, tcfg, RNG)
    step = jax.jit(make_train_step(api.loss, tcfg))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    rep = run_resilient(step, state, lambda i: lm_batch(cfg, 4, 32, step=i),
                        steps=8, ckpt=mgr, ckpt_every=3,
                        fail_at={5: RuntimeError("chaos")})
    assert rep.restarts == 1
    assert np.isfinite(rep.final_loss)
