"""DP partitioning over the memoized segment frontier table (DESIGN.md §10).

Contracts: exact DP never scores worse than the retained SA baseline on the
paper CNNs at fixed seed; the segment DSE runs at most once per contiguous
segment; reconfiguration is charged per switch (P - 1 per batch, none for a
single resident partition); and the multi-chip TPU mode replaces the switch
with an ICI boundary-activation transfer.
"""
import numpy as np
import pytest
from conftest import sparse_cnn_workload as _sparse_layers

import repro.core.dse as dse_mod
from repro.configs.paper_cnns import (MOBILENETV2, MOBILENETV3L, MOBILENETV3S,
                                      RESNET18, RESNET50)
from repro.core.dse import (boundary_activations, incremental_dse,
                            partition_pipeline, partition_pipeline_sa)
from repro.core.perf_model import ACT_BYTES, FPGAModel, TPUModel


KW = dict(n_parts=3, batch=256, reconfig_cycles=1e6, dse_iters=120)


@pytest.mark.parametrize("cfg", [RESNET18, MOBILENETV3S],
                         ids=["resnet18", "mobilenetv3s"])
def test_dp_never_scores_worse_than_sa(cfg):
    layers = _sparse_layers(cfg)
    hw = FPGAModel()
    dp = partition_pipeline(layers, hw, 4096.0, **KW)
    sa = partition_pipeline_sa(layers, hw, 4096.0, seed=0, **KW)
    assert dp.throughput >= sa.throughput * (1 - 1e-12)
    assert dp.time_per_batch <= sa.time_per_batch * (1 + 1e-12)


@pytest.mark.slow
@pytest.mark.parametrize("cfg", [RESNET50, MOBILENETV2, MOBILENETV3L],
                         ids=["resnet50", "mobilenetv2", "mobilenetv3l"])
def test_dp_never_scores_worse_than_sa_slow(cfg):
    layers = _sparse_layers(cfg)
    hw = FPGAModel()
    dp = partition_pipeline(layers, hw, 4096.0, **KW)
    sa = partition_pipeline_sa(layers, hw, 4096.0, seed=0, **KW)
    assert dp.throughput >= sa.throughput * (1 - 1e-12)


def test_segment_dse_runs_at_most_once_per_contiguous_segment(monkeypatch):
    layers = _sparse_layers(RESNET18)
    L = len(layers)
    calls = []
    real = incremental_dse

    def counting(seg_layers, hw, budget, **kw):
        calls.append(tuple(id(l) for l in seg_layers))
        return real(seg_layers, hw, budget, **kw)

    monkeypatch.setattr(dse_mod, "incremental_dse", counting)
    r = partition_pipeline(layers, FPGAModel(), 4096.0, **KW)
    assert len(calls) == len(set(calls))          # once per segment
    assert len(calls) <= L * (L + 1) // 2          # contiguous segments only
    assert r.dse_calls == len(calls)


def test_single_partition_charges_no_reconfiguration():
    layers = _sparse_layers(RESNET18)[:8]
    hw = FPGAModel()
    one = partition_pipeline(layers, hw, 256.0, n_parts=1, batch=256,
                             reconfig_cycles=1e12, dse_iters=100)
    full = incremental_dse(layers, hw, 256.0, max_iters=100)
    assert one.cuts == []
    assert one.time_per_batch == 256.0 / full.throughput
    assert one.part_throughput == [full.throughput]


def test_time_per_batch_charges_switches_not_partitions():
    """P resident partitions -> P - 1 switches per processed batch."""
    layers = _sparse_layers(RESNET18)
    r = partition_pipeline(layers, FPGAModel(), 4096.0, **KW)
    seg_time = sum(r.batch / t for t in r.part_throughput)
    assert r.time_per_batch == pytest.approx(
        seg_time + KW["reconfig_cycles"] * len(r.cuts), rel=1e-12)
    assert len(r.part_throughput) == len(r.cuts) + 1
    assert len(r.part_designs) == len(r.cuts) + 1


def test_huge_reconfig_cost_collapses_to_one_partition():
    layers = _sparse_layers(RESNET18)[:8]
    hw = FPGAModel()
    one = partition_pipeline(layers, hw, 256.0, n_parts=1, batch=256,
                             dse_iters=100)
    expensive = partition_pipeline(layers, hw, 256.0, n_parts=2, batch=256,
                                   reconfig_cycles=1e12, dse_iters=100)
    assert expensive.cuts == []
    assert expensive.time_per_batch == one.time_per_batch


def test_part_designs_materialize_the_segment_results():
    layers = _sparse_layers(RESNET18)
    hw = FPGAModel()
    r = partition_pipeline(layers, hw, 4096.0, **KW)
    bounds = [0] + r.cuts + [len(layers)]
    for (a, b), designs, thr in zip(zip(bounds, bounds[1:]),
                                    r.part_designs, r.part_throughput):
        seg = incremental_dse(layers[a:b], hw, 4096.0, max_iters=120)
        assert designs == seg.designs
        assert thr == seg.throughput


# --------------------------------------------------------------------- #
# Multi-chip TPU mode
# --------------------------------------------------------------------- #
def test_multichip_tpu_partitioning_runs_and_caps_parts():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=8,
                           batch=256, dse_iters=120)
    assert len(r.cuts) + 1 <= tpu.chips       # one partition per chip
    assert r.time_per_batch > 0 and r.throughput > 0
    assert 0 < r.steady_throughput


def test_multichip_switch_is_ici_transfer_of_boundary_activations():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=256, dse_iters=120)
    seg_time = sum(r.batch / t for t in r.part_throughput)
    ici = sum(tpu.ici_transfer_cycles(r.batch * boundary_activations(layers, c)
                                      * ACT_BYTES) for c in r.cuts)
    assert r.time_per_batch == pytest.approx(seg_time + ici, rel=1e-12)


def test_multichip_steady_rate_bounded_by_parts_and_ici():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=256, dse_iters=120)
    assert r.steady_throughput <= min(r.part_throughput) * (1 + 1e-12)
    for c in r.cuts:
        hop = tpu.ici_transfer_cycles(boundary_activations(layers, c)
                                      * ACT_BYTES)
        assert r.steady_throughput <= 1.0 / hop * (1 + 1e-12)


def test_singlechip_tpu_uses_plain_reconfig():
    layers = _sparse_layers(RESNET18)[:8]
    tpu = TPUModel(chips=1)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           batch=256, reconfig_cycles=1e6, dse_iters=100)
    seg_time = sum(r.batch / t for t in r.part_throughput)
    assert r.time_per_batch == pytest.approx(
        seg_time + 1e6 * len(r.cuts), rel=1e-12)
