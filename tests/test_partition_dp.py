"""DP partitioning over the memoized segment frontier table (DESIGN.md §10).

Contracts: exact DP never scores worse than the retained SA baseline on the
paper CNNs at fixed seed; the segment DSE runs at most once per contiguous
segment; reconfiguration is charged per switch (P - 1 per batch, none for a
single resident partition); and the multi-chip TPU mode replaces the switch
with an ICI boundary-activation transfer.
"""
import numpy as np
import pytest
from conftest import sparse_cnn_workload as _sparse_layers

import repro.core.dse as dse_mod
from repro.configs.paper_cnns import (MOBILENETV2, MOBILENETV3L, MOBILENETV3S,
                                      RESNET18, RESNET50)
from repro.core.dse import (boundary_activations, incremental_dse,
                            partition_pipeline, partition_pipeline_sa)
from repro.core.perf_model import ACT_BYTES, FPGAModel, TPUModel


KW = dict(n_parts=3, batch=256, reconfig_cycles=1e6, dse_iters=120)


@pytest.mark.parametrize("cfg", [RESNET18, MOBILENETV3S],
                         ids=["resnet18", "mobilenetv3s"])
def test_dp_never_scores_worse_than_sa(cfg):
    layers = _sparse_layers(cfg)
    hw = FPGAModel()
    dp = partition_pipeline(layers, hw, 4096.0, **KW)
    sa = partition_pipeline_sa(layers, hw, 4096.0, seed=0, **KW)
    assert dp.throughput >= sa.throughput * (1 - 1e-12)
    assert dp.time_per_batch <= sa.time_per_batch * (1 + 1e-12)


@pytest.mark.slow
@pytest.mark.parametrize("cfg", [RESNET50, MOBILENETV2, MOBILENETV3L],
                         ids=["resnet50", "mobilenetv2", "mobilenetv3l"])
def test_dp_never_scores_worse_than_sa_slow(cfg):
    layers = _sparse_layers(cfg)
    hw = FPGAModel()
    dp = partition_pipeline(layers, hw, 4096.0, **KW)
    sa = partition_pipeline_sa(layers, hw, 4096.0, seed=0, **KW)
    assert dp.throughput >= sa.throughput * (1 - 1e-12)


def test_segment_dse_runs_at_most_once_per_contiguous_segment(monkeypatch):
    layers = _sparse_layers(RESNET18)
    L = len(layers)
    calls = []
    real = incremental_dse

    def counting(seg_layers, hw, budget, **kw):
        calls.append(tuple(id(l) for l in seg_layers))
        return real(seg_layers, hw, budget, **kw)

    monkeypatch.setattr(dse_mod, "incremental_dse", counting)
    r = partition_pipeline(layers, FPGAModel(), 4096.0, **KW)
    assert len(calls) == len(set(calls))          # once per segment
    assert len(calls) <= L * (L + 1) // 2          # contiguous segments only
    assert r.dse_calls == len(calls)


def test_single_partition_charges_no_reconfiguration():
    layers = _sparse_layers(RESNET18)[:8]
    hw = FPGAModel()
    one = partition_pipeline(layers, hw, 256.0, n_parts=1, batch=256,
                             reconfig_cycles=1e12, dse_iters=100)
    full = incremental_dse(layers, hw, 256.0, max_iters=100)
    assert one.cuts == []
    assert one.time_per_batch == 256.0 / full.throughput
    assert one.part_throughput == [full.throughput]


def test_time_per_batch_charges_switches_not_partitions():
    """P resident partitions -> P - 1 switches per processed batch."""
    layers = _sparse_layers(RESNET18)
    r = partition_pipeline(layers, FPGAModel(), 4096.0, **KW)
    seg_time = sum(r.batch / t for t in r.part_throughput)
    assert r.time_per_batch == pytest.approx(
        seg_time + KW["reconfig_cycles"] * len(r.cuts), rel=1e-12)
    assert len(r.part_throughput) == len(r.cuts) + 1
    assert len(r.part_designs) == len(r.cuts) + 1


def test_huge_reconfig_cost_collapses_to_one_partition():
    layers = _sparse_layers(RESNET18)[:8]
    hw = FPGAModel()
    one = partition_pipeline(layers, hw, 256.0, n_parts=1, batch=256,
                             dse_iters=100)
    expensive = partition_pipeline(layers, hw, 256.0, n_parts=2, batch=256,
                                   reconfig_cycles=1e12, dse_iters=100)
    assert expensive.cuts == []
    assert expensive.time_per_batch == one.time_per_batch


def test_part_designs_materialize_the_segment_results():
    layers = _sparse_layers(RESNET18)
    hw = FPGAModel()
    r = partition_pipeline(layers, hw, 4096.0, **KW)
    bounds = [0] + r.cuts + [len(layers)]
    for (a, b), designs, thr in zip(zip(bounds, bounds[1:]),
                                    r.part_designs, r.part_throughput):
        seg = incremental_dse(layers[a:b], hw, 4096.0, max_iters=120)
        assert designs == seg.designs
        assert thr == seg.throughput


# --------------------------------------------------------------------- #
# Multi-chip TPU mode
# --------------------------------------------------------------------- #
def test_multichip_tpu_partitioning_runs_and_caps_parts():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=8,
                           batch=256, dse_iters=120)
    assert len(r.cuts) + 1 <= tpu.chips       # one partition per chip
    assert r.time_per_batch > 0 and r.throughput > 0
    assert 0 < r.steady_throughput


def test_multichip_switch_is_ici_transfer_of_boundary_activations():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=256, dse_iters=120)
    seg_time = sum(r.batch / t for t in r.part_throughput)
    ici = sum(tpu.ici_transfer_cycles(r.batch * boundary_activations(layers, c)
                                      * ACT_BYTES) for c in r.cuts)
    assert r.time_per_batch == pytest.approx(seg_time + ici, rel=1e-12)


def test_multichip_steady_rate_bounded_by_parts_and_ici():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=4,
                           batch=256, dse_iters=120)
    assert r.steady_throughput <= min(r.part_throughput) * (1 + 1e-12)
    for c in r.cuts:
        hop = tpu.ici_transfer_cycles(boundary_activations(layers, c)
                                      * ACT_BYTES)
        assert r.steady_throughput <= 1.0 / hop * (1 + 1e-12)


# --------------------------------------------------------------------- #
# Heterogeneous chips (per-stage DSE budgets — DESIGN.md §13)
# --------------------------------------------------------------------- #
def _keep_largest_oracle(budgets, p):
    """Independent restatement of the deployment rule: a p-partition
    deployment keeps the p largest chips, physical order preserved (ties
    keep the earlier chip)."""
    ranked = sorted(range(len(budgets)), key=lambda i: (-budgets[i], i))[:p]
    return [budgets[i] for i in sorted(ranked)]


def _hetero_bruteforce(layers, tpu, budgets, n_parts, batch, dse_iters):
    """Exhaustive max-min steady rate over every cut subset: a k-partition
    configuration keeps the k largest chips (physical order), stage s
    resident on the s-th kept chip."""
    import itertools

    from repro.core.dse import boundary_activations as _ba
    best = -np.inf
    L = len(layers)
    for k in range(n_parts):
        kept = _keep_largest_oracle(budgets, k + 1)
        for cuts in itertools.combinations(range(1, L), k):
            bounds = [0] + list(cuts) + [L]
            rate = min(incremental_dse(layers[a:b], tpu, kept[s],
                                       max_iters=dse_iters).throughput
                       for s, (a, b) in enumerate(zip(bounds, bounds[1:])))
            for c in cuts:
                hop = tpu.ici_transfer_cycles(_ba(layers, c) * ACT_BYTES)
                rate = min(rate, 1.0 / hop)
            best = max(best, rate)
    return best


def test_hetero_maxmin_dp_equals_bruteforce_on_small_stack():
    layers = _sparse_layers(RESNET18)[:9]
    tpu = TPUModel(chips=3, chip_lanes=(512.0, 192.0, 320.0))
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=32, dse_iters=60, objective="maxmin")
    assert r.chip_budgets == _keep_largest_oracle([512.0, 192.0, 320.0],
                                                  len(r.cuts) + 1)
    best = _hetero_bruteforce(layers, tpu, tpu.chip_budgets, 3, 32, 60)
    assert r.steady_throughput == pytest.approx(best, rel=1e-12)


def test_hetero_single_partition_lands_on_the_largest_chip():
    """Regression (review finding): a P=1 deployment must be priced at the
    largest chip's budget, not chip 0's, wherever the largest chip sits."""
    layers = _sparse_layers(RESNET18)[:8]
    small_first = TPUModel(chips=2, chip_lanes=(128.0, 640.0))
    big_first = TPUModel(chips=2, chip_lanes=(640.0, 128.0))
    a = partition_pipeline(layers, small_first, small_first.chip_budget,
                           n_parts=1, batch=32, dse_iters=60,
                           objective="maxmin")
    b = partition_pipeline(layers, big_first, big_first.chip_budget,
                           n_parts=1, batch=32, dse_iters=60,
                           objective="maxmin")
    assert a.chip_budgets == b.chip_budgets == [640.0]
    assert a.steady_throughput == b.steady_throughput
    lone = incremental_dse(layers, small_first, 640.0, max_iters=60)
    assert a.part_throughput == [lone.throughput]


def test_hetero_ordering_matters_and_dp_tracks_it():
    """Reversing the chip order changes which segments afford growth; the
    DP must price stage s at chip s's own budget in both orders."""
    layers = _sparse_layers(MOBILENETV3S)[:8]
    for lanes in ((640.0, 128.0), (128.0, 640.0)):
        tpu = TPUModel(chips=2, chip_lanes=lanes)
        r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                               batch=32, dse_iters=60, objective="maxmin")
        best = _hetero_bruteforce(layers, tpu, tpu.chip_budgets, 2, 32, 60)
        assert r.steady_throughput == pytest.approx(best, rel=1e-12)


def test_hetero_inner_runs_never_price_a_kept_set_prefix():
    """Regression (review finding): a per-P positional run must not fall
    back to fewer partitions priced at a prefix of the p-largest chip set.
    Adversarial slice: a tiny head layer that saturates under the small
    leading chip makes the [small, big] prefix *look* better than any
    rule-compliant deployment — the DP must still honor keep-largest."""
    head = _sparse_layers(RESNET18)[:1]
    tail = _sparse_layers(MOBILENETV3S)[:5]
    layers = head + tail
    tpu = TPUModel(chips=3, chip_lanes=(128.0, 600.0, 512.0))
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=3,
                           batch=32, dse_iters=60, objective="maxmin")
    assert r.chip_budgets == _keep_largest_oracle([128.0, 600.0, 512.0],
                                                  len(r.cuts) + 1)
    best = _hetero_bruteforce(layers, tpu, tpu.chip_budgets, 3, 32, 60)
    assert r.steady_throughput == pytest.approx(best, rel=1e-12)


def test_uniform_chip_budgets_reproduce_the_default_path_exactly():
    layers = _sparse_layers(RESNET18)
    tpu = TPUModel(chips=4)
    kw = dict(n_parts=4, batch=256, dse_iters=120)
    r0 = partition_pipeline(layers, tpu, tpu.chip_budget, **kw)
    r1 = partition_pipeline(layers, tpu, tpu.chip_budget,
                            chip_budgets=[tpu.chip_budget] * 4, **kw)
    assert r0.cuts == r1.cuts
    assert r0.time_per_batch == r1.time_per_batch
    assert r0.steady_throughput == r1.steady_throughput
    assert r0.part_throughput == r1.part_throughput


def test_hetero_model_defaults_its_chip_budgets_into_the_dp():
    layers = _sparse_layers(RESNET18)[:10]
    tpu = TPUModel(chips=3, chip_lanes=(512.0, 192.0, 320.0))
    kw = dict(n_parts=3, batch=32, dse_iters=60, objective="maxmin")
    implicit = partition_pipeline(layers, tpu, tpu.chip_budget, **kw)
    explicit = partition_pipeline(layers, tpu, tpu.chip_budget,
                                  chip_budgets=tpu.chip_budgets, **kw)
    assert implicit.cuts == explicit.cuts
    assert implicit.steady_throughput == explicit.steady_throughput


def test_chip_budget_validation():
    layers = _sparse_layers(RESNET18)[:6]
    with pytest.raises(ValueError, match="chip_budgets"):
        partition_pipeline(layers, FPGAModel(), 4096.0, n_parts=2,
                           chip_budgets=[512.0, 512.0], dse_iters=60)
    with pytest.raises(ValueError, match="chip_budgets"):
        partition_pipeline(layers, TPUModel(chips=3), 512.0, n_parts=3,
                           chip_budgets=[512.0, 512.0], dse_iters=60)
    with pytest.raises(ValueError, match="chip_lanes"):
        TPUModel(chips=2, chip_lanes=(512.0,)).chip_budgets
    het = TPUModel(chips=2, chip_lanes=(512.0, 128.0))
    assert het.chip_budget == 512.0
    assert het.budget == 640.0


def test_singlechip_tpu_uses_plain_reconfig():
    layers = _sparse_layers(RESNET18)[:8]
    tpu = TPUModel(chips=1)
    r = partition_pipeline(layers, tpu, tpu.chip_budget, n_parts=2,
                           batch=256, reconfig_cycles=1e6, dse_iters=100)
    seg_time = sum(r.batch / t for t in r.part_throughput)
    assert r.time_per_batch == pytest.approx(
        seg_time + 1e6 * len(r.cuts), rel=1e-12)
