"""MoE dispatch: sort-based capacity dispatch vs dense one-hot reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import moe as M

RNG = np.random.default_rng(11)


def dense_reference(x, gates, idx, moe, expert_fn_dense):
    """Straightforward per-token loop (no capacity drops)."""
    T, d = x.shape
    out = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(moe.top_k):
            e = int(idx[t, j])
            out[t] += float(gates[t, j]) * np.asarray(
                expert_fn_dense(e, np.asarray(x[t:t + 1])))[0]
    return out


def test_dispatch_matches_dense_when_no_drops():
    T, d, E, k = 32, 8, 4, 2
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=8.0)
    x = jnp.asarray(RNG.normal(size=(T, d)), jnp.float32)
    W = jnp.asarray(RNG.normal(size=(E, d, d)), jnp.float32)
    gates = jnp.asarray(RNG.uniform(0.1, 1.0, size=(T, k)), jnp.float32)
    idx = jnp.asarray(RNG.integers(0, E, size=(T, k)), jnp.int32)

    out = M.dispatch_combine(x, gates, idx, moe,
                             lambda buf: jnp.einsum("ecd,edf->ecf", buf, W))
    ref = dense_reference(x, gates, idx, moe,
                          lambda e, xt: xt @ np.asarray(W[e]))
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens_beyond_C():
    """All tokens to expert 0 with tiny capacity: only C survive."""
    T, d, E = 16, 4, 4
    moe = MoEConfig(num_experts=E, top_k=1, capacity_factor=1.0)
    C = M.capacity(T, moe)
    x = jnp.ones((T, d), jnp.float32)
    gates = jnp.ones((T, 1), jnp.float32)
    idx = jnp.zeros((T, 1), jnp.int32)
    out = M.dispatch_combine(x, gates, idx, moe, lambda buf: buf)
    kept = int((np.asarray(out).sum(axis=1) > 0).sum())
    assert kept == min(T, C)


def test_router_normalizes_gates_and_aux_loss():
    moe = MoEConfig(num_experts=4, top_k=2, aux_loss_coef=0.01)
    x = jnp.asarray(RNG.normal(size=(64, 8)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(8, 4)), jnp.float32)
    gates, idx, aux = M.route(x, w, moe)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert float(aux) > 0
    # perfectly balanced router -> aux ~= coef
    wb = jnp.zeros((8, 4), jnp.float32)
    _, _, aux_b = M.route(x, wb, moe)
    assert float(aux_b) == pytest.approx(0.01, rel=0.3)


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([8, 24, 64]), E=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]))
def test_property_combine_is_gate_weighted_identity(T, E, k):
    """expert_fn = identity => output = sum(gates)*x for surviving tokens."""
    moe = MoEConfig(num_experts=E, top_k=k, capacity_factor=16.0)
    d = 4
    x = jnp.asarray(RNG.normal(size=(T, d)), jnp.float32)
    gates = jnp.full((T, k), 1.0 / k, jnp.float32)
    idx = jnp.asarray(RNG.integers(0, E, size=(T, k)), jnp.int32)
    out = M.dispatch_combine(x, gates, idx, moe, lambda b: b)
    # with k distinct experts per token and identity experts: out == x
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               atol=1e-5, rtol=1e-5)
