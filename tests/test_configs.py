import pytest

from repro.configs import (ASSIGNED, SHAPES, SHAPE_BY_NAME, cell_supported,
                           get_config, list_archs, reduce_config)


def test_registry_has_all_assigned():
    expected = {"deepseek-v3-671b", "mixtral-8x7b", "qwen3-0.6b",
                "stablelm-12b", "qwen2.5-3b", "deepseek-67b", "chameleon-34b",
                "rwkv6-1.6b", "whisper-base", "zamba2-1.2b"}
    assert set(ASSIGNED) == expected
    assert len(list_archs()) >= 15          # + paper CNNs


def test_exact_assigned_dims():
    c = get_config("deepseek-v3-671b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) == \
        (61, 7168, 128, 129280)
    assert c.moe.num_experts == 256 and c.moe.top_k == 8
    c = get_config("deepseek-67b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("mixtral-8x7b")
    assert c.attn_window == 4096 and c.moe.num_experts == 8
    c = get_config("qwen2.5-3b")
    assert c.qkv_bias and c.num_kv_heads == 2
    c = get_config("qwen3-0.6b")
    assert c.qk_norm and c.head_dim == 128
    c = get_config("zamba2-1.2b")
    assert c.ssm.state_dim == 64 and c.hybrid_attn_every == 6
    c = get_config("whisper-base")
    assert c.enc_layers == 6 and c.vocab_size == 51865


def test_shapes():
    assert {s.name for s in SHAPES} == \
        {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPE_BY_NAME["train_4k"].global_batch == 256
    assert SHAPE_BY_NAME["long_500k"].seq_len == 524288


def test_cell_support_matrix():
    """40 cells; long_500k runs only for sub-quadratic archs."""
    runs_long = {a for a in ASSIGNED
                 if cell_supported(get_config(a), SHAPE_BY_NAME["long_500k"])[0]}
    assert runs_long == {"rwkv6-1.6b", "zamba2-1.2b", "mixtral-8x7b"}
    total = sum(1 for a in ASSIGNED for s in SHAPES)
    assert total == 40


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduce_config_valid(arch):
    cfg = reduce_config(get_config(arch))
    assert cfg.d_model <= 128 and cfg.vocab_size <= 1024
    assert cfg.family == get_config(arch).family
