"""Eq. 1–3 of the paper, exactly, plus hypothesis properties."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.perf_model import (DesignPoint, FPGAModel, LayerCost,
                                   TPUModel, lm_layer_costs, cnn_layer_costs,
                                   pair_sparsity, pipeline_throughput,
                                   t_cycles, param_count)
from repro.configs import get_config


def test_eq1_dense():
    # dense: t = ceil(M/N)
    assert t_cycles(0.0, 64, 8) == 8
    assert t_cycles(0.0, 64, 64) == 1
    assert t_cycles(0.0, 65, 8) == 9


def test_eq1_sparse_examples():
    # 50% pair sparsity halves the initiation interval
    assert t_cycles(0.5, 64, 8) == 4
    # never below 1 cycle
    assert t_cycles(0.99, 64, 64) == 1


def test_pair_sparsity():
    assert pair_sparsity(0.0, 0.0) == 0.0
    assert pair_sparsity(1.0, 0.0) == 1.0
    assert abs(pair_sparsity(0.5, 0.5) - 0.75) < 1e-12


def test_eq2_eq3_pipeline_bottleneck():
    hw = FPGAModel()
    l1 = LayerCost("a", macs=1024, m_dot=64, weight_count=1024,
                   act_in=16, act_out=16)
    l2 = LayerCost("b", macs=4096, m_dot=64, weight_count=4096,
                   act_in=16, act_out=16)
    d = DesignPoint(spe=1, macs_per_spe=8)
    th1 = hw.layer_throughput(l1, d)
    th2 = hw.layer_throughput(l2, d)
    assert th1 == pytest.approx(64 / (1024 * 8))
    assert th2 < th1
    assert pipeline_throughput([l1, l2], [d, d], hw) == th2   # Eq. 3 = min


def test_sparsity_raises_throughput():
    hw = FPGAModel()
    dense = LayerCost("l", macs=4096, m_dot=64, weight_count=4096,
                      act_in=1, act_out=1, s_w=0.0, s_a=0.0)
    sparse = LayerCost("l", macs=4096, m_dot=64, weight_count=4096,
                       act_in=1, act_out=1, s_w=0.5, s_a=0.5)
    d = DesignPoint(spe=1, macs_per_spe=8)
    assert hw.layer_throughput(sparse, d) > hw.layer_throughput(dense, d)


def test_tpu_model_uses_tile_sparsity_only():
    """DESIGN.md §6: MXU can only skip whole weight tiles."""
    hw = TPUModel()
    l = LayerCost("l", macs=4096, m_dot=64, weight_count=4096, act_in=1,
                  act_out=1, s_w=0.9, s_a=0.9, s_w_tile=0.25)
    assert hw.effective_sparsity(l) == 0.25


@settings(max_examples=50, deadline=None)
@given(s=st.floats(0, 0.99), M=st.integers(1, 4096), N=st.integers(1, 256))
def test_property_eq1_bounds(s, M, N):
    t = t_cycles(s, M, N)
    assert 1 <= t <= math.ceil(M / N)
    # monotone: more sparsity never raises t
    assert t_cycles(min(0.99, s + 0.3), M, N) <= t


@settings(max_examples=30, deadline=None)
@given(sw=st.floats(0, 1), sa=st.floats(0, 1))
def test_property_pair_sparsity_bounds(sw, sa):
    p = pair_sparsity(sw, sa)
    assert max(sw, sa) - 1e-12 <= p <= min(1.0, sw + sa) + 1e-12


def test_resnet18_has_sixteen_3x3_convs():
    """Fig. 4 of the paper: the ResNet-18 workload has 16 3x3 conv layers."""
    from repro.configs.paper_cnns import RESNET18
    costs = cnn_layer_costs(RESNET18)
    n3x3 = sum(1 for c in costs
               if c.kind == "conv" and c.m_dot % 9 == 0 and "proj" not in c.name
               and c.name not in ("stem",))
    assert n3x3 == 16


def test_param_counts_in_expected_range():
    """Sanity: the analytic parameter counts land near the model names."""
    assert 60e9 < param_count(get_config("deepseek-67b")) < 75e9
    assert 600e9 < param_count(get_config("deepseek-v3-671b")) < 750e9
    assert 40e9 < param_count(get_config("mixtral-8x7b")) < 50e9
    assert 0.4e9 < param_count(get_config("qwen3-0.6b")) < 0.9e9
    assert 1.0e9 < param_count(get_config("rwkv6-1.6b")) < 2.2e9
