"""Proposal-batched DSE correctness (DESIGN.md §15).

The batched path's whole contract is BIT-exactness at every layer of the
stack: ``incremental_dse_batch`` (compiled C kernel AND numpy lockstep
backend) must reproduce the serial engine's result row for row,
``DSECache.dse_vec_batch`` must equal a serial ``dse_vec`` loop, and
``LMEvaluator.evaluate_batch`` / ``hass_search(batch_size=k)`` must replay
the serial trial sequence float for float. These tests fuzz all of it with
kind-tied stacks, tight-budget reverts, truncated iteration caps, and the
non-divisible ``n_trials``/``batch_size`` tail round.
"""
import numpy as np
import pytest

import repro.core.dse as dse_mod
from repro.core import _dse_ckernel
from repro.core.dse import DSECache, incremental_dse, incremental_dse_batch
from repro.core.perf_model import FPGAModel, LayerCost
from repro.core.tpe import TPE

HW = FPGAModel()

# the lockstep backend always exists; the compiled backend needs a C
# compiler in the environment (it is the `auto` choice when present)
ENGINES = ["lockstep"] + \
    (["compiled"] if _dse_ckernel.get_lib() is not None else [])


def kind_tied_stack(seed: int, n_blocks: int = 10):
    rng = np.random.default_rng(seed)
    kinds = [("wq", 64, 64), ("wkv", 64, 32), ("ffn", 64, 256),
             ("tiny", 8, 4)]
    s_of = {k: float(rng.uniform(0.0, 0.8)) for k, _, _ in kinds}
    layers = []
    for b in range(n_blocks):
        for k, m, c in kinds:
            layers.append(LayerCost(
                name=f"l{b}.{k}", macs=m * c, m_dot=m, weight_count=m * c,
                act_in=m, act_out=c, s_w=s_of[k]))
        layers.append(LayerCost(name=f"l{b}.attn", macs=2 * 64 * 16,
                                m_dot=16, weight_count=0, act_in=64,
                                act_out=64, kind="attn", prunable=False))
    return layers


def random_rows(lv, layers, rng, B):
    """B random s_eff rows over the stack's prunable layers (FPGA pair
    sparsity with s_a=0 means s_eff == s_w, so rows are direct)."""
    prunable = np.array([l.prunable for l in layers])
    rows = np.tile(lv.s_eff, (B, 1))
    rows[:, prunable] = rng.uniform(0.0, 0.9, (B, int(prunable.sum())))
    return rows


def assert_result_equal(r, c, tag=""):
    assert [(d.spe, d.macs_per_spe) for d in r.designs] == \
        [(d.spe, d.macs_per_spe) for d in c.designs], tag
    assert r.throughput == c.throughput, tag
    assert r.resource == c.resource, tag
    assert r.theta_r == c.theta_r, tag
    assert r.trace == c.trace, tag
    fr, fc = r.frontier, c.frontier
    assert np.array_equal(fr.res, fc.res) and \
        np.array_equal(fr.thr, fc.thr), tag
    assert np.array_equal(fr.spe, fc.spe) and \
        np.array_equal(fr.n, fc.n), tag


# --------------------------------------------------------------------- #
# incremental_dse_batch == serial engine, both backends
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("seed", range(4))
def test_batch_rows_match_serial(engine, seed):
    layers = kind_tied_stack(seed)
    lv = HW.layer_vectors(layers)
    rng = np.random.default_rng(100 + seed)
    rows = random_rows(lv, layers, rng, 5)
    floor = float(lv.res_unit.sum())
    for budget, iters in ((4096.0, 300), (512.0, 300),
                          (floor * 1.05, 200),   # near-floor: budget reverts
                          (4096.0, 7)):          # truncated iteration cap
        batch = incremental_dse_batch(lv, HW, budget, rows,
                                      max_iters=iters, engine=engine)
        for b in range(len(rows)):
            row_layers = [
                LayerCost(**{**l.__dict__, "s_w": float(rows[b][i])})
                if l.prunable else l for i, l in enumerate(layers)]
            cold = incremental_dse(row_layers, HW, budget, max_iters=iters)
            assert_result_equal(batch[b], cold,
                                f"engine={engine} b={b} budget={budget}")


@pytest.mark.parametrize("engine", ENGINES)
def test_batch_single_row_and_materialize_off(engine):
    layers = kind_tied_stack(11)
    lv = HW.layer_vectors(layers)
    rows = random_rows(lv, layers, np.random.default_rng(11), 1)
    r = incremental_dse_batch(lv, HW, 2048.0, rows, max_iters=200,
                              engine=engine)
    assert len(r) == 1
    lean = incremental_dse_batch(lv, HW, 2048.0, rows, max_iters=200,
                                 engine=engine, materialize_designs=False)[0]
    assert lean.designs == []
    assert lean.throughput == r[0].throughput
    assert np.array_equal(lean.frontier.spe, r[0].frontier.spe)


def test_batch_engine_dispatch(monkeypatch):
    layers = kind_tied_stack(12)
    lv = HW.layer_vectors(layers)
    rows = random_rows(lv, layers, np.random.default_rng(12), 2)
    with pytest.raises(ValueError):
        incremental_dse_batch(lv, HW, 2048.0, rows, engine="nope")
    # no compiler available: auto falls back to lockstep, compiled raises
    monkeypatch.setattr(dse_mod._dse_ckernel, "get_lib", lambda: None)
    auto = incremental_dse_batch(lv, HW, 2048.0, rows, max_iters=150,
                                 engine="auto")
    lock = incremental_dse_batch(lv, HW, 2048.0, rows, max_iters=150,
                                 engine="lockstep")
    for a, b in zip(auto, lock):
        assert_result_equal(a, b)
    with pytest.raises(RuntimeError):
        incremental_dse_batch(lv, HW, 2048.0, rows, engine="compiled")


# --------------------------------------------------------------------- #
# DSECache.dse_vec_batch == serial dse_vec loop
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(3))
def test_dse_vec_batch_matches_serial_loop(seed):
    from dataclasses import replace
    layers = kind_tied_stack(20 + seed)
    lv = HW.layer_vectors(layers)
    rng = np.random.default_rng(20 + seed)
    rows = random_rows(lv, layers, rng, 6)
    rows[3] = rows[0]                       # within-batch duplicate
    serial_cache, batch_cache = DSECache(), DSECache()
    serial = [serial_cache.dse_vec(replace(lv, s_eff=rows[b]), HW, 2048.0,
                                   max_iters=200) for b in range(len(rows))]
    batch = batch_cache.dse_vec_batch(lv, HW, 2048.0, rows, max_iters=200)
    for s, r in zip(serial, batch):
        assert_result_equal(s, r)
    assert batch[3] is batch[0]             # duplicates alias, like serial
    assert batch_cache.stats()["hits"] >= 1
    # a second identical batch is all exact hits, zero cold runs
    cold0 = batch_cache.stats()["cold_runs"]
    again = batch_cache.dse_vec_batch(lv, HW, 2048.0, rows, max_iters=200)
    assert all(a is b for a, b in zip(again, batch))
    assert batch_cache.stats()["cold_runs"] == cold0


def test_dse_vec_batch_empty():
    lv = HW.layer_vectors(kind_tied_stack(30))
    assert DSECache().dse_vec_batch(lv, HW, 2048.0,
                                    np.empty((0, len(lv)))) == []


# --------------------------------------------------------------------- #
# TPE RNG stream position: ask_batch(k) == k asks, incl. truncated tail
# --------------------------------------------------------------------- #
def test_ask_batch_rng_position_matches_serial_protocol():
    lo, hi = np.zeros(3), np.ones(3)
    seeds = np.random.default_rng(7).uniform(0, 1, (12, 3))
    a, b = TPE(lo=lo, hi=hi, seed=5), TPE(lo=lo, hi=hi, seed=5)
    for x in seeds:
        a.tell(x, float(x.sum()))
        b.tell(x, float(x.sum()))
    # a truncated tail round: ask_batch(2) must consume exactly as much
    # RNG as two serial asks, whichever liar protocol ran
    xs_a = a.ask_batch(2, liar="min")
    xs_b = [b.ask() for _ in range(2)]
    assert np.array_equal(xs_a[0], xs_b[0])   # first member == plain ask
    for x in xs_a:                  # tell BOTH sides the same observations,
        a.tell(x, 0.0)              # so the next proposal differs only if
        b.tell(x, 0.0)              # the RNG streams diverged
    assert np.array_equal(a.ask(), b.ask())


# --------------------------------------------------------------------- #
# hass_search: non-divisible n_trials / batch_size regression
# --------------------------------------------------------------------- #
def test_hass_search_non_divisible_batch_runs_exact_trial_count():
    from repro.core.hass import LMEvaluator, hass_search
    from repro.core.perf_model import TPUModel
    from repro.configs import get_config

    cfg = get_config("qwen3-0.6b")
    hw = TPUModel(chips=1)
    ev_batch = LMEvaluator(cfg, hw, hw.budget, dse_iters=200)
    ev_serial = LMEvaluator(cfg, hw, hw.budget, dse_iters=200,
                            dse_engine="flat")     # pins the serial loop
    kw = dict(iters=10, liar=None, seed=9, include_act=False)
    r_b = hass_search(ev_batch, ev_batch.n_search, batch_size=4, **kw)
    r_s = hass_search(ev_serial, ev_serial.n_search, batch_size=4, **kw)
    # exactly n_trials trials despite 10 % 4 != 0, and the batched
    # evaluator path replays the serial-engine transcript bit for bit
    assert len(r_b.trials) == len(r_s.trials) == 10
    for t_b, t_s in zip(r_b.trials, r_s.trials):
        assert np.array_equal(t_b.x, t_s.x)
        assert t_b.score == t_s.score
        assert t_b.metrics == t_s.metrics
    assert r_b.best_score == r_s.best_score
    assert np.array_equal(r_b.best_x, r_s.best_x)


def test_lm_evaluate_batch_bit_exact_vs_serial_calls():
    from repro.core.hass import LMEvaluator
    from repro.core.perf_model import TPUModel
    from repro.configs import get_config

    cfg = get_config("qwen3-0.6b")
    hw = TPUModel(chips=1)
    ev_a = LMEvaluator(cfg, hw, hw.budget, dse_iters=200)
    ev_b = LMEvaluator(cfg, hw, hw.budget, dse_iters=200)
    rng = np.random.default_rng(3)
    xs = [rng.uniform(0, 0.9, ev_a.n_search) for _ in range(5)]
    assert [ev_a(x) for x in xs] == ev_b.evaluate_batch(xs)
    assert ev_b.dse_cache.stats()["cold_runs"] <= 5


# --------------------------------------------------------------------- #
# Sparsity-pattern axis (DESIGN.md §16): degenerate axis replays the
# pre-pattern LM transcript bit for bit, serial AND batched; the full
# axis stays batch==serial exact (the LM evaluator is analytic).
# --------------------------------------------------------------------- #
def _lm_pair(hw_name, patterns, **kw):
    from repro.core.hass import LMEvaluator
    from repro.core.perf_model import FPGAModel, TPUModel
    from repro.configs import get_config

    cfg = get_config("qwen3-0.6b")
    if hw_name == "tpu":
        hw = TPUModel(chips=1)
        budget = hw.budget
    else:
        hw, budget = FPGAModel(), 4096.0
    base = LMEvaluator(cfg, hw, budget, dse_iters=120, **kw)
    pat = LMEvaluator(cfg, hw, budget, dse_iters=120, patterns=patterns,
                      **kw)
    return base, pat


@pytest.mark.parametrize("hw_name", ["tpu", "fpga"])
def test_lm_unstructured_only_pattern_axis_bit_identical(hw_name):
    from repro.core.hass import hass_search

    base, pat = _lm_pair(hw_name, ("unstructured",))
    assert pat.n_pattern_dims == 0
    kw = dict(iters=8, seed=3, include_act=False)
    r0 = hass_search(base, base.n_search, **kw)
    r1 = hass_search(pat, pat.n_search, **kw)
    for t0, t1 in zip(r0.trials, r1.trials):
        assert np.array_equal(t0.x, t1.x)
        assert t0.metrics == t1.metrics
        assert t0.score == t1.score
    assert r0.best_score == r1.best_score


def test_lm_unstructured_only_pattern_axis_bit_identical_batched():
    from repro.core.hass import hass_search

    base, pat = _lm_pair("tpu", ("unstructured",))
    kw = dict(iters=10, seed=4, include_act=False, batch_size=4)
    r0 = hass_search(base, base.n_search, **kw)
    r1 = hass_search(pat, pat.n_search, **kw)
    assert len(r0.trials) == len(r1.trials) == 10
    for t0, t1 in zip(r0.trials, r1.trials):
        assert np.array_equal(t0.x, t1.x)
        assert t0.metrics == t1.metrics


def test_lm_pattern_evaluate_batch_exact_vs_serial():
    all_p = ("unstructured", "nm", "hierarchical", "activation")
    _, ev_a = _lm_pair("tpu", all_p)
    _, ev_b = _lm_pair("tpu", all_p)
    assert ev_a.n_pattern_dims == ev_a.n_search
    rng = np.random.default_rng(9)
    n = ev_a.n_search
    xs = [np.concatenate([rng.uniform(0, 0.9, n),
                          rng.integers(0, 4, n).astype(np.float64) + 0.5])
          for _ in range(6)]
    assert [ev_a(x) for x in xs] == ev_b.evaluate_batch(xs)


def test_lm_pattern_search_with_measured_costs_emits_meas():
    from repro.core.hass import Lambdas, hass_search

    costs = {"unstructured": 1.0, "nm": 2.2, "hierarchical": 1.8,
             "activation": 1.0}
    _, ev = _lm_pair("tpu", ("unstructured", "nm", "hierarchical",
                             "activation"), pattern_costs=costs)
    r = hass_search(ev, ev.n_search, iters=8, seed=0, include_act=False,
                    lambdas=Lambdas(meas=0.1))
    assert len(r.trials) == 8
    for t in r.trials:
        assert len(t.x) == 2 * ev.n_search
        assert "meas" in t.metrics and t.metrics["meas"] >= 0.0
    # the patterned stack threads t_scale through the DSE: nm/hierarchical
    # layers carry a decode-cost multiplier > 1
    x = np.concatenate([np.full(ev.n_search, 0.5),
                        np.full(ev.n_search, 1.5)])      # all-nm codes
    layers = ev.sparse_layers(x)
    pr = [l for l in layers if l.prunable]
    assert all(l.pattern == "nm" for l in pr)
    assert all(l.t_scale == costs["nm"] for l in pr)


def test_hass_search_x0_anchor_trial():
    """x0 is evaluated as trial 0, consumes one iter, and anchors both the
    serial and batched loops; None keeps the pre-anchor stream untouched
    (covered by the bit-identity tests above)."""
    from repro.core.hass import hass_search

    base, _ = _lm_pair("tpu", ("unstructured",))
    n = base.n_search
    x0 = np.zeros(n)
    r = hass_search(base, n, iters=6, seed=5, include_act=False, x0=x0)
    assert len(r.trials) == 6
    assert np.array_equal(r.trials[0].x, x0)
    assert r.trials[0].metrics["acc"] == 1.0
    rb = hass_search(base, n, iters=6, seed=5, include_act=False, x0=x0,
                     batch_size=4)
    assert len(rb.trials) == 6
    assert np.array_equal(rb.trials[0].x, x0)
    with pytest.raises(ValueError):
        hass_search(base, n, iters=4, seed=5, include_act=False,
                    x0=np.zeros(n + 3))
