"""Blockwise attention vs naive oracle, incl. hypothesis property sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (blockwise_attention, decode_attention,
                                    reference_attention)

RNG = np.random.default_rng(0)


def _mk(B, Sq, Sk, H, KV, D, dtype=jnp.float32):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("Sk,block_k", [(256, 64), (384, 64), (520, 64)])
@pytest.mark.parametrize("window", [0, 128])
def test_blockwise_matches_reference(Sk, block_k, window):
    q, k, v = _mk(2, Sk, Sk, 4, 2, 16)
    out = blockwise_attention(q, k, v, causal=True, window=window,
                              block_k=block_k)
    ref = reference_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_banded_matches_full():
    q, k, v = _mk(1, 512, 512, 4, 4, 16)
    full = blockwise_attention(q, k, v, causal=True, block_k=64,
                               impl="blockwise_full")
    band = blockwise_attention(q, k, v, causal=True, block_k=64, impl="banded")
    np.testing.assert_allclose(np.asarray(full), np.asarray(band),
                               atol=2e-5, rtol=2e-5)


def test_banded_window_skips_blocks():
    """With a window, the banded pair table must shrink the scan."""
    from repro.models import attention as A
    q, k, v = _mk(1, 64, 1024, 2, 2, 8)
    # decode-ish: queries at the end attend into a 128-window
    out = blockwise_attention(q, k, v, causal=True, window=128, block_k=64,
                              q_offset=960, impl="banded")
    ref = reference_attention(q, k, v, causal=True, window=128, q_offset=960)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_masks_by_length():
    q, k, v = _mk(3, 1, 64, 4, 2, 16)
    kv_len = jnp.asarray([1, 17, 64])
    out = decode_attention(q, k, v, kv_len)
    ref = reference_attention(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 3),
    Sk=st.sampled_from([96, 128, 200, 256]),
    H=st.sampled_from([2, 4]),
    G=st.sampled_from([1, 2]),
    D=st.sampled_from([8, 16]),
    causal=st.booleans(),
)
def test_property_blockwise_equals_reference(B, Sk, H, G, D, causal):
    KV = H // G if H % G == 0 else H
    q = jnp.asarray(RNG.normal(size=(B, Sk, KV * G, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Sk, KV, D)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, block_k=32)
    ref = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


def test_softmax_rows_sum_to_one_property():
    """Attention output of constant V must be constant (softmax partition)."""
    q, k, _ = _mk(2, 128, 128, 2, 2, 8)
    v = jnp.ones((2, 128, 2, 8), jnp.float32) * 3.5
    out = blockwise_attention(q, k, v, causal=True, block_k=32)
    np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-4)
