"""``lm_layer_costs`` invariants across all ten assigned architectures
(DESIGN.md §11: per-token workloads, sample = token, attn non-prunable).
"""
import math

import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.perf_model import (MXU_TILE, lm_block_bounds, lm_layer_costs,
                                   param_count, thin_cut_points,
                                   tile_quantize_sparsity)

ARCHS = sorted(ASSIGNED)


@pytest.fixture(scope="module")
def stacks():
    return {a: lm_layer_costs(get_config(a)) for a in ARCHS}


@pytest.mark.parametrize("arch", ARCHS)
def test_positive_workloads(arch, stacks):
    for l in stacks[arch]:
        assert l.macs > 0, l.name
        assert l.m_dot > 0, l.name
        assert l.act_in > 0 and l.act_out > 0, l.name


@pytest.mark.parametrize("arch", ARCHS)
def test_linear_weight_counts(arch, stacks):
    """Linears: macs = cin*cout*n_apply with cin = m_dot, weight_count =
    cin*cout, act_in = cin*n_apply, act_out = cout*n_apply. Hence
    macs == m_dot * act_out and weight_count * n_apply == macs."""
    for l in stacks[arch]:
        if l.kind != "linear":
            continue
        assert l.macs == l.m_dot * l.act_out, l.name
        n_apply = l.act_in // l.m_dot
        assert l.act_in == l.m_dot * n_apply, l.name
        assert l.weight_count * n_apply == l.macs, l.name


@pytest.mark.parametrize("arch", ARCHS)
def test_attn_layers_not_prunable(arch, stacks):
    """Attention score/value products are data-data: no weight to prune."""
    attn = [l for l in stacks[arch] if l.kind == "attn"]
    assert len(attn) == get_config(arch).num_layers
    for l in attn:
        assert not l.prunable and l.weight_count == 0, l.name


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).moe is not None])
def test_moe_active_expert_multiplier(arch, stacks):
    """MoE FFN matmuls are applied once per *active* expert
    (top_k + shared); the per-token MAC count carries that multiplier."""
    cfg = get_config(arch)
    active = cfg.moe.top_k + cfg.moe.num_shared_experts
    fe = cfg.moe.expert_d_ff or cfg.d_ff
    for l in stacks[arch]:
        if l.name.endswith(".moe_up"):
            assert l.macs == cfg.d_model * fe * active, l.name
            assert l.act_in == cfg.d_model * active, l.name
            assert l.weight_count == cfg.d_model * fe, l.name


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a).hybrid_attn_every])
def test_hybrid_shared_layers_at_cadence(arch, stacks):
    """Hybrid (zamba-style) stacks interleave the shared attention block
    every ``hybrid_attn_every`` ssm layers, starting at layer 0."""
    cfg = get_config(arch)
    expect = {i for i in range(cfg.num_layers)
              if i % cfg.hybrid_attn_every == 0}
    got = {int(l.name.split(".")[0][1:]) for l in stacks[arch]
           if ".shared_" in l.name}
    assert got == expect
    n_shared = sum(1 for l in stacks[arch] if ".shared_" in l.name)
    assert n_shared == 2 * len(expect)       # shared_qkvo + shared_ffn


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_consistency(arch, stacks):
    """``param_count`` == stack weights + embedding + inactive experts,
    recomputed here from first principles."""
    cfg = get_config(arch)
    total = sum(l.weight_count for l in stacks[arch])
    total += cfg.vocab_size * cfg.d_model
    if cfg.moe is not None:
        fe = cfg.moe.expert_d_ff or cfg.d_ff
        inactive = cfg.moe.num_experts - cfg.moe.top_k
        total += cfg.num_layers * inactive * 3 * cfg.d_model * fe
    assert param_count(cfg) == total


@pytest.mark.parametrize("arch", ARCHS)
def test_block_bounds_partition_the_stack(arch, stacks):
    """One block per transformer layer plus the unembed tail; boundaries
    strictly increasing in 1..L-1 (valid DP cut candidates)."""
    layers = stacks[arch]
    bounds = lm_block_bounds(layers)
    assert bounds == sorted(set(bounds))
    assert all(1 <= b <= len(layers) - 1 for b in bounds)
    assert len(bounds) + 1 == get_config(arch).num_layers + 1
    # every boundary starts a new name prefix
    for b in bounds:
        assert layers[b].name.split(".")[0] != \
            layers[b - 1].name.split(".")[0]


def test_seq_len_scales_attention_only():
    cfg = get_config("qwen3-0.6b")
    short = {l.name: l.macs for l in lm_layer_costs(cfg, seq_len=1)}
    long = {l.name: l.macs for l in lm_layer_costs(cfg, seq_len=4096)}
    for name in short:
        if name.endswith(".attn"):
            assert long[name] > short[name]
        else:
            assert long[name] == short[name]


def test_sliding_window_caps_attention_macs():
    """mixtral's SWA bounds per-token attention work at the window size."""
    cfg = get_config("mixtral-8x7b")
    assert cfg.attn_window == 4096
    at_win = [l.macs for l in lm_layer_costs(cfg, seq_len=4096)
              if l.kind == "attn"]
    beyond = [l.macs for l in lm_layer_costs(cfg, seq_len=32768)
              if l.kind == "attn"]
    assert beyond == at_win


def test_thin_cut_points():
    bounds = list(range(10, 200, 10))
    kept = thin_cut_points(bounds, 5)
    assert len(kept) == 5
    assert set(kept) <= set(bounds)
    assert kept == sorted(kept)
    assert kept[0] == bounds[0] and kept[-1] == bounds[-1]
    assert thin_cut_points(bounds, 0) == bounds
    assert thin_cut_points(bounds, len(bounds) + 5) == bounds


def test_tile_quantize_sparsity():
    # 7168x1536 weights: 56*12 tiles -> steps of 1/672
    n_tiles = math.ceil(7168 / MXU_TILE) * math.ceil(1536 / MXU_TILE)
    q = tile_quantize_sparsity(0.37, 7168, 7168 * 1536)
    assert q <= 0.37 and 0.37 - q < 1.0 / n_tiles
    assert q == math.floor(0.37 * n_tiles) / n_tiles
    # a single tile can only be fully kept or fully pruned
    assert tile_quantize_sparsity(0.9, 64, 64 * 64) == 0.0
    assert tile_quantize_sparsity(1.0, 64, 64 * 64) == 1.0
    assert tile_quantize_sparsity(0.5, 0, 0) == 0.0
