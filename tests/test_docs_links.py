"""Docs stay navigable: intra-repo links in README.md / DESIGN.md resolve
(the CI gate runs ``tools/check_links.py``; this keeps tier-1 covering it).
"""
import os
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "tools"))

from check_links import check_file, github_slug  # noqa: E402
from pathlib import Path  # noqa: E402


@pytest.mark.parametrize("doc", ["README.md", "DESIGN.md"])
def test_intra_repo_links_resolve(doc):
    path = Path(ROOT) / doc
    assert path.exists()
    assert check_file(path) == []


def test_github_slug_rule():
    assert github_slug("§11 LM workload model") == "11-lm-workload-model"
    assert github_slug("Repo map") == "repo-map"
    assert github_slug("§10 Pareto-frontier DSE: frontier-native search, "
                       "DP partitioning, multi-chip TPU") == \
        ("10-pareto-frontier-dse-frontier-native-search-"
         "dp-partitioning-multi-chip-tpu")
    assert github_slug("`code` and *emph*") == "code-and-emph"


def test_checker_flags_broken_links(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text("# Title\n[ok](doc.md)\n[missing](nope.md)\n"
                  "[bad anchor](doc.md#not-a-heading)\n[good](#title)\n"
                  "[O(K^2) caret text](gone.md)\n")
    errors = check_file(md)
    assert len(errors) == 3
    assert any("nope.md" in e for e in errors)
    assert any("not-a-heading" in e for e in errors)
    assert any("gone.md" in e for e in errors)
