"""Checkpointing: atomicity, digests, retention; fault-tolerant run loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data.synthetic import lm_batch
from repro.models import build_model
from repro.train.checkpoint import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.fault_tolerance import (ResilienceReport, StepWatchdog,
                                         run_resilient)
from repro.train.optimizer import OptConfig
from repro.train.train_loop import TrainConfig, init_train_state, make_train_step

CFG = reduce_config(get_config("qwen3-0.6b"))
RNG = jax.random.PRNGKey(0)


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3)},
            "c": jnp.float32(3.5)}
    save_checkpoint(str(tmp_path), 7, tree, meta={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    out, step, meta = restore_checkpoint(str(tmp_path))
    assert step == 7 and meta["note"] == "x"
    np.testing.assert_array_equal(out["a"]["b"], np.arange(6).reshape(2, 3))


def test_corruption_detected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((4,))})
    # tamper with the arrays file
    d = os.path.join(tmp_path, "step_00000001")
    data = np.load(os.path.join(d, "arrays.npz"))
    np.savez(os.path.join(d, "arrays.npz"), w=np.zeros((4,), np.float32))
    with pytest.raises(IOError, match="digest"):
        restore_checkpoint(str(tmp_path))


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": jnp.full((2,), s)})
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, {"w": jnp.ones((8,))})
    mgr.wait()
    assert latest_step(str(tmp_path)) == 5


def _setup(tmp_path):
    api = build_model(CFG)
    tcfg = TrainConfig(opt=OptConfig(lr=1e-3), accum=1, remat=None)
    state = init_train_state(api.init, tcfg, RNG)
    step_fn = jax.jit(make_train_step(api.loss, tcfg))
    mgr = CheckpointManager(str(tmp_path), keep=3)
    nb = lambda i: lm_batch(CFG, 4, 32, seed=0, step=i)
    return state, step_fn, mgr, nb


def test_resilient_run_survives_injected_failures(tmp_path):
    state, step_fn, mgr, nb = _setup(tmp_path)
    rep = run_resilient(step_fn, state, nb, steps=12, ckpt=mgr, ckpt_every=4,
                        fail_at={6: RuntimeError("pod lost"),
                                 10: RuntimeError("host hang")})
    assert rep.restarts == 2
    assert rep.steps_run >= 12                   # re-ran the lost segments
    assert np.isfinite(rep.final_loss)


def test_restart_is_bitwise_deterministic(tmp_path):
    """crash+restore must replay the identical loss trajectory (deterministic
    data cursor + step-atomic state)."""
    state, step_fn, mgr, nb = _setup(tmp_path)
    rep1 = run_resilient(step_fn, state, nb, steps=8, ckpt=mgr, ckpt_every=2)
    # fresh copy, crash in the middle
    state2, step_fn2, _, _ = _setup(tmp_path)
    mgr2 = CheckpointManager(str(tmp_path) + "_b", keep=3)
    rep2 = run_resilient(step_fn2, state2, nb, steps=8, ckpt=mgr2,
                         ckpt_every=2, fail_at={5: RuntimeError("boom")})
    assert rep1.history[-1] == pytest.approx(rep2.history[-1], abs=1e-6)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(ratio=3.0, window=10, grace_steps=2)
    flags = [wd.observe(0.1) for _ in range(5)]
    assert not any(flags)
    assert wd.observe(1.0)                      # 10x median
    assert not wd.observe(0.1)
