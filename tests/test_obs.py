"""Observability (DESIGN.md §18): tracer/recorder invisibility and the
flight-recorder + report-tool contracts.

The heavy end-to-end gates (real-evaluator bit-identity, wall-clock
overhead, Chrome-trace schema) live in ``benchmarks/obs_bench.py``; this
file keeps the cheap invariants in tier-1 with a fake evaluator and a
fake clock.
"""
import io
import json
import os
import sys

import numpy as np
import pytest

from repro.core.hass import hass_search
from repro.obs import (FlightRecorder, NULL_TRACER, Tracer, get_tracer,
                       load_run, read_records, set_tracer, use_tracer)
from repro.obs.log import capture, get_logger

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "tools"))

import trace_report  # noqa: E402


class FakeCache:
    """Quacks like a DSECache for the recorder's counter snapshots."""

    def __init__(self):
        self.calls = 0

    def stats(self):
        return {"hits": 2 * self.calls, "warm_l1": self.calls,
                "warm_l2": 0, "cold_runs": self.calls,
                "warm_hits": self.calls}


class FakeEval:
    """Deterministic metric function of x — cheap stand-in for the
    jit-backed evaluators."""

    def __init__(self):
        self.dse_cache = FakeCache()

    def __call__(self, x):
        self.dse_cache.calls += 1
        x = np.asarray(x)
        return {"acc": float(np.mean(np.cos(3.0 * x))),
                "spa": float(np.mean(x)),
                "thr": 1.0 + float(x[0]), "dsp": 0.5}


def _run(seed=0, iters=8, recorder=None):
    return hass_search(FakeEval(), 4, iters=iters, seed=seed,
                       hardware_aware=False, include_act=False,
                       recorder=recorder)


def _assert_identical(a, b):
    assert len(a.trials) == len(b.trials)
    for ta, tb in zip(a.trials, b.trials):
        assert np.array_equal(ta.x, tb.x)
        assert ta.score == tb.score and ta.metrics == tb.metrics
    assert a.best_score == b.best_score


def test_default_tracer_is_disabled_null():
    tr = get_tracer()
    assert tr is NULL_TRACER and tr.enabled is False
    with tr.span("anything", k=1):
        tr.count("x")
        tr.gauge("y", 2.0)                  # all no-ops


def test_noop_and_enabled_tracers_leave_transcript_bit_identical(tmp_path):
    ref = _run()
    off = _run()                            # NULL tracer (the default)
    with use_tracer(Tracer()):
        with FlightRecorder(str(tmp_path / "run.jsonl")) as rec:
            on = _run(recorder=rec)
    _assert_identical(ref, off)
    _assert_identical(ref, on)
    assert get_tracer() is NULL_TRACER      # use_tracer restored


def test_fake_clock_span_nesting_and_attribution():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(clock=clock)
    with tr.span("outer", job="a"):         # t0=1
        with tr.span("inner"):              # t0=2, t1=3
            pass
    # inner finishes first; depth reflects stack position at entry
    inner, outer = tr.events
    assert inner["name"] == "inner" and inner["depth"] == 1
    assert inner["t0"] == 2.0 and inner["t1"] == 3.0
    assert outer["name"] == "outer" and outer["depth"] == 0
    assert outer["t0"] == 1.0 and outer["t1"] == 4.0
    assert outer["args"] == {"job": "a"}
    doc = tr.to_chrome_trace()
    ev = {e["name"]: e for e in doc["traceEvents"]}
    assert ev["outer"]["ph"] == "X"
    assert ev["outer"]["ts"] == 1e6 and ev["outer"]["dur"] == 3e6
    assert ev["inner"]["ts"] == 2e6 and ev["inner"]["dur"] == 1e6


def test_tracer_counters_gauges_histograms():
    tr = Tracer()
    tr.count("n")
    tr.count("n", 4)
    tr.gauge("g", 2.5)
    for v in (1.0, 3.0, 2.0):
        tr.observe("h", v)
    m = tr.metrics()
    assert m["counters"]["n"] == 5
    assert m["gauges"]["g"] == 2.5
    h = m["histograms"]["h"]
    assert h["count"] == 3 and h["sum"] == 6.0
    assert h["min"] == 1.0 and h["max"] == 3.0


def test_flight_recorder_roundtrip_and_footer_sums(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with FlightRecorder(path) as rec:
        r = _run(recorder=rec)
    # every line re-parses as JSON
    with open(path) as f:
        lines = [json.loads(s) for s in f if s.strip()]
    assert lines[0]["record"] == "header"
    assert lines[0]["search"] == "hass_search"
    assert lines[-1]["record"] == "footer"
    assert read_records(path) == lines
    run = load_run(path)
    assert len(run["trials"]) == len(r.trials) == run["footer"]["n_trials"]
    assert run["footer"]["best_score"] == r.best_score
    # footer totals equal the sum of per-trial records, field for field
    for field in ("cache", "engine", "phases"):
        tot = {}
        for t in run["trials"]:
            for k, v in t[field].items():
                tot[k] = tot.get(k, 0) + v
        for k, v in run["footer"]["totals"][field].items():
            assert v == pytest.approx(tot.get(k, 0), rel=1e-9, abs=1e-12)
    # trial records carry the recorded proposal and score verbatim
    for t, trial in zip(run["trials"], r.trials):
        assert t["x"] == list(trial.x)
        assert t["score"] == trial.score


def test_trace_report_diff_same_and_divergent(tmp_path):
    paths = {}
    for tag, seed in (("a", 0), ("b", 0), ("c", 1)):
        p = str(tmp_path / f"{tag}.jsonl")
        with FlightRecorder(p) as rec:
            _run(seed=seed, recorder=rec)
        paths[tag] = p
    buf = io.StringIO()
    same = trace_report.diff_runs(trace_report.load_run(paths["a"]),
                                  trace_report.load_run(paths["b"]),
                                  out=buf)
    assert same == 0
    assert "0 trials" in buf.getvalue()
    buf = io.StringIO()
    cross = trace_report.diff_runs(trace_report.load_run(paths["a"]),
                                   trace_report.load_run(paths["c"]),
                                   out=buf)
    assert cross > 0
    assert "phase deltas" in buf.getvalue()
    buf = io.StringIO()
    trace_report.summarize(trace_report.load_run(paths["a"]), out=buf)
    out = buf.getvalue()
    assert "hass_search" in out and "phases" in out


def test_trace_report_survives_missing_footer(tmp_path):
    p = str(tmp_path / "crashed.jsonl")
    with FlightRecorder(p) as rec:
        _run(recorder=rec)
    lines = open(p).read().splitlines()
    with open(p, "w") as f:                 # drop the footer: a killed run
        f.write("\n".join(lines[:-1]) + "\n")
    run = trace_report.load_run(p)
    assert run["footer"] is None
    tot = trace_report.totals_of(run)
    assert sum(tot["phases"].values()) > 0


def test_logger_level_filter_and_capture():
    log = get_logger("obs-test")
    with capture("obs-test") as lines:
        log.debug("too quiet")
        log.info("hello")
        log.error("bad")
    assert lines == ["[obs-test] hello", "[obs-test] bad"]
    with use_tracer(Tracer()) as tr:
        with capture("obs-test") as lines:
            log.warning("traced")
        assert tr.metrics()["counters"]["log.obs-test.warning"] == 1


def test_engine_dispatch_counters_track_dse_runs():
    from repro.core.dse import (engine_dispatch_stats, incremental_dse,
                                reset_engine_dispatch)
    from repro.core.perf_model import FPGAModel, LayerCost

    layers = [LayerCost(f"l{i}", macs=4096 * (i + 1), m_dot=64,
                        weight_count=4096, act_in=1, act_out=1)
              for i in range(3)]
    reset_engine_dispatch()
    before = engine_dispatch_stats()
    assert all(v == 0 for v in before.values())
    incremental_dse(layers, FPGAModel(), budget=512)
    after = engine_dispatch_stats()
    assert sum(after.values()) >= 1         # some engine was dispatched
    reset_engine_dispatch()


def test_search_counters_published_when_enabled():
    with use_tracer(Tracer()) as tr:
        _run()
    m = tr.metrics()
    assert m["counters"]["search.trials"] == 8
    assert m["gauges"]["search.dse_cache.cold_runs"] > 0
    spans = [e for e in tr.events if e["name"] == "trial"]
    assert len(spans) == 8
