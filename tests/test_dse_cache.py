"""DSECache + class-grouped engine correctness (DESIGN.md §12).

The acceleration subsystem's whole contract is BIT-exactness: the grouped
engine must replay the flat engine decision for decision, and every cache
answer (exact memo or warm-start certificate) must equal a cold
``incremental_dse`` on the queried stack. These tests drive both with
randomized kind-tied stacks — the structure the LM evaluator produces —
plus engineered floor-stable/bottleneck deltas for the warm certificate.
"""
import math

import numpy as np
import pytest

from repro.core.dse import (DSECache, SegmentTable, _layer_classes,
                            _run_incremental, _run_incremental_grouped,
                            incremental_dse, partition_pipeline)
from repro.core.perf_model import (FPGAModel, LayerCost, TPUModel,
                                   pair_sparsity)

HW = FPGAModel()


def kind_tied_stack(seed: int, n_blocks: int = 12, *, tiny_kind: bool = True):
    """LM-shaped synthetic stack: every block repeats the same few matmul
    kinds, sparsity tied per kind — plus a non-prunable attn layer. The
    optional ``tiny`` kind has so few MACs that its (1,1) floor rate sits
    far above any realistic bottleneck (the warm-certificate target)."""
    rng = np.random.default_rng(seed)
    kinds = [("wq", 64, 64), ("wkv", 64, 32), ("ffn", 64, 256)]
    if tiny_kind:
        kinds.append(("tiny", 8, 4))
    s_of = {k: float(rng.uniform(0.0, 0.8)) for k, _, _ in kinds}
    layers = []
    for b in range(n_blocks):
        for k, m, c in kinds:
            layers.append(LayerCost(
                name=f"l{b}.{k}", macs=m * c, m_dot=m, weight_count=m * c,
                act_in=m, act_out=c, s_w=s_of[k]))
        layers.append(LayerCost(name=f"l{b}.attn", macs=2 * 64 * 16,
                                m_dot=16, weight_count=0, act_in=64,
                                act_out=64, kind="attn", prunable=False))
    return layers


def set_kind(layers, kind, s_w):
    out = []
    for l in layers:
        if l.prunable and l.name.endswith("." + kind):
            out.append(LayerCost(**{**l.__dict__, "s_w": s_w}))
        else:
            out.append(l)
    return out


def assert_same_result(a, b):
    assert [(d.spe, d.macs_per_spe) for d in a.designs] == \
        [(d.spe, d.macs_per_spe) for d in b.designs]
    assert a.throughput == b.throughput
    assert a.resource == b.resource
    assert a.trace == b.trace
    assert a.theta_r == b.theta_r
    fa, fb = a.frontier, b.frontier
    assert np.array_equal(fa.res, fb.res) and np.array_equal(fa.thr, fb.thr)
    assert np.array_equal(fa.spe, fb.spe) and np.array_equal(fa.n, fb.n)


# --------------------------------------------------------------------- #
# Grouped engine == flat engine, bit for bit
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(8))
def test_grouped_engine_matches_flat(seed):
    layers = kind_tied_stack(seed)
    lv = HW.layer_vectors(layers)
    for budget, iters in ((4096.0, 300), (512.0, 300), (4096.0, 7),
                          (float(lv.res_unit.sum()) * 1.2, 100)):
        a = _run_incremental(lv, HW, budget, iters)
        b = _run_incremental_grouped(lv, HW, budget, iters)
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
        assert a[2] == b[2] and a[3] == b[3] and a[4] == b[4] and a[6] == b[6]
        fa, fb = a[5], b[5]
        assert np.array_equal(fa.res, fb.res)
        assert np.array_equal(fa.thr, fb.thr)
        assert np.array_equal(fa.spe, fb.spe)
        assert np.array_equal(fa.n, fb.n)


def test_grouped_engine_matches_flat_on_untied_stack():
    """Per-layer random sparsity: nearly every layer is its own class, the
    worst case for grouping — results must still match exactly."""
    rng = np.random.default_rng(3)
    layers = kind_tied_stack(3)
    layers = [LayerCost(**{**l.__dict__,
                           "s_w": float(rng.uniform(0, 0.9))})
              if l.prunable else l for l in layers]
    lv = HW.layer_vectors(layers)
    a = _run_incremental(lv, HW, 2048.0, 300)
    b = _run_incremental_grouped(lv, HW, 2048.0, 300)
    assert np.array_equal(a[0], b[0]) and a[2] == b[2] and a[4] == b[4]


def test_auto_engine_dispatch():
    layers = kind_tied_stack(0)
    lv = HW.layer_vectors(layers)
    C, pos = _layer_classes(lv)
    assert C <= 5                      # kinds + attn, tied across blocks
    assert sorted(p for ps in pos for p in ps) == list(range(len(lv)))
    r_auto = incremental_dse(layers, HW, 2048.0, max_iters=200)
    r_flat = incremental_dse(layers, HW, 2048.0, max_iters=200,
                             engine="flat")
    assert_same_result(r_auto, r_flat)
    with pytest.raises(ValueError):
        incremental_dse(layers, HW, 2048.0, engine="nope")


# --------------------------------------------------------------------- #
# DSECache: exact memo
# --------------------------------------------------------------------- #
def test_exact_memo_returns_shared_result():
    layers = kind_tied_stack(1)
    cache = DSECache()
    r1 = cache.dse(layers, HW, 2048.0, max_iters=200)
    r2 = cache.dse(layers, HW, 2048.0, max_iters=200)
    assert r1 is r2
    assert cache.stats() == {"hits": 1, "warm_hits": 0, "warm_l1": 0,
                             "warm_l2": 0, "cold_runs": 1}
    # a different budget is a different key
    cache.dse(layers, HW, 1024.0, max_iters=200)
    assert cache.stats()["cold_runs"] == 2


def test_cache_result_equals_direct_dse():
    layers = kind_tied_stack(2)
    cache = DSECache()
    assert_same_result(cache.dse(layers, HW, 2048.0, max_iters=200),
                       incremental_dse(layers, HW, 2048.0, max_iters=200))


# --------------------------------------------------------------------- #
# DSECache: warm-start certificate (floor-stability theorem)
# --------------------------------------------------------------------- #
def test_warm_start_on_floor_stable_delta_is_bit_exact():
    """Perturbing only the tiny kind (floor rate far above theta_r) must
    warm-hit AND equal the cold run on the perturbed stack bit for bit."""
    layers = kind_tied_stack(4)
    cache = DSECache()
    cache.dse(layers, HW, 2048.0, max_iters=200)
    hit = 0
    for s_new in (0.05, 0.33, 0.71):
        pert = set_kind(layers, "tiny", s_new)
        r = cache.dse(pert, HW, 2048.0, max_iters=200)
        cold = incremental_dse(pert, HW, 2048.0, max_iters=200)
        assert r.throughput == cold.throughput
        assert r.resource == cold.resource
        assert r.trace == cold.trace
        assert np.array_equal(r.frontier.spe, cold.frontier.spe)
        hit = cache.stats()["warm_hits"]
    assert hit >= 1, "tiny-kind deltas never certified warm"
    # tiny layers really are at the floor in the cold result
    for l, d in zip(layers, incremental_dse(layers, HW, 2048.0,
                                            max_iters=200).designs):
        if l.name.endswith(".tiny"):
            assert (d.spe, d.macs_per_spe) == (1, 1)


def test_bottleneck_delta_falls_back_cold_and_stays_correct():
    """Perturbing the dominant kind cannot be certified — the cache must
    fall back to a cold run and still return the exact result."""
    layers = kind_tied_stack(5)
    cache = DSECache()
    cache.dse(layers, HW, 2048.0, max_iters=200)
    pert = set_kind(layers, "ffn", 0.02)
    r = cache.dse(pert, HW, 2048.0, max_iters=200)
    assert cache.stats()["warm_hits"] == 0
    assert cache.stats()["cold_runs"] == 2
    assert_same_result(r, incremental_dse(pert, HW, 2048.0, max_iters=200))


@pytest.mark.parametrize("seed", range(6))
def test_random_proposal_deltas_always_match_cold(seed):
    """Property: WHATEVER the cache answers (exact, warm, or cold), it
    equals a cold ``incremental_dse`` of the queried stack."""
    rng = np.random.default_rng(seed)
    layers = kind_tied_stack(seed)
    cache = DSECache()
    kinds = ["wq", "wkv", "ffn", "tiny"]
    for _ in range(6):
        pert = layers
        for k in kinds:
            if rng.random() < 0.5:
                pert = set_kind(pert, k, float(rng.uniform(0, 0.85)))
        r = cache.dse(pert, HW, 1024.0, max_iters=150)
        cold = incremental_dse(pert, HW, 1024.0, max_iters=150)
        assert r.throughput == cold.throughput
        assert r.resource == cold.resource
        assert r.trace == cold.trace
        layers = pert


def test_warm_certificate_respects_activation_sparsity():
    """s_a moves s_pair continuously — certificate keys on the realized
    s_eff, so activation-only deltas behave exactly like weight deltas."""
    layers = kind_tied_stack(6)
    pert = [LayerCost(**{**l.__dict__, "s_a": 0.3})
            if l.prunable and l.name.endswith(".tiny") else l
            for l in layers]
    assert pert[3].s_pair == pair_sparsity(pert[3].s_w, pert[3].s_a)
    cache = DSECache()
    cache.dse(layers, HW, 2048.0, max_iters=150)
    r = cache.dse(pert, HW, 2048.0, max_iters=150)
    assert_same_result(r, incremental_dse(pert, HW, 2048.0, max_iters=150))


def test_materialize_designs_off_keeps_frontier_usable():
    layers = kind_tied_stack(7)
    cache = DSECache(materialize_designs=False)
    r = cache.dse(layers, HW, 2048.0, max_iters=200)
    full = incremental_dse(layers, HW, 2048.0, max_iters=200)
    assert r.designs == []
    k = r.frontier.best_under(2048.0)
    assert [(d.spe, d.macs_per_spe) for d in r.frontier.materialize(k)] == \
        [(d.spe, d.macs_per_spe) for d in full.designs]


# --------------------------------------------------------------------- #
# Shared cache through SegmentTable / partition_pipeline
# --------------------------------------------------------------------- #
def test_partition_pipeline_with_shared_cache_is_identical():
    layers = kind_tied_stack(8, n_blocks=6)
    tpu = TPUModel(chips=4)
    kw = dict(n_parts=4, batch=32, dse_iters=150)
    cache = DSECache()
    plain = [partition_pipeline(layers, tpu, tpu.chip_budget,
                                objective=o, **kw)
             for o in ("sum", "maxmin")]
    shared = [partition_pipeline(layers, tpu, tpu.chip_budget,
                                 objective=o, cache=cache, **kw)
              for o in ("sum", "maxmin")]
    for p, q in zip(plain, shared):
        assert p.cuts == q.cuts
        assert p.time_per_batch == q.time_per_batch
        assert p.throughput == q.throughput
        assert p.steady_throughput == q.steady_throughput
    # repeated-block stacks dedupe even within one call (two segments with
    # identical layer sequences share a key), so cold <= first call's fills;
    # the second call adds NO cold runs at all
    stats = cache.stats()
    assert stats["cold_runs"] <= shared[0].dse_calls
    assert stats["hits"] + stats["warm_hits"] + stats["cold_runs"] == \
        shared[0].dse_calls + shared[1].dse_calls


def test_segment_table_cache_counts_fills_not_cold_runs():
    layers = kind_tied_stack(9, n_blocks=5)
    cache = DSECache()
    t1 = SegmentTable(layers, HW, 1024.0, 32, 150, cache=cache)
    t1.frontier(0, 5)
    t1.frontier(0, 5)
    t2 = SegmentTable(layers, HW, 1024.0, 32, 150, cache=cache)
    t2.frontier(0, 5)
    assert t1.dse_calls == 1 and t2.dse_calls == 1
    assert cache.stats() == {"hits": 1, "warm_hits": 0, "warm_l1": 0,
                             "warm_l2": 0, "cold_runs": 1}


# --------------------------------------------------------------------- #
# Warm-start level 2: dynamics-equivalence certificate (DESIGN.md §15)
# --------------------------------------------------------------------- #
def _cnn_stack(seed):
    from repro.configs.paper_cnns import RESNET18
    from repro.core.perf_model import cnn_layer_costs
    rng = np.random.default_rng(seed)
    layers = cnn_layer_costs(RESNET18)[:14]
    for l in layers:
        if l.prunable:
            l.s_w = float(rng.uniform(0.1, 0.7))
    return layers


def _l2_perturbation(lv, li, eps_list=(1e-13, 1e-12, 1e-11)):
    """A sparsity delta on layer ``li`` that moves the float but keeps the
    t-vector over the reachable-N closure equal (the level-2 condition),
    or None if none of the candidate epsilons lands inside a ceil window."""
    from repro.core.dse import _reachable_n
    ns = np.array(_reachable_n(int(lv.max_n[li])), dtype=np.float64)
    md = float(lv.m_dot[li])

    def tv(s):
        return np.maximum(1.0, np.ceil((1.0 - s) * md / ns))

    s0 = float(lv.s_eff[li])
    for eps in eps_list:
        s1 = s0 + eps
        if s1 != s0 and s1 < 1.0 and np.array_equal(tv(s0), tv(s1)):
            return s1
    return None


@pytest.mark.parametrize("stack", ["lm", "cnn"])
@pytest.mark.parametrize("seed", range(3))
def test_warm_l2_fuzz_cold_vs_warm_bit_exact(stack, seed):
    """Fuzz the level-2 certificate over grown (floor-adjacent) layers:
    a t-vector-preserving sparsity delta on a layer the anchor run GREW
    (level 1 can never cover it) must warm-hit at level 2 and equal a
    fresh cold run bit for bit."""
    from dataclasses import replace
    layers = kind_tied_stack(40 + seed) if stack == "lm" \
        else _cnn_stack(40 + seed)
    lv = HW.layer_vectors(layers)
    cache = DSECache()
    r0 = cache.dse_vec(lv, HW, 2048.0, max_iters=250)
    spe = np.array([d.spe for d in r0.designs])
    n = np.array([d.macs_per_spe for d in r0.designs])
    grown = np.nonzero((spe * n > 1) & (lv.s_eff > 0))[0]
    assert len(grown), "anchor run grew nothing — stack too small"
    l2_hits = 0
    for li in grown[:4].tolist():
        s1 = _l2_perturbation(lv, li)
        if s1 is None:
            continue
        s_eff = lv.s_eff.copy()
        s_eff[li] = s1
        before = dict(cache.stats())
        r = cache.dse_vec(replace(lv, s_eff=s_eff), HW, 2048.0,
                          max_iters=250)
        after = cache.stats()
        assert after["warm_l2"] == before["warm_l2"] + 1
        cold = DSECache().dse_vec(replace(lv, s_eff=s_eff), HW, 2048.0,
                                  max_iters=250)
        assert r.throughput == cold.throughput
        assert r.resource == cold.resource
        assert r.theta_r == cold.theta_r
        assert r.trace == cold.trace
        assert np.array_equal(r.frontier.res, cold.frontier.res)
        assert np.array_equal(r.frontier.thr, cold.frontier.thr)
        assert np.array_equal(r.frontier.spe, cold.frontier.spe)
        l2_hits += 1
    assert l2_hits >= 1, "no level-2 certifiable perturbation found"


@pytest.mark.parametrize("stack", ["lm", "cnn"])
def test_warm_l2_invalidation_falls_back_cold(stack):
    """A delta on a grown layer that CHANGES its t-vector must invalidate
    both certificates, fall back to a cold run, and still be exact."""
    from dataclasses import replace
    layers = kind_tied_stack(50) if stack == "lm" else _cnn_stack(50)
    lv = HW.layer_vectors(layers)
    cache = DSECache()
    r0 = cache.dse_vec(lv, HW, 2048.0, max_iters=250)
    spe = np.array([d.spe for d in r0.designs])
    n = np.array([d.macs_per_spe for d in r0.designs])
    li = int(np.nonzero((spe * n > 1) & (lv.s_eff > 0))[0][0])
    s_eff = lv.s_eff.copy()
    s_eff[li] = min(0.95, s_eff[li] + 0.07)   # crosses ceil boundaries
    before = dict(cache.stats())
    r = cache.dse_vec(replace(lv, s_eff=s_eff), HW, 2048.0, max_iters=250)
    after = cache.stats()
    assert after["cold_runs"] == before["cold_runs"] + 1
    assert after["warm_l1"] == before["warm_l1"]
    assert after["warm_l2"] == before["warm_l2"]
    cold = DSECache().dse_vec(replace(lv, s_eff=s_eff), HW, 2048.0,
                              max_iters=250)
    assert r.trace == cold.trace and r.throughput == cold.throughput


def test_stats_counters_are_consistent():
    """warm_hits is the back-compat aggregate of the two levels, and every
    query lands in exactly one counter bucket."""
    from dataclasses import replace
    layers = kind_tied_stack(60)
    lv = HW.layer_vectors(layers)
    cache = DSECache()
    rng = np.random.default_rng(60)
    queries = 12
    for q in range(queries):
        s_eff = lv.s_eff.copy()
        if q % 3 == 1:                      # floor-stable delta (level 1)
            tiny = [i for i, l in enumerate(layers)
                    if l.name.endswith(".tiny")]
            s_eff[tiny] = float(rng.uniform(0, 0.8))
        elif q % 3 == 2:                    # random delta (usually cold)
            s_eff[1] = float(rng.uniform(0, 0.9))
        cache.dse_vec(replace(lv, s_eff=s_eff), HW, 2048.0, max_iters=200)
    st = cache.stats()
    assert st["warm_hits"] == st["warm_l1"] + st["warm_l2"]
    assert st["hits"] + st["warm_hits"] + st["cold_runs"] == queries
