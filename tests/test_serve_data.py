"""Serving session + data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataPipeline
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.serve.serve_loop import ServeSession

CFG = reduce_config(get_config("qwen3-0.6b"))
RNG = jax.random.PRNGKey(0)


def test_serve_session_matches_manual_greedy():
    api = build_model(CFG)
    params = api.init(RNG)
    prompts = [np.arange(8) % CFG.vocab_size for _ in range(2)]
    sess = ServeSession(api, params, batch_slots=2, S_max=32)
    outs = sess.generate(prompts, max_new=5)
    assert len(outs) == 2 and all(len(o) == 5 for o in outs)

    # manual greedy
    toks = jnp.asarray(np.stack(prompts), jnp.int32)
    logits, cache = api.prefill(params, toks, 32)
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    manual = [np.asarray(cur)]
    for _ in range(4):
        logits, cache = api.decode_step(params, cache, cur)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        manual.append(np.asarray(cur))
    manual = np.concatenate(manual, axis=1)
    assert outs == [list(map(int, r)) for r in manual]


def test_serve_batching_chunks_requests():
    api = build_model(CFG)
    params = api.init(RNG)
    prompts = [np.arange(6) for _ in range(5)]
    sess = ServeSession(api, params, batch_slots=2, S_max=16)
    outs = sess.generate(prompts, max_new=3)
    assert len(outs) == 5


def test_pipeline_prefetch_and_cursor():
    shape = ShapeConfig("t", 16, 4, "train")
    p1 = DataPipeline(CFG, shape, seed=5, start_step=0, prefetch=2)
    batches = [next(p1) for _ in range(3)]
    p1.close()
    # resume from step 2 reproduces batch index 2
    p2 = DataPipeline(CFG, shape, seed=5, start_step=2, prefetch=0)
    b2 = next(p2)
    assert jnp.array_equal(batches[2]["tokens"], b2["tokens"])


def test_annealing_balancer():
    from repro.core.annealing import balance_assignment, buffer_depths
    rates = [5, 1, 1, 1, 1, 1]
    assign = balance_assignment(rates, 2, steps=300)
    loads = np.zeros(2)
    np.add.at(loads, assign, rates)
    assert abs(loads[0] - loads[1]) <= 1.01
    depths = buffer_depths([1.0, 2.0, 1.0])
    assert len(depths) == 3 and depths[1] >= depths[0]
