"""Per-pattern decode microbench: determinism + modeled fallback
(DESIGN.md §16).

The measured Eq. 6 loop only works if the cost table is a pure function of
its config — static lowering analysis, seeded masks, no wall clock. Two
runs must be byte-identical, the disk cache must round-trip, and every
probe must degrade to the modeled estimate when Pallas lowering is
unavailable (CPU CI without a TPU backend)."""
import json
import os

import numpy as np
import pytest

from repro.kernels import kernel_costs as kc
from repro.kernels.kernel_costs import (MicrobenchConfig, cache_key,
                                        decode_factors, load_or_measure,
                                        measure)

CFG = MicrobenchConfig(m=128, k=512, n=256, sparsities=(0.5,))


@pytest.fixture(scope="module")
def table():
    return measure(CFG)


def test_measure_two_runs_identical(table):
    assert measure(CFG) == table


def test_written_json_is_byte_deterministic(tmp_path):
    p1, p2 = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    load_or_measure(p1, CFG)
    load_or_measure(p2, CFG)
    with open(p1, "rb") as f:
        b1 = f.read()
    with open(p2, "rb") as f:
        b2 = f.read()
    assert b1 == b2
    assert b1.endswith(b"\n")


def test_disk_cache_hit_and_config_mismatch(tmp_path, table):
    p = str(tmp_path / "c.json")
    t1 = load_or_measure(p, CFG)
    mtime = os.path.getmtime(p)
    t2 = load_or_measure(p, CFG)              # cache hit: no rewrite
    assert t2 == t1 and os.path.getmtime(p) == mtime
    other = MicrobenchConfig(m=128, k=512, n=256, sparsities=(0.25,))
    t3 = load_or_measure(p, other)            # config mismatch: re-measure
    assert t3["config"] == json.loads(cache_key(other))
    with open(p) as f:
        assert json.load(f)["config"] == t3["config"]
    # a corrupt cache file is ignored, not fatal
    with open(p, "w") as f:
        f.write("{not json")
    t4 = load_or_measure(p, CFG)
    assert t4 == t1


def test_path_none_skips_disk(table):
    assert load_or_measure(None, CFG) == table


def test_table_schema(table):
    assert table["schema"] == kc.SCHEMA_VERSION
    assert table["config"] == json.loads(cache_key(CFG))
    assert table["dense"]["cycles"] > 0
    assert set(table["patterns"]) == {"unstructured", "nm", "hierarchical",
                                      "activation"}
    for levels in table["patterns"].values():
        for rec in levels.values():
            assert rec["cycles"] > 0
            assert 0.0 <= rec["s_eff"] < 1.0
            assert rec["dense_ref"] > 0
    # activation leaves the weight-side schedule dense
    for rec in table["patterns"]["activation"].values():
        assert rec["s_eff"] == 0.0
        assert rec["cycles"] == table["dense"]["cycles"]


def test_decode_factors_contract(table):
    f = decode_factors(table)
    assert set(f) == set(table["patterns"])
    assert all(v >= 1.0 for v in f.values())
    # tile skipping pays no per-element decode; N:M pays the gather
    assert f["unstructured"] == pytest.approx(1.0, abs=0.2)
    assert f["nm"] > 1.0


def test_modeled_fallback_when_lowering_unavailable(monkeypatch, table):
    """No jax.jit at all: every probe independently falls back to the
    schedule-derived modeled estimate, still fully deterministic."""
    import jax

    def boom(*a, **k):
        raise RuntimeError("no backend")

    monkeypatch.setattr(jax, "jit", boom)
    t1 = measure(CFG)
    t2 = measure(CFG)
    assert t1 == t2
    assert t1["dense"]["mode"] == "modeled"
    assert t1["dense"]["cycles"] == t1["dense"]["modeled_cycles"]
    for pat, levels in t1["patterns"].items():
        for rec in levels.values():
            assert "hlo" not in rec["mode"] and "pallas" not in rec["mode"]
    f = decode_factors(t1)
    assert all(v >= 1.0 for v in f.values())
    # modeled tile probes normalize against the modeled (compute-leg) dense
    rec = t1["patterns"]["unstructured"]["0.5000"]
    assert rec["dense_ref"] == t1["dense"]["modeled_cycles"]


def test_seeded_masks_never_empty_a_column():
    rng = np.random.default_rng(0)
    cfg = MicrobenchConfig(m=128, k=512, n=256)
    counts, indices, s_real = kc._tile_schedule(cfg, 0.95, rng)
    assert (counts >= 1).all()
    assert 0.0 <= s_real <= 0.95 + 1e-9
    assert indices.shape == (cfg.n // cfg.bn, int(counts.max()))


def test_cache_key_covers_every_config_field():
    d = json.loads(cache_key(CFG))
    from dataclasses import fields
    for f in fields(MicrobenchConfig):
        assert f.name in d
    assert d["schema"] == kc.SCHEMA_VERSION
    assert cache_key(CFG) != cache_key(MicrobenchConfig())
