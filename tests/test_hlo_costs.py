"""Loop-aware HLO cost parser vs unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_costs import (analyze, cost_analysis_dict, parse_hlo,
                                      trip_count)
from repro.analysis.roofline import parse_collectives


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_scaled_by_trip_count():
    def scan8(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=8)[0]

    def unrolled(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = analyze(_compile(scan8, xs, ws).as_text())
    truth = cost_analysis_dict(_compile(unrolled, xs, ws))["flops"]
    assert a.flops == pytest.approx(truth, rel=1e-6)
    assert a.trip_counts == [8]


def test_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _2):
                return jnp.tanh(c2 @ w), None
            return jax.lax.scan(inner, c, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    a = analyze(_compile(g, xs, ws).as_text())
    assert a.flops == pytest.approx(12 * 2 * 64 ** 3, rel=1e-6)
    assert sorted(a.trip_counts) == [3, 4]


def test_train_step_scan_equals_unrolled(monkeypatch):
    """End-to-end: loop-aware parse of the scanned train step == parse of the
    unrolled program (and both == dot-flops fraction of cost_analysis)."""
    import dataclasses
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.train.train_loop import TrainConfig, make_train_step, \
        train_state_shape

    cfg = dataclasses.replace(reduce_config(get_config("qwen3-0.6b")),
                              num_layers=2)
    api = build_model(cfg)
    tcfg = TrainConfig(accum=2, remat="full")
    ss = train_state_shape(api.init, tcfg)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    step = make_train_step(api.loss, tcfg)
    a_scan = analyze(_compile(step, ss, batch).as_text())

    monkeypatch.setenv("REPRO_UNROLL_SCANS", "1")
    a_unr = analyze(_compile(step, ss, batch).as_text())
    assert a_scan.flops == pytest.approx(a_unr.flops, rel=0.02)
    assert 2 in a_scan.trip_counts and 2 in [t for t in a_scan.trip_counts]


def test_collectives_scaled_by_loops():
    """A psum inside a scan counts trip times."""
    import subprocess, sys, os, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo_costs import analyze
        mesh = jax.make_mesh((4,), ("d",))
        def f(x, w):
            def body(c, _):
                y = c @ w                     # sharded contraction -> psum
                return jnp.tanh(y), None
            return jax.lax.scan(body, x, None, length=5)[0]
        xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        lowered = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, "d")), NamedSharding(mesh, P("d", None)))
        ).lower(xs, ws)
        a = analyze(lowered.compile().as_text())
        n_ar = sum(v for k, v in a.coll_by_op.items())
        single = 64 * 64 * 4
        assert n_ar >= 5 * single, (a.coll_by_op, a.trip_counts)
        print("COLL-OK", a.coll_by_op)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300)
    assert "COLL-OK" in r.stdout, r.stdout + r.stderr


def test_parse_collectives_result_bytes():
    txt = "  %ag = bf16[4,1024]{1,0} all-gather(%p), replica_groups=[4,2]<=[8]"
    ops = parse_collectives(txt)
    assert len(ops) == 1
    assert ops[0].bytes == 4 * 1024 * 2
    assert ops[0].group_size == 2
